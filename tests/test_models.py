"""Per-architecture smoke tests (reduced configs) + model-level invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import LM, reduced
from repro.models.attention import make_mla_cache, mla_apply, mla_init
from repro.models.moe import moe_apply, moe_init

B, S = 2, 16
RNG = jax.random.PRNGKey(0)


def make_batch(cfg, rng=RNG, batch=B, seq=S):
    batch_d = {
        "tokens": jax.random.randint(rng, (batch, seq), 0, cfg.vocab),
        "labels": jax.random.randint(rng, (batch, seq), 0, cfg.vocab),
    }
    if cfg.enc_dec:
        batch_d["frames"] = jax.random.normal(rng, (batch, cfg.enc_len, cfg.d_model))
    if cfg.needs_position_ids:
        batch_d["position_ids"] = jnp.broadcast_to(
            jnp.arange(seq)[None, None], (3, batch, seq)
        ).astype(jnp.int32)
    return batch_d


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced config of the same family: one forward/train step on CPU,
    asserting output shapes + no NaNs (per the assignment)."""
    cfg = reduced(get_config(arch))
    model = LM(cfg)
    params = model.init(RNG)
    batch = make_batch(cfg)

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))

    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_prefill_decode(arch):
    cfg = reduced(get_config(arch))
    model = LM(cfg)
    params = model.init(RNG)
    batch = make_batch(cfg)
    caches = model.init_cache(B, 32)
    logits, caches = jax.jit(model.prefill)(params, batch, caches)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    pos_ids = (jnp.full((3, B, 1), S, jnp.int32) if cfg.needs_position_ids else None)
    lg, caches = jax.jit(model.decode_step)(
        params, jnp.argmax(logits, -1).astype(jnp.int32),
        jnp.full((B,), S, jnp.int32), caches, pos_ids)
    assert lg.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(lg, np.float32)).all()


@pytest.mark.parametrize("arch", ["olmo-1b", "qwen1.5-0.5b", "rwkv6-3b",
                                  "recurrentgemma-9b", "minitron-8b",
                                  "command-r-plus-104b", "qwen2-vl-72b"])
def test_decode_matches_full_forward(arch):
    """Prefill S-1 tokens then decode token S-1 == full forward at S-1.
    (MoE archs excluded: capacity-drop patterns differ between shapes.)"""
    cfg = reduced(get_config(arch))
    model = LM(cfg)
    params = model.init(RNG)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    caches = model.init_cache(B, 32)
    pre_batch = {"tokens": toks[:, : S - 1]}
    if cfg.needs_position_ids:
        pre_batch["position_ids"] = jnp.broadcast_to(
            jnp.arange(S - 1)[None, None], (3, B, S - 1)).astype(jnp.int32)
    _, caches = jax.jit(model.prefill)(params, pre_batch, caches)
    pos_ids = (jnp.full((3, B, 1), S - 1, jnp.int32)
               if cfg.needs_position_ids else None)
    lg_dec, _ = jax.jit(model.decode_step)(
        params, toks[:, S - 1], jnp.full((B,), S - 1, jnp.int32), caches, pos_ids)

    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    pid = (jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
           if cfg.needs_position_ids else None)
    hidden, _, _ = model.backbone(params, toks, pos, position_ids=pid)
    lg_full = model.logits(params, hidden)[:, -1]
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(lg_full),
                               atol=2e-2, rtol=2e-2)


def test_moe_sort_equals_einsum_dispatch():
    cfg = reduced(get_config("deepseek-v3-671b"))
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p = moe_init(RNG, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model), jnp.float32)
    ys, aux_s = moe_apply(cfg, p, x, dispatch="sort")
    ye, aux_e = moe_apply(cfg, p, x, dispatch="einsum")
    np.testing.assert_allclose(np.asarray(ys), np.asarray(ye), atol=1e-4)
    assert float(aux_s) == pytest.approx(float(aux_e), rel=1e-5)


def test_moe_router_gates_normalised():
    cfg = reduced(get_config("qwen2-moe-a2.7b"))
    from repro.models.moe import _router
    p = moe_init(RNG, cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (32, cfg.d_model), jnp.float32)
    gates, idx, probs = _router(cfg, p, x)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-3)
    assert int(idx.max()) < cfg.moe.n_experts
    np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, atol=1e-4)


def test_mla_absorbed_equals_expanded():
    cfg = reduced(get_config("deepseek-v3-671b"))
    p = mla_init(RNG, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 12, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(12)[None], (2, 12)).astype(jnp.int32)
    y_exp, _ = mla_apply(cfg, p, x, pos, absorbed=False)
    y_abs, _ = mla_apply(cfg, p, x, pos, absorbed=True)
    np.testing.assert_allclose(np.asarray(y_exp), np.asarray(y_abs), atol=1e-4)


def test_mla_cache_is_compressed():
    """The MLA cache must store the latent, not full K/V heads."""
    cfg = reduced(get_config("deepseek-v3-671b"))
    cache = make_mla_cache(cfg, batch=2, capacity=32, n_layers=1)
    assert cache["ckv"].shape[-1] == cfg.mla.kv_lora_rank
    full_kv_floats = 2 * cfg.n_heads * cfg.mla.v_head_dim
    latent_floats = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
    assert latent_floats < full_kv_floats


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_matches_init(arch):
    """Analytic param_count (used for roofline MODEL_FLOPS) vs real init."""
    cfg = reduced(get_config(arch))
    model = LM(cfg)
    params = jax.eval_shape(model.init, RNG)
    actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    predicted = cfg.param_count()
    assert predicted == pytest.approx(actual, rel=0.15), (predicted, actual)


def test_local_window_attention_masks_past():
    """Tokens beyond the window must not influence the output."""
    cfg = reduced(get_config("recurrentgemma-9b"))
    model = LM(cfg)
    params = model.init(RNG)
    w = cfg.attn_window
    seq = 3 * w
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, seq), 0, cfg.vocab)
    pos = jnp.arange(seq)[None].astype(jnp.int32)
    h1, _, _ = model.backbone(params, toks, pos)
    # perturb the FIRST token: the recurrent path carries information forward,
    # so instead check pure attention masking via the gqa mask directly
    from repro.models.attention import _mask_bias
    bias = _mask_bias(pos, pos, causal=True, window=w)
    i, j = seq - 1, seq - 1 - w
    assert bias[0, i, j] < -1e29          # outside window
    assert bias[0, i, j + 1] == 0.0       # inside window
    assert bias[0, 0, 1] < -1e29          # causal
