"""Data pipeline: determinism, prefetch semantics, input specs."""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import Prefetcher
from repro.data.synthetic import SyntheticLM, batch_specs
from repro.models import reduced


def test_synthetic_deterministic():
    a = next(iter(SyntheticLM(1000, 4, 32, seed=7)))
    b = next(iter(SyntheticLM(1000, 4, 32, seed=7)))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = next(iter(SyntheticLM(1000, 4, 32, seed=8)))
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_synthetic_labels_are_next_tokens():
    b = next(iter(SyntheticLM(1000, 2, 16, seed=0)))
    assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)
    # labels[t] continues tokens: labels[:, :-1] == tokens[:, 1:]
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_synthetic_learnable_structure():
    """Most transitions follow the deterministic map (structure=0.7)."""
    b = next(iter(SyntheticLM(997, 8, 256, seed=0, structure=0.7)))
    t, l = b["tokens"].astype(np.int64), b["labels"].astype(np.int64)
    pred = (t * 6364136223846793005 + 1442695040888963407) % 997
    frac = (pred == l).mean()
    assert 0.6 < frac < 0.8


def test_prefetcher_preserves_order_and_terminates():
    items = [{"x": np.full((2,), i)} for i in range(10)]
    out = list(Prefetcher(items, depth=3))
    assert [int(o["x"][0]) for o in out] == list(range(10))


def test_prefetcher_propagates_errors():
    def gen():
        yield {"x": np.zeros(1)}
        raise RuntimeError("boom")

    p = Prefetcher(gen(), depth=2)
    next(p)
    with pytest.raises(RuntimeError, match="boom"):
        next(p)


@pytest.mark.parametrize("arch,extra", [
    ("olmo-1b", set()),
    ("whisper-tiny", {"frames"}),
    ("qwen2-vl-72b", {"position_ids"}),
])
def test_batch_specs_per_family(arch, extra):
    cfg = get_config(arch)
    specs = batch_specs(cfg, 4, 128, mode="train")
    assert set(specs) == {"tokens", "labels"} | extra
    assert specs["tokens"].shape == (4, 128)
    if "frames" in specs:
        assert specs["frames"].shape == (4, cfg.enc_len, cfg.d_model)
    if "position_ids" in specs:
        assert specs["position_ids"].shape == (3, 4, 128)
    prefill = batch_specs(cfg, 4, 128, mode="prefill")
    assert "labels" not in prefill
