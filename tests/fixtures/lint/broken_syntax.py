# golden fixture for parse-error resilience: this file deliberately does
# not parse; the analyzer must report it and keep going
def oops(:
    return 1
