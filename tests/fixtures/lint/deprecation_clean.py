"""CLEAN fixture for deprecation: the tier-aware link-matrix API."""


def build_fleet(Device, cluster, out_bytes, model_bytes):
    d = Device(did=0, cls=0, mem_total=1.0, lam=0.0,
               tier=1, up_bw=8e6, down_bw=40e6)
    link = cluster.link_bw()                   # (D, D) bottleneck matrix
    tr = out_bytes / link[0, 1]                # priced on the link
    up = model_bytes / cluster.upload_bw()[1]  # artifact-server link
    return d, tr, up
