"""VIOLATING fixture for deprecation: the pre-PR-3 scalar-bandwidth
surface — symmetric Device shim, receiver-only vector, scalar-priced
transfer/upload helpers."""


def build_fleet(Device, cluster, sched, app):
    d = Device(did=0, cls=0, mem_total=1.0, lam=0.0, bandwidth=50e6)
    bw = cluster.bandwidths()                       # receiver-only (D,)
    up = sched.upload_latency(app, "t0", d, 50e6)   # scalar-priced shim
    tr = sched.transfer_latency(app, "t0", 0, {}, 50e6)
    return d, bw, up, tr
