"""VIOLATING fixture for policy-purity: a policy that mutates the fleet
and writes through its frozen context from inside decide/decide_batch."""


class LeakyPolicy:
    def __init__(self, cluster):
        self.cluster = cluster

    def decide(self, ctx):
        plan = self._plan(ctx)
        self.cluster.apply(plan)              # mutator call inside decide
        ctx.total = ctx.total * 0.5           # store through frozen context
        object.__setattr__(ctx, "pf", None)   # frozen back-door
        return plan

    def decide_batch(self, batch):
        batch.fleet.alive[0] = False          # store through the snapshot
        self.cluster.mark_down(0, batch.fleet.t)
        return [self.decide(batch.row(b)) for b in range(batch.n_rows)]

    def _plan(self, ctx):
        return ctx
