"""Golden fixture: span-parity must stay SILENT on all of this.

Run with options ``{"src_paths": ("",), "test_paths": (),
"schema": ("exec", "plan")}`` — every emitted kind is a string literal
present in the schema, and non-emission calls are ignored.
"""


def emit(tracer, tid, now):
    tracer.event(tid, "plan", now, policy="ibdash")
    sid = tracer.open_span(tid, "exec", now, device=3)
    tracer.close_span(sid, now + 1.0, outcome="ok")
    tracer.add_span(tid, "exec", now, now + 1.0, device=4)


def not_an_emission(queue, logger):
    queue.event(7)                      # one positional arg: no kind to audit
    logger.add_span()                   # no args at all
