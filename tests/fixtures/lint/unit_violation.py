"""Golden violating fixture for unit-consistency: the Eq. (2) bug class —
bytes meeting seconds without the dividing bandwidth."""
import numpy as np


def price_badly(exec_lat, model_bytes, upload_bw, out_bytes, latency):
    # seconds + bytes: the upload term forgot its `/ upload_bw`
    total = exec_lat + model_bytes
    # bytes vs seconds comparison
    if out_bytes > latency:
        total = total + out_bytes / upload_bw
    # exp of a dimensioned quantity (should be exp(-lam * dt))
    risk = np.exp(latency)
    # where() merging seconds with bytes
    slack = np.where(out_bytes > 0.0, latency, model_bytes)
    return total, risk, slack


def mixed_tags(pf, n_feas):
    # probability compared against a cardinality
    return pf >= n_feas
