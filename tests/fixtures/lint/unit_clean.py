"""Clean twin of unit_violation: every term converted before it meets
another — the shape of the real Eq. (2) pricing path."""
import numpy as np


def price_well(exec_lat, model_bytes, upload_bw, out_bytes, link_bw):
    upload = model_bytes / upload_bw          # B / (B/s) -> s
    transfer = out_bytes / link_bw            # B / (B/s) -> s
    total = exec_lat + upload + transfer      # s + s + s
    return total


def replicate_well(pf, beta, lams, dt, queue_len, n_feas):
    combined = pf * pf                        # prob * prob -> prob
    ok = combined >= beta                     # prob vs prob
    surv = np.exp(-lams * dt)                 # (1/s) * s -> dimensionless
    busy = queue_len > n_feas                 # count vs count
    return ok, surv, busy


def deadlines_well(t, deadline, est, wait):
    slack = deadline - (t + est + wait)       # all seconds
    return slack > 0.0
