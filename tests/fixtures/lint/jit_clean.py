"""CLEAN fixture for jit-hygiene: branchless jnp kernels; static
arguments declared static_argnums may drive Python control flow."""
import jax
import jax.numpy as jnp


@jax.jit
def masked_argmin(queue_len, feasible):
    return jnp.argmin(jnp.where(feasible, queue_len, jnp.inf), axis=1)


def escalation_kernel(total, feasible, n_tiers):
    picked = jnp.zeros(total.shape[0], jnp.int64)
    for lv in range(n_tiers):            # static: unrolls at trace time
        masked = jnp.where(feasible, total, jnp.inf)
        picked = jnp.argmin(masked, axis=1)
    return picked


escalation = jax.jit(escalation_kernel, static_argnums=(2,))
