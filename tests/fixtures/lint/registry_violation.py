"""VIOLATING fixture for registry-parity: a "test suite" that pins only
ibdash — any other registered scheme has no batched/scalar parity pin.

The fixture test scans THIS file as the whole test suite with an injected
registry of ("ibdash", "mystery_scheme") and recoveries ("fail_fast",),
so "mystery_scheme" must be reported unpinned.
"""


def test_parity():
    policy = "ibdash"
    recovery = "fail_fast"
    assert policy and recovery
