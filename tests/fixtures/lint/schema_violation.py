"""VIOLATING fixture for snapshot-schema: positional construction and a
keyword construction that misses leaves — both reproduce the 12->13->15
leaf-drift hazard."""


def build_snapshots(FleetSnapshot, t, classes, lams):
    # positional: the next leaf insertion silently shifts every later leaf
    a = FleetSnapshot(t, classes, lams)
    # keyword but incomplete: drops the other declared leaves on the floor
    b = FleetSnapshot(t=t, classes=classes, lams=lams)
    return a, b
