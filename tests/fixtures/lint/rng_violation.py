"""VIOLATING fixture for rng-discipline: global draws, stdlib random,
unseeded generators, and a wall-clock read in sim code."""
import random                      # hidden global state

import numpy as np
import time


def sample_lifetimes(n):
    jitter = random.random()                  # stdlib global stream
    noise = np.random.normal(0.0, 1.0, n)     # global np.random draw
    np.random.seed(0)                         # reseeds everyone's stream
    rng = np.random.default_rng()             # OS-entropy nondeterminism
    stamp = time.time()                       # wall clock in simulated code
    return jitter, noise, rng, stamp
