"""CLEAN fixture for policy-purity: pure decide/decide_batch; the caller
commits with cluster.apply(plan) OUTSIDE the policy, and stateful
policies may advance only their OWN rng/cursor state."""


class PurePolicy:
    def __init__(self, seed):
        self.cursor = seed

    def decide(self, ctx):
        best = int(ctx.feasible_ids[0])
        self.cursor += 1                     # own state: defined row order
        return (best,)

    def decide_batch(self, batch):
        return [self.decide(batch.row(b)) for b in range(batch.n_rows)]


def drive(policy, cluster, ctx):
    plan = policy.decide(ctx)
    token = cluster.apply(plan)              # the one blessed mutation path
    cluster.undo(token)
    return plan
