"""VIOLATING fixture for jit-hygiene: host syncs and traced-value
branching inside jitted kernels (both decorator- and wrapper-jitted)."""
import jax
import numpy as np


@jax.jit
def decorated_kernel(scores, threshold):
    if threshold > 0:                    # Python branch on a traced value
        return scores.item()             # device -> host sync per trace
    return float(scores)                 # concretizes the tracer


def wrapped_kernel(totals):
    best = totals.min()
    while best < 0:                      # traced while-loop
        best = best + 1
    return np.asarray(best)              # numpy pulls the value off-device


wrapped = jax.jit(wrapped_kernel)
