"""CLEAN fixture for rng-discipline: one generator per (seed, id) stream,
derived from explicit seed/SeedSequence arguments; clocks injected."""
import time

import numpy as np


def device_rng(seed, did):
    # the PR 5 stream-keying contract: adding a device never reshuffles
    # any other device's draws
    return np.random.default_rng(np.random.SeedSequence(entropy=(seed, did)))


def sample_lifetimes(seed, n, clock=time.monotonic):
    draws = [device_rng(seed, did).exponential(10.0) for did in range(n)]
    t0 = clock()
    return draws, t0
