"""Pure helpers: no mutation, no global RNG, no wall clock."""
import numpy as np


def best_plan(ctx, plan):
    total = sum(plan)
    return plan if total >= 0 else list(ctx.feasible)


def note_choice(ctx, device):
    # reads only; the decision is RETURNED, never written back
    return (ctx.t, device)


def pick_order(n, rng):
    # explicit per-stream Generator passed in by the caller
    return rng.permutation(np.arange(n))
