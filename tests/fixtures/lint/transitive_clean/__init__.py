"""Clean twin of transitive_violation: same call-graph shape, but the
helpers stay pure and draw from an explicit per-stream Generator."""
