"""Same shape as the violating twin, with pure helpers."""
from .util import best_plan, note_choice, pick_order


class TidyPolicy:
    def decide(self, ctx):
        plan = self._helper(ctx)
        self._note(ctx)
        return plan

    def _helper(self, ctx):
        return best_plan(ctx, [0, 1])

    def _note(self, ctx):
        return note_choice(ctx, 0)

    def decide_batch(self, batch):
        return pick_order(4, batch.rng)
