"""Golden fixture: span-parity MUST flag every violation in here.

Run with options ``{"src_paths": ("",), "test_paths": (),
"schema": ("exec", "plan")}`` — four findings:
two kinds missing from the schema, and two computed (non-literal) kinds.
"""


def emit(tracer, tid, now):
    tracer.event(tid, "rogue_kind", now)                      # not in schema
    tracer.add_span(tid, "other_rogue", now, now + 1.0)       # not in schema
    kind = "exec"
    tracer.open_span(tid, kind, now)                          # computed kind
    tracer.event(tid, "pl" + "an", now)                       # computed kind
