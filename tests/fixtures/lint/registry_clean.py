"""CLEAN fixture for registry-parity: every injected registry name —
("ibdash", "mystery_scheme") and ("fail_fast",) — appears in this
"test suite", so every scheme has a pin."""


def test_parity_all_schemes():
    for policy in ("ibdash", "mystery_scheme"):
        for recovery in ("fail_fast",):
            assert policy and recovery
