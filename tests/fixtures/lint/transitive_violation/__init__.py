"""Transitive-violation fixture package: the policy entry points are
syntactically clean — every contract breach hides one or two helper calls
deep, so only the interprocedural effect pass can see it."""
