"""Helpers that do the dirty work for the fixture policy."""
import numpy as np


def commit_plan(ctx, plan):
    # cluster-mutation: the blessed path is cluster.apply OUTSIDE a policy
    ctx.cluster.apply(plan)
    return plan


def stamp_choice(ctx, device):
    # param-mutation: stores through the caller's frozen context
    ctx.chosen = device
    return device


def pick_order(n):
    # global-rng: hidden np.random module state
    order = list(range(n))
    np.random.shuffle(order)
    return order
