"""A policy whose bodies look pure — the violations live in util.py."""
from .util import commit_plan, pick_order, stamp_choice


class EagerPolicy:
    def decide(self, ctx):
        plan = self._helper(ctx)
        self._note(ctx)
        return plan

    def _helper(self, ctx):
        # decide -> _helper -> commit_plan -> ctx.cluster.apply()
        return commit_plan(ctx, [0, 1])

    def _note(self, ctx):
        # decide -> _note -> stamp_choice -> store through `ctx`
        stamp_choice(ctx, 0)

    def decide_batch(self, batch):
        # decide_batch -> pick_order -> np.random.shuffle()
        return pick_order(4)
