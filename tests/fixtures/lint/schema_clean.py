"""CLEAN fixture for snapshot-schema: keyword-only construction carrying
every leaf of the declared schema, in any keyword order."""


def build_snapshot(FleetSnapshot, t, arrs):
    return FleetSnapshot(
        t=t,
        classes=arrs["classes"],
        lams=arrs["lams"],
        bandwidths=arrs["bandwidths"],
        tiers=arrs["tiers"],
        up_bw=arrs["up_bw"],
        down_bw=arrs["down_bw"],
        backhaul=arrs["backhaul"],
        mem_total=arrs["mem_total"],
        join_times=arrs["join_times"],
        alive=arrs["alive"],
        surv_grid=arrs["surv_grid"],
        survival=arrs["survival"],
        counts=arrs["counts"],
        queue_len=arrs["queue_len"],
        base=arrs["base"],
        slope=arrs["slope"],
    )
