"""Golden violating fixture for kernel-hygiene: four contract breaches
only a jaxpr-level audit can see — a float32 array constant inside an x64
kernel, a host debug callback, per-wave recompilation (no row padding),
and a donated buffer with no matching output."""
import jax
import jax.numpy as jnp

from repro.analysis.kernel_audit import KernelSpec, f64


def leaky_kernel(x):
    # float32 weights inside a kernel that must be bit-identical float64
    w = jnp.asarray([0.5, 2.0, 1.0, 1.0], jnp.float32)
    jax.debug.print("rows {n}", n=x.shape[0])
    return (x * w).sum(axis=1)


def unpadded_kernel(x):
    return x * 2.0


def hoarder_kernel(x, acc):
    # acc is donated below, but no output matches its (3,) buffer
    return x.sum() + acc.sum()


AUDIT_TARGETS = [
    KernelSpec(
        name="leaky_kernel",
        fn=lambda: leaky_kernel,
        build=lambda p: (f64(p["B"], 4),),
        sweep=({"B": 8},),
        x64=True,
    ),
    KernelSpec(
        name="unpadded_kernel",
        fn=lambda: unpadded_kernel,
        # raw wave sizes straight into the shape: every wave recompiles
        build=lambda p: (f64(p["B"], 4),),
        sweep=({"B": 8}, {"B": 9}, {"B": 10}),
        x64=True,
        expected_lowerings=1,
    ),
    KernelSpec(
        name="hoarder_kernel",
        fn=lambda: hoarder_kernel,
        build=lambda p: (f64(4, 4), f64(3)),
        sweep=({},),
        donate_argnums=(1,),
    ),
]
