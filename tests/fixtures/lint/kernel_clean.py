"""Clean twin of kernel_violation: float64 end to end, no callbacks,
wave sizes padded to one bucket, donation with a matching output."""
from repro.analysis.kernel_audit import KernelSpec, f64


def tidy_kernel(x, acc):
    return x * 2.0, acc + 1.0


def _bucket(B):
    # pad like repro.core.batched._padded: one compiled shape serves
    # every wave size in the bucket
    return 1 << max(B - 1, 0).bit_length()


AUDIT_TARGETS = [
    KernelSpec(
        name="tidy_kernel",
        fn=lambda: tidy_kernel,
        build=lambda p: (f64(_bucket(p["B"]), 4), f64(_bucket(p["B"]))),
        sweep=({"B": 70}, {"B": 100}),
        x64=True,
        donate_argnums=(1,),
        expected_lowerings=1,
    ),
]
