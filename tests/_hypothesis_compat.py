"""Optional-hypothesis shim for the property-based tests.

``hypothesis`` is a dev-only dependency (see requirements-dev.txt).  When it
is installed, this module re-exports the real ``given``/``settings``/``st``.
When it is not, the decorators turn each property test into a single test
that calls ``pytest.importorskip("hypothesis")`` — so a bare checkout still
collects and runs every example-based test instead of failing at import.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised on bare checkouts
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipper():
                pytest.importorskip("hypothesis")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _StrategyStub:
        """Absorbs any attribute access / call chain (st.composite, st.lists
        of st.integers, strategy.map, ...) at collection time."""

        def __call__(self, *_args, **_kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _StrategyStub()
