"""Optimizers, schedules, grad accumulation, compression, train step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models import LM, reduced
from repro.optim.compression import (
    compress_gradients,
    decompress_gradients,
    int8_dequantize,
    int8_quantize,
)
from repro.optim.optimizers import Adafactor, AdamW, clip_by_global_norm, global_norm
from repro.optim.schedules import cosine_with_warmup, linear_warmup
from repro.train.step import make_train_step


def _quad_problem():
    target = jnp.asarray(np.random.default_rng(0).standard_normal((16, 16)), jnp.float32)
    params = {"w": jnp.zeros((16, 16), jnp.float32)}

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    return params, loss


@pytest.mark.parametrize("opt", [
    AdamW(lr=0.05),
    AdamW(lr=0.05, state_dtype="bfloat16"),
    Adafactor(lr=0.5, min_dim_size_to_factor=8),
])
def test_optimizer_reduces_quadratic(opt):
    params, loss = _quad_problem()
    state = opt.init(params)
    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 0.2 * l0


def test_adafactor_state_is_factored():
    opt = Adafactor(min_dim_size_to_factor=8)
    params = {"big": jnp.zeros((64, 32)), "small": jnp.zeros((4,))}
    st_ = opt.init(params)
    assert set(st_["v"]["big"]) == {"vr", "vc"}
    assert st_["v"]["big"]["vr"].shape == (64,)
    assert st_["v"]["big"]["vc"].shape == (32,)
    assert set(st_["v"]["small"]) == {"v"}


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((10,)) * 3.0}
    clipped, n = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    assert float(n) == pytest.approx(np.sqrt(90.0), rel=1e-5)


def test_schedules():
    warm = linear_warmup(1.0, 10)
    assert float(warm(jnp.int32(5))) == pytest.approx(0.5)
    assert float(warm(jnp.int32(100))) == pytest.approx(1.0)
    cos = cosine_with_warmup(1.0, 10, 100, final_frac=0.1)
    assert float(cos(jnp.int32(100))) == pytest.approx(0.1, abs=1e-6)
    assert float(cos(jnp.int32(10))) == pytest.approx(1.0, abs=0.05)


@given(st.floats(0.01, 100.0), st.integers(1, 5))
@settings(max_examples=30, deadline=None)
def test_int8_quantization_error_bound(scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(128) * scale, jnp.float32)
    q, s = int8_quantize(x)
    back = int8_dequantize(q, s)
    # deterministic rounding error is at most half a quantization step
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 0.5 + 1e-7


def test_stochastic_rounding_unbiased():
    x = jnp.full((20000,), 0.3, jnp.float32)
    q, s = int8_quantize(x, rng=jax.random.PRNGKey(0))
    back = int8_dequantize(q, s)
    assert float(back.mean()) == pytest.approx(0.3, rel=0.02)


def test_compress_roundtrip_tree():
    tree = {"a": jnp.asarray([1.0, -2.0, 3.0]), "b": {"c": jnp.ones((4, 4))}}
    comp = compress_gradients(tree)
    back = decompress_gradients(comp, tree)
    assert jax.tree.structure(back) == jax.tree.structure(tree)
    np.testing.assert_allclose(np.asarray(back["b"]["c"]), 1.0, atol=1e-2)


def test_grad_accumulation_matches_single_step():
    cfg = reduced(get_config("olmo-1b"), n_layers=1, vocab=128)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=0.0)    # lr 0: isolate the gradient computation
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0, cfg.vocab),
    }
    s1 = make_train_step(model, opt, microbatches=1)
    s2 = make_train_step(model, opt, microbatches=2)
    _, _, m1 = jax.jit(s1)(params, opt.init(params), batch)
    _, _, m2 = jax.jit(s2)(params, opt.init(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    assert float(m1["grad_norm"]) == pytest.approx(float(m2["grad_norm"]), rel=1e-3)


def test_train_step_learns():
    cfg = reduced(get_config("qwen1.5-0.5b"), n_layers=2, vocab=256)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=5e-3)
    state = opt.init(params)
    step = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
    from repro.data.synthetic import SyntheticLM
    it = iter(SyntheticLM(cfg.vocab, 8, 32, seed=0))
    losses = []
    for _ in range(30):
        params, state, m = step(params, state, next(it))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1
