"""The always-on streaming service (repro.stream): arrival processes,
admission control + SLO classes, the service loop, the metrics surface,
and the engine's instance-conservation ledger."""
import json

import numpy as np
import pytest

from repro.core.cluster import ClusterState, Device
from repro.core.dag import AppDAG, TaskSpec
from repro.core.interference import InterferenceModel
from repro.core.policy import IBDASHPolicy, make_policy
from repro.sim.engine import Engine
from repro.stream import (
    AdmissionConfig,
    AdmissionController,
    AppStream,
    Arrival,
    MetricsRegistry,
    PlacementLatencyEstimator,
    SLOClass,
    StreamingOrchestrator,
    default_streams,
    diurnal_arrivals,
    poisson_arrivals,
    trace_replay,
)

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

GB = 1e9
MB = 1e6

CRIT = SLOClass("latency_critical", deadline=5.0, critical=True)
BEST = SLOClass("best_effort", deadline=30.0, critical=False)


def tiny_app(name="app"):
    return AppDAG.from_tasks(name, [TaskSpec("t0", ttype=0)])


def tiny_stream(name="s", slo=BEST, weight=1.0):
    return AppStream(name, tiny_app, slo=slo, weight=weight)


def small_cluster(n=4, lam=1e-6, base=0.1, mem=8 * GB):
    model = InterferenceModel(
        base=np.full((n, 1), base), slope=np.full((n, 1, 1), 0.02)
    )
    devices = [
        Device(did=i, cls=i % n, mem_total=mem, lam=lam, up_bw=100 * MB, down_bw=100 * MB)
        for i in range(n)
    ]
    return ClusterState(devices=devices, model=model, horizon=300.0, dt=0.05)


def arrival(t, slo, deadline=None, kind="s", est=None, uid=0):
    s = AppStream(kind, tiny_app, slo=slo)
    return Arrival(
        t=t, slo=slo,
        deadline=t + slo.deadline if deadline is None else deadline,
        stream=s, uid=uid,
    )


class StubEstimator:
    """Fixed idle-fleet estimate per workload kind (controller-logic tests)."""

    def __init__(self, ests, n_alive=4):
        self.ests = ests
        self._n = n_alive

    def estimate(self, a):
        return self.ests[a.kind]

    def n_alive(self, t):
        return self._n


# ------------------------------------------------------ arrival processes --
def test_poisson_arrivals_deterministic():
    streams = [tiny_stream("a"), tiny_stream("b")]
    one = poisson_arrivals(streams, 40.0, 10.0, seed=3)
    two = poisson_arrivals(streams, 40.0, 10.0, seed=3)
    assert [(a.t, a.kind, a.uid) for a in one] == \
           [(a.t, a.kind, a.uid) for a in two]
    other = poisson_arrivals(streams, 40.0, 10.0, seed=4)
    assert [a.t for a in one] != [a.t for a in other]


def test_keyed_streams_are_extensible():
    """Adding a stream must not reshuffle an existing stream's times (the
    churn.py common-random-numbers contract): stream 0 at per-stream rate R
    draws the same times whether or not stream 1 exists."""
    a = tiny_stream("a")
    b = tiny_stream("b")
    solo = poisson_arrivals([a], 20.0, 10.0, seed=0)
    both = poisson_arrivals([a, b], 40.0, 10.0, seed=0)  # a still gets 20/s
    assert [x.t for x in solo] == [x.t for x in both if x.kind == "a"]


def test_poisson_rate_sanity():
    n = len(poisson_arrivals([tiny_stream()], 100.0, 50.0, seed=0))
    assert 100.0 * 50.0 * 0.9 < n < 100.0 * 50.0 * 1.1


def test_arrival_deadlines_and_uids():
    arr = poisson_arrivals(
        [tiny_stream("c", slo=CRIT), tiny_stream("b", slo=BEST)],
        30.0, 5.0, seed=1,
    )
    assert [a.uid for a in arr] == list(range(len(arr)))
    assert all(a.t <= b.t for a, b in zip(arr, arr[1:]))
    for a in arr:
        assert a.deadline == pytest.approx(a.t + a.slo.deadline)
    inst = arr[0].instantiate()
    assert inst.tasks                       # relabelled per-uid DAG instance
    assert f"#{arr[0].uid}" in next(iter(inst.tasks))


def test_diurnal_density_tracks_the_rate_shape():
    """phase=0 puts the trough at t=0 (mod period): the half-period around
    the peak must hold clearly more arrivals than the trough half."""
    arr = diurnal_arrivals(
        [tiny_stream()], 5.0, 120.0, 40.0, period=20.0, phase=0.0, seed=2,
    )
    ts = np.array([a.t for a in arr])
    phase = np.mod(ts, 20.0)
    trough = np.sum((phase < 5.0) | (phase >= 15.0))
    peak = np.sum((phase >= 5.0) & (phase < 15.0))
    assert peak > 3 * trough
    assert arr == sorted(arr, key=lambda a: a.t)


def test_trace_replay_orders_and_overrides_deadlines():
    streams = [tiny_stream("a", slo=CRIT), tiny_stream("b", slo=BEST)]
    rows = [(3.0, "b"), (1.0, "a", 9.5), (2.0, "b")]
    arr = trace_replay(rows, streams)
    assert [a.t for a in arr] == [1.0, 2.0, 3.0]
    assert [a.uid for a in arr] == [0, 1, 2]
    assert arr[0].deadline == 9.5                       # explicit override
    assert arr[1].deadline == pytest.approx(2.0 + BEST.deadline)
    assert arr[0].slo.critical and not arr[1].slo.critical


# --------------------------------------------------------- admission queue --
def test_capacity_shed_and_ledger():
    ctl = AdmissionController(
        AdmissionConfig(queue_cap=2), StubEstimator({"s": 0.1})
    )
    assert ctl.offer(arrival(0.0, BEST, uid=0), 0.0)
    assert ctl.offer(arrival(0.0, BEST, uid=1), 0.0)
    assert not ctl.offer(arrival(0.0, BEST, uid=2), 0.0)
    assert ctl.shed_log[-1].reason == "capacity"
    wave = ctl.pop_wave(0.0)
    assert [a.uid for a in wave] == [0, 1]
    assert ctl.offered == 3 and ctl.dispatched == 2 and ctl.shed == 1
    ctl.assert_drained()


def test_critical_evicts_latest_deadline_best_effort():
    ctl = AdmissionController(
        AdmissionConfig(queue_cap=2), StubEstimator({"s": 0.1})
    )
    ctl.offer(arrival(0.0, BEST, deadline=20.0, uid=0), 0.0)
    ctl.offer(arrival(0.0, BEST, deadline=40.0, uid=1), 0.0)
    assert ctl.offer(arrival(0.0, CRIT, uid=2), 0.0)    # full queue: evict
    rec = ctl.shed_log[-1]
    assert rec.reason == "evicted" and rec.uid == 1     # latest deadline out
    wave = ctl.pop_wave(0.0)
    assert [a.uid for a in wave] == [2, 0]              # critical first
    ctl.assert_drained()


def test_deadline_shed_uses_idle_estimate():
    ctl = AdmissionController(
        AdmissionConfig(queue_cap=8), StubEstimator({"s": 5.0})
    )
    assert not ctl.offer(arrival(0.0, CRIT, deadline=1.0), 0.0)
    assert ctl.shed_log[-1].reason == "deadline"
    # same workload with enough slack is admitted
    assert ctl.offer(arrival(0.0, CRIT, deadline=6.0), 0.0)


def test_stale_entries_shed_at_dequeue():
    ctl = AdmissionController(
        AdmissionConfig(queue_cap=8), StubEstimator({"s": 1.0})
    )
    assert ctl.offer(arrival(0.0, BEST, deadline=10.0), 0.0)
    wave = ctl.pop_wave(20.0)                           # way past deadline
    assert wave == []
    assert ctl.shed_log[-1].reason == "stale"
    ctl.assert_drained()


def test_no_admission_baseline_never_sheds():
    ctl = AdmissionController(
        AdmissionConfig(queue_cap=None, shed=False),
        StubEstimator({"s": 50.0}),
    )
    for i in range(200):
        assert ctl.offer(arrival(0.0, BEST, deadline=0.5, uid=i), 0.0)
    assert ctl.shed == 0
    assert len(ctl.pop_wave(100.0)) == 200
    ctl.assert_drained()


def test_assert_drained_catches_leftovers():
    ctl = AdmissionController(
        AdmissionConfig(queue_cap=8), StubEstimator({"s": 0.1})
    )
    ctl.offer(arrival(0.0, BEST), 0.0)
    with pytest.raises(RuntimeError, match="not drained"):
        ctl.assert_drained()


def test_estimator_is_idle_fleet_and_cached():
    cluster = small_cluster()
    est = PlacementLatencyEstimator(cluster, IBDASHPolicy())
    a = arrival(0.0, BEST, kind="k")
    e0 = est.estimate(a)
    assert np.isfinite(e0) and e0 > 0
    # loading the REAL fleet must not change the idle-fleet estimate
    cluster.add_interval(0, 0, 0.0, 100.0, w=50)
    assert est.estimate(arrival(1.0, BEST, kind="k")) == e0


# --------------------------------------------------- property-based tests --
@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.floats(0.05, 3.0), st.floats(0.0, 4.0)),
                min_size=1, max_size=40))
def test_shed_criticals_are_provably_idle_infeasible(items):
    """A latency_critical instance is never shed if it could have met its
    deadline on an idle fleet: every critical ShedRecord (no capacity
    pressure) must fail the idle-fleet test ``t + est > deadline``."""
    ests = {f"s{i}": e for i, (e, _) in enumerate(items)}
    ctl = AdmissionController(
        AdmissionConfig(queue_cap=None), StubEstimator(ests)
    )
    for i, (est, slack) in enumerate(items):
        ctl.offer(arrival(0.0, CRIT, deadline=slack, kind=f"s{i}", uid=i),
                  0.0)
    ctl.pop_wave(0.0)
    for rec in ctl.shed_log:
        assert rec.reason in ("deadline", "stale")
        assert rec.t + rec.est > rec.deadline           # provably infeasible
        assert rec.est == ests[rec.kind]                # the idle estimate
    # and the complement: every arrival that COULD meet its deadline ran
    ok = sum(1 for i, (e, s) in enumerate(items) if e <= s)
    assert ctl.dispatched == ok
    ctl.assert_drained()


@settings(max_examples=40, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=60),
       st.integers(2, 8))
def test_criticals_never_shed_while_best_effort_queued(flags, cap):
    """Backpressure ordering: with always-idle-feasible criticals, a
    latency_critical arrival is only ever capacity-shed when NO best_effort
    entry remained to evict."""
    ctl = AdmissionController(
        AdmissionConfig(queue_cap=cap), StubEstimator({"s": 0.1})
    )
    for i, is_crit in enumerate(flags):
        slo = CRIT if is_crit else BEST
        ctl.offer(arrival(0.0, slo, deadline=100.0, kind="s", uid=i), 0.0)
    for rec in ctl.shed_log:
        if rec.slo == "latency_critical":
            assert rec.reason == "capacity"
            assert rec.best_depth == 0     # nothing left to evict
    ctl.pop_wave(100.0 - 0.2)
    ctl.assert_drained()


def test_hypothesis_installed_in_ci():
    import os

    if os.environ.get("CI"):
        assert HAVE_HYPOTHESIS, "CI must run the property tests for real"


# ------------------------------------------------- conservation ledger -----
def test_engine_conservation_identity_holds():
    cluster = small_cluster()
    eng = Engine(cluster, make_policy("ibdash"), noise_sigma=0.0)
    eng.add_arrivals([tiny_app(f"a{i}") for i in range(20)],
                     [0.1 * i for i in range(20)])
    eng.drain()                 # asserts admitted == completed + lost + shed
    s = eng.stats
    assert s["admitted"] == 20 and s["completed"] == 20
    assert s["lost"] == 0 and s["shed"] == 0


def test_engine_drain_raises_on_counter_drift():
    cluster = small_cluster()
    eng = Engine(cluster, make_policy("ibdash"), noise_sigma=0.0)
    eng.add_arrivals([tiny_app()], [0.0])
    eng.stats["admitted"] += 1                          # tamper the ledger
    with pytest.raises(RuntimeError, match="instance-counter drift"):
        eng.drain()


def test_infeasible_arrival_counts_as_lost():
    """The PR's drift fix: an arrival infeasible at plan time must hit the
    ``lost`` counter (it used to be marked failed without any accounting)."""
    cluster = small_cluster(mem=1 * GB)
    app = AppDAG.from_tasks("big", [TaskSpec("t0", ttype=0,
                                             mem_bytes=4 * GB)])
    eng = Engine(cluster, make_policy("ibdash"), noise_sigma=0.0)
    eng.add_arrivals([app, tiny_app()], [0.0, 0.0])
    eng.drain()
    s = eng.stats
    assert s["admitted"] == 2 and s["completed"] == 1 and s["lost"] == 1
    assert eng.records[0].failed and not eng.records[1].failed


# ------------------------------------------------------- service loop ------
@pytest.fixture(scope="module")
def service_run(tmp_path_factory):
    from repro.api import Orchestrator

    cluster = small_cluster(n=6)
    orch = Orchestrator(cluster, IBDASHPolicy())
    streams = [tiny_stream("c", slo=CRIT), tiny_stream("b", slo=BEST)]
    arr = poisson_arrivals(streams, 60.0, 5.0, seed=5)
    svc = StreamingOrchestrator(orch, admission=AdmissionConfig(queue_cap=64),
                                tick=0.25)
    return svc, svc.run(arr), arr


def test_service_conserves_instances(service_run):
    svc, res, arr = service_run
    s = res.stats
    assert s["admitted"] == len(arr)
    assert s["admitted"] == s["completed"] + s["lost"] + s["shed"]
    c = res.metrics["counters"]
    assert c["admitted"] + svc.controller.shed == len(arr)
    assert c["completed"] + c.get("failed", 0) == svc.controller.dispatched


def test_service_e2e_latency_measured_from_arrival(service_run):
    _, res, _ = service_run
    h = res.metrics["histograms"]
    assert h["e2e"]["count"] == res.stats["completed"]
    assert h["e2e"]["p50"] > 0
    assert res.p("p99", "latency_critical") >= res.p("p50", "latency_critical")
    assert res.metrics["gauges"]["placements_per_sec"] > 0


def test_service_metrics_export_json(service_run, tmp_path):
    svc, res, _ = service_run
    path = tmp_path / "metrics.json"
    svc.metrics.to_json(str(path))
    data = json.loads(path.read_text())
    assert set(data) == {"counters", "gauges", "histograms", "samples"}
    assert data["samples"], "interval sampler produced no rows"
    assert all("t" in row and "queue_depth" in row for row in data["samples"])


def test_no_admission_baseline_runs_everything():
    from repro.api import Orchestrator

    cluster = small_cluster(n=4)
    orch = Orchestrator(cluster, IBDASHPolicy())
    arr = poisson_arrivals([tiny_stream("b", slo=BEST)], 40.0, 3.0, seed=9)
    svc = StreamingOrchestrator(orch, admission=None)
    res = svc.run(arr)
    assert res.stats["shed"] == 0
    assert res.stats["completed"] == len(arr)


def test_auto_degrade_policy():
    from repro.stream.service import _auto_degrade

    d = _auto_degrade(IBDASHPolicy(gamma=3))
    assert isinstance(d, IBDASHPolicy) and d.cfg.gamma == 0
    assert _auto_degrade(make_policy("random")) is None
    assert _auto_degrade(IBDASHPolicy(gamma=0)) is None


def test_run_one_stream_scenario():
    from repro.api import SimConfig, run_one

    cfg = SimConfig(scenario="stream", n_devices=24, n_cycles=1,
                    cycle_len=4.0, stream_rate=30.0, seed=0)
    res = run_one("ibdash", cfg)
    assert res.scenario == "stream"
    st_res = res.stream
    assert st_res.stats["admitted"] == st_res.n_arrivals
    assert st_res.metrics["counters"]["completed"] == st_res.stats["completed"]


def test_serving_fleet_admission_path():
    from repro.serve.scheduler import ServingFleet, serving_interference_model

    fleet = ServingFleet(serving_interference_model(), n_replicas=6,
                         horizon=40.0)
    res = fleet.run(n_requests=80, arrival_window=8.0,
                    admission=AdmissionConfig(queue_cap=32))
    sr = res.stream
    assert sr.n_arrivals == 80
    assert sr.stats["admitted"] == sr.stats["completed"] \
        + sr.stats["lost"] + sr.stats["shed"]
    assert np.isfinite(sr.p("p99", "latency_critical"))


# ------------------------------------------------------ metrics registry ---
def test_histogram_exact_quantiles():
    h = MetricsRegistry().histogram("x")
    for v in range(1, 101):
        h.observe(float(v))
    assert h.quantile(0.5) == pytest.approx(50.5)
    s = h.summary()
    assert s["count"] == 100 and s["max"] == 100.0
    assert s["p99"] == pytest.approx(np.quantile(np.arange(1.0, 101.0), 0.99))


def test_registry_samples_counters_and_gauges():
    m = MetricsRegistry()
    m.counter("a").inc(3)
    m.gauge("g").set(1.5)
    row = m.sample(2.0)
    assert row == {"t": 2.0, "a": 3, "g": 1.5}
    m.counter("a").inc()
    m.sample(3.0)
    assert m.snapshot()["samples"][1]["a"] == 4
