"""Tier-aware link matrices (PR 3): the bottleneck rule, sender-aware
transfer pricing, multi-tier fleets, the tier_escalation policy,
snapshot-scoped builder caches, and the fused-burst provisional-interval
alignment."""
import numpy as np
import pytest

from repro.api import (
    Orchestrator,
    TIER_CLOUD,
    TIER_DEVICE,
    TIER_EDGE_SERVER,
    make_policy,
    orchestrate,
    orchestrate_batch,
)
from repro.core.cluster import ClusterState, Device
from repro.core.dag import AppDAG, TaskSpec
from repro.core.interference import InterferenceModel
from repro.sim import SimConfig, make_multi_tier_cluster, make_profile, run_one
from repro.sim.engine import Engine
from repro.sim.runner import ALL_SCHEME_NAMES, _make_workload, policy_for

GB = 1e9
MB = 1e6


@pytest.fixture(scope="module")
def profile():
    return make_profile(seed=0)


def tiered_cluster(ups, downs, tiers, base=None, backhaul=None, lam=1e-6,
                   mem=8 * GB, n_types=1, model_source=None):
    n = len(ups)
    if base is None:
        base = np.full((n, n_types), 0.2)
    model = InterferenceModel(
        base=np.asarray(base, dtype=np.float64),
        slope=np.full((n, n_types, n_types), 0.05),
    )
    devices = [
        Device(did=i, cls=i, mem_total=mem, lam=lam, tier=tiers[i],
               up_bw=float(ups[i]), down_bw=float(downs[i]))
        for i in range(n)
    ]
    return ClusterState(devices=devices, model=model, horizon=120.0, dt=0.05,
                        backhaul=backhaul, model_source=model_source)


def chain_app(out_bytes=10 * MB, parent_ttype=0, child_ttype=0):
    return AppDAG.from_tasks("app", [
        TaskSpec("parent", ttype=parent_ttype, out_bytes=out_bytes),
        TaskSpec("child", ttype=child_ttype, deps=("parent",)),
    ])


def same_placement(a, b):
    assert a.feasible == b.feasible
    assert a.infeasible_task == b.infeasible_task
    assert a.est_latency == b.est_latency
    assert set(a.tasks) == set(b.tasks)
    for k in a.tasks:
        ta, tb = a.tasks[k], b.tasks[k]
        assert [r.did for r in ta.replicas] == [r.did for r in tb.replicas]
        for ra, rb in zip(ta.replicas, tb.replicas):
            assert ra.est_exec == rb.est_exec
            assert ra.est_upload == rb.est_upload
            assert ra.est_transfer == rb.est_transfer
            assert ra.pred_fail == rb.pred_fail


# ------------------------------------------------------- bottleneck rule --
def test_link_matrix_bottleneck_rule():
    """bw_eff[s, d] = min(up[s], down[d], backhaul[tier[s], tier[d]])."""
    ups = (10 * MB, 20 * MB, 30 * MB)
    downs = (40 * MB, 50 * MB, 60 * MB)
    tiers = (TIER_DEVICE, TIER_EDGE_SERVER, TIER_CLOUD)
    backhaul = np.array([
        [25, 500, 15],
        [500, 1250, 150],
        [15, 150, 2500],
    ]) * MB
    c = tiered_cluster(ups, downs, tiers, backhaul=backhaul)
    link = c.link_bw()
    for s in range(3):
        for d in range(3):
            if s == d:
                assert link[s, d] == np.inf     # co-located: no network hop
            else:
                assert link[s, d] == min(
                    ups[s], downs[d], backhaul[tiers[s], tiers[d]]
                )
    # the WAN (device <-> cloud backhaul 15 MB/s) caps the fast cloud link
    assert link[2, 0] == 15 * MB


def test_scalar_bandwidth_is_symmetric_shim():
    """Device(bandwidth=B) == Device(up_bw=B, down_bw=B); up/down-only
    construction back-fills the deprecated scalar with min(up, down)."""
    # the deprecated shim is exactly what this test pins down
    d = Device(did=0, cls=0, mem_total=GB, lam=0.0, bandwidth=50 * MB)  # repro-lint: disable=deprecation
    assert d.up_bw == d.down_bw == 50 * MB
    d2 = Device(did=1, cls=0, mem_total=GB, lam=0.0,
                up_bw=8 * MB, down_bw=40 * MB)
    assert d2.bandwidth == 8 * MB
    with pytest.raises(ValueError, match="bandwidth"):
        Device(did=2, cls=0, mem_total=GB, lam=0.0)


def test_symmetric_fleet_transfer_matches_receiver_pricing():
    """On a symmetric fleet (up = down = old scalar bandwidth, one tier) the
    matrix row out/min(bw, bw) is the seed's out/bw[d] exactly, so
    placements stay bit-identical to pre-PR (see also the seed parity tests
    in test_policy_api)."""
    bw = 100 * MB
    c = tiered_cluster([bw] * 3, [bw] * 3, [0] * 3)
    plan = orchestrate(chain_app(out_bytes=30 * MB), c, 0.0,
                       make_policy("round_robin"))
    child = plan.tasks["child"].replicas[0]
    parent = plan.tasks["parent"].replicas[0]
    assert parent.did != child.did                    # round robin moved it
    assert child.est_transfer == 30 * MB / bw         # receiver rate exactly


# -------------------------------------------- the one-sided pricing bug --
def test_slow_uplink_prices_the_link_not_the_endpoint():
    """A fast device pulling from a slow phone must pay the phone's uplink:
    the corrected ranking keeps the child co-located, and raising the
    phone's uplink (everything else equal) releases it."""
    # parent type runs well only on device 0 (the phone); child type is
    # faster on device 1 (the fast box)
    base = np.array([[0.1, 0.5], [5.0, 0.2]])
    mk = lambda up0: tiered_cluster(
        ups=(up0, 100 * MB), downs=(100 * MB, 100 * MB), tiers=(0, 0),
        base=base, n_types=2,
    )
    app = chain_app(out_bytes=10 * MB, parent_ttype=0, child_ttype=1)

    slow = orchestrate(app, mk(1 * MB), 0.0, make_policy("ibdash"))
    assert slow.tasks["parent"].replicas[0].did == 0
    # pulling 10 MB over the 1 MB/s uplink would cost 10 s: stay on the phone
    assert slow.tasks["child"].replicas[0].did == 0

    fast = orchestrate(app, mk(100 * MB), 0.0, make_policy("ibdash"))
    assert fast.tasks["parent"].replicas[0].did == 0
    # symmetric 100 MB/s link: 0.2 s exec + 0.1 s transfer beats 0.5 s
    assert fast.tasks["child"].replicas[0].did == 1
    assert fast.tasks["child"].replicas[0].est_transfer == pytest.approx(0.1)


def test_upload_charged_over_model_source_link():
    """With a declared artifact server, L(M(T_i)) is priced over the
    bw_eff[model_source, d] link (and is free on the server itself)."""
    ups = (8 * MB, 600 * MB, 600 * MB)
    downs = (40 * MB, 600 * MB, 600 * MB)
    c = tiered_cluster(ups, downs, tiers=(0, 1, 1), model_source=1)
    up = c.upload_bw()
    assert up[0] == 40 * MB          # min(server up 600, phone down 40)
    assert up[2] == 600 * MB
    assert up[1] == np.inf           # the server already holds the artifact
    app = AppDAG.from_tasks("m", [TaskSpec(
        "t", ttype=0, model_id="w", model_bytes=80 * MB)])
    plan = orchestrate(app, c, 0.0, make_policy("lavea"))
    rep = plan.tasks["t"].replicas[0]
    assert rep.est_upload == pytest.approx(80 * MB / up[rep.did])


# ------------------------------------------ plan costs vs link matrices --
def test_plan_costs_priced_over_link_matrix():
    """The replica cost breakdown in the Plan is exactly the link-matrix
    price: out_bytes / bw_eff[parent, child] for transfers, model_bytes /
    upload_bw[d] for artifact uploads."""
    ups = (1 * MB, 100 * MB)
    c = tiered_cluster(ups, (100 * MB, 100 * MB), (0, 0), n_types=2,
                       base=np.array([[0.1, 0.5], [5.0, 0.2]]))
    app = chain_app(out_bytes=10 * MB, parent_ttype=0, child_ttype=1)
    plan = orchestrate(app, c, 0.0, make_policy("ibdash"))
    pdid = plan.tasks["parent"].replicas[0].did
    crep = plan.tasks["child"].replicas[0]
    want = 0.0 if crep.did == pdid else 10 * MB / c.link_bw()[pdid, crep.did]
    assert crep.est_transfer == pytest.approx(want)

    mapp = AppDAG.from_tasks("m", [TaskSpec(
        "t", ttype=0, model_id="w", model_bytes=40 * MB)])
    for name in ("ibdash", "round_robin"):
        p = orchestrate(mapp, c, 0.0, make_policy(name))
        rep = p.tasks["t"].replicas[0]
        assert rep.est_upload == pytest.approx(
            40 * MB / c.upload_bw()[rep.did]
        )


# --------------------------------------------- snapshot-scoped caches --
def test_bandwidth_change_between_waves_is_reflected():
    """set_bandwidth + the next wave reprices transfers (the builder's
    per-wave caches cannot leak across topology changes)."""
    c = tiered_cluster((100 * MB,) * 2, (100 * MB,) * 2, (0, 0))
    app = chain_app(out_bytes=20 * MB)
    p1 = orchestrate(app, c, 0.0, make_policy("round_robin"))
    moved = p1.tasks["child"].replicas[0]
    assert moved.est_transfer == pytest.approx(0.2)
    c.set_bandwidth(p1.tasks["parent"].replicas[0].did, up=2 * MB)
    p2 = orchestrate(app, c, 0.0, make_policy("round_robin"))
    assert p2.tasks["child"].replicas[0].est_transfer == pytest.approx(10.0)


def test_stale_wave_builder_raises():
    from repro.core.orchestrator import _AppPlanState, _WaveContextBuilder

    c = tiered_cluster((100 * MB,) * 2, (100 * MB,) * 2, (0, 0))
    app = chain_app()
    builder = _WaveContextBuilder(c)
    state = _AppPlanState(app=app, arrival=0.0, n_stages=app.n_stages)
    c.set_bandwidth(0, up=1 * MB)
    with pytest.raises(RuntimeError, match="topology changed"):
        builder.batch([(state, "parent", 0.0, 0)])


# -------------------------------------------------- tier escalation ------
def esc_cluster(base=None, mem=None, lam=1e-6):
    """4 nodes: two end devices, one edge server, one cloud node."""
    ups = (8 * MB, 8 * MB, 600 * MB, 2500 * MB)
    downs = (40 * MB, 40 * MB, 600 * MB, 2500 * MB)
    tiers = (TIER_DEVICE, TIER_DEVICE, TIER_EDGE_SERVER, TIER_CLOUD)
    n = 4
    if base is None:
        base = np.array([[0.5], [0.4], [0.2], [0.05]])
    model = InterferenceModel(base=np.asarray(base, float),
                              slope=np.full((n, 1, 1), 0.05))
    mems = mem if mem is not None else [8 * GB] * n
    devices = [Device(did=i, cls=i, mem_total=mems[i], lam=lam,
                      tier=tiers[i], up_bw=ups[i], down_bw=downs[i])
               for i in range(n)]
    return ClusterState(devices=devices, model=model, horizon=120.0, dt=0.05)


def one_task():
    return AppDAG.from_tasks("app", [TaskSpec("t", ttype=0)])


def test_tier_escalation_prefers_lowest_tier():
    # no budget: stay on the device tier even though edge/cloud are faster
    plan = orchestrate(one_task(), esc_cluster(), 0.0,
                       make_policy("tier_escalation"))
    assert plan.tasks["t"].replicas[0].did == 1     # best *device-tier* node


def test_tier_escalation_escalates_past_budget():
    pol = make_policy("tier_escalation", latency_budget=0.3)
    plan = orchestrate(one_task(), esc_cluster(), 0.0, pol)
    assert plan.tasks["t"].replicas[0].did == 2     # device tier > 0.3 s

    pol = make_policy("tier_escalation", latency_budget=0.1)
    plan = orchestrate(one_task(), esc_cluster(), 0.0, pol)
    assert plan.tasks["t"].replicas[0].did == 3     # only the cloud makes it

    # unattainable budget: global feasible best
    pol = make_policy("tier_escalation", latency_budget=0.01)
    plan = orchestrate(one_task(), esc_cluster(), 0.0, pol)
    assert plan.tasks["t"].replicas[0].did == 3


def test_tier_escalation_escalates_on_infeasibility():
    # end devices too small for the task: escalate to the edge server
    c = esc_cluster(mem=[1 * GB, 1 * GB, 8 * GB, 8 * GB])
    app = AppDAG.from_tasks("app", [TaskSpec("t", ttype=0, mem_bytes=2 * GB)])
    plan = orchestrate(app, c, 0.0, make_policy("tier_escalation"))
    assert plan.tasks["t"].replicas[0].did == 2


def test_tier_escalation_single_tier_degenerates_to_greedy():
    c = tiered_cluster((100 * MB,) * 3, (100 * MB,) * 3, (0,) * 3,
                       base=np.array([[0.3], [0.1], [0.2]]))
    plan = orchestrate(one_task(), c, 0.0, make_policy("tier_escalation"))
    assert plan.tasks["t"].replicas[0].did == 1


# --------------------------------------- batched == scalar on 3 tiers ----
@pytest.mark.parametrize("scheme", ALL_SCHEME_NAMES)
def test_decide_batch_parity_on_asymmetric_three_tier_fleet(scheme, profile):
    """All six schemes + tier_escalation: one fused decide_batch over a
    multi-tier wave == looping decide over the same rows, bit for bit."""
    cfg = SimConfig(n_cycles=1, instances_per_cycle=60, scenario="multi_tier",
                    seed=0, n_devices=30, latency_budget=4.0)
    apps, times = _make_workload(cfg)
    cluster = make_multi_tier_cluster(profile, n_devices=cfg.n_devices,
                                      seed=cfg.seed, horizon=cfg.horizon + 30)
    kw = dict(profile=profile, cfg=cfg)
    plans_b = orchestrate_batch(apps, cluster, policy_for(scheme, **kw),
                                times=times)
    plans_s = orchestrate_batch(apps, cluster, policy_for(scheme, **kw),
                                times=times, batched=False)
    for a, b in zip(plans_b, plans_s):
        same_placement(a.placement, b.placement)


def test_multi_tier_scenario_end_to_end_fused(profile):
    """tier_escalation through Orchestrator.submit_batch(fused=True) on the
    multi_tier scenario: every instance resolves and some work escalates off
    the device tier."""
    cfg = SimConfig(n_cycles=1, instances_per_cycle=40, scenario="multi_tier",
                    seed=2, n_devices=30, latency_budget=2.0)
    apps, times = _make_workload(cfg)
    cluster = make_multi_tier_cluster(profile, n_devices=cfg.n_devices,
                                      seed=cfg.seed, horizon=cfg.horizon + 30)
    orch = Orchestrator(cluster, policy_for("tier_escalation", profile, cfg),
                        seed=cfg.seed)
    orch.submit_batch(apps, times, fused=True)
    orch.drain()
    res = orch.result("multi_tier", horizon=cfg.horizon)
    assert res.n == len(apps)
    assert all(np.isfinite(r.finished) for r in res.instances)
    n_end = sum(1 for d in cluster.devices if d.tier == TIER_DEVICE)
    assert res.load_per_device[n_end:].sum() > 0      # escalation happened


def test_multi_tier_run_one(profile):
    cfg = SimConfig(n_cycles=1, instances_per_cycle=30, scenario="multi_tier",
                    seed=1, n_devices=24, fused_burst=True, latency_budget=3.0)
    res = run_one("tier_escalation", cfg, profile)
    assert res.n == 30
    assert all(r.failed or np.isfinite(r.service_time) for r in res.instances)


# ------------------------------- fused-burst provisional intervals -------
def test_fused_wave_planned_at_snapshot_time_leaves_no_residue():
    """Plans computed against one snapshot (plan.now=0) applied at later
    arrival times: the engine must cancel the provisional interval where
    ``apply`` recorded it (plan.now + est_start), not at arrival +
    est_start — post-run T_alloc is exactly clean."""
    c = tiered_cluster((100 * MB,) * 3, (100 * MB,) * 3, (0,) * 3,
                       base=np.array([[0.1], [0.12], [0.14]]))
    pol = make_policy("round_robin")
    apps = [chain_app(out_bytes=2 * MB).relabel(f"#{i}") for i in range(6)]
    plans = orchestrate_batch(apps, c, pol, now=0.0)     # one snapshot at t=0
    eng = Engine(c, pol, noise_sigma=0.0)
    times = [3.0 + 0.1 * i for i in range(6)]            # arrivals later
    eng.add_arrivals(apps, times, plans=plans)
    eng.drain()
    assert all(not r.failed for r in eng.records)
    # nothing actually ran before t=3: the provisional wave (recorded at
    # t=0 + est_start, cancelled at the same origin) must net to zero there
    for t in (0.05, 0.5, 1.5, 2.5):
        assert c.counts_at(t).sum() == 0
    # and no bucket anywhere went negative (cancellation hit what was added)
    assert float(c.alloc.min()) >= 0.0


def test_failed_app_cancels_unstarted_provisional_intervals():
    """When an app dies mid-DAG, the provisional T_alloc occupancy of its
    never-started later stages is removed (no ghost residue)."""
    model = InterferenceModel(base=np.array([[0.1]]),
                              slope=np.full((1, 1, 1), 0.05))
    dev = Device(did=0, cls=0, mem_total=8 * GB, lam=1e-3, up_bw=100 * MB,
                 down_bw=100 * MB, alive_until=0.05)   # dies mid-task
    c = ClusterState(devices=[dev], model=model, horizon=60.0, dt=0.05)
    eng = Engine(c, make_policy("round_robin"), noise_sigma=0.0)
    eng.add_arrivals([chain_app(out_bytes=1 * MB)], [0.0])
    eng.drain()
    assert eng.records[0].failed
    finished = eng.records[0].finished
    # beyond the failed parent's actual run there must be NO occupancy: the
    # child never started, so its provisional interval was cancelled
    b0 = c.bucket(finished + 2 * c.dt)
    assert float(np.abs(c.alloc[:, :, b0:]).max()) == 0.0
    assert float(c.alloc.min()) >= 0.0
