"""Exponential availability model, lambda MLE, Young/Daly cadence."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.availability import (
    availability,
    expected_makespan_with_restarts,
    fit_failure_rate,
    gang_failure_rate,
    prob_fail_during,
    sample_lifetime,
    young_daly_interval,
)


def test_availability_decay():
    lam = 1e-3
    assert availability(lam, 0.0) == pytest.approx(1.0)
    assert availability(lam, 1000.0) == pytest.approx(np.exp(-1.0))
    assert availability(lam, 100.0) > availability(lam, 200.0)


def test_prob_fail_memoryless():
    lam = 2e-4
    assert prob_fail_during(lam, 100.0) == pytest.approx(1 - np.exp(-0.02))
    assert prob_fail_during(lam, 0.0) == 0.0


def test_lifetime_sampling_mean():
    rng = np.random.default_rng(0)
    lam = 1e-2
    xs = [sample_lifetime(lam, rng) for _ in range(4000)]
    assert np.mean(xs) == pytest.approx(1 / lam, rel=0.1)
    assert sample_lifetime(0.0, rng) == float("inf")


def test_fit_failure_rate_mle():
    rng = np.random.default_rng(1)
    lam = 5e-3
    # observe 500 devices for their full lifetimes (uncensored)
    lifetimes = rng.exponential(1 / lam, 500)
    lam_hat = fit_failure_rate(lifetimes, [False] * 500)
    assert lam_hat == pytest.approx(lam, rel=0.15)


def test_young_daly_is_near_optimal():
    """Numeric check: Daly's expected makespan is minimised near sqrt(2C/l)."""
    lam, C, work = 1e-4, 30.0, 100000.0
    tau_star = young_daly_interval(lam, C)
    best = expected_makespan_with_restarts(work, lam, C, interval=tau_star)
    for tau in (tau_star / 4, tau_star / 2, tau_star * 2, tau_star * 4):
        other = expected_makespan_with_restarts(work, lam, C, interval=tau)
        assert best <= other * 1.001


@given(st.lists(st.floats(1e-7, 1e-3), min_size=1, max_size=64))
@settings(max_examples=40, deadline=None)
def test_gang_rate_additive_and_bounds(lams):
    total = gang_failure_rate(lams)
    assert total == pytest.approx(sum(lams), rel=1e-9)
    # P(gang fails) >= max member P, <= sum of member Ps
    h = 3600.0
    pg = prob_fail_during(total, h)
    members = [prob_fail_during(l, h) for l in lams]
    assert pg >= max(members) - 1e-12
    assert pg <= min(sum(members), 1.0) + 1e-12


def test_makespan_monotone_in_lambda():
    C, work = 30.0, 50000.0
    m1 = expected_makespan_with_restarts(work, 1e-5, C)
    m2 = expected_makespan_with_restarts(work, 1e-4, C)
    assert m2 > m1 >= work
