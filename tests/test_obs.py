"""Observability layer (repro.obs): spans & traces, the typed engine
ledger, predicted-vs-actual attribution, and the exporters.

Pins the PR's contracts:

  * the span vocabulary is FROZEN — the literal tuple below must equal
    ``SPAN_SCHEMA`` exactly (this is also the span-parity lint rule's
    behavioural pin: every kind emitted in src appears here as a string
    literal);
  * tracing is zero-cost when disabled and bit-identical: the same seeded
    run with ``trace=`` on and off produces the same records and ledger;
  * the exported Chrome trace round-trips the conservation identity
    ``admitted == completed + lost + shed`` from the JSON alone, equal to
    the live :class:`EngineStats`;
  * exec spans are a lossless replay log: they reconstruct
    ``Engine(track_intervals=True).executed`` tuple-for-tuple, and
    replaying them onto a fresh cluster reproduces the occupancy tensor
    (property-tested over random churn schedules);
  * :class:`EngineStats` turns a misspelled counter into an immediate
    ``AttributeError`` (satellite-1 regression) and checks conservation
    in exactly one place.
"""
import json
import math

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.api import Orchestrator, make_policy, make_recovery
from repro.core.cluster import ClusterState, Device
from repro.core.dag import AppDAG, TaskSpec
from repro.core.interference import InterferenceModel
from repro.obs import (
    ENGINE_COUNTERS,
    EngineStats,
    SPAN_SCHEMA,
    Tracer,
    attribution_report,
    format_report,
    instance_breakdown,
    json_summary,
    ledger_from_trace,
    to_chrome_trace,
    validate_chrome_trace,
)
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.obs.tracing import FLEET_TID
from repro.sim import SimConfig, make_cluster, make_profile, run_one
from repro.sim.churn import ChurnSchedule, deterministic_churn
from repro.sim.engine import Engine
from repro.sim.runner import _make_workload, make_churn, policy_for

GB = 1e9
MB = 1e6


@pytest.fixture(scope="module")
def profile():
    return make_profile(seed=0)


def small_cluster(n=4, lam=1e-6, base=None, horizon=100.0):
    base = np.linspace(0.1, 0.4, n) if base is None else np.asarray(base)
    model = InterferenceModel(
        base=base[:, None], slope=np.full((n, 1, 1), 0.05)
    )
    devices = [
        Device(did=i, cls=i, mem_total=8 * GB, lam=lam,
               up_bw=100e6, down_bw=100e6)
        for i in range(n)
    ]
    return ClusterState(devices=devices, model=model, horizon=horizon, dt=0.05)


def chain_app(name="chain"):
    return AppDAG.from_tasks(name, [
        TaskSpec("a", ttype=0, out_bytes=1 * MB),
        TaskSpec("b", ttype=0, deps=("a",)),
    ])


# ------------------------------------------------------- the span schema --
# The frozen span vocabulary.  This literal tuple is load-bearing twice:
# it pins the schema against accidental edits, AND it is the test-suite
# string-literal pin the span-parity lint rule requires for every kind
# emitted in src (add a kind here + SPAN_SCHEMA + obs/README.md together).
SPAN_KINDS = (
    "instance",
    "admission_queue",
    "plan",
    "model_upload",
    "parent_transfer",
    "exec",
    "recovery_wait",
    "failover",
    "replan",
    "salvage",
    "shed",
    "device_down",
    "device_up",
)


def test_span_schema_is_frozen():
    assert tuple(SPAN_SCHEMA) == SPAN_KINDS
    assert all(isinstance(doc, str) and doc for doc in SPAN_SCHEMA.values())


# ------------------------------------------------------------ tracer unit --
def test_tracer_basic_lifecycle():
    tr = Tracer()
    tid = tr.begin_instance("app#0", 1.0, n_tasks=2)
    assert tid == 0 and tr.n_instances == 1
    sid = tr.open_span(tid, "exec", 1.5, name="a", device=3)
    tr.event(tid, "plan", 1.0, policy="ibdash")
    tr.close_span(sid, 2.5, outcome="ok")
    tr.end_instance(tid, 3.0, outcome="completed")
    tr.check_closed()                       # nothing dangling
    inst = tr.instance(tid)
    assert inst.closed and inst.dur == pytest.approx(2.0)
    assert inst.attrs["outcome"] == "completed"
    # spans_of excludes the envelope; by_kind finds the exec window
    assert [s.kind for s in tr.spans_of(tid)] == ["exec", "plan"]
    (ex,) = tr.by_kind("exec")
    assert (ex.t0, ex.t1, ex.attrs["outcome"]) == (1.5, 2.5, "ok")
    assert tr.outcome_counts() == {"completed": 1}


def test_tracer_rejects_unknown_kind_and_double_close():
    tr = Tracer()
    tid = tr.begin_instance("x", 0.0)
    with pytest.raises(ValueError, match="unknown span kind"):
        tr.event(tid, "not_a_kind", 0.0)
    sid = tr.open_span(tid, "exec", 0.0)
    tr.close_span(sid, 1.0)
    with pytest.raises(RuntimeError, match="closed twice"):
        tr.close_span(sid, 2.0)
    tr.end_instance(tid, 1.0, outcome="completed")
    with pytest.raises(RuntimeError, match="ended twice"):
        tr.end_instance(tid, 2.0, outcome="lost")


def test_check_closed_flags_dangling_spans():
    tr = Tracer()
    tid = tr.begin_instance("x", 0.0)
    tr.open_span(tid, "exec", 0.5)
    with pytest.raises(RuntimeError, match="still open"):
        tr.check_closed()


# -------------------------------------------- EngineStats (satellite-1) --
def test_engine_stats_typo_raises():
    """The regression this class exists for: a misspelled counter is an
    immediate AttributeError, not a silently minted dict key."""
    s = EngineStats()
    with pytest.raises(AttributeError):
        s.completd += 1                     # write typo
    with pytest.raises(AttributeError):
        _ = s.task_failover                 # read typo (singular)
    with pytest.raises(AttributeError):
        EngineStats(admited=3)              # constructor typo
    with pytest.raises(AttributeError):
        s["shedd"] = 1                      # mapping-style typo


def test_engine_stats_mapping_compat():
    s = EngineStats(admitted=3, completed=2, lost=1)
    assert s["admitted"] == 3 and "lost" in s and "nope" not in s
    assert len(s) == len(ENGINE_COUNTERS)
    assert tuple(s.keys()) == ENGINE_COUNTERS
    d = dict(s.items())
    assert d["completed"] == 2 and sum(d.values()) == 6
    assert s == d and s == EngineStats(**d)
    assert dict(s) == {k: s[k] for k in s}  # keys()/__getitem__ protocol
    assert "admitted=3" in repr(s)


def test_engine_stats_conservation():
    EngineStats(admitted=3, completed=1, lost=1, shed=1).check_conservation()
    with pytest.raises(RuntimeError, match="instance-counter drift"):
        EngineStats(admitted=3, completed=1).check_conservation()


def test_engine_stats_to_registry():
    s = EngineStats(admitted=5, completed=4, lost=1)
    reg = MetricsRegistry()
    s.to_registry(reg)
    assert reg.counter("engine_admitted").value == 5
    assert reg.counter("engine_lost").value == 1
    snap = reg.snapshot()
    assert set(snap["counters"]) == {"engine_" + k for k in ENGINE_COUNTERS}


def test_stream_metrics_shim_reexports():
    """repro.stream.metrics stays importable and IS the obs implementation."""
    from repro.stream import metrics as sm

    assert sm.MetricsRegistry is MetricsRegistry
    assert sm.Histogram is Histogram


# ---------------------------------------- histogram edges (satellite-3) --
def test_histogram_empty():
    h = Histogram("h")
    assert h.count == 0
    assert math.isnan(h.quantile(0.5))
    assert h.summary() == {"count": 0}


def test_histogram_single_sample():
    h = Histogram("h")
    h.observe(2.5)
    s = h.summary()
    assert s["count"] == 1
    # every quantile of a single observation is that observation
    assert s["p50"] == s["p99"] == s["p999"] == s["max"] == s["mean"] == 2.5


def test_histogram_all_duplicates():
    h = Histogram("h")
    for _ in range(100):
        h.observe(7.0)
    assert h.quantile(0.01) == h.quantile(0.999) == 7.0
    assert h.summary()["mean"] == 7.0


def test_histogram_p999_under_1000_samples():
    """With fewer than 1000 observations p999 interpolates toward the max
    — it must stay finite and inside [p99, max], never index out of
    range."""
    h = Histogram("h")
    for v in range(10):
        h.observe(float(v))
    s = h.summary()
    assert math.isfinite(s["p999"])
    assert s["p99"] <= s["p999"] <= s["max"] == 9.0


# --------------------------------------------- tracing the churn runtime --
def _traced_orchestrator(profile, scheme="ibdash"):
    """The acceptance scenario: correlated churn hot enough to lose
    instances + replan + salvage, intervals tracked, tracing on."""
    cfg = SimConfig(scenario="correlated_churn", n_cycles=2,
                    instances_per_cycle=60, seed=3, n_devices=12,
                    recovery="replan", salvage=2, shock_rate=0.2,
                    mean_downtime=30.0, gamma=1, max_retries=1)
    mk = lambda: make_cluster(profile, scenario="correlated_churn",
                              n_devices=12, seed=3,
                              horizon=cfg.horizon + 60.0)
    cluster = mk()
    churn = make_churn(cfg, cluster)
    orch = Orchestrator(cluster, policy_for(scheme, profile, cfg), seed=3,
                        churn=churn, recovery=cfg.recovery,
                        salvage=cfg.salvage,
                        detection_delay=cfg.detection_delay,
                        max_retries=cfg.max_retries,
                        track_intervals=True, trace=True)
    apps, times = _make_workload(cfg)
    orch.submit_batch(apps, times)
    orch.drain()
    return orch, cluster, mk


@pytest.fixture(scope="module")
def traced(profile):
    return _traced_orchestrator(profile)


def test_traced_run_covers_the_pipeline(traced):
    """The acceptance trace actually exercises the vocabulary: exec and
    plan everywhere, churn kills, recovery and salvage activity."""
    orch, _, _ = traced
    tr = orch.trace
    tr.check_closed()
    assert tr.n_instances == orch.stats["admitted"]
    kinds = {s.kind for s in tr.spans}
    assert {"instance", "plan", "exec", "model_upload", "parent_transfer",
            "device_down", "device_up", "recovery_wait", "replan",
            "salvage"} <= kinds
    # churn bites and the trace agrees with the counters about how hard
    assert orch.stats["lost"] > 0 and orch.stats["replans"] > 0
    assert orch.stats["salvages"] > 0
    assert len(tr.by_kind("replan")) == orch.stats["replans"]
    assert len(tr.by_kind("salvage")) == orch.stats["salvages"]
    assert len(tr.by_kind("device_down")) == orch.stats["device_down"]
    killed = [s for s in tr.by_kind("exec")
              if s.attrs["outcome"] == "killed"]
    assert killed and all(s.tid != FLEET_TID for s in killed)
    # fleet events belong to no instance
    assert all(s.tid == FLEET_TID for s in tr.by_kind("device_down"))


def test_trace_ledger_matches_engine_stats(traced):
    orch, _, _ = traced
    counts = orch.trace.outcome_counts()
    assert counts.get("completed", 0) == orch.stats["completed"]
    assert counts.get("lost", 0) == orch.stats["lost"]
    assert "open" not in counts


def test_exec_spans_carry_predicted_next_to_realized(traced):
    orch, _, _ = traced
    for s in orch.trace.by_kind("exec"):
        for key in ("pred_exec", "pred_upload", "pred_transfer",
                    "pred_fail", "real_exec", "sched_end", "device",
                    "tier", "ttype", "stage", "outcome"):
            assert key in s.attrs, f"exec span missing {key}"
        assert 0.0 <= s.attrs["pred_fail"] <= 1.0
        if s.attrs["outcome"] == "ok":
            # an ok replica ran exactly to its scheduled end
            assert s.t1 == pytest.approx(s.attrs["sched_end"])


def test_tracing_does_not_perturb_the_run(profile):
    """Bit-identical results with the tracer on and off — the observer
    effect the 'zero overhead when disabled' design rules out."""
    cfg = SimConfig(scenario="churn", n_cycles=1, instances_per_cycle=40,
                    seed=5, n_devices=16, recovery="failover")
    base = run_one("ibdash", cfg, profile)
    traced_res = run_one("ibdash", SimConfig(**{**cfg.__dict__, "trace": True}),
                         profile)
    assert traced_res.trace is not None
    assert base.trace is None
    assert [(r.app, r.finished, r.failed) for r in base.instances] == \
           [(r.app, r.finished, r.failed) for r in traced_res.instances]


def test_disabled_tracing_leaves_no_residue():
    """trace=None (the default): no tracer object, records keep the
    sentinel tid, and no span bookkeeping exists on the engine."""
    cluster = small_cluster()
    eng = Engine(cluster, make_policy("lavea"), noise_sigma=0.0)
    eng.add_arrivals([chain_app()], [0.0])
    eng.drain()
    assert eng.trace is None
    assert all(r.tid == -1 for r in eng.records)
    assert eng._span_of == {}


def test_infeasible_admission_is_traced_as_lost():
    """An instance rejected at planning still opens and closes a trace —
    the ledger must count it."""
    tr = Tracer()
    cluster = small_cluster(n=1)
    churn = deterministic_churn([(0.1, 0, "leave")])
    eng = Engine(cluster, make_policy("lavea"), noise_sigma=0.0,
                 churn=churn, trace=tr)
    eng.add_arrivals([chain_app()], [1.0])   # plans after the only device died
    eng.drain()
    assert eng.stats["lost"] == 1
    (inst,) = list(tr.instances())
    assert inst.attrs["outcome"] == "lost"
    assert inst.attrs["reason"] == "infeasible"
    assert tr.outcome_counts() == {"lost": 1}


# ------------------------------------------------- exec spans == executed --
def _executed_from_trace(tracer):
    """Rebuild the engine's executed-interval log from exec spans alone."""
    return sorted(
        (int(s.attrs["device"]), int(s.attrs["ttype"]), s.t0,
         float(s.attrs["sched_end"]), s.t1)
        for s in tracer.by_kind("exec")
    )


def _rebuild_alloc(cluster_factory, executed):
    c = cluster_factory()
    for did, ttype, t0, t1, t_cut in executed:
        c.add_interval(did, ttype, t0, t1)
        if t_cut < t1:
            c.cancel_from(did, ttype, t0, t1, t_cut)
    return c.alloc


def test_exec_spans_reconstruct_executed_log(traced):
    """Satellite-6 (acceptance half): under correlated churn + salvage the
    exec spans ARE the executed-interval log — tuple for tuple — and
    replaying them onto a fresh cluster reproduces the occupancy tensor
    that ``track_intervals=True`` accumulated."""
    orch, cluster, mk = traced
    eng = orch.engine
    recon = _executed_from_trace(orch.trace)
    assert recon == sorted(eng.executed)
    assert np.array_equal(np.asarray(cluster.alloc),
                          _rebuild_alloc(mk, recon))


@settings(max_examples=15, deadline=None, derandomize=True)
@given(
    deaths=st.lists(
        st.tuples(
            st.floats(min_value=0.05, max_value=20.0),
            st.integers(min_value=0, max_value=3),
            st.one_of(st.none(), st.floats(min_value=0.3, max_value=4.0)),
        ),
        min_size=1, max_size=5,
    ),
    recovery=st.sampled_from(["fail_fast", "failover", "replan"]),
)
def test_exec_spans_replay_property(deaths, recovery):
    """Satellite-6 (property half): for ANY churn schedule and recovery
    mode, exec spans reproduce ``engine.executed`` exactly."""
    events = []
    for t, did, rejoin_after in deaths:
        events.append((t, did, "leave"))
        if rejoin_after is not None:
            events.append((t + rejoin_after, did, "join"))
    schedule = deterministic_churn(events)
    apps = [chain_app(f"#{i}") for i in range(4)]
    times = [5.0 * i for i in range(4)]
    tr = Tracer()
    mk = lambda: small_cluster(base=[0.3, 0.32, 0.34, 0.36], lam=1e-4)
    cluster = mk()
    eng = Engine(cluster, make_policy("lavea"), noise_sigma=0.0,
                 churn=ChurnSchedule(schedule.events),
                 recovery=make_recovery(recovery, detection_delay=0.1),
                 track_intervals=True, trace=tr)
    eng.add_arrivals(apps, times)
    eng.drain()
    tr.check_closed()
    recon = _executed_from_trace(tr)
    assert recon == sorted(eng.executed)
    assert np.array_equal(np.asarray(cluster.alloc),
                          _rebuild_alloc(mk, recon))


# -------------------------------------------------------------- exporters --
def test_chrome_trace_round_trips_the_ledger(traced, tmp_path):
    """The acceptance check: the exported trace_event JSON is structurally
    valid AND the conservation ledger recomputed from the file alone
    equals the live engine counters."""
    orch, _, _ = traced
    path = tmp_path / "trace.json"
    doc = to_chrome_trace(orch.trace, path=str(path))
    n = validate_chrome_trace(doc)
    assert n == len(doc["traceEvents"]) > 0
    # byte round-trip through disk, strict JSON (no NaN/Infinity tokens)
    text = path.read_text()
    assert "NaN" not in text and "Infinity" not in text
    led = ledger_from_trace(json.loads(text))
    assert led["admitted"] == orch.stats["admitted"]
    assert led["completed"] == orch.stats["completed"]
    assert led["lost"] == orch.stats["lost"]
    assert led["shed"] == orch.stats["shed"]
    assert led["admitted"] == led["completed"] + led["lost"] + led["shed"]


def test_chrome_trace_structure(traced):
    orch, _, _ = traced
    ev = to_chrome_trace(orch.trace)["traceEvents"]
    pids = {e["pid"] for e in ev}
    assert pids == {0, 1}                    # instances + devices
    process_names = {e["args"]["name"] for e in ev
                     if e["ph"] == "M" and e["name"] == "process_name"}
    assert process_names == {"instances", "devices"}
    # every exec window sits on its device's row with a flow stitch back
    execs = [e for e in ev if e.get("cat") == "exec" and e["ph"] == "X"]
    assert execs and all(e["pid"] == 1 for e in execs)
    flows = {(e["ph"], e["pid"]) for e in ev if e.get("cat") == "flow"}
    assert ("s", 0) in flows and ("t", 1) in flows
    # churn instants land on device rows
    churn_ev = [e for e in ev if e.get("cat") == "churn"]
    assert churn_ev and all(e["pid"] == 1 and e["ph"] == "i"
                            for e in churn_ev)


def test_export_refuses_open_spans():
    tr = Tracer()
    tid = tr.begin_instance("x", 0.0)
    tr.open_span(tid, "exec", 0.5)
    with pytest.raises(ValueError, match="drain the engine"):
        to_chrome_trace(tr)


def test_ledger_from_trace_rejects_missing_outcome():
    doc = {"traceEvents": [{"name": "i0", "cat": "instance", "ph": "X",
                            "pid": 0, "tid": 0, "ts": 0, "dur": 1,
                            "args": {}}]}
    with pytest.raises(ValueError, match="no terminal outcome"):
        ledger_from_trace(doc)


def test_json_summary(traced, tmp_path):
    orch, _, _ = traced
    reg = MetricsRegistry()
    orch.stats.to_registry(reg)
    path = tmp_path / "summary.json"
    out = json_summary(orch.trace, registry=reg, path=str(path))
    assert out["n_instances"] == orch.stats["admitted"]
    assert out["spans_by_kind"]["exec"] == len(orch.trace.by_kind("exec"))
    on_disk = json.loads(path.read_text())
    assert on_disk["ledger"] == out["ledger"]
    assert on_disk["metrics"]["counters"]["engine_lost"] == orch.stats["lost"]


# ------------------------------------------------------------ attribution --
def _hand_trace():
    """A trace with known arithmetic: 1 s queue, two overlapping execs
    (union 3 s), a recovery wait, 1 s unexplained stall."""
    tr = Tracer()
    tid = tr.begin_instance("app", 1.0)
    tr.add_span(tid, "admission_queue", 0.0, 1.0, slo="best_effort")
    tr.event(tid, "plan", 1.0, policy="p", pred_latency=4.0, pred_fail=0.1)
    tr.add_span(tid, "exec", 1.0, 3.0, name="a", device=0, tier=0, stage=0,
                pred_exec=1.8, pred_upload=0.0, pred_transfer=0.0,
                pred_fail=0.05, sched_end=3.0, outcome="ok")
    tr.add_span(tid, "exec", 2.0, 4.0, name="a", device=1, tier=1, stage=0,
                pred_exec=2.1, pred_upload=0.0, pred_transfer=0.0,
                pred_fail=0.20, sched_end=4.0, outcome="dead")
    tr.add_span(tid, "recovery_wait", 4.0, 4.5, name="a")
    tr.add_span(tid, "exec", 4.5, 5.0, name="b", device=0, tier=0, stage=1,
                pred_exec=0.6, pred_upload=0.0, pred_transfer=0.0,
                pred_fail=0.05, sched_end=5.0, outcome="ok")
    tr.end_instance(tid, 6.0, outcome="completed")
    return tr, tid


def test_instance_breakdown_arithmetic():
    tr, tid = _hand_trace()
    b = instance_breakdown(tr, tid)
    assert b["arrival"] == 0.0                # true arrival = queue start
    assert b["e2e"] == pytest.approx(6.0)
    assert b["queue_wait"] == pytest.approx(1.0)
    assert b["exec_busy"] == pytest.approx(3.5)   # [1,4] u [4.5,5]
    assert b["recovery_wait"] == pytest.approx(0.5)
    assert b["stall"] == pytest.approx(1.0)       # 6 - 1 - 3.5 - 0.5
    assert set(b["stages"]) == {0, 1}
    s0 = b["stages"][0]
    assert s0["n_replicas"] == 2 and s0["critical_device"] == 1
    assert s0["wall"] == pytest.approx(3.0)


def test_calibration_rows():
    from repro.obs.attribution import calibration

    tr, _ = _hand_trace()
    cal = calibration(tr)
    pol = cal["policy"]["p"]
    assert pol["latency"]["n"] == 1
    # e2e from engine arrival (1.0) to end (6.0) = 5.0 vs predicted 4.0
    assert pol["latency"]["real_mean"] == pytest.approx(5.0)
    assert pol["latency"]["bias"] == pytest.approx(1.0)
    assert pol["p_fail"]["empirical"] == 0.0
    # device 1's only replica died -> empirical death rate 1.0
    assert cal["device"]["1"]["p_fail"]["empirical"] == pytest.approx(1.0)
    assert cal["device"]["0"]["p_fail"]["empirical"] == pytest.approx(0.0)
    # duration rows compare pred sum vs realized window
    assert cal["tier"]["0"]["duration"]["n"] == 2
    assert cal["tier"]["0"]["duration"]["pred_mean"] == pytest.approx(1.2)
    assert cal["tier"]["0"]["duration"]["real_mean"] == pytest.approx(1.25)


def test_attribution_report_on_traced_run(traced):
    orch, _, _ = traced
    rep = attribution_report(orch.trace, top_k=3)
    assert rep["ledger"].get("completed", 0) == orch.stats["completed"]
    cp = rep["critical_path"]
    assert cp["n"] == orch.stats["completed"]
    for f in ("e2e", "queue_wait", "exec_busy", "upload_total",
              "transfer_total", "recovery_wait", "stall"):
        assert math.isfinite(cp[f + "_mean"]) and cp[f + "_mean"] >= 0.0
    # the per-stage decomposition never exceeds e2e on any slow offender
    for b in rep["slow"]:
        assert b["queue_wait"] + b["exec_busy"] + b["recovery_wait"] + \
               b["stall"] <= b["e2e"] + 1e-9
    # lost report names the devices whose deaths sank the instance
    assert rep["lost"] and all(b["replica_deaths"] >= 0 for b in rep["lost"])
    assert "ibdash" in rep["calibration"]["policy"]
    text = format_report(rep)
    assert "instance ledger" in text and "calibration: policy" in text
    assert "ibdash" in text


# ------------------------------------------------------- stream tracing --
def test_stream_run_traces_admission(profile):
    """The stream scenario end-to-end with tracing: admission-queue spans
    on dispatched instances, shed instances traced and counted, and the
    exported ledger equal to the engine's, shed included."""
    cfg = SimConfig(scenario="stream", n_cycles=1, cycle_len=6.0,
                    seed=2, n_devices=8, stream_rate=80.0,
                    stream_queue_cap=24, trace=True)
    res = run_one("ibdash", cfg, profile)
    tr = res.trace
    assert tr is not None
    counts = tr.outcome_counts()
    shed = counts.get("shed", 0)
    assert shed > 0, "queue cap chosen to force shedding"
    assert shed == sum(1 for s in tr.by_kind("shed"))
    queue_spans = tr.by_kind("admission_queue")
    assert queue_spans, "dispatched instances carry queue spans"
    assert all(s.dur >= 0.0 for s in queue_spans)
    doc = to_chrome_trace(tr)
    validate_chrome_trace(doc)
    led = ledger_from_trace(doc)
    assert led["shed"] == shed
    assert led["admitted"] == led["completed"] + led["lost"] + led["shed"]
    # the unified registry carries the engine ledger next to service series
    snap = res.stream.metrics
    assert snap["counters"]["engine_admitted"] == led["admitted"]
    assert snap["counters"]["engine_shed"] == led["shed"]
