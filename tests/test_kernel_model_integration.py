"""Model-level kernel integration: attention_impl="kernel_interpret" must
reproduce the XLA path exactly (the TPU deployment path, validated on CPU
via Pallas interpret mode)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import LM, reduced

RNG = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ["olmo-1b", "minitron-8b", "recurrentgemma-9b"])
def test_kernel_attention_matches_xla(arch):
    # S=128 so the kernel's 128-aligned fast path triggers
    B, S = 1, 128
    cfg = reduced(get_config(arch), dtype="float32")
    cfg_k = dataclasses.replace(cfg, attention_impl="kernel_interpret")
    model_x, model_k = LM(cfg), LM(cfg_k)
    params = model_x.init(RNG)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    hx, _, _ = model_x.backbone(params, toks, pos)
    hk, _, _ = model_k.backbone(params, toks, pos)
    np.testing.assert_allclose(np.asarray(hx), np.asarray(hk), atol=5e-4, rtol=5e-4)


def test_kernel_rwkv_matches_xla():
    B, S = 1, 64
    cfg = reduced(get_config("rwkv6-3b"), dtype="float32")
    cfg_k = dataclasses.replace(cfg, attention_impl="kernel_interpret")
    model_x, model_k = LM(cfg), LM(cfg_k)
    params = model_x.init(RNG)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    hx, _, _ = model_x.backbone(params, toks, pos)
    hk, _, _ = model_k.backbone(params, toks, pos)
    np.testing.assert_allclose(np.asarray(hx), np.asarray(hk), atol=2e-3, rtol=2e-3)


def test_kernel_loss_gradients_flow():
    cfg = reduced(get_config("olmo-1b"), dtype="float32")
    cfg = dataclasses.replace(cfg, attention_impl="kernel_interpret")
    model = LM(cfg)
    params = model.init(RNG)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (1, 128), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(3), (1, 128), 0, cfg.vocab),
    }
    loss, _ = model.loss(params, batch)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
