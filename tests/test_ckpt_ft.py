"""Checkpointing (atomicity, replication, corruption recovery) + FT runtime."""
import os
import shutil

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.ckpt.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.ft.runtime import FleetMonitor, plan_remesh
from repro.ft.straggler import StragglerMitigator


@pytest.fixture
def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones((5,)) * 7}}


def test_save_restore_roundtrip(tmp_path, tree):
    save_checkpoint(str(tmp_path), tree, step=3)
    back, step, _ = load_checkpoint([str(tmp_path)], tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(back["b"]["c"]), np.asarray(tree["b"]["c"]))


def test_injected_clock_makes_manifests_deterministic(tmp_path, tree):
    """The manifest timestamp comes from an injectable clock (the
    rng-discipline contract: no bare wall-clock reads in src/repro), so
    two saves under a fixed clock are bit-identical."""
    import json

    d = save_checkpoint(str(tmp_path / "a"), tree, step=1, clock=lambda: 123.5)
    with open(os.path.join(d, "manifest.json")) as f:
        assert json.load(f)["time"] == 123.5

    mgr = CheckpointManager(
        replica_dirs=[str(tmp_path / "r0"), str(tmp_path / "r1")],
        clock=lambda: 7.25,
    )
    mgr.save(tree, step=2)
    for root in mgr.replica_dirs:
        with open(os.path.join(root, "step_00000002", "manifest.json")) as f:
            assert json.load(f)["time"] == 7.25


def test_newest_valid_wins(tmp_path, tree):
    save_checkpoint(str(tmp_path), tree, step=1)
    t2 = {"a": tree["a"] + 1, "b": tree["b"]}
    save_checkpoint(str(tmp_path), t2, step=2)
    back, step, _ = load_checkpoint([str(tmp_path)], tree)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(t2["a"]))


def test_corrupted_replica_skipped(tmp_path, tree):
    d1, d2 = str(tmp_path / "r1"), str(tmp_path / "r2")
    save_checkpoint(d1, tree, step=5)
    save_checkpoint(d2, tree, step=5)
    # corrupt the newer-listed replica's arrays
    victim = os.path.join(d1, "step_00000005", "arrays.npz")
    with open(victim, "r+b") as f:
        f.seek(200)
        f.write(b"\x00" * 64)
    back, step, _ = load_checkpoint([d1, d2], tree)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))


def test_all_corrupt_raises(tmp_path, tree):
    d1 = str(tmp_path / "r1")
    save_checkpoint(d1, tree, step=1)
    shutil.rmtree(os.path.join(d1, "step_00000001"))
    with pytest.raises(FileNotFoundError):
        load_checkpoint([d1], tree)


def test_shape_mismatch_rejected(tmp_path, tree):
    save_checkpoint(str(tmp_path), tree, step=1)
    other = {"a": jnp.zeros((2, 2)), "b": {"c": jnp.zeros((5,))}}
    with pytest.raises(FileNotFoundError):
        load_checkpoint([str(tmp_path)], other)


def test_manager_replication_and_gc(tmp_path, tree):
    dirs = [str(tmp_path / f"r{i}") for i in range(3)]
    mgr = CheckpointManager(replica_dirs=dirs, keep=2, async_save=False)
    for s in (1, 2, 3):
        mgr.save(tree, s)
    for d in dirs:
        steps = sorted(os.listdir(d))
        assert steps == ["step_00000002", "step_00000003"]
    back, step, _ = mgr.restore(tree)
    assert step == 3


def test_manager_async(tmp_path, tree):
    mgr = CheckpointManager(replica_dirs=[str(tmp_path)], async_save=True)
    mgr.save(tree, 1)
    mgr.wait()
    _, step, _ = mgr.restore(tree)
    assert step == 1


def test_young_daly_interval_scales(tmp_path):
    flaky = CheckpointManager(replica_dirs=[str(tmp_path)], fleet_lams=[1e-3] * 8)
    solid = CheckpointManager(replica_dirs=[str(tmp_path)], fleet_lams=[1e-7] * 8)
    assert flaky.interval < solid.interval


# ---------------------------------------------------------------- FT runtime --
def test_monitor_detects_silent_departure():
    mon = FleetMonitor(timeout=10.0)
    mon.join("a", now=0.0)
    mon.join("b", now=0.0)
    for t in (5.0, 10.0, 15.0):
        mon.heartbeat("a", now=t)
    dead = mon.sweep(now=15.0)
    assert dead == ["b"]
    assert mon.alive_pods() == ["a"]


def test_monitor_lambda_estimate():
    mon = FleetMonitor(timeout=5.0)
    rng = np.random.default_rng(0)
    lam = 1e-2
    t = 0.0
    for i in range(200):
        mon.join(f"p{i}", cls="spot", now=0.0)
    deaths = rng.exponential(1 / lam, 200)
    for t in np.arange(1.0, 120.0, 1.0):
        for i in range(200):
            if deaths[i] > t:
                mon.heartbeat(f"p{i}", now=float(t))
        mon.sweep(now=float(t))
    assert mon.lam("spot") == pytest.approx(lam, rel=0.4)


def test_remesh_plan_properties():
    alive = [f"p{i:02d}" for i in range(13)]
    plan = plan_remesh(alive, model_parallel=4, prev_data_parallel=4)
    assert plan.mesh_shape == (3, 4)
    assert len(plan.assignment) == 12
    assert len(plan.dropped_pods) == 1
    coords = [c for _, c in plan.assignment]
    assert len(set(coords)) == len(coords)          # bijective
    assert plan.batch_reshard


def test_remesh_insufficient_pods():
    with pytest.raises(ValueError):
        plan_remesh(["a", "b"], model_parallel=4)


def _check_remesh_plan(n_alive, mp):
    alive = [f"p{i:03d}" for i in range(n_alive)]
    plan = plan_remesh(alive, model_parallel=mp)
    data, model = plan.mesh_shape
    assert model == mp
    assert data * model <= n_alive
    assert data * model + len(plan.dropped_pods) == n_alive
    # deterministic: same input -> same plan
    assert plan == plan_remesh(list(reversed(alive)), model_parallel=mp)


def test_remesh_plan_invariants_examples():
    for n_alive, mp in [(4, 1), (5, 2), (8, 4), (17, 2), (64, 4)]:
        _check_remesh_plan(n_alive, mp)


@given(n_alive=st.integers(4, 64), mp=st.sampled_from([1, 2, 4]))
@settings(max_examples=40, deadline=None)
def test_remesh_plan_invariants(n_alive, mp):
    _check_remesh_plan(n_alive, mp)


def test_straggler_backup_on_flaky_primary():
    mit = StragglerMitigator(beta=0.01, gamma=2)
    d = mit.decide([100.0, 105.0, 110.0], [5e-3, 1e-7, 1e-7])
    assert d.primary == 0                      # fastest
    assert len(d.backups) >= 1                 # but flaky -> backup launched
    assert d.pred_fail < 0.05


def test_straggler_no_backup_when_reliable():
    mit = StragglerMitigator(beta=0.05, gamma=2)
    d = mit.decide([100.0, 105.0], [1e-9, 1e-9])
    assert d.backups == ()
