"""The redesigned pure policy API: registry, two-phase plan/apply/undo,
bit-parity with the seed schedulers, and the online Orchestrator façade."""
from collections import OrderedDict

import numpy as np
import pytest

import _legacy_reference as legacy
from repro.api import Orchestrator, make_policy, orchestrate
from repro.core.cluster import ClusterState, Device
from repro.core.dag import AppDAG, TaskSpec
from repro.core.interference import InterferenceModel
from repro.core.orchestrator import Plan, Placement, Replica, TaskPlacement
from repro.core.policy import (
    Policy,
    PolicyContext,
    TaskDecision,
    available_policies,
    register_policy,
)
from repro.sim import SimConfig, make_cluster, make_profile
from repro.sim.runner import SCHEME_NAMES, _make_workload, policy_for

GB = 1e9
MB = 1e6


@pytest.fixture(scope="module")
def profile():
    return make_profile(seed=0)


def small_cluster(n=4, lam=1e-6, mem=8 * GB, bw=100e6):
    model = InterferenceModel(
        base=np.linspace(0.1, 0.4, n)[:, None],
        slope=np.full((n, 1, 1), 0.05),
    )
    devices = [
        Device(did=i, cls=i, mem_total=mem, lam=lam, up_bw=bw, down_bw=bw)
        for i in range(n)
    ]
    return ClusterState(devices=devices, model=model, horizon=100.0, dt=0.05)


def chain_app(model_id=None, model_bytes=0.0):
    return AppDAG.from_tasks("app", [
        TaskSpec("a", ttype=0, out_bytes=5 * MB, model_id=model_id,
                 model_bytes=model_bytes),
        TaskSpec("b", ttype=0, deps=("a",), model_id=model_id,
                 model_bytes=model_bytes),
    ])


# ---------------------------------------------------------------- registry --
def test_registry_has_all_six_schemes():
    assert set(SCHEME_NAMES) <= set(available_policies())


def test_make_policy_unknown_name():
    with pytest.raises(ValueError, match="unknown policy"):
        make_policy("definitely-not-a-policy")


def test_make_policy_uniform_kwarg_bundle(profile):
    # one kwarg bundle constructs every scheme; extras are ignored
    for name in SCHEME_NAMES:
        pol = make_policy(
            name, alpha=0.3, beta=0.05, gamma=2, seed=7,
            lats_model=profile.lats_model,
        )
        assert pol.name == name
    ib = make_policy("ibdash", alpha=0.3, beta=0.05, gamma=2, seed=7)
    assert (ib.cfg.alpha, ib.cfg.beta, ib.cfg.gamma) == (0.3, 0.05, 2)


def test_register_policy_duplicate_rejected():
    with pytest.raises(ValueError, match="already registered"):

        @register_policy("ibdash")
        class Dup(Policy):
            pass


def test_custom_policy_pluggable():
    # a 3-line user policy slots straight into orchestrate()
    class Slowest(Policy):
        name = "slowest"

        def decide(self, ctx: PolicyContext) -> TaskDecision:
            ids = ctx.feasible_ids
            return TaskDecision(devices=(int(ids[np.argmax(ctx.total[ids])]),))

    cluster = small_cluster()
    plan = orchestrate(chain_app(), cluster, 0.0, Slowest())
    assert plan.feasible
    assert plan.tasks["a"].replicas[0].did == 3        # base 0.4 is slowest


# ------------------------------------------------------- plan / apply / undo --
def snapshot(cluster):
    return (
        cluster.alloc.copy(),
        [(d.mem_free, OrderedDict(d.model_cache)) for d in cluster.devices],
    )


def state_equal(cluster, snap):
    alloc, devs = snap
    if not np.array_equal(cluster.alloc, alloc):
        return False
    for d, (mem_free, cache) in zip(cluster.devices, devs):
        if d.mem_free != mem_free:
            return False
        if list(d.model_cache.items()) != list(cache.items()):
            return False
    return True


def test_plan_is_pure():
    cluster = small_cluster()
    before = snapshot(cluster)
    plan = orchestrate(chain_app(model_id="m", model_bytes=200 * MB),
                       cluster, 0.0, make_policy("ibdash"))
    assert plan.feasible
    assert state_equal(cluster, before)


def test_apply_undo_roundtrips_exactly():
    cluster = small_cluster()
    # pre-existing cache content so undo must restore LRU order, not just size
    cluster.devices[0].admit_model("old-a", 100 * MB)
    cluster.devices[0].admit_model("old-b", 100 * MB)
    cluster.devices[0].touch_model("old-a")
    before = snapshot(cluster)

    plan = orchestrate(chain_app(model_id="m", model_bytes=500 * MB),
                       cluster, 0.0, make_policy("ibdash"))
    token = cluster.apply(plan)
    assert token.applied
    assert not state_equal(cluster, before)             # intervals + model admitted
    cluster.undo(token)
    assert state_equal(cluster, before)                 # alloc tensor + caches exact
    cluster.undo(token)                                 # idempotent
    assert state_equal(cluster, before)


def test_apply_restores_lru_eviction_on_undo():
    # tiny device: admitting the new model evicts the resident one; undo must
    # bring the evicted model back in its original order
    cluster = small_cluster(mem=1 * GB)
    dev = cluster.devices[0]
    dev.admit_model("resident", 800 * MB)
    before = snapshot(cluster)

    app = AppDAG.from_tasks("app", [TaskSpec(
        "t", ttype=0, model_id="big", model_bytes=900 * MB,
    )])
    # force placement onto device 0
    class Pin(Policy):
        name = "pin"

        def decide(self, ctx):
            return TaskDecision(devices=(0,))

    plan = orchestrate(app, cluster, 0.0, Pin())
    token = cluster.apply(plan)
    assert "resident" not in dev.model_cache and "big" in dev.model_cache
    cluster.undo(token)
    assert state_equal(cluster, before)


def test_apply_surfaces_unfittable_model():
    # A model larger than the device's total memory cannot be admitted even
    # after full LRU eviction; apply must roll back and mark the plan
    # infeasible instead of silently pretending the model is cached.
    cluster = small_cluster(mem=1 * GB)
    app = AppDAG.from_tasks("app", [TaskSpec(
        "t", ttype=0, model_id="huge", model_bytes=2 * GB,
    )])
    placement = Placement(
        app_name="app",
        tasks={"t": TaskPlacement(
            task="t", ttype=0,
            replicas=[Replica(did=1, est_exec=0.2, est_upload=1.0,
                              est_transfer=0.0, pred_fail=0.0)],
            est_start=0.0, est_latency=1.2,
        )},
        est_latency=1.2,
    )
    before = snapshot(cluster)
    token = cluster.apply(Plan(app=app, now=0.0, placement=placement))
    assert not token.applied
    assert not placement.feasible and placement.infeasible_task == "t"
    assert state_equal(cluster, before)                 # fully rolled back


def test_infeasible_plan_apply_is_noop():
    cluster = small_cluster(mem=1 * GB)
    app = AppDAG.from_tasks("app", [TaskSpec("t", ttype=0, mem_bytes=2 * GB)])
    plan = orchestrate(app, cluster, 0.0, make_policy("ibdash"))
    assert not plan.feasible and plan.placement.infeasible_task == "t"
    before = snapshot(cluster)
    token = cluster.apply(plan)
    assert not token.applied
    assert state_equal(cluster, before)


def test_speculative_what_if_sweep_leaves_state_intact():
    # alpha/gamma what-if: plan+apply+undo many variants, state must be
    # bit-identical afterwards, then the real apply still works
    cluster = small_cluster(lam=5e-1)
    app = chain_app(model_id="m", model_bytes=100 * MB)
    before = snapshot(cluster)
    est = {}
    for alpha in (0.0, 0.3, 0.7, 1.0):
        plan = orchestrate(app, cluster, 0.0,
                           make_policy("ibdash", alpha=alpha, beta=0.01))
        token = cluster.apply(plan)
        est[alpha] = (plan.est_latency, plan.placement.pred_app_fail)
        cluster.undo(token)
    assert state_equal(cluster, before)
    assert len({v for v in est.values()}) > 1           # sweep actually varied


# ------------------------------------------------------------------ parity --
def _same_placement(a, b):
    assert a.feasible == b.feasible
    assert a.infeasible_task == b.infeasible_task
    assert a.est_latency == b.est_latency
    assert set(a.tasks) == set(b.tasks)
    for k in a.tasks:
        ta, tb = a.tasks[k], b.tasks[k]
        assert [r.did for r in ta.replicas] == [r.did for r in tb.replicas]
        assert ta.est_start == tb.est_start
        assert ta.est_latency == tb.est_latency
        for ra, rb in zip(ta.replicas, tb.replicas):
            assert ra.est_exec == rb.est_exec
            assert ra.est_upload == rb.est_upload
            assert ra.est_transfer == rb.est_transfer
            assert ra.pred_fail == rb.pred_fail


def _uniform_bandwidth(cluster, bw=100e6):
    """Flatten the fleet's link rates to one symmetric value.

    The seed priced a transfer by the RECEIVER's bandwidth alone; since the
    tier-aware link-matrix fix (bw_eff[s, d] = min(up[s], down[d],
    backhaul)), heterogeneous-bandwidth fleets intentionally price the slow
    sender's uplink too, so bit-parity with the seed only holds where the
    two rules coincide — symmetric fleets (min(bw, bw) == bw).  Model-upload
    pricing is receiver-downlink either way and never diverges."""
    for d in cluster.devices:
        d.bandwidth = d.up_bw = d.down_bw = bw
    cluster.refresh_topology()
    return cluster


@pytest.mark.parametrize("scheme", SCHEME_NAMES)
@pytest.mark.parametrize("scenario", ("ced", "ped", "mix"))
def test_policy_parity_with_seed_scheduler(profile, scheme, scenario):
    """Registry policies reproduce the SEED's placements bit-for-bit on the
    (miniaturised) Fig. 8/9 grid — device ids, replica sets, latency
    estimates, and the full evolution of T_alloc + model caches — on a
    symmetric fleet (see _uniform_bandwidth: the link-matrix transfer fix
    deliberately reprices heterogeneous-bandwidth links)."""
    cfg = SimConfig(n_cycles=1, instances_per_cycle=60, scenario=scenario,
                    seed=0, n_devices=32)
    apps, times = _make_workload(cfg)
    mk = lambda: _uniform_bandwidth(make_cluster(
        profile, scenario=cfg.scenario, n_devices=cfg.n_devices,
        seed=cfg.seed, horizon=cfg.horizon + 30.0))
    c_old, c_new = mk(), mk()
    old = legacy.make_legacy_scheduler(
        scheme, lats_model=profile.lats_model, seed=cfg.seed,
        alpha=cfg.alpha, beta=cfg.beta, gamma=cfg.gamma,
    )
    pol = policy_for(scheme, profile, cfg)
    for app, t in zip(apps, times):
        p_old = old.place(app, c_old, t)                # seed: mutates inside
        plan = orchestrate(app, c_new, t, pol)          # new: pure + apply
        c_new.apply(plan)
        _same_placement(p_old, plan.placement)
    assert np.array_equal(c_old.alloc, c_new.alloc)
    for da, db in zip(c_old.devices, c_new.devices):
        assert da.mem_free == db.mem_free
        assert list(da.model_cache.items()) == list(db.model_cache.items())


def test_ibdash_replication_parity_flaky_fleet():
    """Replication loop parity on a fleet flaky enough to trigger it."""
    model = InterferenceModel(
        base=np.array([[0.1], [0.101], [0.102], [0.103]]),
        slope=np.full((4, 1, 1), 0.05),
    )
    mk = lambda: ClusterState(
        devices=[Device(did=i, cls=i, mem_total=8 * GB, lam=5e-1,
                        up_bw=100e6, down_bw=100e6) for i in range(4)],
        model=model, horizon=100.0, dt=0.05,
    )
    from repro.core.orchestrator import IBDASHConfig

    cfg = IBDASHConfig(alpha=0.2, beta=0.01, gamma=3)
    app = chain_app()
    c_old, c_new = mk(), mk()
    p_old = legacy.LegacyIBDASH(cfg).place(app, c_old, 0.0)
    plan = orchestrate(app, c_new, 0.0, make_policy("ibdash", config=cfg))
    c_new.apply(plan)
    assert len(p_old.tasks["a"].replicas) > 1           # replication happened
    _same_placement(p_old, plan.placement)


# ------------------------------------------------------------- orchestrator --
def test_orchestrator_online_submit_step_drain(profile):
    cfg = SimConfig(n_cycles=1, instances_per_cycle=40, scenario="ped", seed=1,
                    n_devices=16)
    cluster = make_cluster(profile, scenario=cfg.scenario,
                           n_devices=cfg.n_devices, seed=cfg.seed,
                           horizon=cfg.horizon + 30.0)
    apps, times = _make_workload(cfg)
    orch = Orchestrator(cluster, "ibdash", seed=cfg.seed)
    orch.submit_batch(apps, times)
    orch.step(until=0.75)                               # mid-burst
    assert 0 < len(orch.records) < len(apps)            # online, not batch
    orch.drain()
    assert len(orch.records) == len(apps)
    assert orch.pending_events == 0
    res = orch.result("ped", horizon=cfg.horizon)
    assert res.n == len(apps)
    assert all(np.isfinite(r.finished) for r in res.instances)


def test_midrun_result_is_nonmutating_snapshot(profile):
    """result() mid-run reports in-flight instances as failed-at-now without
    corrupting the live records — drain + final result stay correct."""
    cfg = SimConfig(n_cycles=1, instances_per_cycle=40, scenario="ped", seed=1,
                    n_devices=16)
    mk = lambda: make_cluster(profile, scenario=cfg.scenario,
                              n_devices=cfg.n_devices, seed=cfg.seed,
                              horizon=cfg.horizon + 30.0)
    apps, times = _make_workload(cfg)

    ref = Orchestrator(mk(), "ibdash", seed=cfg.seed)
    ref.submit_batch(apps, times)
    ref.drain()
    ref_res = ref.result("ped", horizon=cfg.horizon)

    orch = Orchestrator(mk(), "ibdash", seed=cfg.seed)
    orch.submit_batch(apps, times)
    orch.step(until=0.75)
    mid = orch.result("ped", horizon=cfg.horizon)       # snapshot mid-flight
    assert any(r.failed for r in mid.instances)         # in-flight reported
    orch.drain()
    res = orch.result("ped", horizon=cfg.horizon)
    assert res.prob_failure == ref_res.prob_failure
    assert res.avg_service_time == pytest.approx(ref_res.avg_service_time)


def test_engine_string_policy_uses_seed(profile):
    """Engine built with a policy *name* must honour its seed argument."""
    from repro.sim.engine import Engine

    a = Engine(small_cluster(), "random", seed=5)
    b = Engine(small_cluster(), "random", seed=5)
    c = Engine(small_cluster(), "random", seed=6)
    draws = lambda e: [int(e.policy.rng.integers(1000)) for _ in range(8)]
    da, db, dc = draws(a), draws(b), draws(c)
    assert da == db
    assert da != dc


def test_orchestrator_matches_run_one(profile):
    """run_one routes through the façade; driving it by hand is identical."""
    from repro.sim import run_one

    cfg = SimConfig(n_cycles=1, instances_per_cycle=60, scenario="mix", seed=2,
                    n_devices=24)
    ref = run_one("petrel", cfg, profile)

    cluster = make_cluster(profile, scenario=cfg.scenario,
                           n_devices=cfg.n_devices, seed=cfg.seed,
                           horizon=cfg.horizon + 30.0)
    orch = Orchestrator(cluster, policy_for("petrel", profile, cfg),
                        seed=cfg.seed, noise_sigma=cfg.noise_sigma)
    apps, times = _make_workload(cfg)
    orch.submit_batch(apps, times)
    orch.step(until=cfg.horizon + 25.0)
    res = orch.result(cfg.scenario, horizon=cfg.horizon)
    assert res.avg_service_time == pytest.approx(ref.avg_service_time)
    assert res.prob_failure == ref.prob_failure
    assert (res.load_per_device == ref.load_per_device).all()


def test_orchestrator_policy_name_construction():
    cluster = small_cluster()
    orch = Orchestrator(cluster, "round_robin")
    app = AppDAG.from_tasks("app", [TaskSpec("t", ttype=0)])
    dids = [orch.plan(app, now=0.0).tasks["t"].replicas[0].did
            for _ in range(4)]
    assert dids == [0, 1, 2, 3]                          # registry-built policy


def test_stage_context_reused_across_stage_tasks():
    """One T_alloc snapshot + one Eq.(1) vector per (stage, ttype), shared by
    every task in the stage (the burst-placement fast path)."""
    calls = []
    cluster = small_cluster()
    orig = cluster.model.estimate_devices

    def counting(classes, ttype, counts):
        calls.append(ttype)
        return orig(classes, ttype, counts)

    cluster.model.estimate_devices = counting
    app = AppDAG.from_tasks("app", [
        TaskSpec("a1", ttype=0), TaskSpec("a2", ttype=0),
        TaskSpec("a3", ttype=0),
        TaskSpec("b1", ttype=0, deps=("a1", "a2", "a3")),
    ])
    orchestrate(app, cluster, 0.0, make_policy("lavea"))
    # stage 0 has three type-0 tasks -> ONE estimate call; stage 1 -> one more
    assert calls == [0, 0]
