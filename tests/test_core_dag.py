"""DAG staging + validation, including hypothesis property tests."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.dag import AppDAG, TaskSpec, app_stage, topological_order, validate_dag


def _dag(edges, n):
    """Build an AppDAG over n tasks named t0..t{n-1} with dep edges (i->j)."""
    deps = {j: [] for j in range(n)}
    for i, j in edges:
        deps[j].append(f"t{i}")
    return AppDAG.from_tasks(
        "test", [TaskSpec(f"t{j}", ttype=0, deps=tuple(deps[j])) for j in range(n)]
    )


def test_linear_chain_stages():
    dag = _dag([(0, 1), (1, 2), (2, 3)], 4)
    assert dag.n_stages == 4
    assert [dag.stage_of[f"t{i}"] for i in range(4)] == [0, 1, 2, 3]


def test_diamond_stages():
    #   t0 -> t1, t0 -> t2, {t1,t2} -> t3
    dag = _dag([(0, 1), (0, 2), (1, 3), (2, 3)], 4)
    assert dag.stage_of["t0"] == 0
    assert dag.stage_of["t1"] == dag.stage_of["t2"] == 1
    assert dag.stage_of["t3"] == 2
    assert dag.stages[1] == ["t1", "t2"]


def test_longest_path_not_bfs_depth():
    # t0->t2 and t0->t1->t2: stage(t2) must be 2 (longest path), not 1
    dag = _dag([(0, 2), (0, 1), (1, 2)], 3)
    assert dag.stage_of["t2"] == 2


def test_cycle_detection():
    with pytest.raises(ValueError, match="cycle"):
        _dag([(0, 1), (1, 2), (2, 0)], 3)


def test_dangling_dep():
    with pytest.raises(ValueError, match="unknown task"):
        AppDAG.from_tasks("x", [TaskSpec("a", ttype=0, deps=("ghost",))])


def test_relabel_preserves_structure():
    dag = _dag([(0, 1), (1, 2)], 3)
    r = dag.relabel("#7")
    assert r.n_tasks == 3 and r.n_stages == 3
    assert "t1#7" in r.tasks and r.tasks["t2#7"].deps == ("t1#7",)


@st.composite
def random_dags(draw):
    n = draw(st.integers(2, 12))
    edges = []
    for j in range(1, n):
        # edges only i -> j with i < j: guaranteed acyclic
        parents = draw(st.lists(st.integers(0, j - 1), max_size=3, unique=True))
        edges.extend((i, j) for i in parents)
    return edges, n


@given(random_dags())
@settings(max_examples=60, deadline=None)
def test_staging_respects_dependencies(args):
    edges, n = args
    dag = _dag(edges, n)
    # property 1: every task is staged strictly after all its deps
    for t in dag.tasks.values():
        for d in t.deps:
            assert dag.stage_of[t.name] > dag.stage_of[d]
    # property 2: stage = length of longest path from a source
    for t in dag.tasks.values():
        if t.deps:
            assert dag.stage_of[t.name] == 1 + max(dag.stage_of[d] for d in t.deps)
        else:
            assert dag.stage_of[t.name] == 0
    # property 3: stages partition the tasks
    assert sorted(x for s in dag.stages for x in s) == sorted(dag.tasks)
    # property 4: topological order is consistent
    order = {name: i for i, name in enumerate(topological_order(dag.tasks))}
    for t in dag.tasks.values():
        for d in t.deps:
            assert order[d] < order[t.name]
