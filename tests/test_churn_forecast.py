"""Churn-aware planning: availability forecasts as a policy input,
correlated mass-departure churn, and partial-result salvage.

Pins the PR's contracts:
  * forecasts are EXACT for scripted schedules (maintenance windows,
    deterministic scripts, trace replays) and rate-extrapolated for
    stochastic ones; schedules built from raw events install none;
  * ``churn_aware`` never knowingly places a task whose estimated span
    crosses a maintenance window on a departing device while a feasible
    survivor exists (example-based + hypothesis-fuzzed over ANY window
    script), and its batched/scalar twins stay bit-identical with a
    forecast installed;
  * every stochastic generator draws each device's lifetimes from one
    ``(seed, did)``-keyed stream, so growing the fleet reshuffles nobody;
  * ``correlated_churn`` produces true mass departures (whole groups at one
    instant) and exports windows exactly / shocks as rates;
  * salvage re-submits a lost instance seeded with its completed stages
    (pinned, transfer-priced from the devices that hold the outputs), never
    re-runs a completed stage, and the T_alloc occupancy still nets to
    exactly the replay of actual execution spans under correlated churn +
    salvage, for every recovery strategy.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.api import Orchestrator, make_policy, orchestrate
from repro.core.availability import SurvivalForecast
from repro.core.cluster import ClusterState, Device
from repro.core.dag import AppDAG, TaskSpec
from repro.core.interference import InterferenceModel
from repro.ft.runtime import FleetMonitor
from repro.sim import SimConfig, make_cluster, make_profile, run_one
from repro.sim.churn import (
    ChurnSchedule,
    correlated_churn,
    deterministic_churn,
    device_groups,
    exponential_churn,
    maintenance_windows,
    periodic_windows,
    trace_churn,
)
from repro.sim.engine import Engine
from repro.sim.runner import _make_workload, make_churn, policy_for

GB = 1e9
MB = 1e6


@pytest.fixture(scope="module")
def profile():
    return make_profile(seed=0)


def small_cluster(n=4, lam=1e-6, base=None, horizon=100.0, bw=100e6):
    """n single-type devices, device i is class i (distinct base latency)."""
    base = np.linspace(0.3, 0.42, n) if base is None else np.asarray(base)
    model = InterferenceModel(
        base=base[:, None], slope=np.full((n, 1, 1), 0.05)
    )
    devices = [
        Device(did=i, cls=i, mem_total=8 * GB, lam=lam, up_bw=bw, down_bw=bw)
        for i in range(n)
    ]
    return ClusterState(devices=devices, model=model, horizon=horizon, dt=0.05)


def one_task_app(name="app"):
    return AppDAG.from_tasks(name, [TaskSpec("t0", ttype=0)])


def chain_app(name="chain"):
    return AppDAG.from_tasks(name, [
        TaskSpec("a", ttype=0, out_bytes=1 * MB),
        TaskSpec("b", ttype=0, deps=("a",)),
    ])


# ------------------------------------------------------- forecast semantics --
def test_survival_forecast_exact_and_stochastic():
    fc = SurvivalForecast(
        departures=((5.0,), (), (2.0, 9.0)),
        lams=(0.0, 0.1, 0.0),
        horizon=8.0, n_points=5,
    )
    # per-candidate spans: device 0 crosses its departure, 1 decays, 2's
    # NEXT departure after t=3 is 9.0 (the 2.0 one already passed)
    s = fc.survival(3.0, np.array([1.0, 1.0, 5.0]))
    assert s[0] == 1.0                       # 3 + 1 <= 5: survives exactly
    assert s[1] == pytest.approx(np.exp(-0.1))
    assert s[2] == 1.0                       # 3 + 5 = 8 <= 9
    s = fc.survival(3.0, np.array([2.5, 0.0, 6.5]))
    assert s[0] == 0.0                       # 3 + 2.5 > 5: crosses
    assert s[2] == 0.0                       # 3 + 6.5 > 9
    # sampled tensor: exact 0/1 cliffs on the grid
    grid = fc.grid()
    S = fc.sample(3.0)
    assert S.shape == (3, 5)
    assert np.array_equal(S[0], (3.0 + grid <= 5.0).astype(float))


def test_schedule_forecast_tensor_shapes_and_kinds():
    # scripted: exact cliffs, no stochastic decay
    sched = maintenance_windows([(10.0, 15.0, (0, 2))])
    F = sched.forecast(8.0, horizon=4.0, n_points=5, n_devices=3)
    assert F.shape == (3, 5)
    assert F[1].tolist() == [1.0] * 5        # never drained
    assert F[0].tolist() == [1.0, 1.0, 1.0, 0.0, 0.0]   # 8+3 > 10 crosses
    # stochastic: exp(-lam h) extrapolation, no cliffs
    cluster = small_cluster(n=3, lam=0.05)
    sched = exponential_churn(cluster, horizon=50.0, seed=1)
    F = sched.forecast(0.0, horizon=10.0, n_points=3, n_devices=3)
    assert np.allclose(F, np.exp(-0.05 * np.array([0.0, 5.0, 10.0]))[None, :])
    # raw event lists carry no forecast: uniform ones
    raw = ChurnSchedule(sched.events)
    assert (raw.forecast(0.0, n_devices=3) == 1.0).all()


def test_install_attaches_forecast_only_when_forecastable():
    cluster = small_cluster()
    deterministic_churn([(7.0, 2, "leave")]).install(cluster)
    assert cluster.forecast is not None
    assert cluster.forecast.departures[2] == (7.0,)
    # trace replays are scripted futures too
    cluster2 = small_cluster()
    trace_churn([(3.0, 1, False)]).install(cluster2)
    assert cluster2.forecast.departures[1] == (3.0,)
    # raw event schedules leave the cluster forecast-free
    cluster3 = small_cluster()
    ChurnSchedule(deterministic_churn([(7.0, 2, "leave")]).events).install(cluster3)
    assert cluster3.forecast is None


def test_monitor_forecast_extrapolates_mle():
    mon = FleetMonitor(timeout=2.0)
    for pid in ("p0", "p1", "p2", "p3"):
        mon.join(pid, cls="spot", now=0.0)
    for t in range(1, 11):
        for pid in ("p0", "p1"):
            mon.heartbeat(pid, now=float(t))
    mon.sweep(now=10.0)                      # p2/p3 dead -> lam = 2/20
    F = mon.forecast(["spot", "spot"], horizon=10.0, n_points=3)
    assert F.shape == (2, 3)
    assert np.allclose(F[0], np.exp(-0.1 * np.array([0.0, 5.0, 10.0])))
    # the forecaster slots straight onto a cluster
    cluster = small_cluster(n=2)
    cluster.install_forecast(mon.forecaster(["spot", "spot"]))
    assert cluster.snapshot(0.0).survival.shape == (2, 16)


# ------------------------------------------- churn_aware window avoidance --
def _assert_no_knowing_cross(windows, t_plan, n=4):
    """The property's checker: plan one task at ``t_plan`` under a scripted
    window schedule; churn_aware must not choose any device whose estimated
    span crosses its next window while a feasible survivor exists."""
    cluster = small_cluster(n=n)
    maintenance_windows(windows).install(cluster)
    pol = make_policy("churn_aware", alpha=0.4, beta=0.08, gamma=3)
    plan = orchestrate(one_task_app(), cluster, t_plan, pol)
    if not plan.feasible:
        return
    spans = cluster.estimate_exec(0, t_plan)     # no deps/models: total=exec
    surv = cluster.forecast.survival(t_plan, spans)
    survivors = cluster.alive_mask(t_plan) & (surv > 0.0)
    chosen = [r.did for r in plan.tasks["t0"].replicas]
    if survivors.any():
        assert all(survivors[d] for d in chosen), (
            f"churn_aware placed across a window: windows={windows} "
            f"t={t_plan} chosen={chosen} surv={surv}"
        )


def test_churn_aware_avoids_window_crossing_examples():
    # device spans here are ~0.3-0.45 s
    _assert_no_knowing_cross([(1.0, 5.0, (0,))], t_plan=0.8)       # 0 crosses
    _assert_no_knowing_cross([(1.0, 5.0, (0, 1))], t_plan=0.8)     # 0,1 cross
    _assert_no_knowing_cross([(1.0, 5.0, (0, 1, 2, 3))], t_plan=0.8)  # all do
    _assert_no_knowing_cross(
        [(0.5, 2.0, (0,)), (0.9, 1.5, (1, 2))], t_plan=0.35
    )
    _assert_no_knowing_cross([(10.0, 12.0, (0,))], t_plan=0.0)     # far away


def test_churn_aware_picks_best_survivor_not_doomed_fastest():
    """Device 0 is fastest but its window starts mid-span; the best
    NON-crossing device must win, and with every candidate crossing the
    plain latency order returns."""
    cluster = small_cluster(n=3, base=[0.30, 0.35, 0.40])
    maintenance_windows([(1.0, 4.0, (0,))]).install(cluster)
    pol = make_policy("churn_aware")
    plan = orchestrate(one_task_app(), cluster, 0.9, pol)   # 0.9+0.30 > 1.0
    assert plan.tasks["t0"].replicas[0].did == 1
    # planning earlier, the span fits before the window: 0 wins again
    plan = orchestrate(one_task_app(), cluster, 0.5, pol)
    assert plan.tasks["t0"].replicas[0].did == 0
    # everyone crosses: fall back to the plain IBDASH order
    cluster2 = small_cluster(n=3, base=[0.30, 0.35, 0.40])
    maintenance_windows([(1.0, 4.0, (0, 1, 2))]).install(cluster2)
    plan = orchestrate(one_task_app(), cluster2, 0.9, make_policy("churn_aware"))
    assert plan.tasks["t0"].replicas[0].did == 0


@st.composite
def window_cases(draw):
    windows = draw(st.lists(
        st.tuples(
            st.floats(min_value=0.05, max_value=4.0),     # start
            st.floats(min_value=0.1, max_value=5.0),      # duration
            st.lists(st.integers(min_value=0, max_value=3),
                     min_size=1, max_size=4, unique=True),
        ),
        min_size=1, max_size=4,
    ))
    t_plan = draw(st.floats(min_value=0.0, max_value=5.0))
    return windows, t_plan


@given(window_cases())
@settings(max_examples=60, deadline=None)
def test_property_churn_aware_never_knowingly_crosses(case):
    """Property: under ANY scripted maintenance-window schedule,
    churn_aware never places a task whose estimated span crosses a window
    on a departing device when a feasible survivor exists."""
    windows, t_plan = case
    _assert_no_knowing_cross(
        [(t0, t0 + dur, tuple(dids)) for t0, dur, dids in windows], t_plan
    )


def test_churn_aware_batched_scalar_parity_with_forecast():
    """The batched kernel path and the scalar loop stay bit-identical when
    a forecast is installed (pf column adjusted + survivor guard active)."""
    from repro.core.orchestrator import orchestrate_batch

    rng = np.random.default_rng(11)
    cluster = small_cluster(n=8)
    groups = device_groups(8, 2)
    windows = periodic_windows(groups, period=1.0, duration=0.4,
                               horizon=10.0, phase=0.3)
    maintenance_windows(windows).install(cluster)
    apps = [one_task_app(f"#{i}") for i in range(24)] + [
        chain_app(f"c#{i}") for i in range(12)
    ]
    times = list(rng.uniform(0.0, 3.0, len(apps)))
    kw = dict(alpha=0.4, beta=0.08, gamma=3)
    plans_b = orchestrate_batch(apps, cluster, make_policy("churn_aware", **kw),
                                times=times)
    plans_s = orchestrate_batch(apps, cluster, make_policy("churn_aware", **kw),
                                times=times, batched=False)
    for a, b in zip(plans_b, plans_s):
        assert a.feasible == b.feasible
        for k in a.tasks:
            assert ([r.did for r in a.tasks[k].replicas]
                    == [r.did for r in b.tasks[k].replicas])


# ------------------------------------------------------ keyed rng streams --
@pytest.mark.parametrize("gen", ("exponential", "correlated"))
def test_generators_keyed_per_device_rng(gen):
    """Satellite-3 regression: adding a device to the fleet must not
    reshuffle any existing device's lifetimes — every generator draws each
    device from one (seed, did)-keyed stream."""
    def build(n):
        cluster = small_cluster(n=n, lam=0.02, horizon=300.0)
        if gen == "exponential":
            return exponential_churn(cluster, horizon=200.0, seed=7)
        return correlated_churn(
            cluster, horizon=200.0, seed=7, n_groups=2, shock_rate=0.01,
        )
    small, big = build(4), build(5)
    ev_small = [(e.t, e.did, e.kind) for e in small.events]
    ev_big = [(e.t, e.did, e.kind) for e in big.events if e.did < 4]
    assert ev_small == ev_big
    assert any(e.did == 4 for e in big.events)   # the new device does churn


def test_exponential_and_correlated_share_individual_streams():
    """correlated_churn with shocks off IS exponential_churn (the two
    generators share the per-device stream contract)."""
    c1 = small_cluster(n=5, lam=0.02, horizon=300.0)
    c2 = small_cluster(n=5, lam=0.02, horizon=300.0)
    a = exponential_churn(c1, horizon=200.0, seed=3)
    b = correlated_churn(c2, horizon=200.0, seed=3, shock_rate=0.0)
    assert [(e.t, e.did, e.kind) for e in a.events] == \
           [(e.t, e.did, e.kind) for e in b.events]


# ------------------------------------------------------- correlated churn --
def test_correlated_churn_mass_departures_and_forecast():
    cluster = small_cluster(n=8, lam=1e-9, horizon=300.0)
    groups = device_groups(8, 2)
    windows = [(40.0, 45.0, groups[1])]
    sched = correlated_churn(
        cluster, horizon=100.0, seed=3, groups=groups, shock_rate=0.05,
        windows=windows,
    )
    # shared shocks: some instant where a whole group leaves together
    by_t = {}
    for e in sched.events:
        if e.kind == "leave":
            by_t.setdefault(e.t, []).append(e.did)
    mass = [sorted(v) for v in by_t.values() if len(v) > 1]
    assert mass, "no mass departures generated"
    for dids in mass:
        gids = {d % 2 for d in dids}
        assert len(gids) == 1, f"shock crossed groups: {dids}"
    # windows are exported exactly; shocks only as rates
    assert sched.known_departures == {d: (40.0,) for d in groups[1]}
    assert sched.forecast_lams == tuple([1e-9 + 0.05] * 8)
    # and the schedule drives the engine end to end
    sched.install(cluster)
    assert cluster.forecast is not None
    eng = Engine(cluster, make_policy("churn_aware"), churn=sched,
                 recovery="failover")
    eng.add_arrivals([one_task_app()], [0.0])
    eng.drain()
    assert len(eng.records) == 1


def test_correlated_scenario_run_one(profile):
    """SimConfig(scenario="correlated_churn") runs through run_one for both
    ibdash and churn_aware, salvage included; the forecast-aware planner is
    no worse on failures on the seeded workload."""
    cfg = SimConfig(scenario="correlated_churn", n_cycles=2,
                    instances_per_cycle=80, seed=3, n_devices=32, salvage=1)
    res_ib = run_one("ibdash", cfg, profile)
    res_ca = run_one("churn_aware", cfg, profile)
    assert res_ib.n == res_ca.n == 160
    assert res_ca.prob_failure <= res_ib.prob_failure
    for res in (res_ib, res_ca):
        assert all(r.failed or np.isfinite(r.service_time)
                   for r in res.instances)


# ---------------------------------------------------------------- salvage --
def _guard_no_rerun(eng):
    """Instrument an engine so starting an already-completed task fails the
    test on the spot — 'salvage never re-runs a completed stage'."""
    orig = eng._start_task

    def spy(run, tname):
        assert not run.done.get(tname, False), (
            f"completed task {tname} was re-run"
        )
        return orig(run, tname)

    eng._start_task = spy
    return eng


def test_salvage_resubmits_with_completed_stages_pinned():
    """Stage a completes on device 0, then device 0 dies mid-b: fail_fast
    alone loses the instance; with salvage the instance is re-planned with
    a pinned — never re-run — and b's transfer priced from a's device."""
    app = chain_app()
    outcomes = {}
    for salvage in (0, 1):
        cluster = small_cluster(base=[0.3, 0.32, 0.34, 0.36], lam=1e-4)
        churn = deterministic_churn([(0.45, 0, "leave")])
        eng = _guard_no_rerun(Engine(
            cluster, make_policy("lavea"), noise_sigma=0.0, churn=churn,
            recovery="fail_fast", salvage=salvage, track_intervals=True,
        ))
        eng.add_arrivals([app], [0.0])
        eng.drain()
        outcomes[salvage] = (eng.records[0], dict(eng.stats), eng)
    rec0, stats0, _ = outcomes[0]
    rec1, stats1, eng1 = outcomes[1]
    assert rec0.failed and stats0["lost"] == 1 and stats0["salvages"] == 0
    assert not rec1.failed
    assert stats1["salvages"] == 1 and stats1["salvaged"] == 1
    assert stats1["recovered"] == 1 and stats1["lost"] == 0
    # a executed exactly once (on the dead device), b's retry elsewhere
    assert eng1.load[0] == 2                 # a + b's first doomed attempt
    assert eng1.load[1:].sum() == 1          # only the salvaged b
    # b's salvage placement priced the transfer from a's holder (device 0)
    run_b = eng1.records[0]
    assert not run_b.failed


def test_salvage_transfer_priced_from_holding_device():
    """The pinned parent's device is the transfer source for the salvaged
    remainder: est_transfer equals out_bytes / bw_eff[holder, chosen]."""
    cluster = small_cluster(base=[0.3, 0.32, 0.34, 0.36], lam=1e-4, bw=100e6)
    churn = deterministic_churn([(0.45, 0, "leave")])
    eng = Engine(cluster, make_policy("lavea"), noise_sigma=0.0, churn=churn,
                 recovery="fail_fast", salvage=1)
    eng.add_arrivals([chain_app()], [0.0])
    eng.drain()
    rec = eng.records[0]
    assert not rec.failed and eng.stats["salvages"] == 1
    # after salvage the run's b placement moved off device 0 and pays the
    # 1 MB / 100 MB/s = 10 ms hop from a's holder
    # (the engine mutated the placement in place; find it via the records)
    # -> reconstruct from the engine's final placement bookkeeping:
    # the last applied plan's task b replica
    # We can't reach the run object from records, so assert via load + the
    # occupancy having moved; the precise transfer cost is pinned through a
    # fresh pinned-orchestrate call on the same state shape:
    from repro.core.orchestrator import orchestrate as orch_fn

    cluster2 = small_cluster(base=[0.3, 0.32, 0.34, 0.36], lam=1e-4, bw=100e6)
    app = chain_app()
    plan0 = orch_fn(app, cluster2, 0.0, make_policy("lavea"))
    cluster2.mark_down(0, 0.45)
    pinned = {"a": plan0.tasks["a"]}
    plan1 = orch_fn(app, cluster2, 0.5, make_policy("lavea"), pinned=pinned)
    rep = plan1.tasks["b"].replicas[0]
    assert rep.did != 0
    assert rep.est_transfer == pytest.approx(1 * MB / 100e6)


def test_salvage_mid_device_down_consumes_one_attempt():
    """Regression: a single departure that kills the last replicas of TWO
    same-stage tasks fires salvage once — the second (pre-salvage) death
    must not decrement the relaunched tasks' inflight counts or burn a
    second salvage (the dead-list entries carry the run epoch)."""
    app = AppDAG.from_tasks("y", [
        TaskSpec("a", ttype=0),
        TaskSpec("b", ttype=0, deps=("a",)),
        TaskSpec("c", ttype=0, deps=("a",)),
    ])
    for salvage in (1, 3):
        # device 0 is far fastest: a, b and c all land there; it dies mid-b/c
        cluster = small_cluster(base=[0.1, 2.0, 2.0, 2.0], lam=1e-6)
        eng = _guard_no_rerun(Engine(
            cluster, make_policy("lavea"), noise_sigma=0.0,
            churn=deterministic_churn([(0.15, 0, "leave")]),
            recovery="fail_fast", salvage=salvage, track_intervals=True,
        ))
        eng.add_arrivals([app], [0.0])
        eng.drain()
        assert not eng.records[0].failed
        assert eng.stats["salvages"] == 1
        assert eng.stats["salvaged"] == 1
        mk = lambda: small_cluster(base=[0.1, 2.0, 2.0, 2.0], lam=1e-6)
        assert np.array_equal(
            np.asarray(cluster.alloc), _rebuild_alloc(mk, eng.executed)
        )


def test_salvage_exhausted_instance_is_lost():
    """salvage=1 spends its one resubmission, a second failure is final."""
    cluster = small_cluster(base=[0.3, 0.32, 0.34, 0.36], lam=1e-4)
    churn = deterministic_churn([
        (0.45, 0, "leave"),                  # kills b's first attempt
        (0.60, 1, "leave"),                  # kills the salvaged b too
        (0.60, 2, "leave"),
        (0.60, 3, "leave"),
    ])
    eng = Engine(cluster, make_policy("lavea"), noise_sigma=0.0, churn=churn,
                 recovery="fail_fast", salvage=1)
    eng.add_arrivals([chain_app()], [0.0])
    eng.drain()
    assert eng.records[0].failed
    assert eng.stats["salvages"] == 1 and eng.stats["salvaged"] == 0
    assert eng.stats["lost"] == 1


def test_salvage_needs_completed_work():
    """An instance that dies in its first stage has nothing to salvage —
    the resubmission path must not fire."""
    cluster = small_cluster(base=[0.3, 0.32, 0.34, 0.36], lam=1e-4)
    churn = deterministic_churn([(0.1, d, "leave") for d in range(4)])
    eng = Engine(cluster, make_policy("lavea"), noise_sigma=0.0, churn=churn,
                 recovery="fail_fast", salvage=3)
    eng.add_arrivals([one_task_app()], [0.0])
    eng.drain()
    assert eng.records[0].failed
    assert eng.stats["salvages"] == 0


def _rebuild_alloc(cluster_factory, executed):
    """Replay an engine's executed-interval log onto a fresh cluster."""
    c = cluster_factory()
    for did, ttype, t0, t1, t_cut in executed:
        c.add_interval(did, ttype, t0, t1)
        if t_cut < t1:
            c.cancel_from(did, ttype, t0, t1, t_cut)
    return c.alloc


@pytest.mark.parametrize("recovery", ("fail_fast", "failover", "replan"))
def test_occupancy_nets_to_executed_under_correlated_salvage(profile, recovery):
    """Satellite invariant: post-drain T_alloc equals EXACTLY the replay of
    actual execution spans under correlated churn + salvage, for every
    recovery strategy — salvage cancellations leave zero ghost residue."""
    cfg = SimConfig(scenario="correlated_churn", n_cycles=2,
                    instances_per_cycle=60, seed=3, n_devices=24,
                    recovery=recovery, salvage=2)
    mk = lambda: make_cluster(profile, scenario="correlated_churn",
                              n_devices=24, seed=3,
                              horizon=cfg.horizon + 60.0)
    cluster = mk()
    churn = make_churn(cfg, cluster)
    orch = Orchestrator(cluster, policy_for("churn_aware", profile, cfg),
                        seed=3, churn=churn, recovery=cfg.recovery,
                        salvage=cfg.salvage, track_intervals=True)
    _guard_no_rerun(orch.engine)
    apps, times = _make_workload(cfg)
    orch.submit_batch(apps, times)
    orch.drain()
    assert orch.pending_events == 0
    assert orch.stats["device_down"] > 0     # the shocks/windows really bite
    rebuilt = _rebuild_alloc(mk, orch.engine.executed)
    assert np.array_equal(np.asarray(cluster.alloc), rebuilt)


@settings(max_examples=15, deadline=None, derandomize=True)
@given(
    deaths=st.lists(
        st.tuples(
            st.floats(min_value=0.05, max_value=24.0),
            st.integers(min_value=0, max_value=3),
            st.one_of(st.none(), st.floats(min_value=0.3, max_value=4.0)),
        ),
        min_size=1, max_size=6,
    )
)
def test_property_salvage_occupancy_and_no_rerun(deaths):
    """Property: under ANY churn schedule, with salvage enabled and every
    recovery strategy, completed stages never re-run and the occupancy
    books still net to exactly the executed work."""
    events = []
    for t, did, rejoin_after in deaths:
        events.append((t, did, "leave"))
        if rejoin_after is not None:
            events.append((t + rejoin_after, did, "join"))
    schedule = deterministic_churn(events)
    apps = [chain_app(f"#{i}") for i in range(5)]
    times = [5.0 * i for i in range(5)]
    mk = lambda: small_cluster(base=[0.3, 0.32, 0.34, 0.36], lam=1e-4)
    for recovery in ("fail_fast", "failover", "replan"):
        cluster = mk()
        eng = _guard_no_rerun(Engine(
            cluster, make_policy("lavea"), noise_sigma=0.0,
            churn=ChurnSchedule(schedule.events),
            recovery=recovery, salvage=1, track_intervals=True,
        ))
        eng.add_arrivals(apps, times)
        eng.drain()
        assert np.array_equal(
            np.asarray(cluster.alloc), _rebuild_alloc(mk, eng.executed)
        )
