"""Factorized link model + incremental snapshots (PR 10): the dense (D, D)
bw_eff matrix is gone from snapshots and the wave planning path — the
bottleneck rule is carried as its O(D) factors (up_bw / down_bw / backhaul
+ tiers) and sender rows are derived lazily.  These tests pin:

  * link_row == the dense matrix's row, bit for bit, for every sender;
  * factorized placements bit-identical to a dense-reference planning pass
    for all registered policies on the multi-tier grid;
  * set_bandwidth's single-device incremental path (no O(D^2) work, no
    full refresh) with copy-on-write protecting already-taken snapshots;
  * snapshot(survival=...) without surv_grid raises at construction;
  * float64 T_alloc: apply/undo churn cancels to exactly zero (property);
  * backhaul shape validation (non-square / too-small / empty fleets) and
    diagonal-inf semantics surviving the factorized path (co-located
    transfers free, Perfetto export finite);
  * IBDASH top-k candidate pre-pruning == the full stable argsort.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.api import (
    TIER_CLOUD,
    TIER_DEVICE,
    TIER_EDGE_SERVER,
    make_policy,
    orchestrate,
    orchestrate_batch,
)
from repro.core import batched as batched_mod
from repro.core.batched import _topk_stable, ibdash_decide_batch
from repro.core.cluster import ClusterState, Device
from repro.core.dag import AppDAG, TaskSpec
from repro.core.interference import InterferenceModel
from repro.core.orchestrator import _WaveContextBuilder
from repro.obs import Tracer
from repro.obs.export import to_chrome_trace, validate_chrome_trace
from repro.sim import SimConfig, make_multi_tier_cluster, make_profile
from repro.sim.engine import Engine
from repro.sim.runner import ALL_SCHEME_NAMES, _make_workload, policy_for

GB = 1e9
MB = 1e6


@pytest.fixture(scope="module")
def profile():
    return make_profile(seed=0)


def tiered_cluster(ups, downs, tiers, backhaul=None, lam=1e-6, mem=8 * GB,
                   n_types=1, model_source=None, horizon=120.0):
    n = len(ups)
    model = InterferenceModel(
        base=np.full((n, n_types), 0.2),
        slope=np.full((n, n_types, n_types), 0.05),
    )
    devices = [
        Device(did=i, cls=i, mem_total=mem, lam=lam, tier=tiers[i],
               up_bw=float(ups[i]), down_bw=float(downs[i]))
        for i in range(n)
    ]
    return ClusterState(devices=devices, model=model, horizon=horizon,
                        dt=0.05, backhaul=backhaul,
                        model_source=model_source)


def chain_app(out_bytes=10 * MB):
    return AppDAG.from_tasks("app", [
        TaskSpec("parent", ttype=0, out_bytes=out_bytes),
        TaskSpec("child", ttype=0, deps=("parent",)),
    ])


def same_placement(a, b):
    assert a.feasible == b.feasible
    assert a.est_latency == b.est_latency
    assert set(a.tasks) == set(b.tasks)
    for k in a.tasks:
        ta, tb = a.tasks[k], b.tasks[k]
        assert [r.did for r in ta.replicas] == [r.did for r in tb.replicas]
        for ra, rb in zip(ta.replicas, tb.replicas):
            assert ra.est_exec == rb.est_exec
            assert ra.est_upload == rb.est_upload
            assert ra.est_transfer == rb.est_transfer
            assert ra.pred_fail == rb.pred_fail


def _forbid_dense(*_a, **_k):
    raise AssertionError("dense (D, D) link matrix materialized")


BACKHAUL = np.array([
    [25, 500, 15],
    [500, 1250, 150],
    [15, 150, 2500],
]) * MB


# ------------------------------------------------ rows == dense, bit-exact --
def test_link_row_matches_dense_row_for_every_sender():
    ups = (10 * MB, 20 * MB, 30 * MB, 7 * MB)
    downs = (40 * MB, 50 * MB, 60 * MB, 9 * MB)
    tiers = (TIER_DEVICE, TIER_EDGE_SERVER, TIER_CLOUD, TIER_DEVICE)
    c = tiered_cluster(ups, downs, tiers, backhaul=BACKHAUL)
    dense = c.link_bw()
    snap = c.snapshot(0.0)
    for s in range(4):
        assert np.array_equal(c.link_row(s), dense[s])
        assert np.array_equal(snap.link_row(s), dense[s])
        assert c.link_row(s)[s] == np.inf
    # the snapshot's on-demand dense view agrees too
    assert np.array_equal(snap.link_bw, dense)


def test_snapshot_carries_no_quadratic_leaf(profile):
    """Every pytree leaf of a D-device snapshot is O(D) (or O(T^2) for the
    tiny backhaul) — the dense matrix is not in the tree."""
    from dataclasses import fields

    cluster = make_multi_tier_cluster(profile, n_devices=60, seed=0)
    snap = cluster.snapshot(0.0)
    D = snap.n_devices
    for f in fields(snap):
        leaf = getattr(snap, f.name)
        size = getattr(leaf, "size", 1)
        assert size < D * D, f"leaf {f.name} is O(D^2): {size}"


# --------------------------------- factorized == dense reference, parity --
@pytest.mark.parametrize("scheme", ALL_SCHEME_NAMES)
def test_factorized_placements_match_dense_reference(scheme, profile,
                                                     monkeypatch):
    """A planning pass whose transfer vectors are sliced from a fully
    materialized dense matrix places every app identically to the lazy
    factorized path, for all registered policies on the multi-tier grid."""
    cfg = SimConfig(n_cycles=1, instances_per_cycle=60, scenario="multi_tier",
                    seed=0, n_devices=30, latency_budget=4.0)
    apps, times = _make_workload(cfg)

    def build():
        return make_multi_tier_cluster(profile, n_devices=cfg.n_devices,
                                       seed=cfg.seed, horizon=cfg.horizon + 30)

    kw = dict(profile=profile, cfg=cfg)
    plans_fac = orchestrate_batch(apps, build(), policy_for(scheme, **kw),
                                  times=times)

    def dense_transfer_vec(self, out_bytes, src):
        if not hasattr(self, "_dense_ref"):
            self._dense_ref = self.cluster.link_bw()
        return out_bytes / self._dense_ref[src]

    monkeypatch.setattr(_WaveContextBuilder, "transfer_vec",
                        dense_transfer_vec)
    plans_dense = orchestrate_batch(apps, build(), policy_for(scheme, **kw),
                                    times=times)
    for a, b in zip(plans_fac, plans_dense):
        same_placement(a.placement, b.placement)


def test_wave_planning_never_materializes_dense(profile):
    """End-to-end batched planning on a 600-device multi-tier fleet (above
    the top-k pruning threshold) with the dense accessors tripwired."""
    cluster = make_multi_tier_cluster(profile, n_devices=600, seed=0,
                                      horizon=60.0, dt=0.5)
    cluster.link_bw = _forbid_dense           # instance-level tripwire
    apps = [chain_app().relabel(f"#{i}") for i in range(12)]
    plans = orchestrate_batch(apps, cluster, make_policy("ibdash"))
    assert all(p.feasible for p in plans)


# ------------------------------------- incremental set_bandwidth (sat. 1) --
def test_set_bandwidth_is_incremental_on_10k_fleet():
    """A 1-device update on a 10k fleet does no O(D^2) work: no full
    refresh_topology, no dense matrix, yet the topology version bumps and
    repricing sees the new rates."""
    D = 10_000
    model = InterferenceModel(base=np.full((1, 1), 0.2),
                              slope=np.zeros((1, 1, 1)))
    devices = [
        Device(did=i, cls=0, mem_total=GB, lam=1e-6,
               up_bw=5 * MB, down_bw=20 * MB, tier=TIER_DEVICE)
        for i in range(D)
    ]
    cluster = ClusterState(devices=devices, model=model, horizon=10.0, dt=1.0)
    snap_before = cluster.snapshot(0.0)
    v0 = cluster.topology_version
    row9_before = cluster.link_row(9).copy()

    # any O(D^2) path from here on fails loudly
    cluster.refresh_topology = _forbid_dense
    cluster.link_bw = _forbid_dense

    cluster.set_bandwidth(7, up=1 * MB, down=2 * MB, tier=TIER_EDGE_SERVER)

    assert cluster.topology_version == v0 + 1
    assert cluster.up_bandwidths()[7] == 1 * MB
    assert cluster.down_bandwidths()[7] == 2 * MB
    assert cluster.tiers()[7] == TIER_EDGE_SERVER
    # the deprecated scalar shim must track the incremental update too
    assert cluster.bandwidths()[7] == 1 * MB  # repro-lint: disable=deprecation
    # lazily re-derived rows price the new rates
    assert cluster.link_row(7)[0] == min(1 * MB, 20 * MB)
    assert cluster.link_row(9)[7] == min(5 * MB, 2 * MB)
    assert row9_before[7] == min(5 * MB, 20 * MB)
    # copy-on-write: the snapshot taken before the update is untouched
    assert snap_before.up_bw[7] == 5 * MB
    assert snap_before.down_bw[7] == 20 * MB
    assert snap_before.tiers[7] == TIER_DEVICE


def test_set_bandwidth_matches_full_refresh():
    """The incremental path and a full refresh_topology agree exactly."""
    ups = (10 * MB, 20 * MB, 30 * MB)
    downs = (40 * MB, 50 * MB, 60 * MB)
    tiers = (TIER_DEVICE, TIER_EDGE_SERVER, TIER_CLOUD)
    a = tiered_cluster(ups, downs, tiers, backhaul=BACKHAUL)
    b = tiered_cluster(ups, downs, tiers, backhaul=BACKHAUL)
    a.set_bandwidth(1, up=3 * MB, down=4 * MB, tier=TIER_CLOUD)
    b.devices[1].up_bw = 3 * MB
    b.devices[1].down_bw = 4 * MB
    b.devices[1].bandwidth = 3 * MB
    b.devices[1].tier = TIER_CLOUD
    b.refresh_topology()
    assert np.array_equal(a.link_bw(), b.link_bw())
    for s in range(3):
        assert np.array_equal(a.link_row(s), b.link_row(s))


def test_set_bandwidth_tier_out_of_backhaul_raises():
    c = tiered_cluster((MB, MB), (MB, MB), (0, 0),
                       backhaul=np.full((1, 1), np.inf))
    with pytest.raises(ValueError, match="too small"):
        c.set_bandwidth(0, tier=3)


def test_set_bandwidth_grows_unconstrained_backhaul():
    """With no backhaul matrix the all-inf placeholder grows to cover a new
    tier id instead of raising."""
    c = tiered_cluster((MB, 2 * MB), (MB, 2 * MB), (0, 0))
    c.set_bandwidth(1, tier=TIER_CLOUD)
    assert c.link_row(0)[1] == MB                 # still min(up, down) only


# ----------------------------------- snapshot survival guard (satellite 2) --
def test_snapshot_survival_without_grid_raises():
    c = tiered_cluster((MB,), (MB,), (0,))
    with pytest.raises(ValueError, match="together"):
        c.snapshot(0.0, survival=np.ones((1, 1)))
    with pytest.raises(ValueError, match="together"):
        c.snapshot(0.0, surv_grid=np.zeros(1))
    snap = c.snapshot(0.0, surv_grid=np.zeros(1), survival=np.ones((1, 1)))
    assert snap.surv_grid.shape == (1,)


# -------------------------------------------- float64 T_alloc (satellite 3) --
def test_alloc_accumulates_in_float64():
    c = tiered_cluster((MB,), (MB,), (0,))
    assert c.alloc.dtype == np.float64


@settings(max_examples=50, deadline=None)
@given(
    ops=st.lists(
        st.tuples(
            st.integers(0, 2),                       # did
            st.integers(0, 1),                       # ttype
            st.floats(0.0, 90.0),                    # t0
            st.floats(0.01, 50.0),                   # duration
        ),
        min_size=1, max_size=40,
    ),
    seed=st.integers(0, 2**32 - 1),
)
def test_apply_undo_churn_leaves_occupancy_exactly_zero(ops, seed):
    """Long apply/undo churn — every recorded interval later cancelled, in
    shuffled order — leaves the float64 T_alloc tensor EXACTLY zero, not
    clip-masked residue."""
    n = 3
    model = InterferenceModel(base=np.full((n, 2), 0.2),
                              slope=np.zeros((n, 2, 2)))
    devices = [Device(did=i, cls=i, mem_total=GB, lam=1e-6,
                      up_bw=MB, down_bw=MB) for i in range(n)]
    c = ClusterState(devices=devices, model=model, horizon=100.0, dt=0.05)
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("ignore", RuntimeWarning)    # horizon clipping is fine
        for did, ttype, t0, dur in ops:
            c.add_interval(did, ttype, t0, t0 + dur)
        order = np.random.default_rng(seed).permutation(len(ops))
        for i in order:
            did, ttype, t0, dur = ops[i]
            c.add_interval(did, ttype, t0, t0 + dur, w=-1.0)
    assert (c.alloc == 0.0).all()


# ------------------------------- backhaul validation + diag-inf (sat. 4) --
def test_backhaul_non_square_raises():
    with pytest.raises(ValueError, match="square"):
        tiered_cluster((MB, MB), (MB, MB), (0, 1),
                       backhaul=np.full((2, 3), MB))
    with pytest.raises(ValueError, match="square"):
        tiered_cluster((MB,), (MB,), (0,), backhaul=np.full(3, MB))


def test_backhaul_too_small_raises():
    with pytest.raises(ValueError, match="too small"):
        tiered_cluster((MB, MB), (MB, MB), (0, TIER_CLOUD),
                       backhaul=np.full((2, 2), MB))


def test_empty_fleet_topology():
    model = InterferenceModel(base=np.full((1, 1), 0.2),
                              slope=np.zeros((1, 1, 1)))
    c = ClusterState(devices=[], model=model, backhaul=np.full((2, 2), MB))
    assert c.snapshot(0.0).n_devices == 0
    with pytest.raises(ValueError, match="square"):
        ClusterState(devices=[], model=model, backhaul=np.full((2, 3), MB))


def test_colocated_transfer_free_through_factorized_path():
    """One-device fleet: the chain's child lands next to its parent and the
    diagonal-inf row prices the transfer at exactly 0 (not nan/inf)."""
    c = tiered_cluster((MB,), (2 * MB,), (0,), backhaul=BACKHAUL[:1, :1])
    plan = orchestrate(chain_app(), c, 0.0, make_policy("ibdash"))
    child = plan.tasks["child"]
    assert child.replicas[0].did == 0
    assert child.replicas[0].est_transfer == 0.0
    assert np.isfinite(child.replicas[0].est_total)


def test_perfetto_export_finite_with_colocated_transfers():
    """Traced end-to-end run on a co-locating fleet: span attribution stays
    finite and the Chrome trace survives strict JSON validation (allow_nan
    rejects inf/nan anywhere in the document)."""
    c = tiered_cluster((MB, MB), (2 * MB, 2 * MB), (0, 0), horizon=200.0)
    tr = Tracer()
    eng = Engine(c, make_policy("ibdash"), noise_sigma=0.0, trace=tr)
    eng.add_arrivals([chain_app().relabel(f"#{i}") for i in range(3)],
                     [0.0, 0.1, 0.2])
    eng.drain()
    doc = to_chrome_trace(tr)
    assert validate_chrome_trace(doc) > 0


# ---------------------------------------------- IBDASH top-k pre-pruning --
def test_topk_stable_matches_full_stable_argsort():
    rng = np.random.default_rng(3)
    for _ in range(25):
        # tie-heavy rows: few distinct values + infeasible +inf columns
        m = rng.choice([0.25, 0.5, 0.5, 1.0, np.inf], size=(9, 300))
        for k in (1, 2, 5, 299):
            assert np.array_equal(
                _topk_stable(m, k),
                np.argsort(m, axis=1, kind="stable")[:, :k],
            )


def test_ibdash_pruned_matches_unpruned(monkeypatch):
    """decide_batch on a 1000-device fleet with pruning active == the same
    call with pruning disabled (full argsort), replica sets included."""
    rng = np.random.default_rng(7)
    B, D = 32, 1000
    # quantized totals make ties common, exercising the boundary logic
    total = rng.choice(np.linspace(0.1, 2.0, 12), size=(B, D))
    pf = rng.uniform(0.0, 0.9, size=(B, D))
    feasible = rng.uniform(size=(B, D)) > 0.1
    args = (total, pf, feasible, 0.5, 0.25, 2)
    pruned = ibdash_decide_batch(*args)
    monkeypatch.setattr(batched_mod, "TOPK_PRUNE_MIN_DEVICES", 10**9)
    unpruned = ibdash_decide_batch(*args)
    assert pruned == unpruned
