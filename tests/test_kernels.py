"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode
from repro.kernels.ops import attention, decode_attention, rwkv6
from repro.kernels.ref import attention_ref, decode_attention_ref, rwkv6_ref
from repro.kernels.rwkv6_scan import rwkv6_scan

RNG = np.random.default_rng(0)


def _tol(dt):
    return dict(atol=3e-2, rtol=3e-2) if dt == jnp.bfloat16 else dict(atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("B,S,Hq,Hk,D", [
    (2, 256, 8, 2, 64),
    (1, 256, 4, 4, 128),
    (2, 512, 8, 1, 64),
    (1, 128, 2, 2, 32),
    (1, 384, 6, 6, 64),       # whisper-tiny head geometry
    (1, 256, 16, 1, 128),     # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal_sweep(B, S, Hq, Hk, D, dtype):
    q = jnp.asarray(RNG.standard_normal((B, S, Hq, D)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, S, Hk, D)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, S, Hk, D)), dtype)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [64, 128, 256])
def test_flash_attention_local_window(window):
    B, S, Hq, Hk, D = 1, 512, 4, 2, 64
    q = jnp.asarray(RNG.standard_normal((B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, Hk, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, Hk, D)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


def test_flash_attention_non_causal():
    B, S, Hq, Hk, D = 2, 128, 2, 2, 32
    q = jnp.asarray(RNG.standard_normal((B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, Hk, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, Hk, D)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, interpret=True)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


def test_flash_attention_block_size_invariance():
    B, S, Hq, Hk, D = 1, 512, 4, 2, 64
    q = jnp.asarray(RNG.standard_normal((B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, Hk, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, Hk, D)), jnp.float32)
    a = flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    b = flash_attention(q, k, v, block_q=64, block_k=256, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("B,C,Hq,Hk,D", [
    (2, 512, 8, 2, 64),
    (3, 256, 4, 4, 128),
    (1, 1024, 16, 1, 64),
    (2, 256, 8, 8, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(B, C, Hq, Hk, D, dtype):
    q = jnp.asarray(RNG.standard_normal((B, Hq, D)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, C, Hk, D)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, C, Hk, D)), dtype)
    lengths = jnp.asarray(RNG.integers(1, C, B), jnp.int32)
    out = flash_decode(q, k, v, lengths, block_k=128, interpret=True)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_decode_length_masking():
    """Entries past `lengths` must have zero influence."""
    B, C, Hq, Hk, D = 1, 256, 2, 2, 32
    q = jnp.asarray(RNG.standard_normal((B, Hq, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, C, Hk, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, C, Hk, D)), jnp.float32)
    lengths = jnp.asarray([100], jnp.int32)
    out1 = flash_decode(q, k, v, lengths, interpret=True, block_k=128)
    k2 = k.at[:, 100:].set(999.0)
    v2 = v.at[:, 100:].set(-999.0)
    out2 = flash_decode(q, k2, v2, lengths, interpret=True, block_k=128)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


@pytest.mark.parametrize("B,T,H,N,chunk", [
    (2, 128, 2, 32, 32),
    (1, 256, 4, 64, 64),
    (2, 64, 1, 16, 16),
    (1, 128, 2, 64, 128),      # chunk == T
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_chunked_sweep(B, T, H, N, chunk, dtype):
    r = jnp.asarray(RNG.standard_normal((B, T, H, N)) * 0.5, dtype)
    k = jnp.asarray(RNG.standard_normal((B, T, H, N)) * 0.5, dtype)
    v = jnp.asarray(RNG.standard_normal((B, T, H, N)), dtype)
    w = jnp.asarray(RNG.uniform(0.2, 0.999, (B, T, H, N)), jnp.float32)
    u = jnp.asarray(RNG.standard_normal((H, N)) * 0.2, jnp.float32)
    S0 = jnp.asarray(RNG.standard_normal((B, H, N, N)) * 0.1, jnp.float32)
    y, sT = rwkv6_scan(r, k, v, w.astype(dtype), u, S0, chunk=chunk, interpret=True)
    yr, sr = rwkv6_ref(r, k, v, w.astype(dtype), u, S0)
    tol = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else dict(atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sr), **tol)


def test_rwkv6_state_carry_composes():
    """Running two halves with carried state == one full run."""
    B, T, H, N = 1, 128, 2, 32
    r = jnp.asarray(RNG.standard_normal((B, T, H, N)) * 0.5, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, T, H, N)) * 0.5, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, T, H, N)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.3, 0.99, (B, T, H, N)), jnp.float32)
    u = jnp.asarray(RNG.standard_normal((H, N)) * 0.2, jnp.float32)
    S0 = jnp.zeros((B, H, N, N), jnp.float32)
    y_full, s_full = rwkv6_ref(r, k, v, w, u, S0)
    h = T // 2
    y1, s1 = rwkv6_ref(r[:, :h], k[:, :h], v[:, :h], w[:, :h], u, S0)
    y2, s2 = rwkv6_ref(r[:, h:], k[:, h:], v[:, h:], w[:, h:], u, s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=1e-4)


@given(
    s_blocks=st.integers(1, 4),
    hq=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2]),
    d=st.sampled_from([32, 64]),
)
@settings(max_examples=10, deadline=None)
def test_flash_attention_property(s_blocks, hq, g, d):
    """Hypothesis sweep: kernel == oracle for arbitrary small geometries."""
    S = 128 * s_blocks
    Hk, Hq = hq, hq * g
    rng = np.random.default_rng(42)
    q = jnp.asarray(rng.standard_normal((1, S, Hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, S, Hk, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, S, Hk, d)), jnp.float32)
    out = flash_attention(q, k, v, interpret=True)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5, rtol=5e-5)


def test_ops_wrappers_dispatch_to_ref_on_cpu():
    B, S, Hq, Hk, D = 1, 128, 2, 2, 32
    q = jnp.asarray(RNG.standard_normal((B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, Hk, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, Hk, D)), jnp.float32)
    out = attention(q, k, v, impl="auto")       # == ref on CPU
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    out_i = attention(q, k, v, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out_i), np.asarray(ref), atol=5e-5)
