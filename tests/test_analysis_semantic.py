"""Semantic analysis v2 (PR 8): interprocedural effect inference, the
jaxpr kernel auditor, units-of-measure dataflow, stale-suppression
detection, parse-error resilience and the summary cache.

The golden fixture pairs live in tests/fixtures/lint/: the two-file
packages ``transitive_violation``/``transitive_clean`` exercise the
cross-function pass (``decide -> _helper -> ctx.cluster.apply()``), the
``kernel_*``/``unit_*`` modules the two new rules (their pair tests are
parametrized in test_analysis.py).
"""
import json
from pathlib import Path

import pytest

from repro.analysis import Analyzer, LintConfig, RuleSettings
from repro.analysis.callgraph import (
    load_summary_cache,
    save_summary_cache,
    summarize_module,
    summary_cache_stats,
)
from repro.analysis.reporters import render_sarif
from repro.analysis.units import (
    BYTES,
    BYTES_PER_S,
    SECONDS,
    parse_unit,
)

from test_analysis import FIXTURES, REPO, run_rule

VIOLATING_PKG = FIXTURES / "transitive_violation"
CLEAN_PKG = FIXTURES / "transitive_clean"


# -- interprocedural effect inference -----------------------------------------

def test_transitive_purity_reports_full_call_chain():
    """`decide -> _helper -> commit_plan -> ctx.cluster.apply()` — the
    mutation is two hops away from the policy method, and the finding's
    message must spell out the whole chain."""
    report = run_rule("policy-purity", VIOLATING_PKG)
    msgs = [f.message for f in report.findings]
    assert any(
        "decide -> _helper -> commit_plan -> ctx.cluster.apply()" in m
        for m in msgs
    ), msgs
    # the second leak: decide -> _note -> stamp_choice mutates `ctx`
    assert any(
        "decide -> _note -> stamp_choice" in m and "`ctx`" in m
        for m in msgs
    ), msgs
    # findings anchor at the call site inside the entry policy, not the leaf
    assert all(f.path.endswith("policy.py") for f in report.findings)


def test_transitive_rng_reports_full_call_chain():
    report = run_rule("rng-discipline", VIOLATING_PKG,
                      {"time_call_paths": ("",)})
    chains = [f for f in report.findings
              if "decide_batch -> pick_order -> np.random.shuffle()"
              in f.message]
    assert chains, [f.message for f in report.findings]
    assert all(f.path.endswith("policy.py") for f in chains)
    # the intraprocedural fallback still flags the leaf draw itself
    assert any(f.path.endswith("util.py") for f in report.findings)


@pytest.mark.parametrize("rule,options", [
    ("policy-purity", None),
    ("rng-discipline", {"time_call_paths": ("",)}),
])
def test_transitive_clean_twin_is_silent(rule, options):
    report = run_rule(rule, CLEAN_PKG, options)
    assert report.findings == [], [f.format() for f in report.findings]


def test_summary_cache_round_trips(tmp_path):
    src = "def f(x):\n    return x + 1\n"
    import ast
    summarize_module("mod.py", src, ast.parse(src))
    h0, _ = summary_cache_stats()
    summarize_module("mod.py", src, ast.parse(src))   # content-hash hit
    h1, _ = summary_cache_stats()
    assert h1 == h0 + 1
    cache = tmp_path / "summaries.json"
    assert save_summary_cache(str(cache)) >= 1
    assert load_summary_cache(str(cache)) >= 1


# -- jaxpr kernel auditor ------------------------------------------------------

def test_batched_kernels_lower_once_across_fleet_sweep():
    """THE acceptance criterion: every registered core/batched.py kernel
    lowers a bounded number of programs (one per padded wave bucket, not
    one per fleet size) across the D/B sweep — no shape-driven
    recompilation."""
    pytest.importorskip("jax")
    from repro.analysis.kernel_audit import audit_spec, builtin_targets

    specs = builtin_targets()["src/repro/core/batched.py"]
    assert {s.name for s in specs} == {
        "ibdash_scan_kernel", "lavea_kernel",
        "round_robin_kernel", "tier_escalation_kernel",
    }
    for spec in specs:
        assert audit_spec(spec) == []


def test_auditor_counts_distinct_lowerings(tmp_path):
    """A kernel traced at unpadded sizes B in {8, 9, 10} must be reported
    as 3 distinct programs against an expectation of 1."""
    pytest.importorskip("jax")
    from repro.analysis.kernel_audit import KernelSpec, audit_spec, f64

    def load():
        import jax.numpy as jnp

        def k(x):
            return jnp.sum(x * 2.0)
        return k

    spec = KernelSpec(
        name="toy", fn=load,
        build=lambda p: (f64(p["B"]),),
        sweep=({"B": 8}, {"B": 9}, {"B": 10}),
        x64=True, expected_lowerings=1,
    )
    msgs = audit_spec(spec)
    assert any("3 distinct programs" in m for m in msgs), msgs


# -- units-of-measure algebra --------------------------------------------------

def test_unit_algebra():
    assert parse_unit("B/s") == BYTES_PER_S
    assert BYTES.div(BYTES_PER_S) == SECONDS          # B / (B/s) -> s
    assert BYTES_PER_S.mul(SECONDS) == BYTES          # (B/s) * s -> B
    assert SECONDS.compatible(SECONDS)
    assert not SECONDS.compatible(BYTES)
    assert str(BYTES.div(SECONDS)) == "B/s"
    assert str(parse_unit("1/s").mul(SECONDS)) == "dimensionless"


# -- stale suppressions --------------------------------------------------------

def test_useless_suppression_is_reported(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text("x = 1  # repro-lint: disable=rng-discipline\n")
    report = run_rule("rng-discipline", f, root=tmp_path)
    assert [fd.rule for fd in report.findings] == ["useless-suppression"]
    assert report.findings[0].severity == "warning"
    assert "matched no finding" in report.findings[0].message
    assert report.exit_code == 0        # warnings never fail the run


def test_useless_suppression_only_judges_rules_that_ran(tmp_path):
    """A disable for a deselected rule might be load-bearing — leave it."""
    f = tmp_path / "mod.py"
    f.write_text("x = 1  # repro-lint: disable=deprecation\n")
    report = run_rule("rng-discipline", f, root=tmp_path)
    assert report.findings == [], [fd.format() for fd in report.findings]


def test_disable_marker_in_string_literal_is_ignored(tmp_path):
    """Only real comment tokens count: a marker inside a string (e.g. test
    code building fixture sources) neither suppresses nor goes stale."""
    f = tmp_path / "mod.py"
    f.write_text(
        'SRC = "x = 1  # repro-lint: disable=rng-discipline"\n'
        "import numpy as np\n"
        "y = np.random.normal()\n"
    )
    report = run_rule("rng-discipline", f, root=tmp_path)
    assert [fd.rule for fd in report.findings] == ["rng-discipline"]
    assert report.suppressed == 0


# -- parse-error resilience ----------------------------------------------------

def test_broken_file_does_not_abort_the_run(tmp_path):
    """One unparseable file yields a parse-error finding; every other
    file in the same run is still fully analyzed."""
    (tmp_path / "broken.py").write_text("def oops(:\n")
    (tmp_path / "good.py").write_text(
        "import numpy as np\nx = np.random.normal()\n"
    )
    report = run_rule("rng-discipline", tmp_path, root=tmp_path)
    by_rule = {f.rule: f for f in report.findings}
    assert set(by_rule) == {"parse-error", "rng-discipline"}
    assert by_rule["parse-error"].path == "broken.py"
    assert by_rule["rng-discipline"].path == "good.py"
    assert report.files_scanned == 2
    assert report.exit_code == 1


def test_broken_fixture_parses_as_finding():
    report = run_rule("rng-discipline", FIXTURES / "broken_syntax.py")
    assert [f.rule for f in report.findings] == ["parse-error"]
    assert "could not parse" in report.findings[0].message


# -- SARIF ---------------------------------------------------------------------

def test_sarif_report_shape():
    report = run_rule("unit-consistency", FIXTURES / "unit_violation.py")
    assert report.findings
    doc = json.loads(render_sarif(report))
    assert doc["version"] == "2.1.0"
    run = doc["runs"][0]
    assert run["tool"]["driver"]["name"] == "repro-analysis"
    rule_ids = [r["id"] for r in run["tool"]["driver"]["rules"]]
    assert len(run["results"]) == len(report.findings)
    for res in run["results"]:
        assert res["ruleId"] in rule_ids
        region = res["locations"][0]["physicalLocation"]["region"]
        assert region["startLine"] >= 1 and region["startColumn"] >= 1
