"""Discrete-event simulator: determinism, accounting, paper-level behaviour."""
import numpy as np
import pytest

from repro.sim import SimConfig, make_profile, run_one
from repro.sim.apps import all_apps, lightgbm_app, mapreduce_app, matrix_app, video_app


@pytest.fixture(scope="module")
def profile():
    return make_profile(seed=0)


@pytest.fixture(scope="module")
def mini_cfg():
    return SimConfig(n_cycles=2, instances_per_cycle=120, scenario="ped", seed=3)


def test_apps_have_expected_structure():
    lg = lightgbm_app()
    assert lg.n_stages == 4 and lg.n_tasks == 9
    mr = mapreduce_app()
    assert mr.n_stages == 2 and mr.n_tasks == 6
    va = video_app()
    assert va.n_stages == 3
    mx = matrix_app()
    assert mx.n_stages == 3 and mx.n_tasks == 4


def test_determinism(profile, mini_cfg):
    a = run_one("ibdash", mini_cfg, profile)
    b = run_one("ibdash", mini_cfg, profile)
    assert a.avg_service_time == pytest.approx(b.avg_service_time)
    assert a.prob_failure == pytest.approx(b.prob_failure)
    assert (a.load_per_device == b.load_per_device).all()


def test_every_instance_resolves(profile, mini_cfg):
    res = run_one("random", mini_cfg, profile)
    assert res.n == mini_cfg.n_cycles * mini_cfg.instances_per_cycle
    for r in res.instances:
        assert r.failed or np.isfinite(r.service_time)
        assert np.isfinite(r.finished)


def test_service_time_positive(profile, mini_cfg):
    res = run_one("lavea", mini_cfg, profile)
    ok = [r.service_time for r in res.instances if not r.failed]
    assert len(ok) > 0 and min(ok) > 0


def test_ibdash_beats_random(profile):
    cfg = SimConfig(n_cycles=3, instances_per_cycle=250, scenario="ped", seed=0)
    ib = run_one("ibdash", cfg, profile)
    rd = run_one("random", cfg, profile)
    assert ib.avg_service_time < rd.avg_service_time
    assert ib.prob_failure <= rd.prob_failure


def test_replication_only_ibdash(profile, mini_cfg):
    ib = run_one("ibdash", mini_cfg, profile)
    rd = run_one("petrel", mini_cfg, profile)
    assert all(r.n_replicas == 0 for r in rd.instances)
    assert any(r.n_replicas >= 0 for r in ib.instances)


def test_per_app_metrics(profile, mini_cfg):
    res = run_one("lavea", mini_cfg, profile)
    per = res.per_app()
    assert set(per) <= {"lightgbm", "mapreduce", "video", "matrix"}
    for name, (svc, pf) in per.items():
        assert 0 <= pf <= 1


def test_ced_fails_less_than_ped(profile):
    cfg = SimConfig(n_cycles=3, instances_per_cycle=200, seed=1)
    from dataclasses import replace
    ped = run_one("lavea", replace(cfg, scenario="ped"), profile)
    ced = run_one("lavea", replace(cfg, scenario="ced"), profile)
    assert ced.prob_failure <= ped.prob_failure
