"""Batched placement API: FleetSnapshot, decide_batch parity with the
scalar path for all six policies, the fused orchestrate_batch wave planner,
the baseline empty-feasible guards, and the T_alloc horizon clip."""
import warnings

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.api import (
    BatchedDecision,
    BatchedPolicyContext,
    FleetSnapshot,
    Orchestrator,
    make_policy,
    orchestrate,
    orchestrate_batch,
)
from repro.core.batched import BATCH_KERNEL_MIN_ROWS
from repro.core.cluster import ClusterState, Device
from repro.core.dag import AppDAG, TaskSpec
from repro.core.interference import InterferenceModel
from repro.core.policy import LaTSModel, Policy, PolicyContext, TaskDecision
from repro.sim import SimConfig, make_cluster, make_profile
from repro.sim.runner import SCHEME_NAMES, _make_workload, policy_for

GB = 1e9
MB = 1e6


@pytest.fixture(scope="module")
def profile():
    return make_profile(seed=0)


def small_cluster(n=6, n_types=2, lam=5e-2, mem=8 * GB, bw=100e6, seed=0):
    rng = np.random.default_rng(seed)
    model = InterferenceModel(
        base=rng.uniform(0.05, 0.5, (n, n_types)),
        slope=rng.uniform(0.01, 0.08, (n, n_types, n_types)),
    )
    devices = [
        Device(did=i, cls=i, mem_total=mem, lam=lam, up_bw=bw, down_bw=bw)
        for i in range(n)
    ]
    return ClusterState(devices=devices, model=model, horizon=120.0, dt=0.05)


def small_lats(n_classes=16, n_types=2, seed=0):
    rng = np.random.default_rng(seed)
    return LaTSModel(
        base=rng.uniform(0.05, 0.5, (n_classes, n_types)),
        b=rng.uniform(0.1, 0.6, n_classes),
        cpu_usage=rng.uniform(0.1, 0.6, (n_classes, n_types)),
    )


def random_apps(rng, n_apps, n_types=2):
    apps = []
    for i in range(n_apps):
        n_tasks = int(rng.integers(1, 6))
        tasks = []
        for j in range(n_tasks):
            deps = tuple(
                f"t{k}#{i}" for k in range(j) if rng.random() < 0.4
            )
            tasks.append(TaskSpec(
                f"t{j}#{i}",
                ttype=int(rng.integers(n_types)),
                deps=deps,
                out_bytes=float(rng.uniform(0, 20e6)),
                model_id=f"m{int(rng.integers(2))}" if rng.random() < 0.4 else None,
                model_bytes=float(rng.uniform(10e6, 200e6)),
                mem_bytes=float(rng.uniform(0, 1 * GB)),
            ))
        apps.append(AppDAG.from_tasks(f"app{i}", tasks))
    return apps


def fresh_policies(name, seed=0):
    """Two identically-constructed instances (same rng stream / cursor)."""
    kw = dict(seed=seed, alpha=0.4, beta=0.08, gamma=3,
              lats_model=small_lats())
    return make_policy(name, **kw), make_policy(name, **kw)


def same_placement(a, b):
    assert a.feasible == b.feasible
    assert a.infeasible_task == b.infeasible_task
    assert a.est_latency == b.est_latency
    assert set(a.tasks) == set(b.tasks)
    for k in a.tasks:
        ta, tb = a.tasks[k], b.tasks[k]
        assert [r.did for r in ta.replicas] == [r.did for r in tb.replicas]
        assert ta.est_start == tb.est_start
        assert ta.est_latency == tb.est_latency
        for ra, rb in zip(ta.replicas, tb.replicas):
            assert ra.est_exec == rb.est_exec
            assert ra.est_upload == rb.est_upload
            assert ra.est_transfer == rb.est_transfer
            assert ra.pred_fail == rb.pred_fail


# the paper's six plus the forecast-aware IBDASH variant: with no forecast
# installed (every fixture here) churn_aware must ride every parity rail
# bit-identically, and its batched/scalar twins must agree like the rest
ALL_SCHEMES = SCHEME_NAMES + ("churn_aware",)


# ---------------------------------------------------------- fleet snapshot --
def test_fleet_snapshot_shapes_and_values():
    cluster = small_cluster(n=5, n_types=2)
    cluster.add_interval(2, 1, 0.0, 10.0, w=3)
    snap = cluster.snapshot(1.0)
    assert isinstance(snap, FleetSnapshot)
    assert snap.n_devices == 5 and snap.n_types == 2
    assert snap.counts.shape == (5, 2)
    assert snap.counts[2, 1] == 3.0
    assert snap.queue_len[2] == 3.0
    assert np.array_equal(snap.classes, cluster.classes())
    assert np.array_equal(snap.base, cluster.model.base)


def test_fleet_snapshot_is_a_pytree():
    jax = pytest.importorskip("jax")
    from repro.core.batched import _jax

    _jax()  # registers the pytree nodes
    snap = small_cluster(n=3).snapshot(0.0)
    leaves, treedef = jax.tree_util.tree_flatten(snap)
    # + tiers (PR 3), alive (PR 4), surv_grid + survival (PR 5); PR 10
    # factorized the dense link_bw leaf into up_bw + down_bw + backhaul
    assert len(leaves) == 17
    again = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(again, FleetSnapshot)
    assert np.array_equal(again.lams, snap.lams)
    # with no forecast installed the survival leaves are the uniform tensor
    assert snap.surv_grid.shape == (1,)
    assert snap.survival.shape == (3, 1) and (snap.survival == 1.0).all()


# ------------------------------------------------- decide_batch == decide --
@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_decide_batch_matches_looped_decide_on_wave(scheme):
    """decide_batch over a multi-app wave == decide over the same rows in
    order, for every registered policy (exact, including rng streams)."""
    rng = np.random.default_rng(3)
    cluster = small_cluster(n=8, seed=3)
    apps = random_apps(rng, 12)
    pol_b, pol_s = fresh_policies(scheme, seed=7)
    plans_b = orchestrate_batch(apps, cluster, pol_b, batched=True)
    plans_s = orchestrate_batch(apps, cluster, pol_s, batched=False)
    for a, b in zip(plans_b, plans_s):
        same_placement(a.placement, b.placement)


@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_sequential_orchestrate_batched_vs_scalar(scheme, profile):
    """orchestrate(batched=True) == orchestrate(batched=False) arrival by
    arrival on the seeded (miniaturised) Fig. 8/9 grid, with applies in
    between — T_alloc evolution included."""
    cfg = SimConfig(n_cycles=1, instances_per_cycle=50, scenario="mix",
                    seed=0, n_devices=24)
    apps, times = _make_workload(cfg)
    mk = lambda: make_cluster(profile, scenario=cfg.scenario,
                              n_devices=cfg.n_devices, seed=cfg.seed,
                              horizon=cfg.horizon + 30.0)
    c_b, c_s = mk(), mk()
    pol_b = policy_for(scheme, profile, cfg)
    pol_s = policy_for(scheme, profile, cfg)
    for app, t in zip(apps, times):
        pb = orchestrate(app, c_b, t, pol_b, batched=True)
        ps = orchestrate(app, c_s, t, pol_s, batched=False)
        same_placement(pb.placement, ps.placement)
        c_b.apply(pb)
        c_s.apply(ps)
    assert np.array_equal(c_b.alloc, c_s.alloc)


@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_wave_parity_on_seeded_grid(scheme, profile):
    """The fused orchestrate_batch wave == the scalar row loop on the
    seeded Fig. 8/9 grid workload (one shared snapshot, B ~ hundreds of
    rows, so the jitted kernels are exercised)."""
    cfg = SimConfig(n_cycles=1, instances_per_cycle=60, scenario="mix",
                    seed=0, n_devices=24)
    apps, times = _make_workload(cfg)
    cluster = make_cluster(profile, scenario=cfg.scenario,
                           n_devices=cfg.n_devices, seed=cfg.seed,
                           horizon=cfg.horizon + 30.0)
    pol_b = policy_for(scheme, profile, cfg)
    pol_s = policy_for(scheme, profile, cfg)
    plans_b = orchestrate_batch(apps, cluster, pol_b, times=times)
    plans_s = orchestrate_batch(apps, cluster, pol_s, times=times,
                                batched=False)
    for a, b in zip(plans_b, plans_s):
        same_placement(a.placement, b.placement)


@pytest.mark.parametrize("scheme", ("ibdash", "lavea"))
def test_wave_equals_looped_orchestrate_for_stateless(scheme, profile):
    """For stateless policies a fused wave also equals looping pure
    orchestrate per app (no intermediate applies)."""
    cfg = SimConfig(n_cycles=1, instances_per_cycle=40, scenario="ped",
                    seed=1, n_devices=16)
    apps, times = _make_workload(cfg)
    cluster = make_cluster(profile, scenario=cfg.scenario,
                           n_devices=cfg.n_devices, seed=cfg.seed,
                           horizon=cfg.horizon + 30.0)
    pol = policy_for(scheme, profile, cfg)
    plans_b = orchestrate_batch(apps, cluster, pol, times=times)
    plans_l = [orchestrate(app, cluster, t, pol)
               for app, t in zip(apps, times)]
    for a, b in zip(plans_b, plans_l):
        same_placement(a.placement, b.placement)


@pytest.mark.parametrize("uniform_forecast", (False, True))
def test_churn_aware_seed_parity_with_ibdash(profile, uniform_forecast):
    """Satellite-1 seed parity: with no forecast installed — or the uniform
    all-ones forecast — churn_aware's placements equal registry ibdash
    BIT-identically on the seeded Fig. 8/9 grid (the PR-4 placements), with
    applies in between so the T_alloc evolution is pinned too."""
    from repro.core.availability import SurvivalForecast

    cfg = SimConfig(n_cycles=1, instances_per_cycle=50, scenario="mix",
                    seed=0, n_devices=24)
    apps, times = _make_workload(cfg)
    mk = lambda: make_cluster(profile, scenario=cfg.scenario,
                              n_devices=cfg.n_devices, seed=cfg.seed,
                              horizon=cfg.horizon + 30.0)
    c_ib, c_ca = mk(), mk()
    if uniform_forecast:
        # all-ones survival: zero stochastic hazard, nothing scripted
        c_ca.install_forecast(SurvivalForecast.from_rates([0.0] * 24))
    pol_ib = policy_for("ibdash", profile, cfg)
    pol_ca = policy_for("churn_aware", profile, cfg)
    for app, t in zip(apps, times):
        p_ib = orchestrate(app, c_ib, t, pol_ib)
        p_ca = orchestrate(app, c_ca, t, pol_ca)
        same_placement(p_ib.placement, p_ca.placement)
        c_ib.apply(p_ib)
        c_ca.apply(p_ca)
    assert np.array_equal(c_ib.alloc, c_ca.alloc)


def test_round_robin_batch_continues_cursor():
    """The batched cursor picks up exactly where scalar decides left off,
    and advances once per non-empty row."""
    cluster = small_cluster(n=4, n_types=1)
    app = AppDAG.from_tasks("a", [TaskSpec(f"t{i}", ttype=0)
                                  for i in range(6)])
    rr_b, rr_s = fresh_policies("round_robin")
    # advance both cursors by 3 via the scalar path
    warm = AppDAG.from_tasks("w", [TaskSpec("w0", ttype=0),
                                   TaskSpec("w1", ttype=0),
                                   TaskSpec("w2", ttype=0)])
    orchestrate(warm, cluster, 0.0, rr_b, batched=False)
    orchestrate(warm, cluster, 0.0, rr_s, batched=False)
    pb = orchestrate(app, cluster, 0.0, rr_b, batched=True)
    ps = orchestrate(app, cluster, 0.0, rr_s, batched=False)
    same_placement(pb.placement, ps.placement)
    dids = [pb.tasks[f"t{i}"].replicas[0].did for i in range(6)]
    assert dids == [3, 0, 1, 2, 3, 0]                  # cursor started at 3


def test_custom_policy_default_decide_batch_fallback():
    """A user policy with only decide() rides the batched orchestrate path
    through the row() bridge unchanged."""
    class Second(Policy):
        name = "second"

        def decide(self, ctx: PolicyContext) -> TaskDecision:
            ids = ctx.feasible_ids
            order = ids[np.argsort(ctx.total[ids], kind="stable")]
            return TaskDecision(devices=(int(order[min(1, order.size - 1)]),))

    cluster = small_cluster(n=5, n_types=1)
    apps = random_apps(np.random.default_rng(0), 6, n_types=1)
    plans_b = orchestrate_batch(apps, cluster, Second(), batched=True)
    plans_s = orchestrate_batch(apps, cluster, Second(), batched=False)
    for a, b in zip(plans_b, plans_s):
        same_placement(a.placement, b.placement)


def test_batch_kernel_path_used_for_big_pools(monkeypatch):
    """Sanity: pools >= BATCH_KERNEL_MIN_ROWS reach the fused jax kernel
    (guard against silently always taking the scalar fallback)."""
    from repro.core import batched as bt

    if not bt.HAVE_JAX:
        pytest.skip("jax not installed")
    calls = []
    orig = bt.ibdash_decide_batch

    def spy(*a, **kw):
        calls.append(a[0].shape)
        return orig(*a, **kw)

    monkeypatch.setattr("repro.core.policy.ibdash_decide_batch", spy)
    rng = np.random.default_rng(5)
    cluster = small_cluster(n=8, seed=5)
    # many single-task apps with distinct mem footprints -> distinct pool rows
    apps = [AppDAG.from_tasks(f"a{i}", [TaskSpec(
        f"t#{i}", ttype=0, mem_bytes=float(i) * MB)])
        for i in range(BATCH_KERNEL_MIN_ROWS + 4)]
    orchestrate_batch(apps, cluster, make_policy("ibdash"))
    assert calls and calls[0][0] >= BATCH_KERNEL_MIN_ROWS


# ------------------------------------------------------ property (random) --
@st.composite
def parity_cases(draw):
    return dict(
        seed=draw(st.integers(min_value=0, max_value=10_000)),
        n_devices=draw(st.integers(min_value=1, max_value=10)),
        n_apps=draw(st.integers(min_value=1, max_value=10)),
        scheme=draw(st.sampled_from(ALL_SCHEMES)),
    )


@given(parity_cases())
@settings(max_examples=60, deadline=None)
def test_property_decide_batch_parity_random_fleets(case):
    """Property: batched == scalar over random fleets / DAGs / seeds for
    every registered policy, including the stateful round_robin cursor and
    the seeded random/petrel/lats draws."""
    rng = np.random.default_rng(case["seed"])
    cluster = small_cluster(n=case["n_devices"], seed=case["seed"],
                            lam=float(rng.uniform(1e-4, 0.5)))
    apps = random_apps(rng, case["n_apps"])
    pol_b, pol_s = fresh_policies(case["scheme"], seed=case["seed"])
    times = list(rng.uniform(0.0, 2.0, len(apps)))
    plans_b = orchestrate_batch(apps, cluster, pol_b, times=times)
    plans_s = orchestrate_batch(apps, cluster, pol_s, times=times,
                                batched=False)
    for a, b in zip(plans_b, plans_s):
        same_placement(a.placement, b.placement)


# ------------------------------------------- baseline empty-feasible guard --
def empty_feasible_ctx(n=4):
    z = np.zeros(n)
    return PolicyContext(
        task="t", ttype=0, t_start=0.0, stage_offset=0.0,
        exec_lat=z + 0.1, upload=z, transfer=z, total=z + 0.1,
        feasible=np.zeros(n, dtype=bool), feasible_ids=np.array([], dtype=int),
        pf=z + 0.5, lams=z + 1e-3, join_times=z, queue_len=z,
        counts=np.zeros((n, 1)), classes=np.arange(n),
    )


@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_policies_return_empty_decision_on_empty_feasible(scheme):
    pol, _ = fresh_policies(scheme)
    decision = pol.decide(empty_feasible_ctx())
    assert decision.devices == ()


def test_orchestrator_marks_plan_infeasible_not_crash():
    """End to end: a task too big for every device yields an infeasible
    plan under every scheme (the seed crashed inside the baselines)."""
    cluster = small_cluster(n=3, mem=1 * GB)
    app = AppDAG.from_tasks("big", [
        TaskSpec("ok", ttype=0),
        TaskSpec("huge", ttype=0, mem_bytes=5 * GB),
    ])
    for scheme in ALL_SCHEMES:
        pol, _ = fresh_policies(scheme)
        plan = orchestrate(app, cluster, 0.0, pol)
        assert not plan.feasible
        assert plan.placement.infeasible_task == "huge"
        assert "ok" in plan.placement.tasks      # earlier task still placed


def test_shared_model_id_with_different_sizes_not_conflated():
    """Two tasks sharing a model_id but disagreeing on its size must get
    their own upload latencies (the wave builder caches upload vectors per
    (model, size), not per model)."""
    cluster = small_cluster(n=2, n_types=1, bw=100 * MB)
    app = AppDAG.from_tasks("a", [
        TaskSpec("small", ttype=0, model_id="m", model_bytes=100 * MB),
        TaskSpec("big", ttype=0, model_id="m", model_bytes=400 * MB),
    ])
    plan = orchestrate(app, cluster, 0.0, make_policy("lavea"))
    assert plan.tasks["small"].replicas[0].est_upload == pytest.approx(1.0)
    assert plan.tasks["big"].replicas[0].est_upload == pytest.approx(4.0)


# ------------------------------------------------------ horizon clip fix --
def test_add_interval_clips_at_horizon_and_warns_once():
    cluster = small_cluster(n=2, n_types=1)
    h = cluster.horizon
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        cluster.add_interval(0, 0, h - 1.0, h + 50.0)     # clipped
        cluster.add_interval(0, 0, h + 10.0, h + 20.0)    # fully past: no-op
    assert len(caught) == 1                               # warned exactly once
    assert issubclass(caught[0].category, RuntimeWarning)
    # occupancy exists inside the horizon...
    assert cluster.counts_at(h - 0.5)[0, 0] == 1
    # ...but did NOT pile up in the final bucket beyond the single task
    assert cluster.alloc[0, 0, -1] <= 1
    # and the fully-past-horizon interval left no trace anywhere
    assert cluster.alloc[1].sum() == 0
    assert cluster.alloc[0, 0].sum() <= (1.0 / cluster.dt) + 2


def test_add_interval_clip_is_undo_symmetric():
    cluster = small_cluster(n=2, n_types=1)
    h = cluster.horizon
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        cluster.add_interval(1, 0, h - 2.0, h + 30.0, w=1.0)
        cluster.add_interval(1, 0, h - 2.0, h + 30.0, w=-1.0)
        cluster.add_interval(1, 0, h + 5.0, h + 9.0, w=1.0)
        cluster.add_interval(1, 0, h + 5.0, h + 9.0, w=-1.0)
    assert (cluster.alloc == 0).all()


def test_late_horizon_estimates_not_corrupted():
    """Occupancy far past the horizon must not inflate Eq. (1) estimates at
    the horizon edge (the seed piled every late interval into the last
    bucket)."""
    cluster = small_cluster(n=2, n_types=1)
    h = cluster.horizon
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        for _ in range(50):
            cluster.add_interval(0, 0, h + 1.0, h + 2.0)
    assert cluster.counts_at(h)[0, 0] == 0


# ----------------------------------------------------------- fused submit --
def test_fused_submit_batch_end_to_end(profile):
    cfg = SimConfig(n_cycles=1, instances_per_cycle=40, scenario="mix",
                    seed=2, n_devices=16)
    apps, times = _make_workload(cfg)
    cluster = make_cluster(profile, scenario=cfg.scenario,
                           n_devices=cfg.n_devices, seed=cfg.seed,
                           horizon=cfg.horizon + 30.0)
    orch = Orchestrator(cluster, "ibdash", seed=cfg.seed)
    orch.submit_batch(apps, times, fused=True)
    orch.drain()
    res = orch.result("mix", horizon=cfg.horizon)
    assert res.n == len(apps)
    assert all(np.isfinite(r.finished) for r in res.instances)
    assert res.prob_failure < 1.0


def test_fused_run_one_matches_instance_count(profile):
    """fused_burst plans one wave per cycle (cycle-start snapshot), and
    every instance across multiple cycles still resolves."""
    from repro.sim import run_one

    cfg = SimConfig(n_cycles=2, instances_per_cycle=30, scenario="ped",
                    seed=4, n_devices=16, fused_burst=True)
    res = run_one("ibdash", cfg, profile)
    assert res.n == 60
    assert all(r.failed or np.isfinite(r.service_time) for r in res.instances)
    assert all(np.isfinite(r.finished) for r in res.instances)


def test_fused_plans_share_snapshot(profile):
    """Fused plans are computed against one snapshot: identical app
    instances arriving at the same instant get identical placements under a
    stateless policy."""
    cfg = SimConfig(n_devices=12, seed=0)
    cluster = make_cluster(profile, scenario="mix", n_devices=12, seed=0)
    from repro.sim.apps import lightgbm_app

    apps = [lightgbm_app().relabel(f"#{i}") for i in range(5)]
    plans = orchestrate_batch(apps, cluster, policy_for("ibdash", profile, cfg))
    first = [(r.did for r in tp.replicas) for tp in plans[0].tasks.values()]
    for plan in plans[1:]:
        for (k0, tp0), (k1, tp1) in zip(plans[0].tasks.items(),
                                        plan.tasks.items()):
            assert [r.did for r in tp0.replicas] == [r.did for r in tp1.replicas]
