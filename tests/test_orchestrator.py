"""IBDASH Algorithm 1 + baseline policies: placement semantics."""
import numpy as np
import pytest

from repro.core.cluster import ClusterState, Device
from repro.core.dag import AppDAG, TaskSpec
from repro.core.interference import InterferenceModel
from repro.core.orchestrator import IBDASHConfig, orchestrate
from repro.core.policy import IBDASHPolicy, make_policy

GB = 1e9


def make_cluster(n=4, lam=(1e-6, 1e-6, 1e-6, 1e-6), base=(0.1, 0.2, 0.3, 0.4),
                 mem=8 * GB, bw=100e6):
    """n devices, each its own class with distinct base latency, 1 task type."""
    model = InterferenceModel(
        base=np.array(base)[:, None],
        slope=np.full((n, 1, 1), 0.05),
    )
    devices = [
        Device(did=i, cls=i, mem_total=mem, lam=lam[i], up_bw=bw, down_bw=bw)
        for i in range(n)
    ]
    return ClusterState(devices=devices, model=model, horizon=100.0, dt=0.05)


def single_task_app(mem=0.0, model_id=None, model_bytes=0.0):
    return AppDAG.from_tasks("app", [TaskSpec(
        "t0", ttype=0, mem_bytes=mem, model_id=model_id, model_bytes=model_bytes,
    )])


def place(policy, app, cluster, now=0.0):
    """Plan through the pure policy API (no mutation)."""
    return orchestrate(app, cluster, now, policy)


def test_picks_min_latency_device():
    cluster = make_cluster()
    p = place(IBDASHPolicy(), single_task_app(), cluster)
    assert p.feasible
    assert p.tasks["t0"].replicas[0].did == 0          # base 0.1 is fastest


def test_interference_steers_away_from_loaded_device():
    cluster = make_cluster()
    # pre-load device 0 with 10 concurrent tasks: 0.1 + 10*0.05 = 0.6 > 0.2
    cluster.add_interval(0, 0, 0.0, 50.0, w=10)
    p = place(IBDASHPolicy(), single_task_app(), cluster)
    assert p.tasks["t0"].replicas[0].did == 1


def test_memory_constraint_excludes_devices():
    cluster = make_cluster(mem=1 * GB)
    app = single_task_app(mem=2 * GB)
    p = place(IBDASHPolicy(), app, cluster)
    assert not p.feasible and p.infeasible_task == "t0"


def test_model_upload_latency_considered():
    cluster = make_cluster(bw=10e6)
    # 100 MB model: 10 s upload everywhere; but cache it on slow device 3
    cluster.devices[3].admit_model("m", 100e6)
    app = single_task_app(model_id="m", model_bytes=100e6)
    p = place(IBDASHPolicy(), app, cluster)
    # 0.4s exec on dev3 beats 0.1s + 10s upload on dev0
    assert p.tasks["t0"].replicas[0].did == 3
    assert p.tasks["t0"].replicas[0].est_upload == 0.0


def test_transfer_latency_colocates_children():
    cluster = make_cluster(bw=10e6)   # 50 MB transfer = 5 s
    app = AppDAG.from_tasks("app", [
        TaskSpec("parent", ttype=0, out_bytes=50e6),
        TaskSpec("child", ttype=0, deps=("parent",)),
    ])
    p = place(IBDASHPolicy(), app, cluster)
    assert p.tasks["child"].replicas[0].did == p.tasks["parent"].replicas[0].did


def test_replication_triggers_on_flaky_devices():
    # all devices very flaky (F ~ 5% per 0.1s task, above beta=1%) and
    # near-equal in latency, so the weighted score accepts the replica
    # (a 2x-slower replica would be correctly rejected by line 34)
    cluster = make_cluster(lam=(5e-1,) * 4, base=(0.1, 0.101, 0.102, 0.103))
    cfg = IBDASHConfig(alpha=0.2, beta=0.01, gamma=3)
    p = place(IBDASHPolicy(cfg), single_task_app(), cluster)
    tp = p.tasks["t0"]
    assert len(tp.replicas) > 1
    assert tp.pred_fail < tp.replicas[0].pred_fail      # replication reduced F
    # combined failure prob = product over replicas
    prod = np.prod([r.pred_fail for r in tp.replicas])
    assert tp.pred_fail == pytest.approx(prod)


def test_no_replication_on_reliable_devices():
    cluster = make_cluster(lam=(1e-9,) * 4)
    p = place(IBDASHPolicy(beta=0.1, gamma=3), single_task_app(), cluster)
    assert len(p.tasks["t0"].replicas) == 1


def test_gamma_caps_replication():
    cluster = make_cluster(lam=(9e-2,) * 4)
    cfg = IBDASHConfig(alpha=0.0, beta=1e-9, gamma=2)   # always wants more
    p = place(IBDASHPolicy(cfg), single_task_app(), cluster)
    assert len(p.tasks["t0"].replicas) <= 1 + 2


def test_place_is_pure_and_apply_commits_talloc():
    cluster = make_cluster()
    # planning alone must not touch T_alloc ...
    plan = orchestrate(single_task_app(), cluster, now=0.0,
                       policy=IBDASHPolicy())
    assert cluster.counts_at(0.01)[0, 0] == 0
    assert (cluster.alloc == 0).all()
    # ... the explicit apply step records the interval
    cluster.apply(plan)
    assert cluster.counts_at(0.01)[0, 0] >= 1           # interval recorded


def test_registry_policies_plan_without_mutating():
    cluster = make_cluster()
    for name in ("ibdash", "random", "round_robin", "lavea", "petrel"):
        p = place(make_policy(name, seed=0), single_task_app(), cluster)
        assert p.feasible
        assert (cluster.alloc == 0).all()


def test_eq3_stage_sum():
    cluster = make_cluster()
    app = AppDAG.from_tasks("app", [
        TaskSpec("a", ttype=0),
        TaskSpec("b", ttype=0, deps=("a",)),
        TaskSpec("c", ttype=0, deps=("b",)),
    ])
    p = place(IBDASHPolicy(), app, cluster)
    per_stage = [p.tasks[t].est_latency for t in ("a", "b", "c")]
    assert p.est_latency == pytest.approx(sum(per_stage), rel=1e-6)


def test_lavea_picks_shortest_queue():
    cluster = make_cluster()
    cluster.add_interval(0, 0, 0.0, 50.0, w=5)
    cluster.add_interval(1, 0, 0.0, 50.0, w=3)
    cluster.add_interval(2, 0, 0.0, 50.0, w=1)
    cluster.add_interval(3, 0, 0.0, 50.0, w=2)
    p = place(make_policy("lavea", seed=0), single_task_app(), cluster)
    assert p.tasks["t0"].replicas[0].did == 2


def test_round_robin_cycles():
    cluster = make_cluster()
    rr = make_policy("round_robin")
    dids = [place(rr, single_task_app(), cluster).tasks["t0"].replicas[0].did
            for _ in range(4)]
    assert dids == [0, 1, 2, 3]


def test_petrel_power_of_two():
    cluster = make_cluster()
    # device 0 fastest: petrel must never return an infeasible plan here
    pol = make_policy("petrel", seed=1)
    for _ in range(10):
        placement = place(pol, single_task_app(), cluster)
        assert placement.feasible


def test_baselines_single_replica():
    cluster = make_cluster(lam=(5e-2,) * 4)
    for name in ("random", "round_robin", "lavea", "petrel"):
        p = place(make_policy(name, seed=0), single_task_app(), cluster)
        assert len(p.tasks["t0"].replicas) == 1          # no replication in baselines
