"""End-to-end behaviour tests for the whole system.

Covers: training learns + survives a simulated failure; the edge simulator
reproduces the paper's headline ordering; multi-device distribution paths
(sharding rules, dry-run cell, pipeline parallelism) run in subprocesses
with forced host device counts (the main test process must keep 1 device).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_training_learns_and_survives_failure(tmp_path):
    from repro.launch.train import train

    out = train(
        "olmo-1b", use_reduced=True, steps=30, batch=8, seq=64, lr=5e-3,
        ckpt_dirs=(str(tmp_path / "a"), str(tmp_path / "b")),
        simulate_failure=15, log_every=1000,
    )
    assert out["final_loss"] < out["first_loss"]


def test_edge_sim_reproduces_paper_ordering():
    """IBDASH must beat the non-LaTS baselines on both paper metrics."""
    from repro.sim import SimConfig, make_profile, run_one

    cfg = SimConfig(n_cycles=3, instances_per_cycle=300, scenario="ped", seed=0)
    profile = make_profile(seed=0)
    res = {s: run_one(s, cfg, profile) for s in ("ibdash", "lavea", "petrel", "random")}
    for b in ("lavea", "petrel", "random"):
        assert res["ibdash"].avg_service_time < res[b].avg_service_time, b
        assert res["ibdash"].prob_failure <= res[b].prob_failure, b


def test_sharding_rules_on_production_mesh():
    run_sub("""
        import jax
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_production_mesh, dp_axes
        from repro.launch.sharding import param_pspec, batch_shardings, _dp_for
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        # attention projection: column-parallel
        assert param_pspec("segments/0/attn/wq/w", (16, 2048, 2048), mesh) == P(None, "data", "model")
        # embedding: vocab on model, FSDP on d
        assert param_pspec("embed/embedding", (50304, 2048), mesh) == P("model", "data")
        # whisper's odd vocab cannot shard on model -> falls back
        spec = param_pspec("embed/embedding", (51865, 384), mesh)
        assert spec[0] is None
        # experts: EP on expert dim
        assert param_pspec("segments/1/ffn/experts/wi", (58, 256, 7168, 2048), mesh)[1] == "model"
        # batch shardings: B=8 divisible by pod*data=4
        import jax.numpy as jnp
        specs = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        bs = batch_shardings(specs, mesh)
        assert bs["tokens"].spec == P(("pod", "data"))
        # B=1: replicated
        specs = {"tokens": jax.ShapeDtypeStruct((1, 64), jnp.int32)}
        assert batch_shardings(specs, mesh)["tokens"].spec == P(None)
        print("SHARDING-OK")
    """, devices=8)


def test_dryrun_cell_small_mesh():
    """A full dry-run cell (lower+compile+analysis) on an 8-device mesh."""
    run_sub("""
        import jax, numpy as np
        import repro.launch.dryrun as dr
        # shrink the production mesh for the test environment
        import repro.launch.mesh as mesh_mod
        mesh_mod.make_production_mesh = lambda multi_pod=False: (
            jax.make_mesh((2, 2, 2), ("pod", "data", "model")) if multi_pod
            else jax.make_mesh((4, 2), ("data", "model")))
        dr.make_production_mesh = mesh_mod.make_production_mesh
        rec = dr.run_cell("olmo-1b", "train_4k", "single")
        assert rec["status"] == "ok", rec.get("error", "") + rec.get("trace","")
        assert rec["flops_per_device"] > 0
        assert rec["collectives"]["total_bytes"] >= 0
        rec2 = dr.run_cell("olmo-1b", "decode_32k", "multi")
        assert rec2["status"] == "ok", rec2.get("error", "")
        print("DRYRUN-OK")
    """, devices=8, timeout=560)


def test_pipeline_parallel_matches_sequential():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.train.pipeline import pipeline_loss_fn, split_stages
        P_, L, d, V, M, mb, S = 4, 8, 32, 64, 6, 2, 16
        mesh = jax.make_mesh((P_,), ("stage",))
        rng = np.random.default_rng(0)
        stacked = {"w": jnp.asarray(rng.standard_normal((L, d, d))*0.05, jnp.float32)}
        params = {"stages": split_stages(stacked, P_),
                  "embed": {"e": jnp.asarray(rng.standard_normal((V, d))*0.5, jnp.float32)},
                  "head": {"h": jnp.asarray(rng.standard_normal((d, V))*0.5, jnp.float32)}}
        block = lambda lp, x: x + jnp.tanh(x @ lp["w"])
        embed = lambda ep, t: ep["e"][t]
        def loss(hp, y, l):
            lg = y @ hp["h"]
            return (jax.nn.logsumexp(lg, -1) - jnp.take_along_axis(lg, l[..., None], -1)[..., 0]).mean()
        batch = {"tokens": jnp.asarray(rng.integers(0, V, (M, mb, S)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, V, (M, mb, S)), jnp.int32)}
        pl_ = jax.jit(pipeline_loss_fn(mesh, block, embed, loss))(params, batch)
        ref = 0.0
        for m in range(M):
            x = embed(params["embed"], batch["tokens"][m])
            for i in range(L):
                x = block(jax.tree.map(lambda a: a[i], stacked), x)
            ref += loss(params["head"], x, batch["labels"][m])
        ref = ref / M
        assert abs(float(pl_) - float(ref)) < 1e-5, (float(pl_), float(ref))
        g = jax.jit(jax.grad(pipeline_loss_fn(mesh, block, embed, loss)))(params, batch)
        assert sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g)) > 0
        print("PIPELINE-OK")
    """, devices=8)


def test_compressed_cross_pod_step():
    """int8 cross-pod gradient reduction lowers and runs on a pod mesh."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import LM, reduced
        from repro.optim.optimizers import AdamW
        from repro.train.step import make_train_step
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = reduced(get_config("olmo-1b"), n_layers=1, vocab=128)
        model = LM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = AdamW(lr=1e-3)
        step_c = make_train_step(model, opt, mesh=mesh, grad_compression="int8")
        step_p = make_train_step(model, opt)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab),
                 "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, cfg.vocab)}
        with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
            p2, s2, m2 = jax.jit(step_c)(params, opt.init(params), batch, jax.random.PRNGKey(3))
        p1, s1, m1 = jax.jit(step_p)(params, opt.init(params), batch)
        # int8-compressed grads: loss identical, params close to uncompressed
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
        d = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert d < 5e-3, d
        print("COMPRESS-OK")
    """, devices=8)
