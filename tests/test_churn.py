"""Churn runtime: device join/leave streams, failure detection, recovery.

Pins the PR's contracts:
  * a policy can NEVER select a device that already departed (the alive
    mask threaded ClusterState.snapshot -> FleetSnapshot ->
    BatchedPolicyContext -> feasibility);
  * ``fail_fast`` on churn-free runs is bit-identical to the engine's
    default path for all six policies;
  * under churn, ``failover``/``replan`` never lose more instances than
    ``fail_fast`` (property-tested over random schedules) and strictly
    reduce P_f on the benchmark fleet;
  * the occupancy bookkeeping nets to exactly the executed work after
    ``drain()`` — killed replicas and failed apps leave zero ghost residue;
  * FleetMonitor's online lambda MLE (the shared fit_failure_rate
    estimator) feeds the churn generator end-to-end.
"""
from dataclasses import replace

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.api import Orchestrator, make_policy, make_recovery, orchestrate
from repro.core.cluster import ClusterState, Device
from repro.core.dag import AppDAG, TaskSpec
from repro.core.interference import InterferenceModel
from repro.core.recovery import available_recoveries
from repro.ft.runtime import FleetMonitor
from repro.sim import SimConfig, make_cluster, make_profile, run_one
from repro.sim.churn import (
    ChurnSchedule,
    churn_from_monitor,
    deterministic_churn,
    exponential_churn,
    trace_churn,
)
from repro.sim.engine import Engine
from repro.sim.runner import SCHEME_NAMES, _make_workload, policy_for

GB = 1e9
MB = 1e6


@pytest.fixture(scope="module")
def profile():
    return make_profile(seed=0)


def small_cluster(n=4, lam=1e-6, base=None, alive_until=None, horizon=100.0):
    """n single-type devices, device i is class i (distinct base latency)."""
    base = np.linspace(0.1, 0.4, n) if base is None else np.asarray(base)
    model = InterferenceModel(
        base=base[:, None], slope=np.full((n, 1, 1), 0.05)
    )
    devices = [
        Device(did=i, cls=i, mem_total=8 * GB, lam=lam, up_bw=100e6, down_bw=100e6,
               alive_until=(alive_until[i] if alive_until is not None
                            else float("inf")))
        for i in range(n)
    ]
    return ClusterState(devices=devices, model=model, horizon=horizon, dt=0.05)


def one_task_app(name="app"):
    return AppDAG.from_tasks(name, [TaskSpec("t0", ttype=0)])


def two_par_app(name="app"):
    """One stage, two parallel tasks (the cancel-running-siblings shape)."""
    return AppDAG.from_tasks(name, [
        TaskSpec("a", ttype=0),
        TaskSpec("b", ttype=0),
    ])


# ---------------------------------------------------- dead-device masking --
def _policies(profile, cfg):
    return [policy_for(name, profile, cfg) for name in SCHEME_NAMES]


@pytest.mark.parametrize("scheme", SCHEME_NAMES + ("tier_escalation",))
def test_policy_never_selects_dead_device(profile, scheme):
    """Satellite-1 regression: device 0 is the FASTEST but departed at
    t=1.0; planning at t=2.0 must not place anything on it — for every
    registered policy, scalar and batched paths alike."""
    cfg = SimConfig(seed=0)
    for batched in (True, False):
        cluster = small_cluster(alive_until=[1.0, np.inf, np.inf, np.inf])
        pol = policy_for(scheme, profile, cfg)
        plan = orchestrate(one_task_app(), cluster, 2.0, pol, batched=batched)
        assert plan.feasible
        assert all(
            rep.did != 0 for rep in plan.tasks["t0"].replicas
        ), f"{scheme} placed on a dead device (batched={batched})"


def test_dead_device_masked_only_after_departure(profile):
    """Before its departure the device is a normal candidate (future deaths
    stay silent — only pf prices them); after it, it is infeasible."""
    cluster = small_cluster(alive_until=[1.0, np.inf, np.inf, np.inf])
    pol = make_policy("lavea")
    before = orchestrate(one_task_app(), cluster, 0.5, pol)
    after = orchestrate(one_task_app(), cluster, 2.0, pol)
    assert before.tasks["t0"].replicas[0].did == 0     # fastest, still up
    assert after.tasks["t0"].replicas[0].did != 0


def test_snapshot_alive_mask():
    cluster = small_cluster(alive_until=[1.0, 5.0, np.inf, np.inf])
    assert cluster.snapshot(0.0).alive.tolist() == [True, True, True, True]
    assert cluster.snapshot(2.0).alive.tolist() == [False, True, True, True]
    assert cluster.snapshot(6.0).alive.tolist() == [False, False, True, True]


def test_all_devices_dead_is_infeasible(profile):
    cluster = small_cluster(alive_until=[1.0, 1.0, 1.0, 1.0])
    plan = orchestrate(one_task_app(), cluster, 2.0, make_policy("random"))
    assert not plan.feasible and plan.placement.infeasible_task == "t0"


def test_mark_down_and_up_roundtrip():
    cluster = small_cluster()
    cluster.mark_down(1, 3.0)
    assert not cluster.alive_mask(3.0)[1]
    cluster.mark_up(1, 7.0, alive_until=20.0)
    assert cluster.alive_mask(7.5)[1]
    assert not cluster.alive_mask(25.0)[1]
    assert cluster.devices[1].join_time == 7.0
    assert cluster.devices[1].model_cache == {}        # rejoined cold


# -------------------------------------------------- churn-free bit-parity --
def _result_fingerprint(res):
    return [
        (r.app, r.arrival, r.finished, r.failed, r.service_time,
         r.n_replicas, r.pred_latency, r.pred_fail)
        for r in res.instances
    ]


@pytest.mark.parametrize("scheme", SCHEME_NAMES)
def test_fail_fast_churn_free_bit_identical(profile, scheme):
    """Satellite-3 invariant: recovery="fail_fast" with churn disabled IS
    the default engine, bit-for-bit, for all six policies on a scenario
    where devices do die (the passive failure path runs)."""
    cfg = SimConfig(n_cycles=2, instances_per_cycle=80, scenario="ped", seed=3)
    a = run_one(scheme, cfg, profile)
    b = run_one(scheme, replace(cfg, recovery="fail_fast", churn=False), profile)
    assert _result_fingerprint(a) == _result_fingerprint(b)
    assert (a.load_per_device == b.load_per_device).all()


def test_empty_schedule_bit_identical_to_no_churn(profile):
    """An installed schedule with zero events must not perturb anything on
    an immortal fleet (the event machinery itself is inert)."""
    cfg = SimConfig(seed=0)
    apps = [one_task_app(f"#{i}") for i in range(8)]
    times = [0.3 * i for i in range(8)]
    runs = []
    for churn in (None, deterministic_churn([])):
        cluster = small_cluster()
        eng = Engine(cluster, policy_for("ibdash", profile, cfg), seed=0,
                     churn=churn)
        eng.add_arrivals(apps, times)
        eng.drain()
        runs.append((
            [(r.failed, r.finished, r.service_time) for r in eng.records],
            cluster.alloc.copy(),
        ))
    assert runs[0][0] == runs[1][0]
    assert np.array_equal(runs[0][1], runs[1][1])


# ------------------------------------------------- engine churn semantics --
def test_device_down_kills_inflight_and_returns_capacity():
    """A departing device's in-flight replica dies AT the departure (not at
    its estimated completion), its unfinished occupancy is returned, and
    fail_fast loses the instance at that moment."""
    cluster = small_cluster(base=[0.5, 0.5, 0.5, 0.5], lam=1e-4)
    churn = deterministic_churn([(0.2, 0, "leave")])
    eng = Engine(cluster, make_policy("lavea"), noise_sigma=0.0, churn=churn,
                 recovery="fail_fast", track_intervals=True)
    eng.add_arrivals([one_task_app()], [0.0])
    eng.drain()
    rec = eng.records[0]
    assert rec.failed and rec.finished == pytest.approx(0.2)
    assert eng.stats["device_down"] == 1
    assert eng.stats["replica_deaths"] == 1
    assert eng.stats["lost"] == 1
    # capacity returned: no occupancy anywhere after the kill's bucket
    b = cluster.bucket(0.2) + 1
    assert float(np.abs(cluster.alloc[:, :, b:]).max()) == 0.0


def test_device_rejoins_and_is_readmitted():
    cluster = small_cluster(base=[0.1, 0.4, 0.4, 0.4])
    churn = deterministic_churn([(1.0, 0, "leave"), (2.0, 0, "join")])
    eng = Engine(cluster, make_policy("lavea"), noise_sigma=0.0, churn=churn)
    eng.run(until=1.5)      # the departure fired, the rejoin has not
    down = orchestrate(one_task_app(), cluster, 1.5, make_policy("lavea"))
    assert down.tasks["t0"].replicas[0].did != 0
    eng.run(until=5.0)      # the rejoin fired
    up = orchestrate(one_task_app(), cluster, 4.0, make_policy("lavea"))
    # rejoined empty and idle: the fast device wins again
    assert up.tasks["t0"].replicas[0].did == 0
    assert eng.stats["device_up"] == 1


def test_failed_app_cancels_running_siblings():
    """Satellite-2 regression: when an app fails mid-stage, in-flight
    sibling replicas of its OTHER tasks stop occupying T_alloc from the
    failure instant (their output is discarded anyway)."""
    # device 0: fast but dies mid-task; device 1: slow (long sibling run)
    cluster = small_cluster(n=2, base=[0.2, 5.0],
                            alive_until=[0.05, np.inf])
    eng = Engine(cluster, make_policy("round_robin"), noise_sigma=0.0,
                 track_intervals=True)
    eng.add_arrivals([two_par_app()], [0.0])
    eng.drain()
    rec = eng.records[0]
    assert rec.failed
    t_fail = rec.finished                      # task a's passive death
    assert t_fail == pytest.approx(0.2)
    # sibling b (5 s on device 1) was cancelled at the failure: nothing
    # occupies any device afterwards
    b = cluster.bucket(t_fail) + 1
    assert float(np.abs(cluster.alloc[:, :, b:]).max()) == 0.0
    # and the executed log shows b's run was cut at the failure time
    cuts = [e for e in eng.executed if e[0] == 1 and e[4] < e[3]]
    assert len(cuts) == 1 and cuts[0][4] == pytest.approx(t_fail)


def _rebuild_alloc(cluster_factory, executed):
    """Replay an engine's executed-interval log onto a fresh cluster."""
    c = cluster_factory()
    for did, ttype, t0, t1, t_cut in executed:
        c.add_interval(did, ttype, t0, t1)
        if t_cut < t1:
            c.cancel_from(did, ttype, t0, t1, t_cut)
    return c.alloc


@pytest.mark.parametrize("recovery", ("fail_fast", "failover", "replan"))
def test_occupancy_nets_to_executed_work_after_drain(profile, recovery):
    """Satellite-3 invariant: after drain() the T_alloc tensor equals
    EXACTLY the replay of actual execution spans — every provisional
    interval, killed replica and cancelled sibling netted out to zero."""
    cfg = SimConfig(scenario="churn", n_cycles=2, instances_per_cycle=60,
                    seed=3, n_devices=24, recovery=recovery)
    mk = lambda: make_cluster(profile, scenario="churn", n_devices=24, seed=3,
                              horizon=cfg.horizon + 60.0)
    cluster = mk()
    churn = exponential_churn(cluster, horizon=cfg.horizon + 25.0, seed=104)
    orch = Orchestrator(cluster, policy_for("ibdash", profile, cfg), seed=3,
                        churn=churn, recovery=cfg.recovery,
                        track_intervals=True)
    apps, times = _make_workload(cfg)
    orch.submit_batch(apps, times)
    orch.drain()
    assert orch.pending_events == 0
    rebuilt = _rebuild_alloc(mk, orch.engine.executed)
    assert np.array_equal(np.asarray(cluster.alloc), rebuilt)


# ----------------------------------------------------- recovery semantics --
def test_recovery_registry():
    assert {"fail_fast", "failover", "replan"} <= set(available_recoveries())
    with pytest.raises(ValueError, match="unknown recovery"):
        make_recovery("nope")
    r = make_recovery("failover", detection_delay=0.5, max_retries=3)
    assert (r.detection_delay, r.max_retries) == (0.5, 3)


def _run_recovery(profile, recovery, scheme="random",
                  cfg=None) -> tuple:
    cfg = cfg or SimConfig(scenario="churn", n_cycles=2,
                           instances_per_cycle=120, seed=3)
    cluster = make_cluster(profile, scenario="churn", n_devices=100,
                           seed=3, horizon=cfg.horizon + 30.0)
    churn = exponential_churn(cluster, horizon=cfg.horizon + 25.0, seed=104)
    orch = Orchestrator(cluster, policy_for(scheme, profile, cfg), seed=3,
                        churn=churn, recovery=recovery)
    apps, times = _make_workload(cfg)
    orch.submit_batch(apps, times)
    orch.drain()
    return orch.result("churn", cfg.horizon), orch.stats


def test_failover_and_replan_reduce_failures(profile):
    """The acceptance scenario: same fleet, same churn, same workload —
    failover and replan each strictly reduce P_f vs fail_fast."""
    ff, s_ff = _run_recovery(profile, "fail_fast")
    fo, s_fo = _run_recovery(profile, "failover")
    rp, s_rp = _run_recovery(profile, "replan")
    assert s_ff["lost"] > 0                        # churn actually bites
    assert fo.prob_failure < ff.prob_failure
    assert rp.prob_failure < ff.prob_failure
    assert s_fo["task_failovers"] > 0 and s_fo["recovered"] > 0
    assert s_rp["replans"] > 0 and s_rp["recovered"] > 0


def test_failover_retry_lands_on_live_device(profile):
    """The failover replica goes to a surviving device and completes."""
    cluster = small_cluster(base=[0.3, 0.35, 0.4, 0.45], lam=1e-4)
    churn = deterministic_churn([(0.1, 0, "leave")])
    eng = Engine(cluster, make_policy("lavea"), noise_sigma=0.0, churn=churn,
                 recovery=make_recovery("failover", detection_delay=0.05))
    eng.add_arrivals([one_task_app()], [0.0])
    eng.drain()
    rec = eng.records[0]
    assert not rec.failed
    assert eng.stats["task_failovers"] == 1
    assert eng.stats["recovered"] == 1
    # the task's recorded home moved off the dead device
    assert eng.load[0] == 1 and eng.load[1:].sum() == 1


def test_replan_repaints_downstream_stages(profile):
    """replan re-places the dead task AND the not-yet-started downstream
    stage on the survivors, through the pure pinned-orchestrate path."""
    app = AppDAG.from_tasks("chain", [
        TaskSpec("a", ttype=0, out_bytes=1 * MB),
        TaskSpec("b", ttype=0, deps=("a",)),
    ])
    cluster = small_cluster(base=[0.3, 0.32, 0.34, 0.36], lam=1e-4)
    churn = deterministic_churn([(0.1, 0, "leave")])
    eng = Engine(cluster, make_policy("lavea"), noise_sigma=0.0, churn=churn,
                 recovery=make_recovery("replan", detection_delay=0.05),
                 track_intervals=True)
    eng.add_arrivals([app], [0.0])
    eng.drain()
    rec = eng.records[0]
    assert not rec.failed
    assert eng.stats["replans"] == 1
    assert eng.load[0] == 1                        # only a's first attempt
    # post-run occupancy equals the executed work exactly (no ghost from
    # the replaced provisional intervals)
    mk = lambda: small_cluster(base=[0.3, 0.32, 0.34, 0.36], lam=1e-4)
    assert np.array_equal(
        np.asarray(cluster.alloc), _rebuild_alloc(mk, eng.executed)
    )


def test_no_survivor_means_lost(profile):
    cluster = small_cluster(n=2, base=[0.3, 0.35], lam=1e-4)
    churn = deterministic_churn([(0.1, 0, "leave"), (0.12, 1, "leave")])
    eng = Engine(cluster, make_policy("lavea"), noise_sigma=0.0, churn=churn,
                 recovery=make_recovery("failover", detection_delay=0.05))
    eng.add_arrivals([one_task_app()], [0.0])
    eng.drain()
    assert eng.records[0].failed
    assert eng.stats["lost"] == 1


# ------------------------------------------------------- property testing --
@settings(max_examples=20, deadline=None, derandomize=True)
@given(
    deaths=st.lists(
        st.tuples(
            st.floats(min_value=0.05, max_value=24.0),
            st.integers(min_value=0, max_value=3),
            st.one_of(st.none(), st.floats(min_value=0.3, max_value=4.0)),
        ),
        min_size=1, max_size=6,
    )
)
def test_recovery_never_loses_more_than_fail_fast(deaths):
    """Under ANY churn schedule, failover and replan never lose more
    instances than fail_fast.  Arrivals are spaced wider than any app's
    lifetime so instances are independent — recovery work for one cannot
    perturb another."""
    events = []
    for t, did, rejoin_after in deaths:
        events.append((t, did, "leave"))
        if rejoin_after is not None:
            events.append((t + rejoin_after, did, "join"))
    schedule = deterministic_churn(events)
    app = AppDAG.from_tasks("chain", [
        TaskSpec("a", ttype=0, out_bytes=1 * MB),
        TaskSpec("b", ttype=0, deps=("a",)),
    ])
    apps = [app.relabel(f"#{i}") for i in range(5)]
    times = [5.0 * i for i in range(5)]           # isolation spacing
    lost = {}
    for recovery in ("fail_fast", "failover", "replan"):
        cluster = small_cluster(base=[0.3, 0.32, 0.34, 0.36], lam=1e-4)
        eng = Engine(cluster, make_policy("lavea"), noise_sigma=0.0,
                     churn=ChurnSchedule(schedule.events),
                     recovery=make_recovery(recovery, detection_delay=0.1))
        eng.add_arrivals(apps, times)
        eng.drain()
        lost[recovery] = sum(r.failed for r in eng.records)
        # occupancy sums to zero beyond the final event in every mode
        assert float(np.abs(cluster.alloc[:, :, cluster.bucket(60.0):]).max()) == 0.0
    assert lost["failover"] <= lost["fail_fast"]
    assert lost["replan"] <= lost["fail_fast"]


# -------------------------------------------------- trace / monitor wiring --
def test_trace_churn_replay():
    sched = trace_churn([
        (1.0, 0, False), (2.0, 0, True), (3.0, 0, False),
        (0.5, 1, True), (4.0, 1, False),
    ])
    kinds = [(e.t, e.did, e.kind) for e in sched.events]
    assert kinds == [
        (1.0, 0, "leave"), (2.0, 0, "join"), (3.0, 0, "leave"),
        (4.0, 1, "leave"),
    ]
    # the join is re-armed with the device's next departure
    join = [e for e in sched.events if e.kind == "join"][0]
    assert join.until == 3.0
    assert sched.first_leave(0) == 1.0 and sched.first_leave(2) == np.inf


def test_install_makes_schedule_own_lifetimes():
    cluster = small_cluster(alive_until=[50.0, 50.0, 50.0, 50.0])
    deterministic_churn([(7.0, 2, "leave")]).install(cluster)
    au = [d.alive_until for d in cluster.devices]
    assert au == [np.inf, np.inf, 7.0, np.inf]
    assert cluster.alive_mask(8.0).tolist() == [True, True, False, True]


def test_monitor_lam_is_the_shared_mle():
    """FleetMonitor's online estimate == fit_failure_rate on the same
    exposure/death ledger."""
    from repro.core.availability import fit_failure_rate

    mon = FleetMonitor(timeout=2.0)
    for pid in ("p0", "p1", "p2", "p3"):
        mon.join(pid, cls="spot", now=0.0)
    for t in range(1, 11):
        for pid in ("p0", "p1"):
            mon.heartbeat(pid, now=float(t))
    # p2/p3 never heartbeat again -> dead on sweep
    mon.sweep(now=10.0)
    assert mon.lam("spot") == pytest.approx(
        fit_failure_rate([20.0, 0.0, 0.0], [True, False, False])
    )
    assert mon.lam("spot") == pytest.approx(2 / 20.0)


def test_churn_from_monitor_end_to_end(profile):
    """Satellite-6: the monitor's fitted rates drive the churn generator —
    a flaky-observed fleet produces a dense schedule, a solid-observed one
    produces none."""
    flaky, solid = FleetMonitor(timeout=2.0), FleetMonitor(timeout=2.0)
    for mon, keep in ((flaky, 1), (solid, 40)):
        for i in range(40):
            mon.join(f"p{i}", cls="0", now=0.0)
        for t in range(1, 6):
            for i in range(keep):
                mon.heartbeat(f"p{i}", now=float(t))
        mon.sweep(now=5.0)
    cluster = small_cluster(n=4, lam=1e-6)
    for d in cluster.devices:
        d.cls = 0                                   # one monitor class
    cluster.refresh_topology()
    dense = churn_from_monitor(flaky, cluster, horizon=100.0, seed=1)
    sparse = churn_from_monitor(solid, cluster, horizon=100.0, seed=1)
    assert flaky.lam("0") > solid.lam("0")
    assert dense.n_events > sparse.n_events
    # and the schedule slots straight into the engine
    eng = Engine(cluster, make_policy("lavea"), churn=dense,
                 recovery="failover")
    eng.add_arrivals([one_task_app()], [0.0])
    eng.drain()
    assert len(eng.records) == 1


# ------------------------------------------------------------ end-to-end --
def test_simconfig_churn_replan_end_to_end(profile):
    """The acceptance smoke: SimConfig(scenario="churn", recovery="replan")
    runs through run_one unmodified."""
    cfg = SimConfig(scenario="churn", recovery="replan", n_cycles=2,
                    instances_per_cycle=60, seed=3, n_devices=32)
    res = run_one("ibdash", cfg, profile)
    assert res.n == 120
    assert all(r.failed or np.isfinite(r.service_time) for r in res.instances)


def test_serving_fleet_churn_replan(profile):
    """Replica preemption in the serving fleet: replan re-shards in-flight
    requests onto surviving replicas and loses no more than fail_fast."""
    from repro.serve.scheduler import ServingFleet, serving_interference_model

    interference = serving_interference_model()
    results = {}
    for recovery in ("fail_fast", "replan"):
        fleet = ServingFleet(
            interference, n_replicas=8, seed=0, horizon=60.0,
            lams=(1e-5, 2e-2),                     # very flaky spot pool
            churn=True, recovery=recovery, detection_delay=0.05,
        )
        res = fleet.run(n_requests=120, arrival_window=30.0, seed=1)
        results[recovery] = (res.prob_failure, fleet.orchestrator.stats)
    pf_ff, stats_ff = results["fail_fast"]
    pf_rp, stats_rp = results["replan"]
    assert stats_ff["device_down"] > 0
    assert pf_rp <= pf_ff
    if stats_ff["lost"] > 0:
        assert stats_rp["replans"] > 0
