"""The orchestration-contract linter (repro.analysis).

Covers: each rule fires on its violating golden fixture and stays silent
on the clean one; inline and file-level suppressions; the default config
excluding the fixture directory; the JSON report shape; the runtime
snapshot-schema twin (FleetSnapshot.validate); and the self-clean gate —
``python -m repro.analysis src tests benchmarks examples`` exits 0 on
this very repo.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import (
    Analyzer,
    LintConfig,
    RuleSettings,
    available_rules,
    report_dict,
)

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "lint"

ALL_RULES = (
    "rng-discipline",
    "policy-purity",
    "snapshot-schema",
    "jit-hygiene",
    "deprecation",
    "registry-parity",
    "kernel-hygiene",
    "unit-consistency",
    "span-parity",
)

# the jaxpr auditor can only trace when jax is importable; everything else
# in this suite is dependency-free
NEEDS_JAX = ("kernel-hygiene",)


def run_rule(rule, path, options=None, root=REPO):
    """Run ONE rule over one file/dir with everywhere-scoping."""
    cfg = LintConfig(
        exclude=(),
        select=(rule,),
        rules={rule: RuleSettings(paths=("",), options=options or {})},
    )
    return Analyzer(cfg, root=str(root)).run([str(path)])


# -- the six golden fixture pairs ---------------------------------------------

FIXTURE_OPTIONS = {
    # the wall-clock check is path-scoped to src/repro by default; point it
    # at everything so the fixture exercises it too
    "rng-discipline": {"time_call_paths": ("",)},
    # inject a registry so the fixture is hermetic: "mystery_scheme" is
    # registered but only the clean fixture ever names it
    "registry-parity": {
        "test_paths": ("",),
        "policies": ("ibdash", "mystery_scheme"),
        "recoveries": ("fail_fast",),
    },
    # hermetic schema; no test files scanned, so only the literal/schema
    # halves of the contract are exercised (the test-pin half has its own
    # two-file test below)
    "span-parity": {
        "src_paths": ("",),
        "test_paths": (),
        "schema": ("exec", "plan"),
    },
}

FIXTURE_STEMS = {
    "rng-discipline": "rng",
    "policy-purity": "purity",
    "snapshot-schema": "schema",
    "jit-hygiene": "jit",
    "deprecation": "deprecation",
    "registry-parity": "registry",
    "kernel-hygiene": "kernel",
    "unit-consistency": "unit",
    "span-parity": "span",
}

# every violation the fixture encodes must be reported (count pins the
# rule's sensitivity, not just its existence)
MIN_VIOLATIONS = {
    "rng-discipline": 4,      # import random, global draw, seed(), default_rng()
    "policy-purity": 4,       # apply, ctx store, __setattr__, snapshot store
    "snapshot-schema": 2,     # positional + missing leaves
    "jit-hygiene": 4,         # if-on-tracer, .item(), float(), while/np.asarray
    "deprecation": 4,         # Device(bandwidth=), bandwidths(), 2 latency shims
    "registry-parity": 1,     # mystery_scheme unpinned
    "kernel-hygiene": 4,      # f32 const + callback, 3-vs-1 lowerings, donation
    "unit-consistency": 5,    # s+B, B-vs-s, exp(s), where(s,B), prob-vs-count
    "span-parity": 4,         # 2 off-schema kinds, 2 computed kinds
}


@pytest.mark.parametrize("rule", ALL_RULES)
def test_rule_fires_on_violating_fixture(rule):
    if rule in NEEDS_JAX:
        pytest.importorskip("jax")
    path = FIXTURES / f"{FIXTURE_STEMS[rule]}_violation.py"
    report = run_rule(rule, path, FIXTURE_OPTIONS.get(rule))
    assert len(report.findings) >= MIN_VIOLATIONS[rule], report.findings
    assert all(f.rule == rule for f in report.findings)
    assert all(f.severity == "error" for f in report.findings)


@pytest.mark.parametrize("rule", ALL_RULES)
def test_rule_silent_on_clean_fixture(rule):
    if rule in NEEDS_JAX:
        pytest.importorskip("jax")
    path = FIXTURES / f"{FIXTURE_STEMS[rule]}_clean.py"
    report = run_rule(rule, path, FIXTURE_OPTIONS.get(rule))
    assert report.findings == [], [f.format() for f in report.findings]


def test_all_rules_registered():
    assert set(ALL_RULES) <= set(available_rules())


# -- suppressions --------------------------------------------------------------

def test_inline_suppression(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text(
        "import numpy as np\n"
        "x = np.random.normal()  # repro-lint: disable=rng-discipline\n"
        "y = np.random.uniform()\n"
    )
    report = run_rule("rng-discipline", f, root=tmp_path)
    assert len(report.findings) == 1          # only the unsuppressed line
    assert report.findings[0].line == 3
    assert report.suppressed == 1


def test_file_level_suppression(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text(
        "# repro-lint: disable-file=rng-discipline\n"
        "import numpy as np\n"
        "x = np.random.normal()\n"
        "y = np.random.uniform()\n"
    )
    report = run_rule("rng-discipline", f, root=tmp_path)
    assert report.findings == []
    assert report.suppressed == 2


def test_suppression_is_rule_scoped(tmp_path):
    """disable=<other-rule> must NOT silence a different rule's finding."""
    f = tmp_path / "mod.py"
    f.write_text(
        "import numpy as np\n"
        "x = np.random.normal()  # repro-lint: disable=deprecation\n"
    )
    report = run_rule("rng-discipline", f, root=tmp_path)
    assert len(report.findings) == 1
    assert report.suppressed == 0


# -- config / scoping ----------------------------------------------------------

def test_default_config_excludes_fixtures():
    report = Analyzer(LintConfig(), root=str(REPO)).run([str(FIXTURES)])
    assert report.files_scanned == 0
    assert report.findings == []


def test_path_scoping(tmp_path):
    """A rule scoped to src/ must ignore violations elsewhere."""
    (tmp_path / "src").mkdir()
    (tmp_path / "other").mkdir()
    (tmp_path / "src" / "a.py").write_text("import random\n")
    (tmp_path / "other" / "b.py").write_text("import random\n")
    cfg = LintConfig(
        exclude=(), select=("rng-discipline",),
        rules={"rng-discipline": RuleSettings(paths=("src/",))},
    )
    report = Analyzer(cfg, root=str(tmp_path)).run([str(tmp_path)])
    assert [f.path for f in report.findings] == ["src/a.py"]


def test_parse_error_is_a_finding(tmp_path):
    f = tmp_path / "broken.py"
    f.write_text("def oops(:\n")
    report = run_rule("rng-discipline", f, root=tmp_path)
    assert [f.rule for f in report.findings] == ["parse-error"]
    assert report.exit_code == 1


def test_span_parity_requires_test_pin(tmp_path):
    """A kind emitted in src but never named in a scanned test file is an
    unpinned span — and naming it silences the finding."""
    (tmp_path / "src").mkdir()
    (tmp_path / "tests").mkdir()
    (tmp_path / "src" / "emit.py").write_text(
        'def go(tr, tid, t):\n    tr.event(tid, "exec", t)\n'
    )
    (tmp_path / "tests" / "test_spans.py").write_text("x = 'unrelated'\n")
    opts = {"schema": ("exec",)}
    report = run_rule("span-parity", tmp_path, options=opts, root=tmp_path)
    assert len(report.findings) == 1
    assert "no behavioural pin" in report.findings[0].message
    (tmp_path / "tests" / "test_spans.py").write_text("kinds = ('exec',)\n")
    report = run_rule("span-parity", tmp_path, options=opts, root=tmp_path)
    assert report.findings == []


def test_span_parity_silent_without_emissions(tmp_path):
    """Linting only tests (no emitting src files) must not guess."""
    f = tmp_path / "mod.py"
    f.write_text("x = 'exec'\n")
    report = run_rule(
        "span-parity", f,
        options={"test_paths": ("",), "src_paths": ("src",),
                 "schema": ("exec",)},
        root=tmp_path,
    )
    assert report.findings == []


def test_registry_parity_silent_without_test_files(tmp_path):
    """Linting only src must not guess about parity pins."""
    f = tmp_path / "mod.py"
    f.write_text("x = 1\n")
    report = run_rule(
        "registry-parity", f,
        options={"test_paths": ("tests",),
                 "policies": ("ibdash",), "recoveries": ()},
        root=tmp_path,
    )
    assert report.findings == []


# -- reporters -----------------------------------------------------------------

def test_json_report_shape():
    report = run_rule(
        "deprecation", FIXTURES / "deprecation_violation.py"
    )
    d = report_dict(report)
    assert d["version"] == 1
    assert d["errors"] == len(d["findings"]) > 0
    assert d["elapsed_s"] >= 0  # the CI wall-clock budget record
    f = d["findings"][0]
    assert set(f) == {"rule", "severity", "path", "line", "col", "message"}
    json.dumps(d)  # must be serialisable


# -- the runtime snapshot-schema twin ------------------------------------------

def _tiny_cluster():
    from repro.core.cluster import ClusterState, Device
    from repro.core.interference import InterferenceModel

    model = InterferenceModel(base=np.array([[0.1]]),
                              slope=np.full((1, 1, 1), 0.05))
    devices = [Device(did=i, cls=0, mem_total=1e9, lam=1e-3,
                      up_bw=1e8, down_bw=1e8) for i in range(2)]
    return ClusterState(devices=devices, model=model, horizon=10.0, dt=0.05)


def test_snapshot_validate_passes_and_chains():
    snap = _tiny_cluster().snapshot(0.0)
    assert snap.validate() is snap


def test_snapshot_validate_catches_leaf_drift(monkeypatch):
    from repro.core import batched

    snap = _tiny_cluster().snapshot(0.0)
    monkeypatch.setattr(
        batched, "FLEET_SNAPSHOT_SCHEMA", batched.FLEET_SNAPSHOT_SCHEMA[:-1]
    )
    with pytest.raises(TypeError, match="leaf drift"):
        snap.validate()


def test_cluster_snapshot_asserts_schema_under_debug(monkeypatch):
    from repro.core import batched

    cluster = _tiny_cluster()
    monkeypatch.setattr(
        batched, "FLEET_SNAPSHOT_SCHEMA",
        batched.FLEET_SNAPSHOT_SCHEMA + ("ghost_leaf",),
    )
    with pytest.raises(TypeError, match="leaf drift"):
        cluster.snapshot(0.0)


def test_schema_matches_dataclass_fields():
    from dataclasses import fields

    from repro.core.batched import FLEET_SNAPSHOT_SCHEMA, FleetSnapshot

    assert tuple(f.name for f in fields(FleetSnapshot)) == FLEET_SNAPSHOT_SCHEMA
    assert len(FLEET_SNAPSHOT_SCHEMA) == 17


# -- the self-clean gate -------------------------------------------------------

def _run_cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=str(REPO), env=env, capture_output=True, text=True, timeout=300,
    )


def test_repo_is_self_clean(tmp_path):
    """THE acceptance gate: the analyzer runs clean on the repo itself."""
    out = tmp_path / "lint-report.json"
    proc = _run_cli("src", "tests", "benchmarks", "examples",
                    "--json", str(out))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    data = json.loads(out.read_text())
    assert data["errors"] == 0
    assert data["files_scanned"] > 100
    assert set(ALL_RULES) <= set(data["rules_run"])


def test_cli_fails_on_violations():
    proc = _run_cli(str(FIXTURES / "rng_violation.py"), "--all-paths")
    assert proc.returncode == 1
    assert "rng-discipline" in proc.stdout


def test_cli_list_rules():
    proc = _run_cli("--list-rules")
    assert proc.returncode == 0
    for rule in ALL_RULES:
        assert rule in proc.stdout
