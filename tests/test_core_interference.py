"""Interference model (Eq. 1) unit + property tests."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.interference import InterferenceModel, fit_linear_interference


def _model(P=3, N=4, seed=0):
    rng = np.random.default_rng(seed)
    return InterferenceModel(
        base=rng.uniform(0.05, 0.5, (P, N)),
        slope=rng.uniform(0.0, 0.1, (P, N, N)),
    )


def test_estimate_matches_eq1():
    m = _model()
    counts = np.array([1.0, 0.0, 2.0, 3.0])
    got = m.estimate(1, 2, counts)
    want = m.base[1, 2] + float(m.slope[1, 2] @ counts)
    assert got == pytest.approx(want)


def test_additivity():
    """Paper Fig. 4: f(T_i, j*T_a + k*T_b) == f(..j*T_a) + f(..k*T_b) - base."""
    m = _model()
    ca = np.array([2.0, 0.0, 0.0, 0.0])
    cb = np.array([0.0, 0.0, 3.0, 0.0])
    lhs = m.estimate(0, 1, ca + cb)
    rhs = m.estimate(0, 1, ca) + m.estimate(0, 1, cb) - m.base[0, 1]
    assert lhs == pytest.approx(rhs)


def test_vectorised_consistency():
    m = _model()
    classes = np.array([0, 2, 1])
    counts = np.random.default_rng(1).uniform(0, 3, (3, 4))
    vec = m.estimate_devices(classes, 3, counts)
    for i in range(3):
        assert vec[i] == pytest.approx(m.estimate(int(classes[i]), 3, counts[i]))


def test_pair_plot_is_linear():
    m = _model()
    plot = m.pair_plot(0, 1, 2, k_max=5)
    diffs = np.diff(plot)
    assert np.allclose(diffs, diffs[0])
    assert plot[0] == pytest.approx(m.base[0, 1])


def test_validation():
    with pytest.raises(ValueError):
        InterferenceModel(base=np.ones((2, 3)), slope=np.ones((2, 3, 4)))
    with pytest.raises(ValueError):
        InterferenceModel(base=-np.ones((2, 3)), slope=np.ones((2, 3, 3)))


@given(
    m=st.floats(0.0, 5.0),
    c=st.floats(0.01, 5.0),
    n=st.integers(3, 20),
)
@settings(max_examples=50, deadline=None)
def test_fit_recovers_exact_line(m, c, n):
    k = np.arange(n, dtype=float)
    lat = m * k + c
    m_hat, c_hat, r2 = fit_linear_interference(k, lat)
    assert m_hat == pytest.approx(m, abs=1e-8)
    assert c_hat == pytest.approx(c, abs=1e-8)
    assert r2 == pytest.approx(1.0, abs=1e-9)


def test_fit_noisy_r2():
    rng = np.random.default_rng(0)
    k = np.arange(30, dtype=float)
    lat = 0.2 * k + 1.0 + rng.normal(0, 0.05, 30)
    m_hat, c_hat, r2 = fit_linear_interference(k, lat)
    assert m_hat == pytest.approx(0.2, abs=0.02)
    assert r2 > 0.95
