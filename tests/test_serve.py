"""Serving engine + fleet scheduler tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import LM, reduced
from repro.serve.engine import ServingEngine
from repro.serve.scheduler import (
    LONG,
    SHORT,
    ServingFleet,
    make_request_dag,
    serving_interference_model,
)


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_config("qwen1.5-0.5b"), n_layers=2, vocab=128)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_engine_generates_requested_tokens(tiny):
    cfg, model, params = tiny
    eng = ServingEngine(model, params, max_batch=4, max_seq=64)
    eng.add_request("a", [1, 2, 3], 5)
    eng.add_request("b", [4, 5, 6, 7], 8)
    done = {}
    for _ in range(10):
        done.update(eng.step())
        if len(done) == 2:
            break
    assert len(done["a"]) == 6          # first token from prefill + 5 decode
    assert len(done["b"]) == 9


def test_engine_slot_reuse(tiny):
    cfg, model, params = tiny
    eng = ServingEngine(model, params, max_batch=2, max_seq=64)
    eng.add_request("a", [1], 2)
    eng.add_request("b", [2], 2)
    assert eng.free_slots() == []
    done = {}
    while len(done) < 2:
        done.update(eng.step())
    assert len(eng.free_slots()) == 2
    eng.add_request("c", [3], 2)        # slot reuse must not raise
    assert eng.active == 1


def test_engine_matches_single_request_decode(tiny):
    """Batched continuous decoding == standalone greedy decode per request."""
    cfg, model, params = tiny
    prompt = [5, 9, 2, 7]
    n_new = 6

    eng = ServingEngine(model, params, max_batch=3, max_seq=64)
    eng.add_request("x", prompt, n_new)
    eng.add_request("y", [1, 2], n_new)        # co-batched neighbour
    done = {}
    while "x" not in done:
        done.update(eng.step())

    # standalone greedy reference
    caches = model.init_cache(1, 64)
    toks = jnp.asarray([prompt], jnp.int32)
    logits, caches = jax.jit(model.prefill)(params, {"tokens": toks}, caches)
    cur = int(jnp.argmax(logits[0]))
    out = [cur]
    pos = len(prompt)
    for _ in range(n_new):
        lg, caches = jax.jit(model.decode_step)(
            params, jnp.asarray([cur], jnp.int32), jnp.asarray([pos], jnp.int32), caches)
        cur = int(jnp.argmax(lg[0]))
        out.append(cur)
        pos += 1
    assert done["x"] == out[: len(done["x"])]


def test_request_dag_structure():
    dag = make_request_dag("#1", LONG)
    assert dag.n_stages == 2
    assert dag.tasks["decode#1"].deps == ("prefill#1",)
    assert dag.tasks["prefill#1"].model_id == "lora-long"


def test_fleet_policies_run_and_ibdash_wins():
    im = serving_interference_model()
    results = {}
    for pol in ("ibdash", "petrel", "round_robin"):
        fleet = ServingFleet(im, policy=pol, n_replicas=8, seed=0)
        res = fleet.run(n_requests=250, arrival_window=8.0, seed=1)
        results[pol] = res
        assert res.n == 250
    assert results["ibdash"].avg_service_time <= results["round_robin"].avg_service_time
    assert results["ibdash"].prob_failure <= results["petrel"].prob_failure
