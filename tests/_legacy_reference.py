# repro-lint: disable-file=deprecation — this module IS the frozen seed
# reference: it must keep using the scalar-bandwidth arithmetic verbatim so
# the parity suites can replay the original placements bit-for-bit.
"""Verbatim copy of the SEED's mutate-inside-``place()`` schedulers.

The production code now routes every scheme through the pure
``orchestrate(app, cluster, now, policy)`` / ``cluster.apply(plan)``
protocol.  To prove the redesign changed *nothing* about the placements
(device ids, replica sets, estimated latencies) on the paper's Fig. 8/9
grid, this module preserves the original seed implementations — IBDASH's
Algorithm 1 loop and the five baselines — exactly as they shipped, and the
parity tests in ``test_policy_api.py`` replay both against identical
clusters.

Do not "fix" or modernise this file: its value is bit-for-bit fidelity to
the seed.
"""
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.core.availability import prob_fail_during
from repro.core.cluster import ClusterState
from repro.core.dag import AppDAG
from repro.core.orchestrator import IBDASHConfig, Placement, Replica, TaskPlacement
from repro.core.policy import LaTSModel


class LegacyScheduler:
    """Seed ``Scheduler``: ``place`` mutates cluster state via ``commit``."""

    name: str = "base"

    def place(self, app: AppDAG, cluster: ClusterState, now: float) -> Placement:
        raise NotImplementedError

    @staticmethod
    def transfer_latency(app, task, did, chosen, bandwidth):
        total = 0.0
        for dep in app.tasks[task].deps:
            parent = chosen.get(dep)
            if parent is None:
                continue
            if parent.replicas and parent.replicas[0].did != did:
                total += app.tasks[dep].out_bytes / bandwidth
        return total

    @staticmethod
    def upload_latency(app, task, device, bandwidth):
        spec = app.tasks[task]
        if spec.model_id is None or device.has_model(spec.model_id):
            return 0.0
        return spec.model_bytes / bandwidth

    @staticmethod
    def commit(app, cluster, now, placements):
        est_latency = 0.0
        stage_offsets = {}
        offset = 0.0
        for si, stage in enumerate(app.stages):
            stage_offsets[si] = offset
            stage_lat = 0.0
            for tname in stage:
                tp = placements.get(tname)
                if tp is None:
                    continue
                stage_lat = max(stage_lat, tp.est_latency)
            offset += stage_lat
        est_latency = offset
        for tname, tp in placements.items():
            spec = app.tasks[tname]
            start = now + tp.est_start
            for rep in tp.replicas:
                cluster.add_interval(
                    rep.did, spec.ttype, start, start + rep.est_total
                )
                dev = cluster.devices[rep.did]
                if spec.model_id is not None:
                    dev.admit_model(spec.model_id, spec.model_bytes)
        return Placement(app_name=app.name, tasks=placements, est_latency=est_latency)


class LegacyIBDASH(LegacyScheduler):
    """Seed ``IBDASH.place`` (Algorithm 1), verbatim."""

    name = "ibdash"

    def __init__(self, config: Optional[IBDASHConfig] = None):
        self.cfg = config or IBDASHConfig()

    def place(self, app: AppDAG, cluster: ClusterState, now: float) -> Placement:
        cfg = self.cfg
        placements: Dict[str, TaskPlacement] = {}
        bw = cluster.bandwidths()
        lams = cluster.lams()
        stage_offset = 0.0

        mem_total = cluster.mem_totals()
        join = np.array([d.join_time for d in cluster.devices])
        n_dev = cluster.n_devices

        for si, stage in enumerate(app.stages):
            stage_latency = 0.0
            for tname in stage:
                spec = app.tasks[tname]
                t_start = now + stage_offset
                exec_lat = cluster.estimate_exec(spec.ttype, t_start)

                up = np.zeros(n_dev)
                if spec.model_id is not None:
                    for did in range(n_dev):
                        if not cluster.devices[did].has_model(spec.model_id):
                            up[did] = spec.model_bytes / bw[did]
                tr = np.zeros(n_dev)
                for dep in spec.deps:
                    parent = placements.get(dep)
                    if parent is None or not parent.replicas:
                        continue
                    pdid = parent.replicas[0].did
                    add = app.tasks[dep].out_bytes / bw
                    add[pdid] = 0.0
                    tr += add
                total = exec_lat + up + tr

                feasible = mem_total >= (spec.mem_bytes + spec.model_bytes)
                if cfg.avail_floor > 0.0:
                    feasible &= np.exp(-lams * (t_start - join)) >= cfg.avail_floor
                if not feasible.any():
                    return Placement(
                        app_name=app.name, tasks=placements, est_latency=0.0,
                        feasible=False, infeasible_task=tname,
                    )

                window = (t_start - join) + total
                pf = 1.0 - np.exp(-lams * window)

                cand = np.flatnonzero(feasible)
                order = cand[np.argsort(total[cand], kind="stable")]

                def mk(did: int) -> Replica:
                    return Replica(
                        did=int(did), est_exec=float(exec_lat[did]),
                        est_upload=float(up[did]), est_transfer=float(tr[did]),
                        pred_fail=float(pf[did]),
                    )

                best = mk(order[0])
                best_total = float(total[order[0]])
                l_ref = max(best_total, 1e-9)
                replicas = [best]
                comb_fail = best.pred_fail
                weight_s = cfg.alpha * (best_total / l_ref) + (1 - cfg.alpha) * comb_fail

                t_rep = 0
                qi = 1
                while comb_fail >= cfg.beta and t_rep < cfg.gamma and qi < order.size:
                    did = order[qi]
                    qi += 1
                    cand_total = float(total[did])
                    new_fail = comb_fail * float(pf[did])
                    weight_new = cfg.alpha * (cand_total / l_ref) + (1 - cfg.alpha) * new_fail
                    if weight_new <= weight_s:
                        replicas.append(mk(did))
                        comb_fail = new_fail
                        weight_s = weight_new
                        t_rep += 1
                    else:
                        break

                tp = TaskPlacement(
                    task=tname,
                    ttype=spec.ttype,
                    replicas=replicas,
                    est_start=stage_offset,
                    est_latency=replicas[0].est_total,
                )
                placements[tname] = tp
                stage_latency = max(stage_latency, tp.est_latency)
            stage_offset += stage_latency
        return self.commit(app, cluster, now, placements)


class _LegacySingleChoice(LegacyScheduler):
    """Seed ``_SingleChoiceScheduler.place``, verbatim."""

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def choose(self, feasible, exec_lat, cluster, t_start, ttype) -> int:
        raise NotImplementedError

    def place(self, app: AppDAG, cluster: ClusterState, now: float) -> Placement:
        placements: Dict[str, TaskPlacement] = {}
        bw = cluster.bandwidths()
        lams = cluster.lams()
        mem_total = cluster.mem_totals()
        stage_offset = 0.0
        for stage in app.stages:
            stage_latency = 0.0
            for tname in stage:
                spec = app.tasks[tname]
                t_start = now + stage_offset
                need = spec.mem_bytes + spec.model_bytes
                feasible = np.flatnonzero(mem_total >= need)
                if feasible.size == 0:
                    return Placement(
                        app_name=app.name, tasks=placements, est_latency=0.0,
                        feasible=False, infeasible_task=tname,
                    )
                exec_lat = cluster.estimate_exec(spec.ttype, t_start)
                did = int(self.choose(feasible, exec_lat, cluster, t_start, spec.ttype))
                dev = cluster.devices[did]
                up = self.upload_latency(app, tname, dev, bw[did])
                tr = self.transfer_latency(app, tname, did, placements, bw[did])
                total = float(exec_lat[did]) + up + tr
                window = (t_start - dev.join_time) + total
                rep = Replica(
                    did=did, est_exec=float(exec_lat[did]), est_upload=up,
                    est_transfer=tr,
                    pred_fail=prob_fail_during(lams[did], window),
                )
                tp = TaskPlacement(
                    task=tname, ttype=spec.ttype, replicas=[rep],
                    est_start=stage_offset, est_latency=total,
                )
                placements[tname] = tp
                stage_latency = max(stage_latency, total)
            stage_offset += stage_latency
        return self.commit(app, cluster, now, placements)


class LegacyRandom(_LegacySingleChoice):
    name = "random"

    def choose(self, feasible, exec_lat, cluster, t_start, ttype) -> int:
        return int(self.rng.choice(feasible))


class LegacyRoundRobin(_LegacySingleChoice):
    name = "round_robin"

    def __init__(self, seed: int = 0):
        super().__init__(seed)
        self._next = 0

    def choose(self, feasible, exec_lat, cluster, t_start, ttype) -> int:
        did = int(feasible[self._next % feasible.size])
        self._next += 1
        return did


class LegacyLAVEA(_LegacySingleChoice):
    name = "lavea"

    def choose(self, feasible, exec_lat, cluster, t_start, ttype) -> int:
        q = cluster.queue_len_at(t_start)[feasible]
        return int(feasible[int(np.argmin(q))])


class LegacyPetrel(_LegacySingleChoice):
    name = "petrel"

    def choose(self, feasible, exec_lat, cluster, t_start, ttype) -> int:
        if feasible.size == 1:
            return int(feasible[0])
        a, b = self.rng.choice(feasible, size=2, replace=False)
        return int(a if exec_lat[a] <= exec_lat[b] else b)


class LegacyLaTS(_LegacySingleChoice):
    name = "lats"

    def __init__(self, model: LaTSModel, seed: int = 0):
        super().__init__(seed)
        self.model = model

    def choose(self, feasible, exec_lat, cluster, t_start, ttype) -> int:
        counts = np.asarray(cluster.counts_at(t_start), dtype=np.float64)[feasible]
        pred = self.model.predict(cluster.classes()[feasible], ttype, counts)
        lo = pred.min()
        ties = np.flatnonzero(pred <= lo * (1.0 + 1e-9))
        return int(feasible[int(self.rng.choice(ties))])


def make_legacy_scheduler(name, lats_model=None, seed=0, alpha=0.5, beta=0.1,
                          gamma=3):
    """The seed's ``make_scheduler`` if-chain, preserved for the parity test."""
    if name == "ibdash":
        return LegacyIBDASH(IBDASHConfig(alpha=alpha, beta=beta, gamma=gamma))
    if name == "lats":
        return LegacyLaTS(lats_model, seed=seed)
    if name == "lavea":
        return LegacyLAVEA(seed=seed)
    if name == "petrel":
        return LegacyPetrel(seed=seed)
    if name == "round_robin":
        return LegacyRoundRobin(seed=seed)
    if name == "random":
        return LegacyRandom(seed=seed)
    raise ValueError(name)
