"""Quickstart: train a tiny LM end to end, checkpoint it, reload it, serve it.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.launch.train import train
from repro.models import LM
from repro.serve.engine import ServingEngine


def main():
    # 1) train a reduced Qwen-family model on the synthetic stream
    out = train("qwen1.5-0.5b", use_reduced=True, steps=30, batch=8, seq=64,
                lr=5e-3, ckpt_dirs=("/tmp/quickstart_ckpt/a", "/tmp/quickstart_ckpt/b"))
    print(f"loss: {out['first_loss']:.3f} -> {out['final_loss']:.3f}")
    assert out["final_loss"] < out["first_loss"], "training must reduce loss"

    # 2) serve the trained weights with batched requests
    model = LM(out["config"])
    eng = ServingEngine(model, out["params"], max_batch=4, max_seq=128)
    rng = np.random.default_rng(0)
    for i in range(4):
        eng.add_request(f"r{i}", rng.integers(0, out["config"].vocab, 8).tolist(), 12)
    done = {}
    while len(done) < 4:
        done.update(eng.step())
    for rid in sorted(done):
        print(f"  {rid}: generated {len(done[rid])} tokens: {done[rid][:8]}...")
    print("quickstart OK")


if __name__ == "__main__":
    main()
