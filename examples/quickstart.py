"""Quickstart: the whole stack in three bites.

  1. Place a DAG application with the pure policy API (`repro.api`):
     plan -> inspect -> apply (undoable) -> run online via Orchestrator.
  2. Train a tiny LM end to end, checkpoint it, reload it.
  3. Serve the trained weights with batched requests.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def orchestration_quickstart():
    from repro.api import Orchestrator, make_cluster, make_profile, make_policy, orchestrate
    from repro.sim.apps import video_app

    profile = make_profile(seed=0)
    cluster = make_cluster(profile, scenario="mix", n_devices=16, seed=0)
    app = video_app().relabel("#demo")

    # two-phase: pure plan, explicit (undoable) apply
    policy = make_policy("ibdash", alpha=0.5, beta=0.1, gamma=3)
    plan = orchestrate(app, cluster, now=0.0, policy=policy)
    print(f"planned {len(plan.tasks)} tasks, est latency {plan.est_latency:.3f}s, "
          f"pred P_f {plan.placement.pred_app_fail:.4f}")
    token = cluster.apply(plan)      # T_alloc intervals + model uploads recorded
    cluster.undo(token)              # ...and rolled back exactly (what-if mode)

    # online: the Orchestrator façade drives the same policy event by event
    orch = Orchestrator(cluster, policy, seed=0)
    rng = np.random.default_rng(1)
    apps = [video_app().relabel(f"#{i}") for i in range(20)]
    # fused=True plans the whole burst in one batched decide_batch call per
    # wave-stage (bit-identical to the per-task loop, ~10x faster at B=1000)
    orch.submit_batch(apps, sorted(rng.uniform(0.0, 1.0, 20).tolist()),
                      fused=True)
    orch.drain()
    res = orch.result("mix")
    print(f"orchestrated {res.n} instances online: "
          f"avg service {res.avg_service_time:.3f}s, P_f {res.prob_failure:.3f}")


def training_and_serving_quickstart():
    from repro.launch.train import train
    from repro.models import LM
    from repro.serve.engine import ServingEngine

    # train a reduced Qwen-family model on the synthetic stream
    out = train("qwen1.5-0.5b", use_reduced=True, steps=30, batch=8, seq=64,
                lr=5e-3, ckpt_dirs=("/tmp/quickstart_ckpt/a", "/tmp/quickstart_ckpt/b"))
    print(f"loss: {out['first_loss']:.3f} -> {out['final_loss']:.3f}")
    assert out["final_loss"] < out["first_loss"], "training must reduce loss"

    # serve the trained weights with batched requests
    model = LM(out["config"])
    eng = ServingEngine(model, out["params"], max_batch=4, max_seq=128)
    rng = np.random.default_rng(0)
    for i in range(4):
        eng.add_request(f"r{i}", rng.integers(0, out["config"].vocab, 8).tolist(), 12)
    done = {}
    while len(done) < 4:
        done.update(eng.step())
    for rid in sorted(done):
        print(f"  {rid}: generated {len(done[rid])} tokens: {done[rid][:8]}...")


def main():
    orchestration_quickstart()
    training_and_serving_quickstart()
    print("quickstart OK")


if __name__ == "__main__":
    main()
