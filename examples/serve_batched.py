"""End-to-end serving driver (the paper's kind dictates serving):

  1. serve a small model with batched requests (continuous batching);
  2. measure + fit the linear interference law on real decode timings
     (the Fig.-4 linearity verification, serving edition);
  3. feed the measured coefficients to the IBDASH fleet scheduler and
     compare policies across a 16-replica, half-preemptible fleet.

    PYTHONPATH=src python examples/serve_batched.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import serve_demo

if __name__ == "__main__":
    serve_demo(n_requests=48, max_batch=8)
