"""Always-on streaming service demo: diurnal load through admission control.

A day-shaped (sinusoidal) open-loop arrival stream drives the mixed fleet
through the bounded admission queue.  At the traffic peak the offered load
exceeds what the fleet can absorb; deadline-aware shedding drops
best_effort work that provably cannot meet its deadline while
latency_critical traffic (the AR-style apps) dequeues first and keeps its
p99 inside the SLO.  The same run repeats with admission disabled — the
no-admission baseline executes everything, however late — to show what the
queue buys.

    PYTHONPATH=src python examples/stream_demo.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import Orchestrator, make_cluster, make_policy, make_profile
from repro.stream import (
    AdmissionConfig,
    StreamingOrchestrator,
    default_streams,
    diurnal_arrivals,
)

BASE_RATE = 30.0        # trough, instances/sec
PEAK_RATE = 220.0       # peak — well past fleet capacity
PERIOD = 30.0           # one "day", seconds
HORIZON = 60.0          # two days
N_DEVICES = 100


def build(admission):
    profile = make_profile(seed=0)
    cluster = make_cluster(
        profile, scenario="stream", n_devices=N_DEVICES, seed=0,
        horizon=HORIZON * 6 + 120.0,
    )
    orch = Orchestrator(
        cluster,
        make_policy("ibdash", alpha=0.5, beta=0.1, gamma=3,
                    lats_model=profile.lats_model),
    )
    return StreamingOrchestrator(
        orch, admission=admission,
        wave_cap=30 if admission is not None else None, tick=0.25,
    )


def main():
    arrivals = diurnal_arrivals(
        default_streams(), BASE_RATE, PEAK_RATE, HORIZON,
        period=PERIOD, seed=11,
    )
    print(f"offered: {len(arrivals)} instances over {HORIZON:.0f}s "
          f"(diurnal {BASE_RATE:.0f} -> {PEAK_RATE:.0f}/s, "
          f"period {PERIOD:.0f}s)\n")

    for label, admission in (
        ("admission (queue_cap=256)", AdmissionConfig(queue_cap=256)),
        ("no-admission baseline", None),
    ):
        res = build(admission).run(arrivals)
        c = res.metrics["counters"]
        print(f"== {label} ==")
        print(f"  shed          {res.stats['shed']:5d}  "
              f"({100 * res.shed_rate:.1f}%)")
        for reason in ("deadline", "stale", "capacity", "evicted"):
            n = c.get(f"shed_reason_{reason}", 0)
            if n:
                print(f"    - {reason:9s} {n:5d}")
        print(f"  completed     {res.stats['completed']:5d}")
        print(f"  deadline miss {c.get('deadline_missed', 0):5d}")
        for slo in ("latency_critical", "best_effort"):
            print(f"  {slo:16s} p50 {res.p('p50', slo):6.2f}s   "
                  f"p99 {res.p('p99', slo):6.2f}s   "
                  f"p999 {res.p('p999', slo):6.2f}s")
        print(f"  placements/s  {res.metrics['gauges']['placements_per_sec']:,.0f}")
        # the sampled time series shows the queue breathing with the day
        depths = [row["queue_depth"] for row in res.metrics["samples"]]
        print(f"  queue depth   max {max(depths):.0f} over "
              f"{len(depths)} samples\n")


if __name__ == "__main__":
    main()
