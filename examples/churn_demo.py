"""Churn runtime demo: device join/leave streams, detection, and recovery.

The paper's headline scenario — personal edge devices that leave the
network unannounced (§V-F: P(ED) = exp(-lambda t), validated on a campus
mobility trace) — driven end to end: an exponential leave/rejoin event
stream over a scaled-PED fleet, DEVICE_DOWN events that kill in-flight
replicas on the spot, and the three recovery strategies racing on the same
workload:

  * fail_fast — the paper's Eq. (4): lose the instance immediately;
  * failover — restart the dead task on the best surviving device;
  * replan   — re-invoke the placement policy on the live sub-fleet for
               the dead task and its not-yet-started downstream stages.

Then the churn-AWARE planning race: the correlated scenario (per-group
shared shocks + rotating scripted maintenance windows) installs an exact
availability forecast, and `churn_aware` — IBDASH scoring over
forecast-adjusted failure probabilities — runs the same workload through
the same windows as memoryless `ibdash`, with partial-result salvage
re-seeding lost instances from their completed stages.

    PYTHONPATH=src python examples/churn_demo.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.api import Orchestrator, SimConfig, make_cluster, make_profile
from repro.sim.churn import exponential_churn
from repro.sim.runner import _make_workload, make_churn, policy_for

RECOVERIES = ("fail_fast", "failover", "replan")


def main():
    profile = make_profile(seed=0)
    cfg = SimConfig(scenario="churn", n_cycles=4, instances_per_cycle=300,
                    n_devices=80, seed=0)

    peek = make_cluster(profile, scenario=cfg.scenario,
                        n_devices=cfg.n_devices, seed=cfg.seed,
                        horizon=cfg.horizon + 30.0)
    schedule = exponential_churn(peek, horizon=cfg.horizon + 25.0,
                                 seed=cfg.seed + 101,
                                 mean_downtime=cfg.mean_downtime)
    leaves = sum(1 for e in schedule.events if e.kind == "leave")
    joins = schedule.n_events - leaves
    print(f"scenario=churn  devices={cfg.n_devices}  "
          f"horizon={cfg.horizon:.0f}s  schedule: {leaves} departures, "
          f"{joins} rejoins (mean downtime {cfg.mean_downtime:.0f}s)")

    for scheme in ("lavea", "ibdash"):
        print(f"\n--- {scheme} "
              f"({'no proactive replication' if scheme != 'ibdash' else 'pf-aware + replication'}) ---")
        print(f"{'recovery':10s} {'P_f':>7s} {'service(s)':>10s} "
              f"{'deaths':>7s} {'recovered':>9s} {'lost':>5s} {'replans':>8s}")
        for recovery in RECOVERIES:
            cluster = make_cluster(profile, scenario=cfg.scenario,
                                   n_devices=cfg.n_devices, seed=cfg.seed,
                                   horizon=cfg.horizon + 30.0)
            churn = exponential_churn(cluster, horizon=cfg.horizon + 25.0,
                                      seed=cfg.seed + 101,
                                      mean_downtime=cfg.mean_downtime)
            orch = Orchestrator(cluster, policy_for(scheme, profile, cfg),
                                seed=cfg.seed, churn=churn, recovery=recovery,
                                detection_delay=cfg.detection_delay)
            apps, times = _make_workload(cfg)
            orch.submit_batch(apps, times)
            orch.drain()
            res = orch.result(cfg.scenario, cfg.horizon)
            s = orch.stats
            print(f"{recovery:10s} {res.prob_failure:7.4f} "
                  f"{res.avg_service_time:10.3f} {s['replica_deaths']:7d} "
                  f"{s['recovered']:9d} {s['lost']:5d} {s['replans']:8d}")

    print("\nfailover/replan turn departures that caught a task in flight "
          "into recovered instances;\nIBDASH's proactive replication absorbs "
          "most of them before recovery is even needed.")

    # -- churn-aware planning through maintenance windows ----------------------
    corr = SimConfig(scenario="correlated_churn", n_cycles=4,
                     instances_per_cycle=300, n_devices=80, seed=0)
    print(f"\n=== correlated churn: {corr.churn_groups} shock groups, one "
          f"{corr.maintenance_duration:.0f}s maintenance window every "
          f"{corr.maintenance_period:.1f}s ===")
    print(f"{'scheme':12s} {'mode':18s} {'P_f':>7s} {'service(s)':>10s} "
          f"{'lost':>5s} {'salvaged':>9s}")
    for scheme in ("ibdash", "churn_aware"):
        for recovery, salvage in (("fail_fast", 0), ("fail_fast", 1),
                                  ("replan", 1)):
            cluster = make_cluster(profile, scenario=corr.scenario,
                                   n_devices=corr.n_devices, seed=corr.seed,
                                   horizon=corr.horizon + 30.0)
            churn = make_churn(corr, cluster)
            orch = Orchestrator(cluster, policy_for(scheme, profile, corr),
                                seed=corr.seed, churn=churn, recovery=recovery,
                                salvage=salvage,
                                detection_delay=corr.detection_delay)
            apps, times = _make_workload(corr)
            orch.submit_batch(apps, times)
            orch.drain()
            res = orch.result(corr.scenario, corr.horizon)
            s = orch.stats
            mode = recovery + ("+salvage" if salvage else "")
            print(f"{scheme:12s} {mode:18s} {res.prob_failure:7.4f} "
                  f"{res.avg_service_time:10.3f} {s['lost']:5d} "
                  f"{s['salvaged']:9d}")

    print("\nchurn_aware reads the installed availability forecast: tasks "
          "whose estimated span\ncrosses a scripted window are never placed "
          "on the departing group, so the mass\ndrain that kills ibdash "
          "placements passes it by; salvage re-seeds what's left.")


if __name__ == "__main__":
    main()
