"""Observability demo: trace a correlated-churn + salvage run end to end.

Runs the acceptance scenario with the tracer on — correlated device churn
(per-group shared shocks + scripted maintenance windows) hot enough to
kill instances, replan recovery, and partial-result salvage — then shows
what the repro.obs layer produces from the spans alone:

  * ``trace_demo.trace.json`` — Chrome/Perfetto ``trace_event`` JSON.
    Open it at https://ui.perfetto.dev or chrome://tracing: pid 0 is one
    row per instance (envelope + queue/recovery waits + plan/replan/
    salvage instants), pid 1 is one row per device (replica exec windows
    with upload/transfer heads, churn down/up markers), with flow arrows
    stitching instances to the devices that ran them.
  * ``trace_demo.summary.json`` — the compact JSON export: the ledger
    recomputed from spans, span counts by kind, engine counters.
  * the attribution report — critical-path breakdown over completed
    instances, per-policy / per-tier calibration of the planner's Eq. (2)
    estimates against realized durations and death rates, and the
    slowest / lost offender lists.

The conservation identity ``admitted == completed + lost + shed`` is
recomputed from the exported JSON alone and asserted against the engine's
live counters before anything is printed.

    PYTHONPATH=src python examples/trace_demo.py
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import SimConfig, make_profile
from repro.obs import (
    attribution_report,
    format_report,
    json_summary,
    ledger_from_trace,
    to_chrome_trace,
    validate_chrome_trace,
)
from repro.sim import run_one


def main():
    profile = make_profile(seed=0)
    cfg = SimConfig(scenario="correlated_churn", n_cycles=2,
                    instances_per_cycle=60, seed=3, n_devices=12,
                    recovery="replan", salvage=2, shock_rate=0.2,
                    mean_downtime=30.0, gamma=1, max_retries=1,
                    trace=True)
    print(f"running {cfg.scenario}: {cfg.n_cycles * cfg.instances_per_cycle} "
          f"instances on {cfg.n_devices} devices, recovery={cfg.recovery}, "
          f"salvage={cfg.salvage} ...")
    res = run_one("ibdash", cfg, profile)
    tr = res.trace

    trace_path = "trace_demo.trace.json"
    doc = to_chrome_trace(tr, path=trace_path)
    n_events = validate_chrome_trace(doc)
    with open(trace_path) as f:
        led = ledger_from_trace(json.load(f))
    assert led["admitted"] == led["completed"] + led["lost"] + led["shed"]
    print(f"\n{len(tr.spans)} spans -> {n_events} trace events "
          f"-> {trace_path}")
    print(f"ledger recomputed from the JSON alone: {led}")
    print("open the file at https://ui.perfetto.dev (or chrome://tracing)")

    summary_path = "trace_demo.summary.json"
    json_summary(tr, path=summary_path)
    print(f"compact summary -> {summary_path}")

    print()
    print(format_report(attribution_report(tr, top_k=3)))


if __name__ == "__main__":
    main()
