"""The paper's own experiment, miniaturised: orchestrate 4 DAG applications
over 100 heterogeneous edge devices with all 6 schemes and print the Fig.8 /
Fig.9 metrics (service time, probability of failure).

    PYTHONPATH=src python examples/edge_orchestration_demo.py [--full]

``--full`` runs the complete paper protocol (20 cycles x 1000 instances);
the default is a 4-cycle miniature that finishes in ~30 s.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.sim import SimConfig, make_profile, run_one


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--scenario", default="ped", choices=("ped", "ced", "mix"))
    args = ap.parse_args()

    cfg = SimConfig(
        scenario=args.scenario,
        n_cycles=20 if args.full else 4,
        instances_per_cycle=1000 if args.full else 250,
    )
    profile = make_profile(seed=cfg.seed)
    print(f"scenario={args.scenario}  cycles={cfg.n_cycles}  "
          f"instances/cycle={cfg.instances_per_cycle}")
    print(f"{'scheme':14s} {'service(s)':>10s} {'P_f':>7s} {'replicas':>9s}")
    rows = {}
    for scheme in ("ibdash", "lats", "lavea", "petrel", "round_robin", "random"):
        res = run_one(scheme, cfg, profile)
        nrep = float(np.mean([r.n_replicas for r in res.instances]))
        rows[scheme] = res
        print(f"{scheme:14s} {res.avg_service_time:10.3f} {res.prob_failure:7.3f} "
              f"{nrep:9.2f}")
    base_lat = min(r.avg_service_time for k, r in rows.items() if k != "ibdash")
    base_pf = min(r.prob_failure for k, r in rows.items() if k != "ibdash")
    ib = rows["ibdash"]
    print(f"\nIBDASH vs best baseline:  service time "
          f"{100*(1 - ib.avg_service_time/base_lat):+.1f}%,  P_f "
          f"{100*(1 - ib.prob_failure/max(base_pf, 1e-9)):+.1f}%")


if __name__ == "__main__":
    main()
