"""The paper's own experiment, miniaturised, driven entirely through the
``repro.api`` façade: orchestrate 4 DAG applications over 100 heterogeneous
edge devices with all 6 registry policies and print the Fig.8 / Fig.9
metrics (service time, probability of failure), then demo the pure
plan/apply/undo protocol with a speculative alpha what-if sweep.

    PYTHONPATH=src python examples/edge_orchestration_demo.py [--full]

``--full`` runs the complete paper protocol (20 cycles x 1000 instances);
the default is a 4-cycle miniature that finishes in ~30 s.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.api import (
    Orchestrator,
    SimConfig,
    make_cluster,
    make_policy,
    make_profile,
    orchestrate,
    run_one,
)
from repro.sim.apps import lightgbm_app
from repro.sim.runner import SCHEME_NAMES


def paper_grid(args):
    cfg = SimConfig(
        scenario=args.scenario,
        n_cycles=20 if args.full else 4,
        instances_per_cycle=1000 if args.full else 250,
    )
    profile = make_profile(seed=cfg.seed)
    print(f"scenario={args.scenario}  cycles={cfg.n_cycles}  "
          f"instances/cycle={cfg.instances_per_cycle}")
    print(f"{'scheme':14s} {'service(s)':>10s} {'P_f':>7s} {'replicas':>9s}")
    rows = {}
    for scheme in SCHEME_NAMES:
        # run_one = Orchestrator(cluster, policy).submit_batch(...).step(...)
        res = run_one(scheme, cfg, profile)
        nrep = float(np.mean([r.n_replicas for r in res.instances]))
        rows[scheme] = res
        print(f"{scheme:14s} {res.avg_service_time:10.3f} {res.prob_failure:7.3f} "
              f"{nrep:9.2f}")
    base_lat = min(r.avg_service_time for k, r in rows.items() if k != "ibdash")
    base_pf = min(r.prob_failure for k, r in rows.items() if k != "ibdash")
    ib = rows["ibdash"]
    print(f"\nIBDASH vs best baseline:  service time "
          f"{100*(1 - ib.avg_service_time/base_lat):+.1f}%,  P_f "
          f"{100*(1 - ib.prob_failure/max(base_pf, 1e-9)):+.1f}%")
    return cfg, profile


def what_if_sweep(cfg, profile):
    """Two-phase protocol: plan speculatively, inspect, roll back — the
    cluster is bit-identical afterwards, so the sweep is free."""
    print("\nspeculative alpha sweep (plan/apply/undo, no state corruption):")
    cluster = make_cluster(profile, scenario="ped", n_devices=40, seed=0)
    app = lightgbm_app().relabel("#whatif")
    alloc_before = cluster.alloc.copy()
    for alpha in (0.0, 0.25, 0.5, 0.75, 1.0):
        plan = orchestrate(app, cluster, 0.0,
                           make_policy("ibdash", alpha=alpha, beta=1e-5))
        token = cluster.apply(plan)          # make it real...
        print(f"  alpha={alpha:4.2f}  est_latency={plan.est_latency:6.3f}s  "
              f"pred_fail={plan.placement.pred_app_fail:.4f}  "
              f"extra_replicas={plan.placement.n_replicas()}")
        cluster.undo(token)                  # ...then roll it back exactly
    assert (cluster.alloc == alloc_before).all()
    print("  cluster state untouched after sweep: True")


def online_demo(profile):
    """The Orchestrator façade in online mode: submit, step, drain."""
    print("\nonline orchestration (submit/step/drain):")
    cluster = make_cluster(profile, scenario="mix", n_devices=24, seed=3)
    orch = Orchestrator(cluster, "ibdash", seed=3)
    rng = np.random.default_rng(0)
    apps = [lightgbm_app().relabel(f"#{i}") for i in range(50)]
    orch.submit_batch(apps, sorted(rng.uniform(0.0, 1.5, 50).tolist()))
    orch.step(until=1.0)
    print(f"  t=1.0s: {len(orch.records)} arrivals placed, "
          f"{orch.pending_events} events in flight")
    orch.drain()
    res = orch.result("mix")
    print(f"  drained at t={orch.now:.2f}s: {res.n} instances, "
          f"avg service {res.avg_service_time:.3f}s, P_f {res.prob_failure:.3f}")


def fused_burst_demo(profile):
    """The batched placement API: a whole burst planned in one fused
    decide_batch call per wave-stage (vs the per-task scalar loop)."""
    import time

    from repro.api import orchestrate_batch
    from repro.sim.runner import policy_for

    print("\nfused burst placement (orchestrate_batch vs per-task loop):")
    cfg = SimConfig(n_devices=100)
    cluster = make_cluster(profile, scenario="mix", n_devices=100, seed=0,
                           horizon=400.0)
    apps = [lightgbm_app().relabel(f"#{i}") for i in range(1000)]

    pol = policy_for("ibdash", profile, cfg)
    orchestrate_batch(apps, cluster, pol)           # warm the jitted kernels
    t0 = time.perf_counter()
    plans = orchestrate_batch(apps, cluster, pol)
    fused_s = time.perf_counter() - t0

    pol = policy_for("ibdash", profile, cfg)
    t0 = time.perf_counter()
    loop = [orchestrate(app, cluster, 0.0, pol, batched=False)
            for app in apps]
    loop_s = time.perf_counter() - t0

    assert all(
        [r.did for tp in a.tasks.values() for r in tp.replicas]
        == [r.did for tp in b.tasks.values() for r in tp.replicas]
        for a, b in zip(plans, loop)
    ), "fused and scalar paths must be bit-identical"
    print(f"  per-task loop: {len(apps)/loop_s:8.0f} placements/s")
    print(f"  fused batched: {len(apps)/fused_s:8.0f} placements/s "
          f"({loop_s/fused_s:.1f}x, bit-identical)")
    # the online flow uses the same path via submit_batch(..., fused=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--scenario", default="ped", choices=("ped", "ced", "mix"))
    args = ap.parse_args()

    cfg, profile = paper_grid(args)
    what_if_sweep(cfg, profile)
    online_demo(profile)
    fused_burst_demo(profile)


if __name__ == "__main__":
    main()
