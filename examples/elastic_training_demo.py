"""Fault-tolerant elastic training walkthrough.

Simulates a 16-pod fleet (half preemptible) running a training job:
  * heartbeats feed the FleetMonitor; its online lambda estimate drives the
    Young/Daly checkpoint cadence and the straggler-backup policy,
  * at t=60s three spot pods vanish silently; the monitor detects them by
    timeout, plan_remesh computes the survivor mesh, and training resumes
    from the replicated checkpoint,
  * a real (tiny) model train loop runs underneath so the restore is real.

    PYTHONPATH=src python examples/elastic_training_demo.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.availability import young_daly_interval
from repro.ft.runtime import FleetMonitor, plan_remesh
from repro.ft.straggler import StragglerMitigator
from repro.launch.train import train


def main():
    # ---- fleet bookkeeping (simulated clock) ---------------------------------
    mon = FleetMonitor(timeout=10.0)
    for i in range(16):
        mon.join(f"pod{i:02d}", cls="spot" if i % 2 else "reserved", now=0.0)

    t = 0.0
    dead_at = {"pod03": 60.0, "pod05": 60.0, "pod11": 60.0}
    while t < 90.0:
        t += 5.0
        for p in list(mon.pods):
            if p in dead_at and t >= dead_at[p]:
                continue  # departed silently: no more heartbeats
            mon.heartbeat(p, now=t)
        newly_dead = mon.sweep(now=t)
        if newly_dead:
            print(f"[t={t:5.1f}s] failure detected: {newly_dead}")
            plan = plan_remesh(mon.alive_pods(), model_parallel=4,
                               prev_data_parallel=4, restore_step=40)
            print(f"          elastic plan: mesh {plan.mesh_shape} "
                  f"{plan.axis_names}, dropped={plan.dropped_pods}, "
                  f"reshard batch={plan.batch_reshard}, "
                  f"restore step {plan.restore_step}")
            break

    lam = sum(mon.fleet_lams())
    print(f"online fleet failure rate: {lam:.2e}/s -> Young-Daly interval "
          f"for a 30 s checkpoint: {young_daly_interval(lam, 30.0):.0f}s")
    print(f"P(job interrupted within 1h): {mon.prob_job_interrupted(3600.0):.3f}")

    # ---- straggler backups (paper's replication loop on pods) -----------------
    mit = StragglerMitigator(beta=0.05, gamma=2)
    est_latency = [120.0, 125.0, 130.0, 180.0]       # per-pod step estimate (s)
    lams = [1e-6, 8e-4, 8e-4, 1e-6]                  # reserved/spot/spot/reserved
    d = mit.decide(est_latency, lams)
    print(f"straggler policy: primary pod {d.primary}, backups {d.backups}, "
          f"P(all fail)={d.pred_fail:.4f}")

    # ---- real crash-restart under the checkpoint manager ----------------------
    print("\nreal train loop with simulated failure at step 20:")
    out = train("olmo-1b", use_reduced=True, steps=40, batch=4, seq=64,
                simulate_failure=20,
                ckpt_dirs=("/tmp/elastic_ckpt/a", "/tmp/elastic_ckpt/b"))
    print(f"loss {out['first_loss']:.3f} -> {out['final_loss']:.3f} "
          f"(training survived the failure)")


if __name__ == "__main__":
    main()
