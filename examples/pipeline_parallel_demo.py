"""Pipeline parallelism demo: GPipe schedule over a 4-stage mesh via
shard_map + ppermute, validated against the sequential model, with the
bubble-fraction accounting.

    python examples/pipeline_parallel_demo.py     (no PYTHONPATH needed)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.pipeline import pipeline_efficiency, pipeline_loss_fn, split_stages


def main():
    P, L, d, V = 4, 8, 64, 128
    M, mb, S = 8, 2, 32
    mesh = jax.make_mesh((P,), ("stage",))
    rng = np.random.default_rng(0)

    stacked = {"w": jnp.asarray(rng.standard_normal((L, d, d)) * 0.05, jnp.float32)}
    params = {
        "stages": split_stages(stacked, P),
        "embed": {"e": jnp.asarray(rng.standard_normal((V, d)) * 0.5, jnp.float32)},
        "head": {"h": jnp.asarray(rng.standard_normal((d, V)) * 0.5, jnp.float32)},
    }

    def block_fn(lp, x):
        return x + jnp.tanh(x @ lp["w"])

    def embed_fn(ep, toks):
        return ep["e"][toks]

    def loss_fn(hp, y, labels):
        lg = y @ hp["h"]
        logz = jax.nn.logsumexp(lg, -1)
        gold = jnp.take_along_axis(lg, labels[..., None], -1)[..., 0]
        return (logz - gold).mean()

    batch = {
        "tokens": jnp.asarray(rng.integers(0, V, (M, mb, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, V, (M, mb, S)), jnp.int32),
    }
    pipe_loss = pipeline_loss_fn(mesh, block_fn, embed_fn, loss_fn)
    loss = jax.jit(pipe_loss)(params, batch)
    grads = jax.jit(jax.grad(pipe_loss))(params, batch)
    gnorm = float(sum(jnp.sum(x * x) for x in jax.tree.leaves(grads))) ** 0.5
    print(f"stages={P} microbatches={M} loss={float(loss):.4f} "
          f"grad_norm={gnorm:.3f}")
    print(f"pipeline efficiency (1 - bubble fraction): "
          f"{pipeline_efficiency(M, P):.3f}")
    for m_ in (4, 8, 16, 32):
        print(f"  microbatches={m_:3d}: efficiency {pipeline_efficiency(m_, P):.3f}")


if __name__ == "__main__":
    main()
