"""Multi-tier fleets (device -> edge server -> cloud) with tier-aware link
matrices.

Demonstrates the PR-3 cost model: every transfer is priced over the actual
link — min(sender uplink, receiver downlink, inter-tier backhaul) — instead
of the receiver's scalar bandwidth, and the `tier_escalation` policy keeps
work on the end-device tier until the latency budget forces it up to the
edge servers or the cloud.

    PYTHONPATH=src python examples/multi_tier_demo.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.api import (
    Orchestrator,
    SimConfig,
    TIER_NAMES,
    make_multi_tier_cluster,
    make_profile,
)
from repro.sim.runner import ALL_SCHEME_NAMES, _make_workload, policy_for


def show_link_matrix(cluster):
    tiers = cluster.tiers()
    link = cluster.link_bw()
    print("effective inter-tier bandwidth (MB/s, first device of each tier):")
    reps = [int(np.flatnonzero(tiers == t)[0]) for t in np.unique(tiers)]
    header = "".join(f"{TIER_NAMES[tiers[d]]:>14s}" for d in reps)
    corner = "from / to"
    print(f"{corner:>14s}{header}")
    for s in reps:
        row = "".join(
            f"{'-' if s == d else f'{link[s, d] / 1e6:.0f}':>14s}" for d in reps
        )
        print(f"{TIER_NAMES[tiers[s]]:>14s}{row}")


def main():
    profile = make_profile(seed=0)
    cfg = SimConfig(scenario="multi_tier", n_cycles=4, instances_per_cycle=150,
                    n_devices=60, latency_budget=3.0)
    cluster = make_multi_tier_cluster(profile, n_devices=cfg.n_devices,
                                      seed=cfg.seed, horizon=cfg.horizon + 30)
    show_link_matrix(cluster)
    tiers = cluster.tiers()

    print(f"\nscenario=multi_tier  devices={cfg.n_devices} "
          f"(tiers: {np.bincount(tiers).tolist()})  "
          f"budget={cfg.latency_budget}s")
    print(f"{'scheme':16s} {'service(s)':>10s} {'P_f':>7s} "
          f"{'%device':>8s} {'%edge':>7s} {'%cloud':>7s}")
    apps, times = _make_workload(cfg)
    for scheme in ALL_SCHEME_NAMES:
        c = make_multi_tier_cluster(profile, n_devices=cfg.n_devices,
                                    seed=cfg.seed, horizon=cfg.horizon + 30)
        orch = Orchestrator(c, policy_for(scheme, profile, cfg), seed=cfg.seed)
        # fused: one batched decide_batch call per wave-stage, priced on the
        # full (D, D) link matrix
        orch.submit_batch(apps, times, fused=True)
        orch.step(until=cfg.horizon + 25.0)
        res = orch.result("multi_tier", horizon=cfg.horizon)
        load = res.load_per_device.astype(float)
        shares = [
            100.0 * load[tiers == t].sum() / max(load.sum(), 1.0)
            for t in (0, 1, 2)
        ]
        print(f"{scheme:16s} {res.avg_service_time:10.3f} "
              f"{res.prob_failure:7.3f} "
              f"{shares[0]:8.1f} {shares[1]:7.1f} {shares[2]:7.1f}")

    print("\ntier_escalation keeps work device-local until the budget binds;"
          "\nschemes blind to the slow uplinks pull data across them instead.")


if __name__ == "__main__":
    main()
