"""Jaxpr-level auditing of the repo's registered jitted kernels.

AST lint sees call syntax; it cannot see what a kernel *traces to*.  This
module abstract-traces each registered kernel with ``jax.make_jaxpr`` over
shape/dtype specs derived from the fleet-snapshot layout at several fleet
sizes, and checks the lowered program against the contracts the rest of
the repo relies on:

  * **x64 bit-identity** (PR 2): the batched decision kernels run under
    ``jax.experimental.enable_x64`` and must be float64 end to end — a
    stray ``float32`` constant or low-precision promotion silently breaks
    batched==scalar parity.  Any non-f64 floating value in the jaxpr of an
    ``x64=True`` kernel is flagged.
  * **no host round-trips**: ``pure_callback``/``io_callback``/
    ``debug_callback``/``debug_print`` (and in/outfeed) primitives in a
    hot kernel stall the dispatch queue; the audit walks every sub-jaxpr
    (pjit, scan, cond bodies) looking for them.
  * **bounded recompilation**: ``decide_batch`` pads wave sizes to a
    bounded shape set (:func:`repro.core.batched._padded`), so a sweep of
    wave sizes must produce exactly the padded-bucket count of distinct
    lowerings.  ``expected_lowerings`` pins that number; more means a
    missing pad or a ``static_argnums`` mistake is recompiling per wave.
  * **donation**: every buffer named by ``donate_argnums`` must be
    reusable — each donated input leaf needs a matching (shape, dtype)
    output leaf, otherwise the donation is silently dropped and the
    serving engine double-buffers its KV cache.

The audit runs from the ``kernel-hygiene`` lint rule's ``finalize``: the
registered repo kernels come from :func:`builtin_targets`; test fixtures
self-describe by exporting a module-level ``AUDIT_TARGETS`` list of
:class:`KernelSpec` (the rule spots the assignment in the AST and imports
the module by path).  Everything degrades to a no-op when jax is absent.
"""
from __future__ import annotations

import contextlib
from collections import Counter
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = [
    "KernelSpec",
    "audit_spec",
    "builtin_targets",
    "have_jax",
    "f64",
    "f32",
    "i64",
    "i32",
    "bools",
]


def have_jax() -> bool:
    try:
        import jax  # noqa: F401

        return True
    except Exception:  # pragma: no cover - exercised on jax-less installs
        return False


# -- shape-spec helpers (ShapeDtypeStructs without importing jax at top) -------

def _sds(shape: Tuple[int, ...], dtype: str):
    import jax

    return jax.ShapeDtypeStruct(shape, dtype)


def f64(*shape: int):
    return _sds(shape, "float64")


def f32(*shape: int):
    return _sds(shape, "float32")


def i64(*shape: int):
    return _sds(shape, "int64")


def i32(*shape: int):
    return _sds(shape, "int32")


def bools(*shape: int):
    return _sds(shape, "bool")


@dataclass
class KernelSpec:
    """One kernel to audit.

    ``fn`` is a thunk (imports stay lazy so the linter never pays for jax
    unless the rule actually runs); ``build(point)`` turns one sweep point
    (e.g. ``{"D": 6, "B": 100}``) into the positional arguments —
    ``ShapeDtypeStruct`` pytrees for traced args, plain Python values for
    scalars and for ``static_argnums`` positions.
    """

    name: str
    fn: Callable[[], Callable]
    build: Callable[[Dict[str, int]], Tuple[Any, ...]]
    sweep: Tuple[Dict[str, int], ...]
    x64: bool = False
    static_argnums: Tuple[int, ...] = ()
    donate_argnums: Tuple[int, ...] = ()
    expected_lowerings: Optional[int] = None
    anchor: Optional[str] = None      # substring locating the finding's line


# -- jaxpr walking -------------------------------------------------------------

_HOST_PRIMITIVES = {
    "pure_callback",
    "io_callback",
    "debug_callback",
    "callback",
    "debug_print",
    "infeed",
    "outfeed",
}


def _subjaxprs(value: Any):
    """Yield raw Jaxprs nested inside an eqn param value."""
    from jax.extend import core as jcore

    if isinstance(value, jcore.ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, jcore.Jaxpr):
        yield value
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _subjaxprs(v)


def _walk_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from _walk_eqns(sub)


def _aval_of(var) -> Optional[Any]:
    return getattr(var, "aval", None)


def _bad_float(dtype) -> bool:
    import numpy as np

    return (
        np.issubdtype(dtype, np.floating)
        and np.dtype(dtype) != np.dtype("float64")
    )


def _scan_x64(closed, name: str) -> List[str]:
    """Non-f64 floating values inside a bit-identical x64 kernel."""
    problems: List[str] = []
    seen = set()

    def flag(what: str, dtype) -> None:
        msg = (
            f"x64 kernel `{name}` carries a {dtype} {what} — the batched "
            "twins are bit-identical float64 end to end (PR 2); promote "
            "the constant/op to float64"
        )
        if msg not in seen:
            seen.add(msg)
            problems.append(msg)

    for const in closed.consts:
        dtype = getattr(const, "dtype", None)
        if dtype is not None and _bad_float(dtype):
            flag("constant", dtype)
    for eqn in _walk_eqns(closed.jaxpr):
        for var in eqn.invars:
            aval = _aval_of(var)
            if aval is None or not hasattr(aval, "dtype"):
                continue
            if _bad_float(aval.dtype):
                what = (
                    "literal" if type(var).__name__ == "Literal"
                    else f"`{eqn.primitive.name}` input"
                )
                flag(what, aval.dtype)
        for var in eqn.outvars:
            aval = _aval_of(var)
            if aval is not None and hasattr(aval, "dtype") \
                    and _bad_float(aval.dtype):
                flag(f"`{eqn.primitive.name}` output", aval.dtype)
    return problems


def _scan_callbacks(closed, name: str) -> List[str]:
    hits = sorted({
        eqn.primitive.name
        for eqn in _walk_eqns(closed.jaxpr)
        if eqn.primitive.name in _HOST_PRIMITIVES
    })
    return [
        f"kernel `{name}` lowers a host-callback primitive `{p}` — "
        "debug prints / callbacks stall the dispatch queue; strip them "
        "from the registered kernel"
        for p in hits
    ]


def _leaf_avals(tree) -> List[Tuple[Tuple[int, ...], str]]:
    import jax

    out = []
    for leaf in jax.tree_util.tree_leaves(tree):
        shape = tuple(getattr(leaf, "shape", ()))
        dtype = str(getattr(leaf, "dtype", ""))
        out.append((shape, dtype))
    return out


def _check_donation(spec: KernelSpec, args: Tuple[Any, ...],
                    closed) -> List[str]:
    """Every donated input leaf must find a (shape, dtype)-matching output
    leaf, or XLA silently drops the donation."""
    problems = []
    outs = Counter(
        (tuple(a.shape), str(a.dtype))
        for a in closed.out_avals if hasattr(a, "shape")
    )
    for argnum in spec.donate_argnums:
        if argnum >= len(args):
            problems.append(
                f"kernel `{spec.name}` donates argnum {argnum} but only "
                f"{len(args)} arguments were specified"
            )
            continue
        for shape, dtype in _leaf_avals(args[argnum]):
            if outs[(shape, dtype)] > 0:
                outs[(shape, dtype)] -= 1
            else:
                problems.append(
                    f"kernel `{spec.name}` donates argnum {argnum} but its "
                    f"{dtype}{list(shape)} buffer has no matching output — "
                    "the donation is silently dropped and the buffer is "
                    "double-allocated"
                )
    return problems


def audit_spec(spec: KernelSpec) -> List[str]:
    """Run every check on one kernel; returns human-readable problems."""
    import jax

    try:
        fn = spec.fn()
    except Exception as e:  # the kernel itself failed to load
        return [f"kernel `{spec.name}` could not be loaded: {e!r}"]

    problems: List[str] = []
    lowerings: Dict[str, Dict[str, int]] = {}
    traced: List[Tuple[Dict[str, int], Tuple[Any, ...], Any]] = []
    for point in spec.sweep:
        args = spec.build(point)
        ctx = (
            __import__("jax.experimental", fromlist=["enable_x64"])
            .enable_x64() if spec.x64 else contextlib.nullcontext()
        )
        try:
            with ctx:
                closed = jax.make_jaxpr(
                    fn, static_argnums=spec.static_argnums
                )(*args)
        except Exception as e:
            problems.append(
                f"kernel `{spec.name}` failed to trace at {point}: "
                f"{type(e).__name__}: {e}"
            )
            continue
        traced.append((point, args, closed))
        lowerings.setdefault(str(closed), point)

    if spec.expected_lowerings is not None and traced:
        n = len(lowerings)
        if n > spec.expected_lowerings:
            pts = ", ".join(str(p) for p in lowerings.values())
            problems.append(
                f"kernel `{spec.name}` lowers {n} distinct programs across "
                f"the size sweep (expected <= {spec.expected_lowerings}; "
                f"one per padded bucket) — wave sizes are recompiling; pad "
                f"the row count (`_padded`) or fix static_argnums "
                f"[distinct at: {pts}]"
            )

    seen = set()
    for i, (point, args, closed) in enumerate(traced):
        msgs: List[str] = []
        if spec.x64:
            msgs.extend(_scan_x64(closed, spec.name))
        msgs.extend(_scan_callbacks(closed, spec.name))
        if i == 0 and spec.donate_argnums:
            msgs.extend(_check_donation(spec, args, closed))
        for m in msgs:
            if m not in seen:
                seen.add(m)
                problems.append(m)
    return problems


# -- the registered repo kernels ----------------------------------------------

_IBDASH_GAMMA = 3          # replication budget used for the trace specs
_ALPHA, _BETA = 0.5, 0.25


def _batched_kernel(key: str) -> Callable[[], Callable]:
    def thunk():
        from repro.core import batched

        return batched._jax()[key]

    return thunk


def _padded(B: int) -> int:
    from repro.core import batched

    return batched._padded(B)


# Fleet-size sweep: wave sizes B spanning three padded buckets (8 -> 8,
# 100 -> 128, 900/1000 -> 1024) at two fleet sizes D.  The ibdash scan's
# shapes depend only on n_scan = min(gamma+1, D-1), which saturates for
# D >= gamma+2 — the audit *proves* fleet growth does not recompile it.
_FLEET_SWEEP = (
    {"D": 6, "B": 8},
    {"D": 6, "B": 100},
    {"D": 24, "B": 900},
    {"D": 24, "B": 1000},
)


def _ibdash_args(p):
    B = _padded(p["B"])
    n_scan = min(_IBDASH_GAMMA + 1, p["D"] - 1)
    return (
        f64(B, n_scan + 1),              # s_total
        f64(B, n_scan + 1),              # s_pf
        i64(B),                          # n_feas
        _ALPHA, _BETA, _IBDASH_GAMMA,
    )


def _lavea_args(p):
    B = _padded(p["B"])
    return (f64(B, p["D"]), bools(B, p["D"]))


def _round_robin_args(p):
    B = _padded(p["B"])
    return (bools(B, p["D"]), i64(B))


def _tier_args(p):
    B = _padded(p["B"])
    return (f64(B, p["D"]), bools(B, p["D"]), i64(p["D"]), 2.5, 3)


def _ops_kernel(opname: str, **fixed) -> Callable[[], Callable]:
    def thunk():
        from repro.kernels import ops

        op = getattr(ops, opname)

        def wrapped(*arrays):
            return op(*arrays, impl="ref", **fixed)

        return wrapped

    return thunk


_ENGINE_CTX: Dict[str, Any] = {}


def _engine_ctx() -> Dict[str, Any]:
    """Tiny LM mirroring tests/test_serve.py, built once: abstract param
    avals via eval_shape, concrete (tiny) caches mapped to avals."""
    if _ENGINE_CTX:
        return _ENGINE_CTX
    import jax

    from repro.configs import get_config
    from repro.models import LM, reduced

    cfg = reduced(get_config("qwen1.5-0.5b"), n_layers=2, vocab=128)
    model = LM(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    B, S = 2, 32
    caches = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
        model.init_cache(B, S),
    )
    _ENGINE_CTX.update(
        model=model, params=params, caches=caches, B=B, S=S,
        vocab=cfg.vocab,
    )
    return _ENGINE_CTX


def _engine_decode() -> Callable:
    import jax

    ctx = _engine_ctx()
    # mirrors ServingEngine.__init__: jax.jit(model.decode_step,
    # donate_argnums=(3,))
    return jax.jit(ctx["model"].decode_step, donate_argnums=(3,))


def _engine_prefill() -> Callable:
    import jax

    ctx = _engine_ctx()
    return jax.jit(ctx["model"].prefill, donate_argnums=(2,))


def _engine_decode_args(p):
    ctx = _engine_ctx()
    B = ctx["B"]
    return (ctx["params"], i32(B), i32(B), ctx["caches"])


def _engine_prefill_args(p):
    ctx = _engine_ctx()
    return (ctx["params"], {"tokens": i32(ctx["B"], 8)}, ctx["caches"])


def builtin_targets() -> Dict[str, List[KernelSpec]]:
    """Registered kernels keyed by the repo-relative file that defines
    them; the rule audits an entry when its file is in the scanned set."""
    return {
        "src/repro/core/batched.py": [
            KernelSpec(
                name="ibdash_scan_kernel",
                fn=_batched_kernel("ibdash_scan_kernel"),
                build=_ibdash_args, sweep=_FLEET_SWEEP, x64=True,
                expected_lowerings=3,
                anchor="def ibdash_scan_kernel",
            ),
            KernelSpec(
                name="lavea_kernel",
                fn=_batched_kernel("lavea_kernel"),
                build=_lavea_args, sweep=_FLEET_SWEEP, x64=True,
                expected_lowerings=3,
                anchor="def lavea_kernel",
            ),
            KernelSpec(
                name="round_robin_kernel",
                fn=_batched_kernel("round_robin_kernel"),
                build=_round_robin_args, sweep=_FLEET_SWEEP, x64=True,
                expected_lowerings=3,
                anchor="def round_robin_kernel",
            ),
            KernelSpec(
                name="tier_escalation_kernel",
                fn=_batched_kernel("tier_escalation_kernel"),
                build=_tier_args, sweep=_FLEET_SWEEP, x64=True,
                static_argnums=(4,),
                expected_lowerings=3,
                anchor="def tier_escalation_kernel",
            ),
        ],
        "src/repro/kernels/ops.py": [
            KernelSpec(
                name="attention",
                fn=_ops_kernel("attention", causal=True),
                build=lambda p: (
                    f32(1, p["S"], 2, 8), f32(1, p["S"], 2, 8),
                    f32(1, p["S"], 2, 8),
                ),
                sweep=({"S": 16}, {"S": 32}),
                expected_lowerings=2,
                anchor="def attention",
            ),
            KernelSpec(
                name="decode_attention",
                fn=_ops_kernel("decode_attention"),
                build=lambda p: (
                    f32(1, 2, 8), f32(1, p["S"], 2, 8),
                    f32(1, p["S"], 2, 8), i32(1),
                ),
                sweep=({"S": 16}, {"S": 32}),
                expected_lowerings=2,
                anchor="def decode_attention",
            ),
            KernelSpec(
                name="rwkv6",
                fn=_ops_kernel("rwkv6"),
                build=lambda p: (
                    f32(1, p["T"], 2, 8), f32(1, p["T"], 2, 8),
                    f32(1, p["T"], 2, 8), f32(1, p["T"], 2, 8),
                    f32(2, 8), f32(1, 2, 8, 8),
                ),
                sweep=({"T": 8}, {"T": 16}),
                expected_lowerings=2,
                anchor="def rwkv6",
            ),
        ],
        "src/repro/serve/engine.py": [
            KernelSpec(
                name="engine.decode_step",
                fn=_engine_decode,
                build=_engine_decode_args, sweep=({},),
                donate_argnums=(3,),
                expected_lowerings=1,
                anchor="jax.jit(model.decode_step",
            ),
            KernelSpec(
                name="engine.prefill",
                fn=_engine_prefill,
                build=_engine_prefill_args, sweep=({},),
                donate_argnums=(2,),
                expected_lowerings=1,
                anchor="jax.jit(model.prefill",
            ),
        ],
    }
