"""span-parity: every span kind emitted in src must be in SPAN_SCHEMA and
pinned by the test suite.

The observability contract (repro.obs): emitters pass the span ``kind`` as
a string literal from :data:`repro.obs.tracing.SPAN_SCHEMA`, so the whole
span vocabulary is statically enumerable.  This rule enforces the three
halves of that contract:

  * a ``Tracer.add_span`` / ``open_span`` / ``event`` call whose kind
    argument is NOT a string literal defeats static auditing — finding at
    the call site;
  * a literal kind that is missing from the schema table would raise at
    runtime (the tracer validates) but should be caught at lint time —
    finding at the call site;
  * a kind emitted somewhere in src but never named in any scanned test
    file has no behavioural pin (nothing fails if its emission silently
    disappears) — finding anchored at the obs test file, mirroring
    registry-parity.

Like registry-parity, the rule stays silent about test pins when no test
files were scanned (e.g. ``python -m repro.analysis src``).
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set, Tuple

from ..framework import FileContext, Finding, ProjectContext, Rule, register_rule

# Tracer emission methods whose second positional argument is a span kind.
_EMIT_METHODS = ("add_span", "open_span", "event")


def _live_schema() -> Tuple[str, ...]:
    from repro.obs.tracing import SPAN_SCHEMA

    return tuple(SPAN_SCHEMA)


def _kind_arg(call: ast.Call) -> Optional[ast.expr]:
    """The span-kind argument of an emission call: positional #2
    (after tid) or the ``kind=`` keyword."""
    if len(call.args) >= 2:
        return call.args[1]
    for kw in call.keywords:
        if kw.arg == "kind":
            return kw.value
    return None


@register_rule
class SpanParityRule(Rule):
    name = "span-parity"
    severity = "error"
    description = (
        "every span kind emitted via Tracer.add_span/open_span/event must "
        "be a string literal, present in SPAN_SCHEMA, and named in the "
        "scanned test suite (repro.obs contract)"
    )
    default_paths = ("",)
    TEST_PATHS_OPTION = "test_paths"      # prefixes that count as test files
    SRC_PATHS_OPTION = "src_paths"        # prefixes whose emissions are audited
    SCHEMA_OPTION = "schema"              # schema override (fixtures)

    def _test_paths(self) -> Tuple[str, ...]:
        return tuple(self.options.get(self.TEST_PATHS_OPTION, ("tests",)))

    def _src_paths(self) -> Tuple[str, ...]:
        return tuple(self.options.get(self.SRC_PATHS_OPTION, ("src",)))

    def check_file(self, ctx: FileContext, project: ProjectContext
                   ) -> Iterator[Finding]:
        if any(ctx.path.startswith(p) for p in self._test_paths()):
            literals: Set[str] = project.store.setdefault(
                "span_test_literals", set())  # type: ignore[assignment]
            test_files: List[str] = project.store.setdefault(
                "span_test_files", [])  # type: ignore[assignment]
            test_files.append(ctx.path)
            for node in ast.walk(ctx.tree):
                if isinstance(node, ast.Constant) and isinstance(node.value, str):
                    literals.add(node.value)
        if not any(ctx.path.startswith(p) for p in self._src_paths()):
            return
        emits: List[Tuple[str, str, int]] = project.store.setdefault(
            "span_emits", [])  # type: ignore[assignment]
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in _EMIT_METHODS):
                continue
            kind = _kind_arg(node)
            if kind is None:
                continue
            if not (isinstance(kind, ast.Constant)
                    and isinstance(kind.value, str)):
                yield self.finding(
                    ctx, node,
                    f"span kind passed to .{node.func.attr}() must be a "
                    "string literal from SPAN_SCHEMA — a computed kind "
                    "defeats the static span audit",
                )
                continue
            emits.append((kind.value, ctx.path, node.lineno))

    def finalize(self, project: ProjectContext) -> Iterator[Finding]:
        emits: List[Tuple[str, str, int]] = project.store.get(
            "span_emits", [])  # type: ignore[assignment]
        if not emits:
            return
        schema = self.options.get(self.SCHEMA_OPTION)
        if schema is None:
            try:
                schema = _live_schema()
            except Exception as e:  # schema unimportable in this env
                yield self.finding(
                    emits[0][1], emits[0][2],
                    f"could not import repro.obs.tracing.SPAN_SCHEMA to "
                    f"cross-check emitted span kinds: {e!r}",
                )
                return
        schema = tuple(schema)
        for kind, path, line in emits:
            if kind not in schema:
                yield self.finding(
                    path, line,
                    f"span kind {kind!r} is not in SPAN_SCHEMA — add it to "
                    "the schema table (and obs/README.md) or fix the typo",
                )
        test_files: List[str] = project.store.get(
            "span_test_files", [])  # type: ignore[assignment]
        if not test_files:
            return
        literals: Set[str] = project.store.get(
            "span_test_literals", set())  # type: ignore[assignment]
        anchor = self._anchor(test_files)
        for kind in sorted({k for k, _, _ in emits}):
            if kind in schema and kind not in literals:
                yield self.finding(
                    anchor, 1,
                    f"span kind {kind!r} is emitted in src but never named "
                    "in the scanned test suite — it has no behavioural pin "
                    "(add it to the obs suite)",
                )

    @staticmethod
    def _anchor(test_files: List[str]) -> str:
        for path in test_files:
            if "test_obs" in path:
                return path
        return test_files[0]
