"""jit-hygiene: no host syncs or traced-value branching inside jitted
kernels.

The invariant (PR 2): the batched decision kernels in ``core/batched.py``
run under ``jax.jit`` + ``enable_x64`` and must be bit-identical to their
scalar numpy twins.  A ``.item()`` / ``float()`` / ``np.asarray`` inside a
jitted body forces a device->host sync per trace (or a silent
ConcretizationError much later), and a Python ``if``/``while`` on a traced
value bakes ONE branch into the compiled artifact — the jitted twin then
diverges from the scalar twin on exactly the inputs the parity suite
doesn't cover.

Detection: a function counts as jitted when it is decorated with
``jit``/``jax.jit``/``partial(jax.jit, ...)`` OR wrapped anywhere in the
module as ``jax.jit(fn, ...)`` (the lazy-``_jax()`` pattern this repo
uses).  Parameters named by ``static_argnums``/``static_argnames`` are
compile-time constants and may be branched on; everything else — including
values assigned from traced parameters (one forward taint pass) — may not.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from ..astutil import call_name, dotted_name, names_in, param_names, walk_functions
from ..framework import FileContext, Finding, ProjectContext, Rule, register_rule

_JIT_NAMES = {"jit", "jax.jit"}
_HOST_CASTS = {"float", "int", "bool", "complex"}
_NP_PREFIXES = ("np.", "numpy.")


def _jit_from_decorator(dec: ast.AST) -> Optional[ast.Call]:
    """Return the jit Call node (for static args) if this decorator jits,
    else None; plain ``@jax.jit`` returns a synthetic empty Call."""
    if dotted_name(dec) in _JIT_NAMES:
        return ast.Call(func=dec, args=[], keywords=[])
    if isinstance(dec, ast.Call):
        name = dotted_name(dec.func)
        if name in _JIT_NAMES:
            return dec
        if name in ("partial", "functools.partial") and dec.args:
            if dotted_name(dec.args[0]) in _JIT_NAMES:
                return dec
    return None


def _static_params(fn: ast.FunctionDef, jit_call: ast.Call,
                   wrapped: bool) -> Set[str]:
    """Parameter names declared static via static_argnums/static_argnames."""
    params = param_names(fn)
    static: Set[str] = set()
    for kw in jit_call.keywords:
        if kw.arg == "static_argnames":
            for node in ast.walk(kw.value):
                if isinstance(node, ast.Constant) and isinstance(node.value, str):
                    static.add(node.value)
        elif kw.arg == "static_argnums":
            for node in ast.walk(kw.value):
                if isinstance(node, ast.Constant) and isinstance(node.value, int):
                    idx = node.value
                    if 0 <= idx < len(params):
                        static.add(params[idx])
    return static


@register_rule
class JitHygieneRule(Rule):
    name = "jit-hygiene"
    severity = "error"
    description = (
        "no .item()/float()/np.asarray host syncs and no Python branching "
        "on traced values inside @jit kernels (bit-identical batched/scalar "
        "twins, PR 2)"
    )
    # the jitted kernels live in core/batched.py (policy kernels included);
    # widen via config when new jitted modules appear
    default_paths = ("src/repro/core",)

    def check_file(self, ctx: FileContext, project: ProjectContext
                   ) -> Iterator[Finding]:
        # pass 1: functions wrapped as jax.jit(fn, ...) anywhere in the module
        wrapped: Dict[str, ast.Call] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) and dotted_name(node.func) in _JIT_NAMES:
                if node.args and isinstance(node.args[0], ast.Name):
                    wrapped[node.args[0].id] = node
        # pass 2: check every jitted function body
        for fn in walk_functions(ctx.tree):
            jit_call = None
            for dec in fn.decorator_list:
                jit_call = _jit_from_decorator(dec)
                if jit_call is not None:
                    break
            if jit_call is None and fn.name in wrapped:
                jit_call = wrapped[fn.name]
            if jit_call is None:
                continue
            static = _static_params(fn, jit_call, wrapped=fn.name in wrapped)
            yield from self._check_body(ctx, fn, static)

    def _check_body(self, ctx: FileContext, fn: ast.FunctionDef,
                    static: Set[str]) -> Iterator[Finding]:
        tainted: Set[str] = set(param_names(fn)) - static - {"self"}
        # one forward taint pass: names assigned from traced values are traced
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                if names_in(node.value) & tainted:
                    for tgt in node.targets:
                        for leaf in ast.walk(tgt):
                            if isinstance(leaf, ast.Name):
                                tainted.add(leaf.id)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not fn:
                # closures passed to lax.scan etc: their params are tracers too
                tainted |= set(param_names(node)) - {"self"}
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                name = call_name(node)
                if isinstance(node.func, ast.Attribute) and node.func.attr == "item":
                    yield self.finding(
                        ctx, node,
                        f"`.item()` inside jitted `{fn.name}` forces a "
                        "device->host sync per trace; keep the value on "
                        "device (jnp.where / lax.cond)",
                    )
                elif name in _HOST_CASTS and node.args and not all(
                    isinstance(a, ast.Constant) for a in node.args
                ):
                    if names_in(node.args[0]) & tainted:
                        yield self.finding(
                            ctx, node,
                            f"`{name}()` on a traced value inside jitted "
                            f"`{fn.name}` is a concretization/host sync; use "
                            "jnp casts (`.astype`) instead",
                        )
                elif name and name.startswith(_NP_PREFIXES):
                    if any(names_in(a) & tainted for a in node.args):
                        yield self.finding(
                            ctx, node,
                            f"numpy call `{name}()` on a traced value inside "
                            f"jitted `{fn.name}` leaves the device; use the "
                            "jnp equivalent",
                        )
            elif isinstance(node, (ast.If, ast.While)):
                hot = names_in(node.test) & tainted
                if hot:
                    kind = "if" if isinstance(node, ast.If) else "while"
                    yield self.finding(
                        ctx, node,
                        f"Python `{kind}` on traced value(s) "
                        f"{sorted(hot)} inside jitted `{fn.name}` bakes one "
                        "branch into the compiled kernel — use jnp.where / "
                        "lax.cond / lax.scan (or declare the argument "
                        "static_argnums)",
                    )
