"""snapshot-schema: every FleetSnapshot site must agree with the ONE
declared leaf schema.

The invariant: ``FleetSnapshot`` is a registered JAX pytree whose leaf
order IS its dataclass field order (``flatten_fleet`` iterates
``fields()``).  The schema has already drifted 12 -> 13 -> 15 -> 17 leaves
across PRs 3-10; a construction site that goes positional, or misses a new
leaf, reorders/omits pytree leaves *silently* — jitted kernels then read
the wrong tensor with no shape error in sight.  The single source of
truth is :data:`repro.core.batched.FLEET_SNAPSHOT_SCHEMA`; this rule
checks, statically:

  * the ``FleetSnapshot`` dataclass declares exactly those fields in that
    order (and stays ``frozen=True``);
  * every ``FleetSnapshot(...)`` call is keyword-only and its keyword set
    equals the schema exactly (a ``**splat`` construction is accepted —
    the pytree unflattener builds from the authoritative field list).

The runtime twin is ``FleetSnapshot.validate()``, asserted on every
``ClusterState.snapshot()`` under ``__debug__``.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional, Sequence, Tuple

from ..astutil import dotted_name, has_kwsplat, keyword_names
from ..framework import FileContext, Finding, ProjectContext, Rule, register_rule

_CLASS = "FleetSnapshot"


def _declared_schema() -> Tuple[str, ...]:
    from repro.core.batched import FLEET_SNAPSHOT_SCHEMA

    return tuple(FLEET_SNAPSHOT_SCHEMA)


@register_rule
class SnapshotSchemaRule(Rule):
    name = "snapshot-schema"
    severity = "error"
    description = (
        "FleetSnapshot dataclass fields and every construction site must "
        "match FLEET_SNAPSHOT_SCHEMA exactly (keyword-only; no positional "
        "leaf drift)"
    )
    default_paths = ("",)
    SCHEMA_OPTION = "schema"      # override for fixture tests

    def _schema(self) -> Tuple[str, ...]:
        override = self.options.get(self.SCHEMA_OPTION)
        if override is not None:
            return tuple(override)  # type: ignore[arg-type]
        return _declared_schema()

    def check_file(self, ctx: FileContext, project: ProjectContext
                   ) -> Iterator[Finding]:
        schema = self._schema()
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef) and node.name == _CLASS:
                yield from self._check_classdef(ctx, node, schema)
            elif isinstance(node, ast.Call):
                name = dotted_name(node.func)
                if name == _CLASS or (name and name.endswith("." + _CLASS)):
                    yield from self._check_construction(ctx, node, schema)

    # -- the dataclass declaration -------------------------------------------
    def _check_classdef(self, ctx: FileContext, node: ast.ClassDef,
                        schema: Sequence[str]) -> Iterator[Finding]:
        fields = tuple(
            stmt.target.id
            for stmt in node.body
            if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name)
        )
        if fields != tuple(schema):
            yield self.finding(
                ctx, node,
                f"{_CLASS} declares leaves {list(fields)} but the declared "
                f"schema is {list(schema)} — field order IS pytree leaf "
                "order; update FLEET_SNAPSHOT_SCHEMA and every construction "
                "site together",
            )
        if not self._is_frozen_dataclass(node):
            yield self.finding(
                ctx, node,
                f"{_CLASS} must be @dataclass(frozen=True) — snapshots are "
                "immutable views shared across waves",
            )

    @staticmethod
    def _is_frozen_dataclass(node: ast.ClassDef) -> bool:
        for dec in node.decorator_list:
            if isinstance(dec, ast.Call) and dotted_name(dec.func) in (
                "dataclass", "dataclasses.dataclass"
            ):
                for kw in dec.keywords:
                    if kw.arg == "frozen" and isinstance(kw.value, ast.Constant):
                        return bool(kw.value.value)
        return False

    # -- construction sites ---------------------------------------------------
    def _check_construction(self, ctx: FileContext, call: ast.Call,
                            schema: Sequence[str]) -> Iterator[Finding]:
        if call.args:
            yield self.finding(
                ctx, call,
                f"positional {_CLASS} construction — leaf order has drifted "
                "12->13->15 across PRs; pass every leaf by keyword so the "
                "next schema change cannot silently reorder pytree leaves",
            )
        if has_kwsplat(call):
            # FleetSnapshot(**dict(zip(fields, vals))): the unflattener —
            # built from the authoritative field list, nothing to check
            return
        names = [n for n, _ in keyword_names(call)]
        missing = [s for s in schema if s not in names]
        unknown = [n for n in names if n not in schema]
        dupes = sorted({n for n in names if names.count(n) > 1})
        if missing and not call.args:
            yield self.finding(
                ctx, call,
                f"{_CLASS} construction misses schema leaves {missing} — "
                "every construction site must produce the full "
                f"{len(schema)}-leaf pytree",
            )
        if unknown:
            yield self.finding(
                ctx, call,
                f"{_CLASS} construction passes unknown leaves {unknown} "
                f"(schema: {list(schema)})",
            )
        if dupes:
            yield self.finding(
                ctx, call, f"{_CLASS} construction repeats leaves {dupes}",
            )
