"""unit-consistency: seconds, bytes, bytes/s and probabilities may not be
mixed by ``+``/``-``/comparison on the pricing paths.

Eq. (2)'s total latency sums three *seconds* terms — ``exec_lat``,
``model_bytes / upload_bw`` and ``out_bytes / link_bw[src, dst]`` — and
PR 3's receiver-only-bandwidth bug is exactly what happens when a bytes
term slips into that sum without its dividing bandwidth.  This rule runs
the :mod:`..units` dataflow over every function in scope: names are
seeded from the core-API table (plus ``*_bytes``/``*_bw``/``n_*`` …
suffix rules), units propagate through assignments and arithmetic, and a
finding fires only when BOTH operands of an add/compare are known and
disagree (or a transcendental is applied to a dimensioned value).

Options:
  * ``units`` — ``{name: unit}`` entries merged over the default table
    (unit strings: ``s``, ``B``, ``B/s``, ``1/s``, ``prob``, ``count``,
    ``dimensionless``)
  * ``drop_units`` — names to remove from the table (when a repo area
    reuses a table name with a different meaning)
"""
from __future__ import annotations

from typing import Dict, Iterator, Tuple

from ..astutil import walk_functions
from ..framework import FileContext, Finding, ProjectContext, Rule, register_rule
from ..units import (
    DEFAULT_SUFFIXES,
    DEFAULT_TABLE,
    Unit,
    UnitChecker,
    parse_unit,
)


@register_rule
class UnitConsistencyRule(Rule):
    name = "unit-consistency"
    severity = "error"
    description = (
        "units-of-measure dataflow on the pricing paths: no seconds+bytes "
        "adds, no mixed-unit comparisons, no exp/log of dimensioned values"
    )
    # the pricing arithmetic lives here; examples/benchmarks wrap it
    default_paths = ("src/repro/core", "src/repro/stream")

    def __init__(self, options=None) -> None:
        super().__init__(options)
        table: Dict[str, Unit] = {
            name: parse_unit(u) for name, u in DEFAULT_TABLE.items()
        }
        for name, u in dict(self.options.get("units", {})).items():
            table[name] = parse_unit(u)
        for name in tuple(self.options.get("drop_units", ())):
            table.pop(name, None)
        suffixes: Tuple[Tuple[str, Unit], ...] = tuple(
            (pat, parse_unit(u)) for pat, u in DEFAULT_SUFFIXES
        )
        self._checker = UnitChecker(table, suffixes)

    def check_file(self, ctx: FileContext, project: ProjectContext
                   ) -> Iterator[Finding]:
        for fn in walk_functions(ctx.tree):
            for p in self._checker.check_function(fn):
                yield self.finding(ctx, p.lineno, p.message, col=p.col)
