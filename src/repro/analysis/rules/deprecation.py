"""deprecation: no new code on the pre-PR-3 scalar-bandwidth shims.

The invariant (PR 3): transfers are priced on the *link* —
``bw_eff[s, d] = min(up[s], down[d], backhaul[tier[s], tier[d]])`` — not
on a per-device scalar.  The scalar surface survives only as
compatibility shims, and every remaining use is a site where a
heterogeneous fleet silently mis-prices a transfer:

  * ``Device(bandwidth=B)`` — the symmetric shim; pass ``up_bw=``/
    ``down_bw=`` (and ``tier=``) instead;
  * ``cluster.bandwidths()`` / ``snapshot.bandwidths`` — the receiver-only
    ``(D,)`` vector; use ``link_bw()`` / ``up_bandwidths()`` /
    ``down_bandwidths()``;
  * ``transfer_latency(...)`` / ``upload_latency(...)`` — the removed
    PR-1 Scheduler helpers whose scalar-bandwidth arithmetic predates the
    link matrix entirely.
"""
from __future__ import annotations

import ast
from typing import Iterator

from ..astutil import dotted_name
from ..framework import FileContext, Finding, ProjectContext, Rule, register_rule

_LEGACY_CALLS = {
    "transfer_latency":
        "the scalar-bandwidth `transfer_latency` shim predates the link "
        "model; price transfers with `cluster.link_row(src)[dst]`",
    "upload_latency":
        "the scalar-bandwidth `upload_latency` shim predates the link "
        "model; price uploads with `cluster.upload_bw()[dst]`",
    "bandwidths":
        "`bandwidths()` is the deprecated receiver-only (D,) vector; use "
        "`link_row()` / `up_bandwidths()` / `down_bandwidths()` (PR 3)",
}


@register_rule
class DeprecationRule(Rule):
    name = "deprecation"
    severity = "error"
    description = (
        "no Device(bandwidth=), cluster.bandwidths(), or scalar-bandwidth "
        "transfer_latency/upload_latency — use the tier/link-matrix API "
        "(PR 3)"
    )
    default_paths = ("",)

    def check_file(self, ctx: FileContext, project: ProjectContext
                   ) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func) or ""
            tail = name.rsplit(".", 1)[-1]
            if tail == "Device":
                for kw in node.keywords:
                    if kw.arg == "bandwidth":
                        yield self.finding(
                            ctx, kw.value,
                            "Device(bandwidth=) is the deprecated symmetric "
                            "scalar shim; pass up_bw=/down_bw= (and tier=) — "
                            "the link matrix prices the slow direction "
                            "(PR 3)",
                        )
            elif isinstance(node.func, ast.Attribute) and tail in _LEGACY_CALLS:
                yield self.finding(ctx, node, _LEGACY_CALLS[tail])
