"""registry-parity: every registered policy/recovery must be pinned by the
test suite.

The invariant (PRs 2/4/5): every scheme in ``available_policies()`` ships
a jitted batched kernel AND a scalar twin proven bit-identical by the
parity harness (``tests/test_batched_policy.py``), and every recovery
strategy in ``available_recoveries()`` is exercised by the churn suite.
A scheme that is registered but never named in a test file has *no parity
pin* — its batched and scalar paths can silently diverge, which is exactly
the failure mode the parity suites exist to prevent.

Mechanism: while walking the configured test paths the rule collects every
string literal; at finalize it imports the live registries (or takes them
from rule options, for fixtures) and reports any registered name that no
scanned test file ever mentions.  When no test files were scanned (e.g.
``python -m repro.analysis src``) the rule stays silent rather than
guessing.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set, Tuple

from ..framework import FileContext, Finding, ProjectContext, Rule, register_rule


def _live_registries() -> Tuple[Tuple[str, ...], Tuple[str, ...]]:
    from repro.core.policy import available_policies
    from repro.core.recovery import available_recoveries

    return tuple(available_policies()), tuple(available_recoveries())


@register_rule
class RegistryParityRule(Rule):
    name = "registry-parity"
    severity = "error"
    description = (
        "every name in available_policies()/available_recoveries() must "
        "appear in the scanned test suite (batched/scalar parity pins, "
        "PRs 2/4/5)"
    )
    default_paths = ("",)
    TEST_PATHS_OPTION = "test_paths"      # prefixes that count as test files
    POLICIES_OPTION = "policies"          # registry overrides (fixtures)
    RECOVERIES_OPTION = "recoveries"

    def _test_paths(self) -> Tuple[str, ...]:
        return tuple(self.options.get(self.TEST_PATHS_OPTION, ("tests",)))

    def check_file(self, ctx: FileContext, project: ProjectContext
                   ) -> Iterator[Finding]:
        if any(ctx.path.startswith(p) for p in self._test_paths()):
            literals: Set[str] = project.store.setdefault("literals", set())  # type: ignore[assignment]
            test_files: List[str] = project.store.setdefault("test_files", [])  # type: ignore[assignment]
            test_files.append(ctx.path)
            for node in ast.walk(ctx.tree):
                if isinstance(node, ast.Constant) and isinstance(node.value, str):
                    literals.add(node.value)
        return iter(())

    def finalize(self, project: ProjectContext) -> Iterator[Finding]:
        test_files: List[str] = project.store.get("test_files", [])  # type: ignore[assignment]
        if not test_files:
            return
        literals: Set[str] = project.store.get("literals", set())  # type: ignore[assignment]
        policies = self.options.get(self.POLICIES_OPTION)
        recoveries = self.options.get(self.RECOVERIES_OPTION)
        if policies is None or recoveries is None:
            try:
                live_p, live_r = _live_registries()
            except Exception as e:  # registries unimportable in this env
                yield self.finding(
                    test_files[0], 1,
                    f"could not import the policy/recovery registries to "
                    f"cross-check parity pins: {e!r}",
                )
                return
            policies = live_p if policies is None else policies
            recoveries = live_r if recoveries is None else recoveries
        anchor = self._anchor(test_files)
        for name in policies:
            if name not in literals:
                yield self.finding(
                    anchor, 1,
                    f"registered policy {name!r} is never named in the "
                    "scanned test suite — it has no batched/scalar parity "
                    "pin (add it to the parity harness)",
                )
        for name in recoveries:
            if name not in literals:
                yield self.finding(
                    anchor, 1,
                    f"registered recovery {name!r} is never named in the "
                    "scanned test suite — add it to the churn/recovery "
                    "suite",
                )

    @staticmethod
    def _anchor(test_files: List[str]) -> str:
        for path in test_files:
            if "test_batched_policy" in path:
                return path
        return test_files[0]
