"""Built-in orchestration-contract rules.

Importing this package registers every rule with the framework registry.
"""
from . import rng            # noqa: F401
from . import purity         # noqa: F401
from . import schema         # noqa: F401
from . import jit            # noqa: F401
from . import deprecation    # noqa: F401
from . import registry_parity  # noqa: F401
from . import kernel_hygiene   # noqa: F401
from . import unit_consistency  # noqa: F401
from . import span_parity      # noqa: F401
