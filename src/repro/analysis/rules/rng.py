"""rng-discipline: every random draw must come from an explicitly seeded
generator, and simulated code must not read the wall clock.

The invariant (PR 5/6 common-random-numbers contract): all stochastic
generators derive one ``np.random.Generator`` per logical stream from an
explicit ``seed``/``SeedSequence`` key — ``(seed, device_id)`` for churn
lifetimes, ``(seed, stream_index)`` for arrivals — so adding a device or a
stream never reshuffles any other stream's draws, and two runs with equal
seeds are bit-identical.  Global-state draws (``np.random.rand`` & co.),
the stdlib ``random`` module, unseeded ``default_rng()``, and bare
``time.time()`` inside ``src/repro`` all break that contract silently:
the run still *looks* deterministic until a fleet-size change or a wall
clock poisons a DRL rollout.

PR 8 adds an interprocedural ``finalize`` pass: a policy entry point
(``decide``/``decide_batch``) that reaches a global-state draw or a bare
``time.time()`` *through helpers* is flagged with the full call chain —
``decide_batch -> util -> np.random.shuffle()``.  The per-file checks
above remain the fallback for direct violations.
"""
from __future__ import annotations

import ast
from typing import Iterator, Set

from ..astutil import call_name
from ..callgraph import summarize_module
from ..effects import engine_for
from ..framework import FileContext, Finding, ProjectContext, Rule, register_rule

_POLICY_METHODS = ("decide", "decide_batch")

# np.random attributes that are construction/typing, not global-state draws
_ALLOWED_NP_RANDOM = {
    "default_rng",
    "Generator",
    "SeedSequence",
    "BitGenerator",
    "PCG64",
    "PCG64DXSM",
    "Philox",
    "SFC64",
    "MT19937",
}

_NP_RANDOM_PREFIXES = ("np.random.", "numpy.random.")


@register_rule
class RngDisciplineRule(Rule):
    name = "rng-discipline"
    severity = "error"
    description = (
        "random draws must come from generators keyed by explicit "
        "seed/SeedSequence arguments; no global np.random state, no stdlib "
        "`random`, no bare time.time() in src/repro"
    )
    default_paths = ("",)          # the draw checks apply everywhere scanned
    # the wall-clock check is scoped separately: benchmarks/ legitimately
    # wall-clock their own harness, the simulator must not
    TIME_PATHS_OPTION = "time_call_paths"
    DEFAULT_TIME_PATHS = ("src/repro",)

    def check_file(self, ctx: FileContext, project: ProjectContext
                   ) -> Iterator[Finding]:
        time_paths = tuple(
            self.options.get(self.TIME_PATHS_OPTION, self.DEFAULT_TIME_PATHS)
        )
        check_time = any(ctx.path.startswith(p) for p in time_paths)
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name == "random" or alias.name.startswith("random."):
                        yield self.finding(
                            ctx, node,
                            "stdlib `random` uses hidden global state; key a "
                            "`np.random.default_rng(seed)` stream instead "
                            "(PR 5/6 common-random-numbers contract)",
                        )
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if mod == "random":
                    yield self.finding(
                        ctx, node,
                        "stdlib `random` uses hidden global state; key a "
                        "`np.random.default_rng(seed)` stream instead "
                        "(PR 5/6 common-random-numbers contract)",
                    )
                elif mod in ("numpy.random", "np.random"):
                    for alias in node.names:
                        if alias.name not in _ALLOWED_NP_RANDOM:
                            yield self.finding(
                                ctx, node,
                                f"`from numpy.random import {alias.name}` pulls "
                                "a global-state draw function; use an explicit "
                                "Generator (`default_rng(seed)`)",
                            )
            elif isinstance(node, ast.Call):
                name = call_name(node)
                if name is None:
                    continue
                if name.startswith(_NP_RANDOM_PREFIXES):
                    attr = name.split(".", 2)[2]
                    head = attr.split(".", 1)[0]
                    if head not in _ALLOWED_NP_RANDOM:
                        yield self.finding(
                            ctx, node,
                            f"global np.random draw `{name}()` — draws must "
                            "come from a per-stream `default_rng(seed)` "
                            "Generator so streams never reshuffle each other",
                        )
                    elif head == "default_rng" and self._unseeded(node):
                        yield self.finding(
                            ctx, node,
                            "`default_rng()` without an explicit seed is "
                            "OS-entropy nondeterminism; derive the generator "
                            "from a seed/SeedSequence argument",
                        )
                elif name.endswith("default_rng") and self._unseeded(node):
                    # e.g. `from numpy.random import default_rng; default_rng()`
                    yield self.finding(
                        ctx, node,
                        "`default_rng()` without an explicit seed is "
                        "OS-entropy nondeterminism; derive the generator from "
                        "a seed/SeedSequence argument",
                    )
                elif check_time and name in ("time.time", "time.time_ns"):
                    yield self.finding(
                        ctx, node,
                        f"bare `{name}()` in simulated code — the sim owns "
                        "virtual time; inject a clock parameter (wall-clock "
                        "interval measurement should use time.perf_counter/"
                        "time.monotonic)",
                    )

    def finalize(self, project: ProjectContext) -> Iterator[Finding]:
        """Transitive pass: policy entry points reaching global-RNG draws
        or wall-clock reads through helper chains."""
        time_paths = tuple(
            self.options.get(self.TIME_PATHS_OPTION, self.DEFAULT_TIME_PATHS)
        )
        summaries = []
        for fctx in project.files:
            try:
                summaries.append(
                    summarize_module(fctx.path, fctx.source, fctx.tree)
                )
            except (SyntaxError, RecursionError):  # pragma: no cover
                continue
        if not summaries:
            return
        engine = engine_for(summaries)
        emitted: Set[tuple] = set()
        for entry in sorted(
            (f for name in _POLICY_METHODS
             for f in engine.functions_named(name)),
            key=lambda f: (f.path, f.lineno),
        ):
            check_time = any(entry.path.startswith(p) for p in time_paths)
            for eff in engine.effects_of(entry.qualname):
                if not eff.transitive:
                    continue   # direct draws belong to check_file
                if eff.kind == "global-rng":
                    why = (
                        "draws from hidden global RNG state — draws must come "
                        "from a per-stream `default_rng(seed)` Generator"
                    )
                elif eff.kind == "wall-clock" and check_time:
                    why = (
                        "reads the wall clock inside simulated code — the sim "
                        "owns virtual time; inject a clock parameter"
                    )
                else:
                    continue
                msg = (
                    f"`{entry.name}` reaches `{eff.origin}` through the call "
                    f"chain `{eff.render_chain()}` — {why}"
                )
                key = (entry.path, eff.site_line, msg)
                if key in emitted:
                    continue
                emitted.add(key)
                yield self.finding(entry.path, eff.site_line, msg)

    @staticmethod
    def _unseeded(call: ast.Call) -> bool:
        if call.keywords:
            return False
        if not call.args:
            return True
        return (
            len(call.args) == 1
            and isinstance(call.args[0], ast.Constant)
            and call.args[0].value is None
        )
