"""policy-purity: ``decide``/``decide_batch`` bodies must stay pure.

The invariant (PR 1): planning and state mutation are split — a policy is
a pure function ``PolicyContext -> TaskDecision`` and the ONLY blessed
mutation path is ``cluster.apply(plan)`` (undoable, outside the policy).
A policy that calls a cluster mutator or writes through its context
corrupts speculative what-if sweeps, breaks batched==scalar parity (the
batched kernel would miss the side effect), and poisons DRL rollouts that
replay the same snapshot.

Flags, inside any function named ``decide``/``decide_batch``:
  * calls to the cluster mutators ``apply``, ``add_interval``,
    ``cancel_from``, ``mark_down``/``mark_up``, ``set_bandwidth``,
    ``install_forecast``, ``refresh_topology``, ``undo`` on any receiver
    other than ``self`` (stateful policies may advance their OWN rng or
    cursor — that is defined row-order state, not fleet state);
  * attribute/subscript stores through a non-``self`` parameter
    (``ctx.total = ...``, ``batch.fleet.alive[0] = ...``);
  * ``object.__setattr__(ctx, ...)`` back-doors into frozen contexts.

PR 8 makes the rule *interprocedural*: ``finalize`` builds the project
call graph (:mod:`..callgraph`) and the bottom-up effect sets
(:mod:`..effects`), so ``decide -> _helper -> ctx.cluster.apply()`` is a
finding even though no single body shows both ends — the full call chain
appears in the message.  The per-file pass above stays as the fallback
for direct violations (and for files the call graph cannot resolve).
"""
from __future__ import annotations

import ast
from typing import Iterator, Set

from ..astutil import dotted_name, param_names, walk_functions
from ..effects import PARAM_MUTATION, engine_for
from ..callgraph import summarize_module
from ..framework import FileContext, Finding, ProjectContext, Rule, register_rule

MUTATORS = frozenset({
    "apply",
    "add_interval",
    "cancel_from",
    "mark_down",
    "mark_up",
    "set_bandwidth",
    "install_forecast",
    "refresh_topology",
    "undo",
})

_POLICY_METHODS = ("decide", "decide_batch")


def _root_name(node: ast.AST):
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


@register_rule
class PolicyPurityRule(Rule):
    name = "policy-purity"
    severity = "error"
    description = (
        "decide/decide_batch may not call cluster mutators or assign "
        "through their context/snapshot arguments (pure orchestrate vs "
        "mutating apply, PR 1)"
    )
    default_paths = ("",)

    def check_file(self, ctx: FileContext, project: ProjectContext
                   ) -> Iterator[Finding]:
        for fn in walk_functions(ctx.tree):
            if fn.name not in _POLICY_METHODS:
                continue
            params: Set[str] = set(param_names(fn))
            foreign = params - {"self", "cls"}
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    yield from self._check_call(ctx, fn, node, foreign)
                elif isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                    targets = (
                        node.targets if isinstance(node, ast.Assign)
                        else [node.target]
                    )
                    for tgt in targets:
                        if isinstance(tgt, (ast.Attribute, ast.Subscript)):
                            root = _root_name(tgt)
                            if root in foreign:
                                yield self.finding(
                                    ctx, tgt,
                                    f"`{fn.name}` stores through its argument "
                                    f"`{root}` — contexts/snapshots are frozen "
                                    "read-only views; a policy must return a "
                                    "decision, not mutate its inputs",
                                )

    def finalize(self, project: ProjectContext) -> Iterator[Finding]:
        """Transitive pass: flag policy entry points whose *callees* mutate
        the cluster or the policy's own context arguments."""
        summaries = []
        for fctx in project.files:
            try:
                summaries.append(
                    summarize_module(fctx.path, fctx.source, fctx.tree)
                )
            except (SyntaxError, RecursionError):  # pragma: no cover
                continue
        if not summaries:
            return
        engine = engine_for(summaries)
        emitted: Set[tuple] = set()
        for entry in sorted(
            (f for name in _POLICY_METHODS
             for f in engine.functions_named(name)),
            key=lambda f: (f.path, f.lineno),
        ):
            foreign = set(entry.params) - {"self", "cls"}
            for eff in engine.effects_of(entry.qualname):
                if not eff.transitive:
                    continue   # direct violations belong to check_file
                if eff.kind == "cluster-mutation":
                    what = f"calls cluster mutator `{eff.origin}`"
                elif (eff.kind.startswith(PARAM_MUTATION + ":")
                        and eff.kind.split(":", 1)[1] in foreign):
                    what = (
                        "mutates its argument "
                        f"`{eff.kind.split(':', 1)[1]}`"
                    )
                else:
                    continue
                msg = (
                    f"`{entry.name}` {what} through the call chain "
                    f"`{eff.render_chain()}` — placement is pure; only "
                    "`cluster.apply(plan)` outside the policy may commit state"
                )
                key = (entry.path, eff.site_line, msg)
                if key in emitted:
                    continue
                emitted.add(key)
                yield self.finding(entry.path, eff.site_line, msg)

    def _check_call(self, ctx: FileContext, fn: ast.FunctionDef,
                    call: ast.Call, foreign: Set[str]) -> Iterator[Finding]:
        func = call.func
        if isinstance(func, ast.Attribute) and func.attr in MUTATORS:
            # only a method on the policy ITSELF (bare `self.x()`) is own
            # state; `self.cluster.apply()` still mutates the fleet
            bare_self = (
                isinstance(func.value, ast.Name)
                and func.value.id in ("self", "cls")
            )
            recv = _root_name(func.value) or dotted_name(func.value) or "<expr>"
            if not bare_self:
                yield self.finding(
                    ctx, call,
                    f"`{fn.name}` calls cluster mutator `{recv}.{func.attr}()` "
                    "— placement is pure; only `cluster.apply(plan)` outside "
                    "the policy may commit state",
                )
        elif dotted_name(func) == "object.__setattr__" and call.args:
            first = call.args[0]
            if isinstance(first, ast.Name) and first.id in foreign:
                yield self.finding(
                    ctx, call,
                    f"`{fn.name}` writes into frozen argument "
                    f"`{first.id}` via object.__setattr__",
                )
