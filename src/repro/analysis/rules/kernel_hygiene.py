"""kernel-hygiene: audit what the registered jitted kernels trace to.

AST lint cannot see inside ``jax.jit`` — a float32 constant baked into a
bit-identical x64 kernel, a forgotten ``jax.debug.print``, a missing
``static_argnums`` that recompiles per wave, or a ``donate_argnums``
buffer that XLA silently refuses to donate are all invisible until a run
is slow or a parity test fails.  This rule abstract-traces the kernels
with ``jax.make_jaxpr`` over shape specs derived from the fleet-snapshot
layout at several fleet sizes (see :mod:`..kernel_audit`) and turns every
contract breach into a finding.

Audit targets:
  * the built-in table (:func:`..kernel_audit.builtin_targets`) covering
    ``core/batched.py``'s jitted decision kernels, ``kernels/ops.py``'s
    jitted wrappers, and ``serve/engine.py``'s donated decode/prefill —
    each audited only when its defining file is in the scanned set;
  * any scanned module exporting a top-level ``AUDIT_TARGETS`` list of
    :class:`~repro.analysis.kernel_audit.KernelSpec` (how the golden
    fixtures describe themselves) — the module is imported by path at
    finalize time.

The whole pass is a no-op when jax is not installed.
"""
from __future__ import annotations

import ast
import hashlib
import importlib.util
import os
from typing import Iterator, List

from ..framework import FileContext, Finding, ProjectContext, Rule, register_rule
from ..kernel_audit import KernelSpec, audit_spec, builtin_targets, have_jax

_TARGETS_NAME = "AUDIT_TARGETS"


@register_rule
class KernelHygieneRule(Rule):
    name = "kernel-hygiene"
    severity = "error"
    description = (
        "jaxpr audit of registered jitted kernels: no float32 in x64 "
        "kernels, no host callbacks, bounded lowerings across the fleet-"
        "size sweep, donations that actually donate"
    )
    default_paths = ("",)

    def check_file(self, ctx: FileContext, project: ProjectContext
                   ) -> Iterator[Finding]:
        for node in ctx.tree.body:
            targets = (
                node.targets if isinstance(node, ast.Assign)
                else [node.target] if isinstance(node, ast.AnnAssign)
                else []
            )
            for tgt in targets:
                if isinstance(tgt, ast.Name) and tgt.id == _TARGETS_NAME:
                    project.store.setdefault("targets", []).append(
                        (ctx.path, node.lineno)
                    )
        return iter(())

    def finalize(self, project: ProjectContext) -> Iterator[Finding]:
        if not have_jax():  # pragma: no cover - jax is baked into the image
            return
        scanned = {fctx.path: fctx for fctx in project.files}
        for path, specs in builtin_targets().items():
            fctx = scanned.get(path)
            if fctx is None:
                continue
            for spec in specs:
                line = _anchor_line(fctx, spec.anchor)
                for msg in audit_spec(spec):
                    yield self.finding(path, line, msg)
        for path, lineno in project.store.get("targets", []):
            try:
                specs = _load_targets(project.root, path)
            except Exception as e:
                yield self.finding(
                    path, lineno,
                    f"could not import {_TARGETS_NAME} module: "
                    f"{type(e).__name__}: {e}",
                )
                continue
            for spec in specs:
                fctx = scanned.get(path)
                line = (
                    _anchor_line(fctx, spec.anchor)
                    if fctx is not None and spec.anchor else lineno
                )
                for msg in audit_spec(spec):
                    yield self.finding(path, line, msg)


def _anchor_line(fctx: FileContext, anchor) -> int:
    if anchor:
        for i, text in enumerate(fctx.lines, start=1):
            if anchor in text:
                return i
    return 1


def _load_targets(root: str, path: str) -> List[KernelSpec]:
    abspath = os.path.join(root, path) if root else path
    modname = "_repro_lint_audit_" + hashlib.sha1(
        abspath.encode()
    ).hexdigest()[:12]
    spec = importlib.util.spec_from_file_location(modname, abspath)
    if spec is None or spec.loader is None:
        raise ImportError(f"no import spec for {abspath}")
    module = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(module)
    targets = getattr(module, _TARGETS_NAME, [])
    if not isinstance(targets, (list, tuple)):
        raise TypeError(f"{_TARGETS_NAME} must be a list of KernelSpec")
    return [t for t in targets if isinstance(t, KernelSpec)]
