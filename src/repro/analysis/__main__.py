"""CLI: ``python -m repro.analysis [paths ...]``.

Exits 1 when any error-severity finding survives suppressions — warnings
never fail the run.  ``--json FILE`` writes the machine-readable report
(the CI artifact) alongside the text output.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional

from .callgraph import (
    load_summary_cache,
    save_summary_cache,
    summary_cache_stats,
)
from .framework import Analyzer, LintConfig, available_rules, rule_class
from .reporters import render_json, render_sarif, render_text

DEFAULT_PATHS = ("src", "tests", "benchmarks", "examples")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Statically enforce the repo's orchestration contracts "
        "(rng streams, policy purity, snapshot schema, jit hygiene, "
        "deprecations, registry parity).",
    )
    ap.add_argument(
        "paths", nargs="*",
        help=f"files/directories to scan (default: {' '.join(DEFAULT_PATHS)})",
    )
    ap.add_argument(
        "--select", metavar="RULES",
        help="comma-separated subset of rules to run",
    )
    ap.add_argument(
        "--json", metavar="FILE", dest="json_out",
        help="also write the JSON report to FILE (the CI artifact)",
    )
    ap.add_argument(
        "--sarif", metavar="FILE", dest="sarif_out",
        help="also write a SARIF 2.1.0 report to FILE (for GitHub "
        "code-scanning upload / PR annotations)",
    )
    ap.add_argument(
        "--cache", metavar="FILE", dest="cache_file",
        help="warm the call-graph summary memo from FILE before the run "
        "and persist it after (JSON, keyed by file content hash)",
    )
    ap.add_argument(
        "--format", choices=("text", "json", "sarif"), default="text",
        help="stdout format (default: text)",
    )
    ap.add_argument(
        "--all-paths", action="store_true",
        help="ignore per-rule path scoping and default excludes "
        "(used by the fixture tests)",
    )
    ap.add_argument(
        "--list-rules", action="store_true",
        help="list registered rules and exit",
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for name in available_rules():
            cls = rule_class(name)
            scope = ", ".join(p or "<everywhere>" for p in cls.default_paths)
            print(f"{name:18s} [{cls.severity:7s}] ({scope}) {cls.description}")
        return 0

    select = None
    if args.select:
        select = tuple(r.strip() for r in args.select.split(",") if r.strip())
    config = LintConfig(select=select)
    if args.all_paths:
        config = config.permissive()
    paths = args.paths or [p for p in DEFAULT_PATHS if os.path.isdir(p)]
    if not paths:
        print("no paths to scan", file=sys.stderr)
        return 2
    if args.cache_file and os.path.exists(args.cache_file):
        n = load_summary_cache(args.cache_file)
        print(f"summary cache: loaded {n} entr(y/ies) from "
              f"{args.cache_file}", file=sys.stderr)

    report = Analyzer(config).run(paths)

    if args.format == "json":
        sys.stdout.write(render_json(report))
    elif args.format == "sarif":
        sys.stdout.write(render_sarif(report))
    else:
        print(render_text(report))
    if args.json_out:
        with open(args.json_out, "w", encoding="utf-8") as f:
            f.write(render_json(report))
    if args.sarif_out:
        with open(args.sarif_out, "w", encoding="utf-8") as f:
            f.write(render_sarif(report))
    if args.cache_file:
        n = save_summary_cache(args.cache_file)
        hits, misses = summary_cache_stats()
        print(f"summary cache: saved {n} entr(y/ies) to {args.cache_file} "
              f"({hits} hit(s), {misses} miss(es) this run)",
              file=sys.stderr)
    return report.exit_code


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:     # `... | head` closed the pipe mid-report
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        sys.exit(1)
