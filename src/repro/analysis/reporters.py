"""Text and JSON reporters for lint reports."""
from __future__ import annotations

import json
from typing import Dict

from .framework import LintReport

__all__ = ["render_text", "render_json", "report_dict"]


def render_text(report: LintReport) -> str:
    lines = [f.format() for f in report.findings]
    lines.append(
        f"{len(report.findings)} finding(s) "
        f"({report.errors} error(s), {report.warnings} warning(s)), "
        f"{report.suppressed} suppressed, "
        f"{report.files_scanned} file(s) scanned"
    )
    return "\n".join(lines)


def report_dict(report: LintReport) -> Dict[str, object]:
    return {
        "version": 1,
        "files_scanned": report.files_scanned,
        "rules_run": list(report.rules_run),
        "errors": report.errors,
        "warnings": report.warnings,
        "suppressed": report.suppressed,
        "findings": [f.to_dict() for f in report.findings],
    }


def render_json(report: LintReport) -> str:
    return json.dumps(report_dict(report), indent=2, sort_keys=True) + "\n"
