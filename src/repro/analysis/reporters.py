"""Text, JSON and SARIF reporters for lint reports.

SARIF 2.1.0 is the format GitHub's code-scanning upload understands, so
the CI lint job can render findings as PR annotations instead of a text
artifact nobody opens.
"""
from __future__ import annotations

import json
from typing import Dict, List

from .framework import LintReport

__all__ = ["render_text", "render_json", "render_sarif", "report_dict"]


def render_text(report: LintReport) -> str:
    lines = [f.format() for f in report.findings]
    lines.append(
        f"{len(report.findings)} finding(s) "
        f"({report.errors} error(s), {report.warnings} warning(s)), "
        f"{report.suppressed} suppressed, "
        f"{report.files_scanned} file(s) scanned "
        f"in {report.elapsed_s:.2f}s"
    )
    return "\n".join(lines)


def report_dict(report: LintReport) -> Dict[str, object]:
    return {
        "version": 1,
        "files_scanned": report.files_scanned,
        "rules_run": list(report.rules_run),
        "errors": report.errors,
        "warnings": report.warnings,
        "suppressed": report.suppressed,
        "elapsed_s": round(report.elapsed_s, 3),
        "findings": [f.to_dict() for f in report.findings],
    }


def render_json(report: LintReport) -> str:
    return json.dumps(report_dict(report), indent=2, sort_keys=True) + "\n"


def sarif_dict(report: LintReport) -> Dict[str, object]:
    """SARIF 2.1.0 log: one run, one driver, rule metadata for every rule
    that ran or produced a finding (parse-error/useless-suppression are
    synthesized by the framework, not registered)."""
    from .framework import available_rules, rule_class

    registered = set(available_rules())
    rule_ids: List[str] = list(report.rules_run)
    for f in report.findings:
        if f.rule not in rule_ids:
            rule_ids.append(f.rule)
    rules = []
    for rid in rule_ids:
        if rid in registered:
            cls = rule_class(rid)
            desc, level = cls.description, cls.severity
        elif rid == "parse-error":
            desc, level = "file failed to parse", "error"
        else:
            desc, level = "framework-synthesized finding", "warning"
        rules.append({
            "id": rid,
            "shortDescription": {"text": desc or rid},
            "defaultConfiguration": {"level": level},
        })
    results = [
        {
            "ruleId": f.rule,
            "ruleIndex": rule_ids.index(f.rule),
            "level": f.severity,
            "message": {"text": f.message},
            "locations": [{
                "physicalLocation": {
                    "artifactLocation": {
                        "uri": f.path,
                        "uriBaseId": "%SRCROOT%",
                    },
                    "region": {
                        "startLine": max(f.line, 1),
                        "startColumn": f.col + 1,
                    },
                },
            }],
        }
        for f in report.findings
    ]
    return {
        "$schema": "https://json.schemastore.org/sarif-2.1.0.json",
        "version": "2.1.0",
        "runs": [{
            "tool": {
                "driver": {
                    "name": "repro-analysis",
                    "informationUri":
                        "https://github.com/invalid/repro#static-analysis",
                    "rules": rules,
                },
            },
            "results": results,
            "columnKind": "utf16CodeUnits",
        }],
    }


def render_sarif(report: LintReport) -> str:
    return json.dumps(sarif_dict(report), indent=2, sort_keys=True) + "\n"
