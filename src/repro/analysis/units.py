"""Units-of-measure dataflow for the pricing paths.

The paper's Eq. (2) prices a placement as
``exec + model_bytes / upload_bw + out_bytes / link_bw[src, dst]`` —
seconds, bytes and bytes/second all flow through the same float arrays,
and the PR 3 receiver-only-bandwidth bug showed what happens when a bytes
term meets a seconds term without the dividing bandwidth.  This module
gives the linter a tiny unit system to catch that class statically:

  * :class:`Unit` — a signed exponent vector over the base dimensions
    ``s`` (seconds) and ``B`` (bytes), plus a *tag* for the dimensionless
    families worth keeping apart: ``prob`` (probabilities) and ``count``
    (cardinalities).  Tags survive same-tag arithmetic (``pf * pf`` is
    still a probability) and wash out against anything else.
  * a seeding table — the core API names with known units (``total``,
    ``upload``, ``deadline`` … are seconds; ``model_bytes``/``out_bytes``
    bytes; ``link_bw``/``up_bw`` bytes/s; ``pf``/``survival``
    probabilities; ``lam`` a hazard rate 1/s) plus suffix rules
    (``*_bytes``, ``*_bw``, ``*_lat``, ``n_*``, ``*_count`` …).  Rule
    options can extend/override the table per repo area.
  * :class:`UnitChecker` — intraprocedural forward propagation through
    assignments and expressions of one function.  Parameters and
    attribute reads seed from the table; any name assigned locally is
    *blocked* from table seeding (a local ``budget = len(queue)`` must
    not inherit the seconds of a ``budget`` API elsewhere).

Flagged (only when BOTH sides are known — silence is the failure mode of
every unit checker that guesses):
  * ``+``/``-``/comparison between different dimensions
    (``out_bytes + latency``) or between different tags (``pf > n_feas``)
  * ``exp``/``log``/``sqrt`` of a dimensioned quantity — a missing
    normalising divide (``exp(-lam * dt)`` is fine: 1/s x s cancels).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Set, Tuple

__all__ = [
    "Unit",
    "parse_unit",
    "DEFAULT_TABLE",
    "DEFAULT_SUFFIXES",
    "UnitChecker",
    "UnitProblem",
]


@dataclass(frozen=True)
class Unit:
    """Exponents over base dims + a dimensionless-family tag."""

    dims: Tuple[Tuple[str, int], ...] = ()     # sorted ((base, exp), ...)
    tag: Optional[str] = None                  # "prob" | "count" | None

    @staticmethod
    def of(tag: Optional[str] = None, **dims: int) -> "Unit":
        d = tuple(sorted((k, v) for k, v in dims.items() if v))
        return Unit(dims=d, tag=tag)

    @property
    def dimensionless(self) -> bool:
        return not self.dims

    def _combine(self, other: "Unit", sign: int) -> "Unit":
        acc = dict(self.dims)
        for base, exp in other.dims:
            acc[base] = acc.get(base, 0) + sign * exp
        tag = self.tag if self.tag == other.tag else None
        return Unit.of(tag=tag, **acc)

    def mul(self, other: "Unit") -> "Unit":
        return self._combine(other, +1)

    def div(self, other: "Unit") -> "Unit":
        return self._combine(other, -1)

    def compatible(self, other: "Unit") -> bool:
        """May the two be added/compared?  Same dims, and tags that don't
        contradict (an untagged dimensionless value mixes with either
        family)."""
        if self.dims != other.dims:
            return False
        return (
            self.tag == other.tag or self.tag is None or other.tag is None
        )

    def __str__(self) -> str:
        if self.tag is not None and not self.dims:
            return self.tag
        if not self.dims:
            return "dimensionless"
        num = [b if e == 1 else f"{b}^{e}" for b, e in self.dims if e > 0]
        den = [b if e == -1 else f"{b}^{-e}" for b, e in self.dims if e < 0]
        if num and den:
            return "/".join(["*".join(num), "*".join(den)])
        if den:
            return "1/" + "*".join(den)
        return "*".join(num)


SECONDS = Unit.of(s=1)
BYTES = Unit.of(B=1)
BYTES_PER_S = Unit.of(B=1, s=-1)
PER_S = Unit.of(s=-1)
PROB = Unit.of(tag="prob")
COUNT = Unit.of(tag="count")
DIMLESS = Unit.of()

_NAMED = {
    "s": SECONDS,
    "seconds": SECONDS,
    "B": BYTES,
    "bytes": BYTES,
    "B/s": BYTES_PER_S,
    "bytes/s": BYTES_PER_S,
    "1/s": PER_S,
    "prob": PROB,
    "count": COUNT,
    "dimensionless": DIMLESS,
}


def parse_unit(text: str) -> Unit:
    """Parse the unit strings used by the rule's options table."""
    try:
        return _NAMED[text.strip()]
    except KeyError:
        raise ValueError(
            f"unknown unit {text!r}; one of {sorted(_NAMED)}"
        ) from None


# The core API vocabulary.  Everything here is load-bearing somewhere in
# core/ or stream/ — keep names OUT of this table when the repo uses them
# with more than one meaning (e.g. `budget`: seconds for the tier-
# escalation latency budget, a row count in admission.pop_wave).
DEFAULT_TABLE: Dict[str, str] = {
    # seconds
    "t": "s",
    "dt": "s",
    "horizon": "s",
    "deadline": "s",
    "latency": "s",
    "latency_budget": "s",
    "exec_lat": "s",
    "upload": "s",
    "transfer": "s",
    "total": "s",
    "t_start": "s",
    "stage_offset": "s",
    "join_times": "s",
    "surv_grid": "s",
    "est": "s",
    "wait": "s",
    "e2e": "s",
    "finished": "s",
    "elapsed": "s",
    # bytes
    "model_bytes": "B",
    "out_bytes": "B",
    "in_bytes": "B",
    "mem_total": "B",
    "mem_required": "B",
    # bandwidths
    "bandwidth": "B/s",
    "bandwidths": "B/s",
    "link_bw": "B/s",
    "up_bw": "B/s",
    "down_bw": "B/s",
    "upload_bw": "B/s",
    "backhaul_bw": "B/s",
    "backhaul": "B/s",
    # probabilities
    "pf": "prob",
    "survival": "prob",
    "survival_pool": "prob",
    "alpha": "prob",
    "beta": "prob",
    # hazard rates (per-second): lam * dt is dimensionless
    "lam": "1/s",
    "lams": "1/s",
    # cardinalities
    "n_feas": "count",
    "queue_len": "count",
    "n_devices": "count",
    "n_rows": "count",
    "gamma": "count",
}

# (suffix/prefix pattern, unit) — matched when the exact table misses.
DEFAULT_SUFFIXES: Tuple[Tuple[str, str], ...] = (
    ("*_bytes", "B"),
    ("*_bw", "B/s"),
    ("*_lat", "s"),
    ("*_latency", "s"),
    ("*_deadline", "s"),
    ("*_seconds", "s"),
    ("n_*", "count"),
    ("*_count", "count"),
    ("*_len", "count"),
    ("*_depth", "count"),
)

_TRANSCENDENTALS = {"exp", "log", "log1p", "expm1", "log2", "log10", "sqrt"}

# numpy-style wrappers whose result carries the first array argument's unit
_PASSTHROUGH = {
    "abs", "asarray", "array", "maximum", "minimum", "max", "min", "sum",
    "mean", "median", "clip", "sort", "cumsum", "broadcast_to", "full_like",
    "zeros_like", "ones_like", "ascontiguousarray", "take_along_axis",
    "nan_to_num", "squeeze", "reshape", "ravel", "copy", "astype",
}
# where(cond, a, b): unit comes from the VALUE arguments
_WHERE = {"where"}


@dataclass(frozen=True)
class UnitProblem:
    lineno: int
    col: int
    message: str


class UnitChecker:
    """Forward unit propagation through one function body."""

    def __init__(self, table: Dict[str, Unit],
                 suffixes: Tuple[Tuple[str, Unit], ...]):
        self.table = table
        self.suffixes = suffixes

    # -- seeding -------------------------------------------------------------
    def lookup(self, name: str) -> Optional[Unit]:
        unit = self.table.get(name)
        if unit is not None:
            return unit
        import fnmatch

        for pat, u in self.suffixes:
            if fnmatch.fnmatchcase(name, pat):
                return u
        return None

    # -- per-function check --------------------------------------------------
    def check_function(self, fn: ast.AST) -> List[UnitProblem]:
        problems: List[UnitProblem] = []
        assigned = _assigned_names(fn)
        env: Dict[str, Optional[Unit]] = {}
        # parameters seed from the table even when reassigned later
        for pname in _params(fn):
            env[pname] = self.lookup(pname)

        def resolve_name(name: str) -> Optional[Unit]:
            if name in env:
                return env[name]
            if name in assigned:
                return None         # local not yet assigned on this path
            return self.lookup(name)

        def ev(node: ast.AST) -> Optional[Unit]:
            if isinstance(node, ast.Name):
                return resolve_name(node.id)
            if isinstance(node, ast.Attribute):
                return self.lookup(node.attr)
            if isinstance(node, ast.Subscript):
                return ev(node.value)
            if isinstance(node, ast.UnaryOp):
                return ev(node.operand)
            if isinstance(node, ast.IfExp):
                return ev(node.body) or ev(node.orelse)
            if isinstance(node, ast.BinOp):
                lu, ru = ev(node.left), ev(node.right)
                if isinstance(node.op, (ast.Add, ast.Sub)):
                    if lu is not None and ru is not None \
                            and not lu.compatible(ru):
                        problems.append(UnitProblem(
                            node.lineno, node.col_offset,
                            f"mixed-unit arithmetic: `{lu}` "
                            f"{'+' if isinstance(node.op, ast.Add) else '-'} "
                            f"`{ru}` — a conversion (divide by a bandwidth/"
                            "rate?) is missing",
                        ))
                        return None
                    return lu if lu is not None else ru
                if isinstance(node.op, ast.Mult):
                    if lu is not None and ru is not None:
                        return lu.mul(ru)
                    return None
                if isinstance(node.op, (ast.Div, ast.FloorDiv)):
                    if lu is not None and ru is not None:
                        return lu.div(ru)
                    return None
                if isinstance(node.op, ast.Mod):
                    return lu
                return None
            if isinstance(node, ast.Compare):
                left = node.left
                lu = ev(left)
                for op, right in zip(node.ops, node.comparators):
                    ru = ev(right)
                    if lu is not None and ru is not None \
                            and not lu.compatible(ru) \
                            and isinstance(op, (ast.Lt, ast.LtE, ast.Gt,
                                                ast.GtE, ast.Eq, ast.NotEq)):
                        problems.append(UnitProblem(
                            right.lineno, right.col_offset,
                            f"mixed-unit comparison: `{lu}` vs `{ru}` — "
                            "these measure different things",
                        ))
                    lu, left = ru, right
                return DIMLESS
            if isinstance(node, ast.Call):
                return ev_call(node)
            if isinstance(node, ast.Constant):
                return None         # bare numbers adopt the context's unit
            return None

        def ev_call(call: ast.Call) -> Optional[Unit]:
            func = call.func
            attr = func.attr if isinstance(func, ast.Attribute) else (
                func.id if isinstance(func, ast.Name) else None
            )
            if attr in _TRANSCENDENTALS and call.args:
                arg_u = ev(call.args[0])
                if arg_u is not None and not arg_u.dimensionless:
                    problems.append(UnitProblem(
                        call.lineno, call.col_offset,
                        f"`{attr}()` of a dimensioned quantity (`{arg_u}`) "
                        "— normalise first (divide by a rate/scale)",
                    ))
                return DIMLESS if attr != "sqrt" else None
            if attr in _WHERE and len(call.args) >= 3:
                a, b = ev(call.args[1]), ev(call.args[2])
                if a is not None and b is not None and not a.compatible(b):
                    problems.append(UnitProblem(
                        call.lineno, call.col_offset,
                        f"`where()` merges mixed units: `{a}` vs `{b}`",
                    ))
                return a if a is not None else b
            if attr in _PASSTHROUGH and call.args:
                return ev(call.args[0])
            if attr == "astype" and isinstance(func, ast.Attribute):
                return ev(func.value)
            return None

        def do_assign(target: ast.AST, unit: Optional[Unit]) -> None:
            if isinstance(target, ast.Name):
                env[target.id] = unit
            elif isinstance(target, (ast.Tuple, ast.List)):
                for elt in target.elts:
                    do_assign(elt, None)
            # attribute/subscript stores don't update the env

        for node in _walk_own_body(fn):
            if isinstance(node, ast.Assign):
                unit = ev(node.value)
                for tgt in node.targets:
                    do_assign(tgt, unit)
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                do_assign(node.target, ev(node.value))
            elif isinstance(node, ast.AugAssign):
                # x += expr is x = x + expr
                synth = ast.BinOp(
                    left=node.target, op=node.op, right=node.value
                )
                ast.copy_location(synth, node)
                unit = ev(synth)
                if isinstance(node.target, ast.Name):
                    env[node.target.id] = unit
            elif isinstance(node, ast.For):
                # iterating an array yields elements of the same unit
                do_assign(node.target, ev(node.iter))
            elif isinstance(node, (ast.If, ast.While)):
                ev(node.test)
            elif isinstance(node, ast.Return) and node.value is not None:
                ev(node.value)
            elif isinstance(node, ast.Assert):
                ev(node.test)
            elif isinstance(node, ast.Expr):
                ev(node.value)
        return problems


def _params(fn: ast.AST) -> List[str]:
    args = getattr(fn, "args", None)
    if args is None:
        return []
    names = [a.arg for a in args.posonlyargs + args.args + args.kwonlyargs]
    if args.vararg:
        names.append(args.vararg.arg)
    if args.kwarg:
        names.append(args.kwarg.arg)
    return names


def _assigned_names(fn: ast.AST) -> Set[str]:
    """Every bare name the function assigns anywhere (incl. loop targets,
    with-as, comprehension targets) — blocked from table seeding."""
    names: Set[str] = set(_params(fn))

    def collect(target: ast.AST) -> None:
        if isinstance(target, ast.Name):
            names.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                collect(elt)
        elif isinstance(target, ast.Starred):
            collect(target.value)

    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                collect(tgt)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            collect(node.target)
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            collect(node.target)
        elif isinstance(node, ast.withitem) and node.optional_vars:
            collect(node.optional_vars)
        elif isinstance(node, ast.comprehension):
            collect(node.target)
        elif isinstance(node, ast.NamedExpr):
            collect(node.target)
    return names


def _walk_own_body(fn: ast.AST) -> Iterator[ast.AST]:
    """Statements/expressions of ``fn`` in source order, skipping nested
    function/class bodies (they get their own checker pass) but entering
    control-flow blocks."""
    stack = list(reversed(getattr(fn, "body", [])))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        yield node
        for field_name in ("body", "orelse", "finalbody"):
            for child in reversed(getattr(node, field_name, []) or []):
                stack.append(child)
        for handler in getattr(node, "handlers", []) or []:
            for child in reversed(handler.body):
                stack.append(child)
