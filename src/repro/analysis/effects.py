"""Bottom-up interprocedural effect inference.

Given the :class:`~repro.analysis.callgraph.CallGraph`, compute for every
project function the set of effects it may perform *transitively*:

  * ``cluster-mutation`` — calls a :data:`MUTATORS` method on anything
    other than its own bare ``self``/``cls``
  * ``param-mutation:<name>`` — stores through one of its parameters
    (attribute/subscript assignment or ``object.__setattr__``), directly
    or by passing that parameter into a callee that mutates it
  * ``global-rng`` — draws from ``np.random.*`` / stdlib ``random``
    module-level state
  * ``wall-clock`` — reads ``time.time``/``time.time_ns``
  * ``host-sync`` — forces a device→host transfer (``.item()``)
  * ``io`` — touches the filesystem

Propagation runs over Tarjan's strongly-connected components in reverse
topological order, so mutual recursion converges in one pass.  Every
transitive effect carries a **witness**: the chain of call sites that
reaches the base effect, which the rules render as
``decide -> _helper -> ctx.cluster.apply()``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .callgraph import CallGraph, FuncInfo, ModuleSummary

__all__ = ["Effect", "EffectEngine", "PARAM_MUTATION", "engine_for"]

PARAM_MUTATION = "param-mutation"


@dataclass(frozen=True)
class Effect:
    """One (possibly transitive) effect of a function.

    ``chain`` is the witness: ``((qualname, path, lineno), ...)`` for each
    call hop, ending at the function whose body contains the base effect.
    ``origin`` is the base-effect description, e.g. ``np.random.shuffle()``.
    For a direct effect the chain has length one (the function itself) and
    ``site_line`` is the base effect's own line; for a transitive effect
    ``site_line`` is the line of the *first call hop* inside the function
    the rule is reporting on.
    """

    kind: str                     # e.g. "global-rng" or "param-mutation:ctx"
    origin: str                   # base-effect description
    origin_line: int              # line of the base effect in its own file
    chain: Tuple[Tuple[str, str, int], ...]   # (qualname, path, lineno) hops
    site_line: int                # line to anchor a finding on

    @property
    def transitive(self) -> bool:
        return len(self.chain) > 1

    def render_chain(self) -> str:
        """``decide -> _helper -> np.random.shuffle()`` (short names)."""
        hops = [q.rsplit(".", 1)[-1] for q, _, _ in self.chain]
        return " -> ".join(hops + [self.origin])


def _short_kind(kind: str) -> str:
    return kind.split(":", 1)[0]


class EffectEngine:
    """Fixed-point effect propagation over the project call graph.

    Built once per run from the shared per-rule summaries; both the
    purity and RNG rules query the same instance (memoised in the
    ``ProjectContext`` store under the key ``"effect-engine"``).
    """

    STORE_KEY = "effect-engine"

    def __init__(self, summaries: Sequence[ModuleSummary]):
        self.graph = CallGraph(summaries)
        self._effects: Dict[str, List[Effect]] = {}
        self._compute()

    # -- public API ----------------------------------------------------------
    def effects_of(self, qualname: str) -> List[Effect]:
        return self._effects.get(qualname, [])

    def function(self, qualname: str) -> Optional[FuncInfo]:
        return self.graph.functions.get(qualname)

    def functions_named(self, name: str) -> List[FuncInfo]:
        return [f for f in self.graph.functions.values() if f.name == name]

    # -- SCC condensation (iterative Tarjan) ---------------------------------
    def _sccs(self) -> List[List[str]]:
        graph = {
            q: sorted({rc.callee for rc in self.graph.edges(fi)})
            for q, fi in self.graph.functions.items()
        }
        index: Dict[str, int] = {}
        low: Dict[str, int] = {}
        on_stack: Dict[str, bool] = {}
        stack: List[str] = []
        sccs: List[List[str]] = []
        counter = [0]

        for root in graph:
            if root in index:
                continue
            work: List[Tuple[str, int]] = [(root, 0)]
            while work:
                node, ei = work[-1]
                if ei == 0:
                    index[node] = low[node] = counter[0]
                    counter[0] += 1
                    stack.append(node)
                    on_stack[node] = True
                advanced = False
                succs = graph[node]
                while ei < len(succs):
                    nxt = succs[ei]
                    ei += 1
                    if nxt not in index:
                        work[-1] = (node, ei)
                        work.append((nxt, 0))
                        advanced = True
                        break
                    if on_stack.get(nxt):
                        low[node] = min(low[node], index[nxt])
                if advanced:
                    continue
                work.pop()
                if low[node] == index[node]:
                    comp: List[str] = []
                    while True:
                        w = stack.pop()
                        on_stack[w] = False
                        comp.append(w)
                        if w == node:
                            break
                    sccs.append(comp)
                if work:
                    parent = work[-1][0]
                    low[parent] = min(low[parent], low[node])
        return sccs  # already reverse-topological (callees first)

    # -- propagation ---------------------------------------------------------
    def _compute(self) -> None:
        funcs = self.graph.functions
        for scc in self._sccs():
            members = set(scc)
            # seed with direct (base) effects
            for q in scc:
                fi = funcs[q]
                eff: List[Effect] = []
                hop = ((q, fi.path, fi.lineno),)
                for be in fi.effects:
                    eff.append(Effect(
                        kind=be.kind, origin=be.desc,
                        origin_line=be.lineno, chain=hop,
                        site_line=be.lineno,
                    ))
                for pname, (line, desc) in fi.param_mutations.items():
                    eff.append(Effect(
                        kind=f"{PARAM_MUTATION}:{pname}", origin=desc,
                        origin_line=line, chain=hop, site_line=line,
                    ))
                self._effects[q] = eff
            # iterate within the SCC until no new (kind, origin) pairs appear
            changed = True
            guard = 0
            while changed and guard < 64:
                changed = False
                guard += 1
                for q in scc:
                    fi = funcs[q]
                    mine = self._effects[q]
                    seen = {(e.kind, e.origin, e.chain) for e in mine}
                    for rc in self.graph.edges(fi):
                        callee_eff = self._effects.get(rc.callee, [])
                        for e in callee_eff:
                            lifted = self._lift(fi, rc, e)
                            if lifted is None:
                                continue
                            key = (lifted.kind, lifted.origin, lifted.chain)
                            if key in seen:
                                continue
                            if len(lifted.chain) > 12:
                                continue  # depth guard inside cycles
                            seen.add(key)
                            mine.append(lifted)
                            if rc.callee in members:
                                changed = True

    def _lift(self, caller: FuncInfo, rc, e: Effect) -> Optional[Effect]:
        """Translate a callee effect into the caller's frame."""
        hop = ((caller.qualname, caller.path, rc.site.lineno),)
        if not e.kind.startswith(PARAM_MUTATION + ":"):
            return Effect(
                kind=e.kind, origin=e.origin, origin_line=e.origin_line,
                chain=hop + e.chain, site_line=rc.site.lineno,
            )
        # param-mutation: map the callee's mutated parameter back to the
        # caller-local name passed at this call site.
        callee = self.graph.functions.get(rc.callee)
        if callee is None:
            return None
        pname = e.kind.split(":", 1)[1]
        params = list(callee.params)
        if rc.skip_first_param and params:
            params = params[1:]
        local: Optional[str] = None
        try:
            idx = params.index(pname)
        except ValueError:
            idx = -1
        if 0 <= idx < len(rc.site.pos_args):
            local = rc.site.pos_args[idx]
        if local is None:
            for kw, val in rc.site.kw_args:
                if kw == pname:
                    local = val
                    break
        if local is None:
            return None
        if local in ("self", "cls"):
            # mutating own state through a helper — not a param mutation
            # from the caller's point of view
            return None
        if local not in caller.params:
            return None  # a local object, mutation doesn't escape caller
        return Effect(
            kind=f"{PARAM_MUTATION}:{local}", origin=e.origin,
            origin_line=e.origin_line, chain=hop + e.chain,
            site_line=rc.site.lineno,
        )


# The purity and rng rules finalize over the SAME file set in one run;
# summaries are interned by content hash (see callgraph), so identical
# summary identity tuples mean an identical graph — share the engine.
_ENGINE_MEMO: Dict[Tuple[int, ...], EffectEngine] = {}


def engine_for(summaries: Sequence[ModuleSummary]) -> EffectEngine:
    key = tuple(sorted(id(s) for s in summaries))
    eng = _ENGINE_MEMO.get(key)
    if eng is None:
        eng = EffectEngine(summaries)
        _ENGINE_MEMO[key] = eng
        if len(_ENGINE_MEMO) > 64:       # fixture matrices build many tiny graphs
            _ENGINE_MEMO.pop(next(iter(_ENGINE_MEMO)))
    return eng
