"""Small AST helpers shared by the lint rules."""
from __future__ import annotations

import ast
from typing import Iterator, Optional, Set, Tuple

__all__ = [
    "dotted_name",
    "call_name",
    "walk_functions",
    "names_in",
    "keyword_names",
]


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> Optional[str]:
    """Dotted name of the callee, e.g. ``np.random.rand``."""
    return dotted_name(call.func)


def walk_functions(tree: ast.AST) -> Iterator[ast.FunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node  # type: ignore[misc]


def names_in(node: ast.AST) -> Set[str]:
    """All Name identifiers referenced anywhere under ``node``."""
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def keyword_names(call: ast.Call) -> Tuple[Tuple[str, ast.keyword], ...]:
    """(name, keyword) pairs for explicit keywords (skips ``**splat``)."""
    return tuple((kw.arg, kw) for kw in call.keywords if kw.arg is not None)


def has_kwsplat(call: ast.Call) -> bool:
    return any(kw.arg is None for kw in call.keywords)


def param_names(fn: ast.FunctionDef) -> Tuple[str, ...]:
    a = fn.args
    params = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        params.append(a.vararg.arg)
    if a.kwarg:
        params.append(a.kwarg.arg)
    return tuple(params)
