"""Project-wide call graph over the scanned files.

The transitive contract rules (``policy-purity``, ``rng-discipline``) need
to see *through* helpers: ``decide -> _helper -> ctx.cluster.apply()`` is a
purity violation even though no single function body shows both the policy
entry point and the mutator call.  This module builds the inter-procedural
substrate:

  * :class:`ModuleSummary` — everything one file contributes: its dotted
    module name, defined functions/methods (with their raw call sites and
    *base* effects, see :mod:`.effects`), classes with their base-class
    names, and the import table.  Summaries are pure data — JSON
    round-trippable — and memoised by **content hash**, so repeated runs
    (the fixture test matrix, a warm CI cache) never re-walk an unchanged
    file's AST.
  * :class:`CallGraph` — resolves raw call sites against the project:
    local functions, ``from m import f`` targets (re-export chains
    followed), ``mod.f`` through import aliases, ``self.m()``/``super().m()``
    through the class hierarchy.  Unresolvable calls (third-party,
    dynamic dispatch) are simply absent — the analysis under-approximates,
    which is the right polarity for a linter.

Resolution is name-based and best-effort by design: the repo's contracts
live in statically-known helper chains, not in dynamic dispatch.
"""
from __future__ import annotations

import ast
import hashlib
import json
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from .astutil import dotted_name, param_names

__all__ = [
    "BaseEffect",
    "CallSite",
    "FuncInfo",
    "ModuleSummary",
    "CallGraph",
    "summarize_module",
    "module_name_for",
    "load_summary_cache",
    "save_summary_cache",
    "summary_cache_stats",
]

# Cluster mutators (kept in sync with rules.purity.MUTATORS — the single
# list is re-exported there to avoid a cycle).
MUTATORS = frozenset({
    "apply",
    "add_interval",
    "cancel_from",
    "mark_down",
    "mark_up",
    "set_bandwidth",
    "install_forecast",
    "refresh_topology",
    "undo",
})

# np.random attributes that are construction, not global-state draws
# (mirrors rules.rng._ALLOWED_NP_RANDOM).
_ALLOWED_NP_RANDOM = {
    "default_rng", "Generator", "SeedSequence", "BitGenerator",
    "PCG64", "PCG64DXSM", "Philox", "SFC64", "MT19937",
}

_IO_CALLS = {
    "open",
    "os.remove", "os.unlink", "os.makedirs", "os.mkdir", "os.rename",
    "shutil.copy", "shutil.copytree", "shutil.rmtree", "shutil.move",
    "json.dump", "pickle.dump", "np.save", "numpy.save", "np.savez",
}
_IO_METHOD_ATTRS = {"write_text", "write_bytes", "to_csv", "savefig"}


@dataclass(frozen=True)
class BaseEffect:
    """One intra-procedural effect occurrence inside a function body."""

    kind: str          # cluster-mutation | global-rng | wall-clock | host-sync | io
    lineno: int
    desc: str          # e.g. "ctx.cluster.apply()" or "np.random.shuffle()"

    def to_dict(self) -> dict:
        return {"kind": self.kind, "lineno": self.lineno, "desc": self.desc}

    @staticmethod
    def from_dict(d: dict) -> "BaseEffect":
        return BaseEffect(str(d["kind"]), int(d["lineno"]), str(d["desc"]))


@dataclass(frozen=True)
class CallSite:
    """One call expression inside a function body, pre-resolution.

    ``target_kind`` says how to resolve ``target``:
      * ``"name"``   — bare ``f(...)``: local function / from-import
      * ``"self"``   — ``self.m(...)`` / ``cls.m(...)``: method lookup
      * ``"super"``  — ``super().m(...)``: base-class method lookup
      * ``"dotted"`` — ``alias.attr(...)``: module alias or local class

    ``pos_args``/``kw_args`` carry the *caller-local names* passed as bare
    ``Name`` arguments (None for any other expression) — the data the
    effect engine needs to propagate parameter mutations through calls.
    """

    lineno: int
    target_kind: str
    target: str
    pos_args: Tuple[Optional[str], ...] = ()
    kw_args: Tuple[Tuple[str, Optional[str]], ...] = ()

    def to_dict(self) -> dict:
        return {
            "lineno": self.lineno, "target_kind": self.target_kind,
            "target": self.target, "pos_args": list(self.pos_args),
            "kw_args": [list(kv) for kv in self.kw_args],
        }

    @staticmethod
    def from_dict(d: dict) -> "CallSite":
        return CallSite(
            int(d["lineno"]), str(d["target_kind"]), str(d["target"]),
            tuple(d["pos_args"]),
            tuple((str(k), v) for k, v in d["kw_args"]),
        )


@dataclass
class FuncInfo:
    """One function or method, with its raw call sites and base effects."""

    qualname: str                 # "repro.core.policy.IBDASHPolicy.decide"
    module: str
    cls: Optional[str]
    name: str
    path: str                     # repo-relative posix path
    lineno: int
    params: Tuple[str, ...]       # declared order, `self` included
    calls: Tuple[CallSite, ...] = ()
    effects: Tuple[BaseEffect, ...] = ()
    # param name -> (lineno, description) for direct stores through it
    param_mutations: Dict[str, Tuple[int, str]] = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {
            "qualname": self.qualname, "module": self.module,
            "cls": self.cls, "name": self.name, "path": self.path,
            "lineno": self.lineno, "params": list(self.params),
            "calls": [c.to_dict() for c in self.calls],
            "effects": [e.to_dict() for e in self.effects],
            "param_mutations": {
                k: list(v) for k, v in self.param_mutations.items()
            },
        }

    @staticmethod
    def from_dict(d: dict) -> "FuncInfo":
        return FuncInfo(
            qualname=str(d["qualname"]), module=str(d["module"]),
            cls=d["cls"], name=str(d["name"]), path=str(d["path"]),
            lineno=int(d["lineno"]), params=tuple(d["params"]),
            calls=tuple(CallSite.from_dict(c) for c in d["calls"]),
            effects=tuple(BaseEffect.from_dict(e) for e in d["effects"]),
            param_mutations={
                k: (int(v[0]), str(v[1]))
                for k, v in d["param_mutations"].items()
            },
        )


@dataclass
class ModuleSummary:
    """Everything one file contributes to the project call graph."""

    path: str
    module: str                               # dotted module name
    functions: Dict[str, FuncInfo]            # qualname -> info
    classes: Dict[str, Tuple[str, ...]]       # class name -> raw base names
    import_modules: Dict[str, str]            # alias -> dotted module
    import_names: Dict[str, Tuple[str, str]]  # name -> (module, attr)

    def to_dict(self) -> dict:
        return {
            "path": self.path, "module": self.module,
            "functions": {q: f.to_dict() for q, f in self.functions.items()},
            "classes": {c: list(b) for c, b in self.classes.items()},
            "import_modules": dict(self.import_modules),
            "import_names": {k: list(v) for k, v in self.import_names.items()},
        }

    @staticmethod
    def from_dict(d: dict) -> "ModuleSummary":
        return ModuleSummary(
            path=str(d["path"]), module=str(d["module"]),
            functions={
                q: FuncInfo.from_dict(f) for q, f in d["functions"].items()
            },
            classes={c: tuple(b) for c, b in d["classes"].items()},
            import_modules=dict(d["import_modules"]),
            import_names={
                k: (str(v[0]), str(v[1]))
                for k, v in d["import_names"].items()
            },
        )


def module_name_for(path: str) -> str:
    """Dotted module name for a repo-relative path: ``src/repro/core/x.py``
    -> ``repro.core.x``, ``tests/foo.py`` -> ``tests.foo``; ``__init__``
    names the package itself."""
    p = path[:-3] if path.endswith(".py") else path
    if p.startswith("src/"):
        p = p[4:]
    parts = [seg for seg in p.split("/") if seg]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


# -- per-file extraction (memoised by content hash) ----------------------------

_SUMMARY_MEMO: Dict[str, ModuleSummary] = {}
_MEMO_HITS = [0, 0]  # [hits, misses] — exposed for the cache tests/CI log


def summary_cache_stats() -> Tuple[int, int]:
    """(hits, misses) of the content-hash summary memo."""
    return _MEMO_HITS[0], _MEMO_HITS[1]


def _content_key(path: str, source: str) -> str:
    h = hashlib.sha256()
    h.update(path.encode())
    h.update(b"\0")
    h.update(source.encode())
    return h.hexdigest()


def summarize_module(path: str, source: str,
                     tree: Optional[ast.Module] = None) -> ModuleSummary:
    """Extract (or recall, keyed by content hash) one file's summary."""
    key = _content_key(path, source)
    cached = _SUMMARY_MEMO.get(key)
    if cached is not None:
        _MEMO_HITS[0] += 1
        return cached
    _MEMO_HITS[1] += 1
    if tree is None:
        tree = ast.parse(source, filename=path)
    summary = _extract(path, tree)
    _SUMMARY_MEMO[key] = summary
    return summary


def load_summary_cache(file: str) -> int:
    """Pre-warm the memo from a JSON cache file; returns entries loaded."""
    try:
        with open(file, "r", encoding="utf-8") as f:
            data = json.load(f)
    except (OSError, ValueError):
        return 0
    n = 0
    for key, d in data.get("summaries", {}).items():
        try:
            _SUMMARY_MEMO[key] = ModuleSummary.from_dict(d)
            n += 1
        except (KeyError, TypeError, ValueError):
            continue
    return n


def save_summary_cache(file: str) -> int:
    """Persist the memo as JSON keyed by content hash; returns entries."""
    data = {
        "version": 1,
        "summaries": {k: s.to_dict() for k, s in _SUMMARY_MEMO.items()},
    }
    with open(file, "w", encoding="utf-8") as f:
        json.dump(data, f)
    return len(_SUMMARY_MEMO)


def _root_name(node: ast.AST) -> Optional[str]:
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _base_effects_of_call(call: ast.Call) -> Iterator[BaseEffect]:
    name = dotted_name(call.func)
    func = call.func
    # cluster mutators on any receiver other than bare self/cls
    if isinstance(func, ast.Attribute) and func.attr in MUTATORS:
        bare_self = (
            isinstance(func.value, ast.Name)
            and func.value.id in ("self", "cls")
        )
        if not bare_self:
            recv = dotted_name(func.value) or _root_name(func.value) or "<expr>"
            yield BaseEffect(
                "cluster-mutation", call.lineno, f"{recv}.{func.attr}()"
            )
    if name:
        if name.startswith(("np.random.", "numpy.random.")):
            attr = name.split(".", 2)[2].split(".", 1)[0]
            if attr not in _ALLOWED_NP_RANDOM:
                yield BaseEffect("global-rng", call.lineno, f"{name}()")
        elif name.startswith("random."):
            yield BaseEffect("global-rng", call.lineno, f"{name}()")
        elif name in ("time.time", "time.time_ns"):
            yield BaseEffect("wall-clock", call.lineno, f"{name}()")
        elif name in _IO_CALLS:
            yield BaseEffect("io", call.lineno, f"{name}()")
    if isinstance(func, ast.Attribute):
        if func.attr == "item" and not call.args:
            yield BaseEffect("host-sync", call.lineno, ".item()")
        elif func.attr in _IO_METHOD_ATTRS:
            yield BaseEffect("io", call.lineno, f".{func.attr}()")


def _call_site(call: ast.Call) -> Optional[CallSite]:
    """Classify one call expression into a resolvable CallSite (or None)."""
    func = call.func
    pos = tuple(
        a.id if isinstance(a, ast.Name) else None
        for a in call.args if not isinstance(a, ast.Starred)
    )
    kws = tuple(
        (kw.arg, kw.value.id if isinstance(kw.value, ast.Name) else None)
        for kw in call.keywords if kw.arg is not None
    )
    if isinstance(func, ast.Name):
        return CallSite(call.lineno, "name", func.id, pos, kws)
    if isinstance(func, ast.Attribute):
        base = func.value
        if isinstance(base, ast.Name) and base.id in ("self", "cls"):
            return CallSite(call.lineno, "self", func.attr, pos, kws)
        if (isinstance(base, ast.Call) and isinstance(base.func, ast.Name)
                and base.func.id == "super"):
            return CallSite(call.lineno, "super", func.attr, pos, kws)
        dn = dotted_name(func)
        if dn is not None:
            return CallSite(call.lineno, "dotted", dn, pos, kws)
    return None


def _extract(path: str, tree: ast.Module) -> ModuleSummary:
    module = module_name_for(path)
    summary = ModuleSummary(
        path=path, module=module, functions={}, classes={},
        import_modules={}, import_names={},
    )
    pkg_parts = module.split(".")

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                summary.import_modules[
                    alias.asname or alias.name.split(".", 1)[0]
                ] = alias.name if alias.asname else alias.name.split(".", 1)[0]
                if alias.asname:
                    summary.import_modules[alias.asname] = alias.name
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if node.level:
                # relative import: resolve against this module's package
                base = pkg_parts[:-node.level] if node.level <= len(pkg_parts) else []
                mod = ".".join(base + ([mod] if mod else []))
            for alias in node.names:
                if alias.name == "*":
                    continue
                summary.import_names[alias.asname or alias.name] = (
                    mod, alias.name
                )

    def visit_function(fn, cls_name: Optional[str]) -> None:
        qual = ".".join(
            [module] + ([cls_name] if cls_name else []) + [fn.name]
        )
        params = param_names(fn)
        pset = set(params)
        calls: List[CallSite] = []
        effects: List[BaseEffect] = []
        param_mut: Dict[str, Tuple[int, str]] = {}
        # nested defs/lambdas are attributed to the enclosing function —
        # a closure's effects escape through the function that created it
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Call):
                effects.extend(_base_effects_of_call(sub))
                site = _call_site(sub)
                if site is not None:
                    calls.append(site)
                # object.__setattr__(param, ...) back-door
                if (dotted_name(sub.func) == "object.__setattr__"
                        and sub.args
                        and isinstance(sub.args[0], ast.Name)
                        and sub.args[0].id in pset
                        and sub.args[0].id not in ("self", "cls")):
                    param_mut.setdefault(
                        sub.args[0].id,
                        (sub.lineno, f"object.__setattr__({sub.args[0].id}, ...)"),
                    )
            elif isinstance(sub, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = (
                    sub.targets if isinstance(sub, ast.Assign) else [sub.target]
                )
                for tgt in targets:
                    if isinstance(tgt, (ast.Attribute, ast.Subscript)):
                        root = _root_name(tgt)
                        if root in pset and root not in ("self", "cls"):
                            param_mut.setdefault(
                                root, (tgt.lineno, f"store through {root}")
                            )
        summary.functions[qual] = FuncInfo(
            qualname=qual, module=module, cls=cls_name, name=fn.name,
            path=path, lineno=fn.lineno, params=params,
            calls=tuple(calls), effects=tuple(effects),
            param_mutations=param_mut,
        )

    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            visit_function(node, None)
        elif isinstance(node, ast.ClassDef):
            bases = tuple(
                b for b in (dotted_name(base) for base in node.bases)
                if b is not None
            )
            summary.classes[node.name] = bases
            for item in node.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    visit_function(item, node.name)
    return summary


# -- project graph -------------------------------------------------------------

@dataclass(frozen=True)
class ResolvedCall:
    """A call edge resolved to a project function."""

    site: CallSite
    callee: str                   # qualname
    skip_first_param: bool        # True when callee's `self` is bound


class CallGraph:
    """Resolve the raw call sites of a set of summaries project-wide."""

    def __init__(self, summaries: Sequence[ModuleSummary]):
        self.summaries = list(summaries)
        self.by_module: Dict[str, ModuleSummary] = {}
        for s in self.summaries:
            self.by_module[s.module] = s
        self.functions: Dict[str, FuncInfo] = {}
        for s in self.summaries:
            self.functions.update(s.functions)

    # -- module lookup -------------------------------------------------------
    def _module(self, dotted: str) -> Optional[ModuleSummary]:
        s = self.by_module.get(dotted)
        if s is not None:
            return s
        # unique-suffix fallback: scanned roots may sit below sys.path roots
        tail = "." + dotted
        hits = [m for m in self.by_module if m == dotted or m.endswith(tail)]
        if len(hits) == 1:
            return self.by_module[hits[0]]
        return None

    def _function(self, module: str, attr: str,
                  _depth: int = 0) -> Optional[FuncInfo]:
        """``module.attr`` as a project function, following re-exports."""
        s = self._module(module)
        if s is None or _depth > 4:
            return None
        fi = s.functions.get(f"{s.module}.{attr}")
        if fi is not None:
            return fi
        reexp = s.import_names.get(attr)
        if reexp is not None:
            return self._function(reexp[0], reexp[1], _depth + 1)
        return None

    def _method(self, module: str, cls: str, meth: str,
                seen: Optional[Set[Tuple[str, str]]] = None,
                skip_own: bool = False) -> Optional[FuncInfo]:
        """Method lookup through the (project-visible) class hierarchy."""
        seen = seen or set()
        if (module, cls) in seen:
            return None
        seen.add((module, cls))
        s = self._module(module)
        if s is None:
            return None
        if not skip_own:
            fi = s.functions.get(f"{s.module}.{cls}.{meth}")
            if fi is not None:
                return fi
        for base in s.classes.get(cls, ()):
            base_mod, base_cls = self._resolve_class(s, base)
            if base_cls is None:
                continue
            fi = self._method(base_mod, base_cls, meth, seen)
            if fi is not None:
                return fi
        return None

    def _resolve_class(self, s: ModuleSummary, raw: str
                       ) -> Tuple[str, Optional[str]]:
        """A raw base-class name -> (module, class) in the project."""
        if "." in raw:
            alias, cls = raw.rsplit(".", 1)
            mod = s.import_modules.get(alias)
            return (mod or alias), cls
        if raw in s.classes:
            return s.module, raw
        imp = s.import_names.get(raw)
        if imp is not None:
            return imp[0], imp[1]
        return s.module, None

    # -- call resolution -----------------------------------------------------
    def resolve(self, caller: FuncInfo, site: CallSite
                ) -> Optional[ResolvedCall]:
        s = self.by_module.get(caller.module)
        if s is None:
            return None
        if site.target_kind == "name":
            fi = s.functions.get(f"{s.module}.{site.target}")
            if fi is None:
                imp = s.import_names.get(site.target)
                if imp is not None:
                    fi = self._function(imp[0], imp[1])
            if fi is not None:
                return ResolvedCall(site, fi.qualname, skip_first_param=False)
        elif site.target_kind == "self" and caller.cls is not None:
            fi = self._method(caller.module, caller.cls, site.target)
            if fi is not None:
                return ResolvedCall(site, fi.qualname, skip_first_param=True)
        elif site.target_kind == "super" and caller.cls is not None:
            fi = self._method(
                caller.module, caller.cls, site.target, skip_own=True
            )
            if fi is not None:
                return ResolvedCall(site, fi.qualname, skip_first_param=True)
        elif site.target_kind == "dotted":
            head, attr = site.target.rsplit(".", 1)
            if "." not in head:
                mod = s.import_modules.get(head)
                if mod is not None:
                    fi = self._function(mod, attr)
                    if fi is not None:
                        return ResolvedCall(site, fi.qualname, False)
                if head in s.classes:       # ClassName.method(...)
                    fi = self._method(s.module, head, attr)
                    if fi is not None:
                        return ResolvedCall(site, fi.qualname, False)
            else:
                alias = head.split(".", 1)[0]
                mod = s.import_modules.get(alias)
                if mod is not None:
                    full = mod + head[len(alias):]
                    fi = self._function(full, attr)
                    if fi is not None:
                        return ResolvedCall(site, fi.qualname, False)
        return None

    def edges(self, caller: FuncInfo) -> Iterator[ResolvedCall]:
        for site in caller.calls:
            rc = self.resolve(caller, site)
            if rc is not None and rc.callee != caller.qualname:
                yield rc
