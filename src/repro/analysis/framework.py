"""AST lint framework enforcing this repo's orchestration contracts.

Six PRs of hardening produced a set of load-bearing invariants — pure
``orchestrate`` vs mutating ``apply``, per-``(seed, id)`` common-random-
number streams, the declared :data:`~repro.core.batched.FLEET_SNAPSHOT_SCHEMA`
pytree layout, bit-identical jitted/scalar policy twins — that until now
existed only as convention plus runtime parity tests.  Each has been
violated at least once (ghost occupancy, reshuffled churn streams, silently
stale topology caches; see CHANGES.md).  This package turns them into lint
rules that fire at analysis time, before a 100k-device run or a DRL
training job ever executes.

The framework is deliberately small and dependency-free:

  * :class:`Rule` — one invariant.  ``check_file`` visits a parsed module;
    ``finalize`` runs once after the whole tree was walked (for cross-file
    rules like registry-parity).  Rules self-register via
    :func:`register_rule`.
  * :class:`LintConfig` / :class:`RuleSettings` — per-rule severity and
    *path scoping*: a rule only fires on files whose repo-relative path
    starts with one of its configured prefixes (``""`` = everywhere).
  * Suppressions — ``# repro-lint: disable=<rule>[,<rule>...]`` on the
    finding's line silences it; ``# repro-lint: disable-file=<rule>``
    anywhere in the file silences the whole module.  ``all`` matches every
    rule.  Suppressed findings are counted, not lost — and a suppression
    that silences *nothing* is itself reported as a ``useless-suppression``
    warning (stale disables may not rot in place, PR 8).
  * :class:`Analyzer` — walks the paths, parses each ``*.py`` once, runs
    the scoped rules, applies suppressions (to per-file AND finalize-time
    findings), and returns findings sorted by location.  A file that fails
    to parse yields a ``parse-error`` finding instead of crashing the run.
"""
from __future__ import annotations

import ast
import fnmatch
import io
import os
import re
import time
import tokenize
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Set, Tuple, Type

__all__ = [
    "Finding",
    "FileContext",
    "ProjectContext",
    "Rule",
    "RuleSettings",
    "LintConfig",
    "Analyzer",
    "SuppressionTable",
    "register_rule",
    "available_rules",
    "SEVERITIES",
]

SEVERITIES = ("error", "warning")

_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Za-z0-9_,\- ]+)")
_SUPPRESS_FILE_RE = re.compile(r"#\s*repro-lint:\s*disable-file=([A-Za-z0-9_,\- ]+)")


@dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str
    severity: str          # "error" | "warning"
    path: str              # repo-relative (or as-given) path
    line: int              # 1-based
    col: int               # 0-based
    message: str

    def to_dict(self) -> Dict[str, object]:
        return {
            "rule": self.rule,
            "severity": self.severity,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
        }

    def format(self) -> str:
        return (
            f"{self.path}:{self.line}:{self.col}: "
            f"{self.severity}[{self.rule}] {self.message}"
        )


@dataclass
class FileContext:
    """One parsed module handed to every scoped rule."""

    path: str                     # repo-relative posix path
    source: str
    tree: ast.Module
    lines: List[str] = field(default_factory=list)

    def __post_init__(self) -> None:
        if not self.lines:
            self.lines = self.source.splitlines()

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""


@dataclass
class ProjectContext:
    """Cross-file state for ``Rule.finalize``: the scoped files each rule
    saw plus a free-form per-rule scratch store filled during
    ``check_file``."""

    files: List[FileContext] = field(default_factory=list)
    store: Dict[str, object] = field(default_factory=dict)
    root: str = ""                # analyzer root (abs path of rel paths)


class Rule:
    """Base class: one statically-checkable orchestration contract.

    Subclasses set ``name`` (the id used in reports, config, and
    suppression comments), ``severity``, ``description`` (one line, shown
    by ``--list-rules``) and ``default_paths`` (repo-relative prefixes the
    rule applies to by default; ``("",)`` = everywhere).
    """

    name: str = ""
    severity: str = "error"
    description: str = ""
    default_paths: Tuple[str, ...] = ("",)

    def __init__(self, options: Optional[Dict[str, object]] = None) -> None:
        self.options: Dict[str, object] = dict(options or {})

    # -- hooks ---------------------------------------------------------------
    def check_file(self, ctx: FileContext, project: ProjectContext
                   ) -> Iterator[Finding]:
        return iter(())

    def finalize(self, project: ProjectContext) -> Iterator[Finding]:
        return iter(())

    # -- helpers -------------------------------------------------------------
    def finding(self, ctx_or_path, node_or_line, message: str,
                col: Optional[int] = None) -> Finding:
        path = ctx_or_path.path if isinstance(ctx_or_path, FileContext) else str(ctx_or_path)
        if isinstance(node_or_line, ast.AST):
            line = getattr(node_or_line, "lineno", 1)
            c = getattr(node_or_line, "col_offset", 0) if col is None else col
        else:
            line = int(node_or_line)
            c = 0 if col is None else col
        return Finding(self.name, self.severity, path, line, c, message)


# -- rule registry -------------------------------------------------------------

_RULES: Dict[str, Type[Rule]] = {}


def register_rule(cls: Type[Rule]) -> Type[Rule]:
    if not cls.name:
        raise ValueError(f"rule class {cls.__name__} has no name")
    if cls.name in _RULES:
        raise ValueError(f"duplicate rule name {cls.name!r}")
    if cls.severity not in SEVERITIES:
        raise ValueError(f"rule {cls.name!r}: bad severity {cls.severity!r}")
    _RULES[cls.name] = cls
    return cls


def available_rules() -> Tuple[str, ...]:
    _load_builtin_rules()
    return tuple(sorted(_RULES))


def rule_class(name: str) -> Type[Rule]:
    _load_builtin_rules()
    return _RULES[name]


def _load_builtin_rules() -> None:
    # importing the package registers every built-in rule exactly once
    from . import rules as _  # noqa: F401


# -- configuration -------------------------------------------------------------

@dataclass(frozen=True)
class RuleSettings:
    """Per-rule knobs: on/off, severity override, path scope, rule options."""

    enabled: bool = True
    severity: Optional[str] = None          # None = the rule's own default
    paths: Optional[Tuple[str, ...]] = None  # None = the rule's default_paths
    options: Dict[str, object] = field(default_factory=dict)


@dataclass(frozen=True)
class LintConfig:
    """Analyzer configuration.

    ``exclude`` holds glob patterns matched against repo-relative paths;
    the default excludes the deliberately-violating lint fixtures under
    ``tests/fixtures/lint/``.  ``rules`` maps rule name -> settings; rules
    absent from the map run with their class defaults.  ``select`` limits
    the run to the named rules (None = all registered).
    """

    exclude: Tuple[str, ...] = ("tests/fixtures/lint/*", "*/fixtures/lint/*")
    rules: Dict[str, RuleSettings] = field(default_factory=dict)
    select: Optional[Tuple[str, ...]] = None

    def settings(self, name: str) -> RuleSettings:
        return self.rules.get(name, RuleSettings())

    def permissive(self) -> "LintConfig":
        """Every rule everywhere, no excludes — what the fixture tests use."""
        rules = {
            name: replace(self.settings(name), paths=("",))
            for name in available_rules()
        }
        return replace(self, exclude=(), rules=rules)


def _rel(path: str, root: str) -> str:
    try:
        rel = os.path.relpath(path, root)
    except ValueError:           # different drive (windows) — keep absolute
        rel = path
    return rel.replace(os.sep, "/")


def _match_scope(path: str, prefixes: Tuple[str, ...]) -> bool:
    return any(path.startswith(p) for p in prefixes)


def _excluded(path: str, patterns: Tuple[str, ...]) -> bool:
    return any(fnmatch.fnmatch(path, pat) for pat in patterns)


def _iter_comments(source: str) -> Iterator[Tuple[int, str]]:
    """(lineno, text) of each comment token; falls back to a raw line scan
    when the source does not tokenize (the caller already parsed it, so
    this is belt-and-braces for exotic encodings)."""
    try:
        toks = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        for i, line in enumerate(source.splitlines(), start=1):
            if "#" in line:
                yield i, line[line.index("#"):]
        return
    for tok in toks:
        if tok.type == tokenize.COMMENT:
            yield tok.start[0], tok.string


@dataclass
class _SuppEntry:
    """One ``repro-lint: disable[-file]=`` comment: where it sits, which
    rules it names, and how many findings each named rule suppressed."""

    line: int                     # 1-based line of the comment itself
    file_level: bool
    hits: Dict[str, int]          # rule name (or "all") -> findings silenced


class SuppressionTable:
    """All suppression comments of one file, with hit accounting."""

    def __init__(self, source: str):
        self.entries: List[_SuppEntry] = []
        # only real COMMENT tokens count — a disable marker inside a string
        # literal (e.g. test code building fixture sources) must neither
        # suppress anything nor be reported as a stale suppression
        for lineno, comment in _iter_comments(source):
            if "repro-lint" not in comment:
                continue
            m = _SUPPRESS_FILE_RE.search(comment)
            file_level = bool(m)
            if not m:
                m = _SUPPRESS_RE.search(comment)
            if not m:
                continue
            rules = [r.strip() for r in m.group(1).split(",") if r.strip()]
            if rules:
                self.entries.append(_SuppEntry(
                    line=lineno, file_level=file_level,
                    hits={r: 0 for r in rules},
                ))

    def suppress(self, fnd: Finding) -> bool:
        """True (and count the hit) when some entry silences ``fnd``."""
        hit = False
        for e in self.entries:
            if not (e.file_level or e.line == fnd.line):
                continue
            for key in (fnd.rule, "all"):
                if key in e.hits:
                    e.hits[key] += 1
                    hit = True
                    break
        return hit

    def useless(self, ran_rules: Set[str]) -> Iterator[Tuple[int, str]]:
        """(line, rule-name) for every named rule that silenced nothing.

        Only rules that actually RAN on this file are judged — a disable
        for a rule outside this run's selection might be load-bearing."""
        for e in self.entries:
            for rule, n in e.hits.items():
                if n:
                    continue
                if rule == "all":
                    if ran_rules:
                        yield e.line, rule
                elif rule in ran_rules:
                    yield e.line, rule


@dataclass
class LintReport:
    """Everything one Analyzer.run produced."""

    findings: List[Finding]
    suppressed: int
    files_scanned: int
    rules_run: Tuple[str, ...]
    elapsed_s: float = 0.0        # wall-clock of the whole run (CI budget log)

    @property
    def errors(self) -> int:
        return sum(1 for f in self.findings if f.severity == "error")

    @property
    def warnings(self) -> int:
        return sum(1 for f in self.findings if f.severity == "warning")

    @property
    def exit_code(self) -> int:
        return 1 if self.errors else 0


class Analyzer:
    """Walk paths, parse modules once, run every scoped rule, apply
    suppressions, finalize cross-file rules."""

    def __init__(self, config: Optional[LintConfig] = None,
                 root: Optional[str] = None) -> None:
        self.config = config or LintConfig()
        self.root = os.path.abspath(root or os.getcwd())
        _load_builtin_rules()
        names = self.config.select or available_rules()
        unknown = [n for n in names if n not in _RULES]
        if unknown:
            raise ValueError(
                f"unknown rules {unknown}; available: {list(available_rules())}"
            )
        self._rules: List[Tuple[Rule, Tuple[str, ...], Optional[str]]] = []
        for name in names:
            st = self.config.settings(name)
            if not st.enabled:
                continue
            cls = _RULES[name]
            rule = cls(options=st.options)
            paths = st.paths if st.paths is not None else cls.default_paths
            self._rules.append((rule, paths, st.severity))

    # -- file discovery ------------------------------------------------------
    def _iter_py_files(self, paths: Iterable[str]) -> Iterator[str]:
        seen = set()
        for p in paths:
            ap = os.path.abspath(p)
            if os.path.isfile(ap):
                if ap.endswith(".py") and ap not in seen:
                    seen.add(ap)
                    yield ap
            elif os.path.isdir(ap):
                for dirpath, dirnames, filenames in os.walk(ap):
                    dirnames[:] = sorted(
                        d for d in dirnames
                        if d not in {"__pycache__", ".git", ".pytest_cache"}
                    )
                    for fn in sorted(filenames):
                        if fn.endswith(".py"):
                            fp = os.path.join(dirpath, fn)
                            if fp not in seen:
                                seen.add(fp)
                                yield fp

    # -- driver --------------------------------------------------------------
    def run(self, paths: Iterable[str]) -> LintReport:
        t0 = time.perf_counter()
        projects: Dict[str, ProjectContext] = {
            rule.name: ProjectContext(root=self.root) for rule, _, _ in self._rules
        }
        findings: List[Finding] = []
        suppressed = 0
        n_files = 0
        supp_tables: Dict[str, SuppressionTable] = {}
        ran_rules: Dict[str, Set[str]] = {}
        for fp in self._iter_py_files(paths):
            rel = _rel(fp, self.root)
            if _excluded(rel, self.config.exclude):
                continue
            n_files += 1
            try:
                with open(fp, "r", encoding="utf-8") as f:
                    source = f.read()
                tree = ast.parse(source, filename=rel)
            except (SyntaxError, UnicodeDecodeError) as e:
                lineno = getattr(e, "lineno", 1) or 1
                findings.append(Finding(
                    "parse-error", "error", rel, int(lineno), 0,
                    f"could not parse: {e.__class__.__name__}: {e}",
                ))
                continue
            table = supp_tables[rel] = SuppressionTable(source)
            ran_rules[rel] = set()
            ctx = FileContext(path=rel, source=source, tree=tree)
            for rule, scope, sev_override in self._rules:
                if not _match_scope(rel, scope):
                    continue
                ran_rules[rel].add(rule.name)
                project = projects[rule.name]
                project.files.append(ctx)
                for fnd in rule.check_file(ctx, project):
                    if sev_override:
                        fnd = replace(fnd, severity=sev_override)
                    if table.suppress(fnd):
                        suppressed += 1
                    else:
                        findings.append(fnd)
        # finalize-time (cross-file) findings honour suppressions too: the
        # transitive rules anchor findings at real source lines, and a
        # justified inline disable must silence those the same way
        for rule, _, sev_override in self._rules:
            for fnd in rule.finalize(projects[rule.name]):
                if sev_override:
                    fnd = replace(fnd, severity=sev_override)
                table = supp_tables.get(fnd.path)
                if table is not None and table.suppress(fnd):
                    suppressed += 1
                else:
                    findings.append(fnd)
        # a disable that silenced nothing is itself a (warning) finding —
        # stale suppressions from old fix-up passes may not rot in place
        for rel, table in supp_tables.items():
            for line, rule_name in table.useless(ran_rules[rel]):
                fnd = Finding(
                    "useless-suppression", "warning", rel, line, 0,
                    f"suppression `disable={rule_name}` matched no finding "
                    "of that rule in this run — remove the stale comment "
                    "(or fix the rule name)",
                )
                if not table.suppress(fnd):
                    findings.append(fnd)
        findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
        return LintReport(
            findings=findings,
            suppressed=suppressed,
            files_scanned=n_files,
            rules_run=tuple(r.name for r, _, _ in self._rules),
            elapsed_s=time.perf_counter() - t0,
        )
