"""repro.analysis — the orchestration-contract linter.

Statically enforces the repo's load-bearing invariants (see each rule's
docstring for the contract and the PR that established it):

  * ``rng-discipline``   — per-(seed, id) common-random-number streams
  * ``policy-purity``    — pure ``decide``/``decide_batch``, mutate only
                           via ``cluster.apply``
  * ``snapshot-schema``  — the declared FleetSnapshot pytree leaf schema
  * ``jit-hygiene``      — no host syncs / traced branching in jitted
                           kernels
  * ``deprecation``      — no scalar-bandwidth shims; tier/link-matrix API
  * ``registry-parity``  — every registered scheme has a test-suite pin

Run ``python -m repro.analysis src tests benchmarks examples``; suppress a
deliberate finding with ``# repro-lint: disable=<rule>`` on its line (plus
a justification comment) or ``# repro-lint: disable-file=<rule>``.
"""
from .framework import (
    Analyzer,
    FileContext,
    Finding,
    LintConfig,
    LintReport,
    ProjectContext,
    Rule,
    RuleSettings,
    available_rules,
    register_rule,
    rule_class,
)
from .reporters import render_json, render_text, report_dict

__all__ = [
    "Analyzer",
    "FileContext",
    "Finding",
    "LintConfig",
    "LintReport",
    "ProjectContext",
    "Rule",
    "RuleSettings",
    "available_rules",
    "register_rule",
    "rule_class",
    "render_json",
    "render_text",
    "report_dict",
]
