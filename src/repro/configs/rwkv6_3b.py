"""RWKV6-3B "Finch" [arXiv:2404.05892; hf].

32L, d_model=2560 (attention-free), channel-mix d_ff=8960, vocab=65536.
Data-dependent per-channel decay (the Finch signature), head_size=64
(40 heads).  Supports the 500k-token decode shape natively: state is
O(H * N^2) regardless of context length.
"""
from ..models.config import ModelConfig, RecurrentConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        family="ssm",
        n_layers=32,
        d_model=2560,
        n_heads=40,
        n_kv_heads=40,
        head_dim=64,
        d_ff=8960,
        vocab=65536,
        norm="layernorm",
        rope="none",
        attention="none",
        tie_embeddings=False,
        recurrent=RecurrentConfig(kind="rwkv6", head_size=64),
    )
