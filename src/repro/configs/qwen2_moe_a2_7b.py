"""Qwen1.5/2-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

24L, d_model=2048, 16 heads (MHA), vocab=151936.  MoE every layer:
60 routed experts (top-4) + 4 shared experts, expert d_ff=1408,
softmax router.  QKV bias, RMSNorm, SwiGLU experts.
"""
from ..models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab=151936,
        act="silu",
        mlp="swiglu",
        norm="rmsnorm",
        qkv_bias=True,
        rope="rope",
        rope_theta=1000000.0,
        tie_embeddings=False,
        moe=MoEConfig(
            n_experts=60,
            n_shared_experts=4,
            top_k=4,
            d_expert=1408,
            n_dense_layers=0,
            router_act="softmax",
            group_size=512,
            dispatch="einsum",
        ),
    )
