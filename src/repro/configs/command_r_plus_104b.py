"""Command R+ (104B) — Cohere [hf:CohereForAI/c4ai-command-r-plus; unverified].

64L, d_model=12288, 96 heads (GQA kv=8), d_ff=33792, vocab=256000.
Cohere family: bias-free LayerNorm, no QKV bias, tied embeddings, SiLU
gated MLP, RoPE.
"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b",
        family="dense",
        n_layers=64,
        d_model=12288,
        n_heads=96,
        n_kv_heads=8,
        head_dim=128,
        d_ff=33792,
        vocab=256000,
        act="silu",
        mlp="swiglu",
        norm="layernorm_nobias",
        rope="rope",
        rope_theta=75000.0,
        tie_embeddings=True,
    )
