"""DeepSeek-V3 (671B total / 37B active) [arXiv:2412.19437; hf].

61L, d_model=7168, 128 heads, vocab=129280.  MLA attention (q_lora 1536,
kv_lora 512, nope 128 + rope 64 per head, v 128); first 3 layers dense FFN
(d_ff=18432), remaining 58 layers MoE: 1 shared + 256 routed top-8 experts
of d_expert=2048, sigmoid router with normalised gates.

Not implemented (documented in DESIGN.md §Arch-applicability): the MTP
(multi-token-prediction) auxiliary head — orthogonal to the paper's
orchestration technique and to the serving/roofline story.

Dispatch default is ``einsum`` (t5x-style capacity dispatch): GSPMD shards
the one-hot dispatch matmuls cleanly, whereas the sort/scatter alternative
forces replication of the scattered buffers under GSPMD (4.7x the
collective bytes — measured, see EXPERIMENTS.md §Perf hillclimb #3).  On
real TPU hardware a sort-based dispatch belongs in a Pallas kernel, not in
XLA-level scatters; documented in DESIGN.md §Hardware-adaptation.
"""
from ..models.config import MLAConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        d_ff=18432,            # dense layers' FFN width
        vocab=129280,
        act="silu",
        mlp="swiglu",
        norm="rmsnorm",
        rope="rope",
        rope_theta=10000.0,
        tie_embeddings=False,
        attention="mla",
        mla=MLAConfig(
            q_lora_rank=1536,
            kv_lora_rank=512,
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            n_experts=256,
            n_shared_experts=1,
            top_k=8,
            d_expert=2048,
            n_dense_layers=3,
            router_act="sigmoid",
            group_size=256,
            dispatch="einsum",
        ),
    )
