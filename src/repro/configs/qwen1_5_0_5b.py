"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B; hf].

24L, d_model=1024, 16 heads (kv=16, i.e. MHA), d_ff=2816, vocab=151936.
QKV bias (the Qwen signature), RMSNorm, SwiGLU, tied embeddings.
"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b",
        family="dense",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=2816,
        vocab=151936,
        act="silu",
        mlp="swiglu",
        norm="rmsnorm",
        qkv_bias=True,
        rope="rope",
        rope_theta=1000000.0,
        tie_embeddings=True,
    )
