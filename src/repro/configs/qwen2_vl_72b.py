"""Qwen2-VL-72B backbone [arXiv:2409.12191; hf].

80L, d_model=8192, 64 heads (GQA kv=8, head_dim=128), d_ff=29568,
vocab=152064.  M-RoPE (multimodal rotary: temporal/height/width sections
16/24/24 over the 64 half-dims); the vision frontend (dynamic-resolution
ViT) is a STUB — ``input_specs`` provides fused M-RoPE position ids
(3, B, S) alongside tokens, per the assignment sheet.
"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab=152064,
        act="silu",
        mlp="swiglu",
        norm="rmsnorm",
        qkv_bias=True,
        rope="mrope",
        rope_theta=1000000.0,
        mrope_sections=(16, 24, 24),
        tie_embeddings=False,
        needs_position_ids=True,
    )
