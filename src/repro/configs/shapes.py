"""Assigned input shapes (the 4 per-arch cells).

  train_4k     seq 4096,   global batch 256  -> lowers train_step
  prefill_32k  seq 32768,  global batch 32   -> lowers prefill
  decode_32k   seq 32768,  global batch 128  -> lowers serve_step (1 new
                token against a KV cache of seq_len)
  long_500k    seq 524288, global batch 1    -> serve_step; requires
                sub-quadratic attention (SSM/hybrid only — full-attention
                archs skip this cell, see DESIGN.md §Arch-applicability)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from ..models.config import ModelConfig

__all__ = ["ShapeSpec", "SHAPES", "cell_applicable"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str           # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple:
    """(applicable, reason).  The only skip in the assigned grid is
    long_500k on pure full-attention architectures."""
    if shape.name == "long_500k" and not cfg.sub_quadratic():
        return False, "full quadratic attention at 524k context (skip per assignment; see DESIGN.md)"
    return True, ""
