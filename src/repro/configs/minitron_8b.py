"""Minitron-8B — width/depth-pruned Nemotron-4 [arXiv:2407.14679; hf].

32L, d_model=4096, 32 heads (GQA kv=8), d_ff=16384, vocab=256000.
Nemotron family: squared-ReLU non-gated MLP, RoPE, no biases, untied
embeddings, RMSNorm.
"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab=256000,
        act="relu2",
        mlp="mlp",
        norm="rmsnorm",
        rope="rope",
        rope_theta=10000.0,
        tie_embeddings=False,
    )
