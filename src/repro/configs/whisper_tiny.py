"""Whisper-tiny [arXiv:2212.04356; unverified].

Encoder-decoder, 4L each side, d_model=384, 6 heads (MHA), d_ff=1536,
vocab=51865.  The conv audio frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, 1500, d_model), per the assignment sheet.
GELU, plain (non-gated) MLP, LayerNorm with bias, sinusoidal positions
(rope="none").
"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="audio",
        n_layers=4,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        head_dim=64,
        d_ff=1536,
        vocab=51865,
        act="gelu",
        mlp="mlp",
        norm="layernorm",
        rope="none",
        tie_embeddings=True,
        enc_dec=True,
        enc_len=1500,
    )
