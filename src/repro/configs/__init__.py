"""Assigned-architecture registry: ``get_config(arch_id)`` returns the exact
published ModelConfig; ``ARCHS`` lists every selectable ``--arch``.

Sources are cited in each config module ([arXiv/hf; verification tier] per
the assignment sheet).
"""
from __future__ import annotations

from typing import Callable, Dict, List

from ..models.config import ModelConfig
from .minitron_8b import config as _minitron_8b
from .command_r_plus_104b import config as _command_r_plus
from .qwen1_5_0_5b import config as _qwen05
from .olmo_1b import config as _olmo
from .whisper_tiny import config as _whisper
from .qwen2_moe_a2_7b import config as _qwen_moe
from .deepseek_v3_671b import config as _dsv3
from .rwkv6_3b import config as _rwkv6
from .recurrentgemma_9b import config as _rgemma
from .qwen2_vl_72b import config as _qwen_vl

ARCH_BUILDERS: Dict[str, Callable[[], ModelConfig]] = {
    "minitron-8b": _minitron_8b,
    "command-r-plus-104b": _command_r_plus,
    "qwen1.5-0.5b": _qwen05,
    "olmo-1b": _olmo,
    "whisper-tiny": _whisper,
    "qwen2-moe-a2.7b": _qwen_moe,
    "deepseek-v3-671b": _dsv3,
    "rwkv6-3b": _rwkv6,
    "recurrentgemma-9b": _rgemma,
    "qwen2-vl-72b": _qwen_vl,
}

ARCHS: List[str] = list(ARCH_BUILDERS)


def get_config(arch: str, **overrides) -> ModelConfig:
    if arch not in ARCH_BUILDERS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    cfg = ARCH_BUILDERS[arch]()
    if overrides:
        import dataclasses

        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


__all__ = ["ARCHS", "ARCH_BUILDERS", "get_config"]
