"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427; unverified].

38L, d_model=4096, 16 heads (MQA kv=1, head_dim=256), d_ff=12288,
vocab=256000.  Block pattern 2:1 — (recurrent, recurrent, local-attention)
repeated; RG-LRU recurrence (lru_width=4096, conv width 4), local window
2048, GeGLU MLP.  The 500k decode shape runs natively: attention cache is
the 2048-token ring buffer + O(W) recurrent state.
"""
from ..models.config import ModelConfig, RecurrentConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab=256000,
        act="gelu",
        mlp="geglu",
        norm="rmsnorm",
        rope="rope",
        rope_theta=10000.0,
        tie_embeddings=True,
        attention="local",
        attn_window=2048,
        recurrent=RecurrentConfig(
            kind="rglru",
            conv_width=4,
            lru_width=4096,
            pattern=("rec", "rec", "attn"),
        ),
    )
