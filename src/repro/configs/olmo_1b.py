"""OLMo-1B [arXiv:2402.00838; hf].

16L, d_model=2048, 16 heads (MHA), d_ff=8192, vocab=50304.
OLMo signature: NON-PARAMETRIC LayerNorm (no scale/bias), SwiGLU, RoPE,
no biases, tied embeddings.
"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b",
        family="dense",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=8192,
        vocab=50304,
        act="silu",
        mlp="swiglu",
        norm="nonparametric",
        rope="rope",
        tie_embeddings=True,
    )
