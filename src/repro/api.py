"""repro.api — the one front door for DAG orchestration.

Everything the paper's evaluation, the benchmarks, and the serving fleet do
is a composition of three primitives:

  * ``plan = orchestrate(app, cluster, now, policy)`` — pure planning: the
    policy (a registered name or a :class:`~repro.core.policy.Policy`) maps
    array-native :class:`~repro.core.policy.PolicyContext` snapshots to
    device decisions; nothing is mutated.
  * ``token = cluster.apply(plan)`` / ``cluster.undo(token)`` — the single
    explicit mutation path (T_alloc intervals + model-cache admission),
    undoable for speculative what-if planning (alpha/gamma sweeps).
  * :class:`Orchestrator` — the online façade: ``submit(app, t)`` arrivals,
    ``step(until)`` the discrete-event clock forward, ``drain()`` to
    quiescence.  ``sim.runner.run_one/run_grid/sweep_*`` and
    ``serve.scheduler.ServingFleet`` are thin drivers over this class.

Quick tour::

    from repro.api import Orchestrator, make_policy, orchestrate

    orch = Orchestrator(cluster, "ibdash", seed=0)
    orch.submit_batch(apps, times)          # the 1000-instance burst
    orch.step(until=15.0)                   # one paper cycle
    res = orch.result("mix", horizon=15.0)

    # fused burst: ONE batched decide_batch kernel call per wave-stage
    # places all 1000 instances at once (plans share one fleet snapshot)
    orch.submit_batch(apps, times, fused=True)

    # speculative what-if: plan, inspect, roll back
    plan = orch.plan(app, now=0.0)
    token = orch.commit(plan)
    orch.cluster.undo(token)
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Union

from .core.cluster import (
    TIER_CLOUD,
    TIER_DEVICE,
    TIER_EDGE_SERVER,
    TIER_NAMES,
    ApplyToken,
    ClusterState,
    Device,
)
from .core.dag import AppDAG, TaskSpec
from .core.interference import InterferenceModel
from .core.batched import BatchedDecision, BatchedPolicyContext, FleetSnapshot
from .core.orchestrator import (
    IBDASHConfig,
    Placement,
    Plan,
    Replica,
    TaskPlacement,
    orchestrate,
    orchestrate_batch,
)
from .core.policy import (
    Policy,
    PolicyContext,
    TaskDecision,
    available_policies,
    make_policy,
    register_policy,
)
from .core.recovery import (
    RecoveryStrategy,
    available_recoveries,
    make_recovery,
    register_recovery,
)
from .sim.engine import Engine, InstanceRecord, SimResult

__all__ = [
    "Orchestrator",
    "orchestrate",
    "orchestrate_batch",
    "Plan",
    "Placement",
    "TaskPlacement",
    "Replica",
    "Policy",
    "PolicyContext",
    "TaskDecision",
    "FleetSnapshot",
    "BatchedPolicyContext",
    "BatchedDecision",
    "register_policy",
    "make_policy",
    "available_policies",
    "RecoveryStrategy",
    "register_recovery",
    "make_recovery",
    "available_recoveries",
    "IBDASHConfig",
    "ApplyToken",
    "ClusterState",
    "Device",
    "TIER_DEVICE",
    "TIER_EDGE_SERVER",
    "TIER_CLOUD",
    "TIER_NAMES",
    "InterferenceModel",
    "AppDAG",
    "TaskSpec",
    "Engine",
    "InstanceRecord",
    "SimResult",
    # lazily re-exported (see __getattr__): run_one, run_grid, sweep_alpha,
    # sweep_gamma, SimConfig, make_profile, make_cluster,
    # make_multi_tier_cluster, ServingFleet
]


class Orchestrator:
    """Online orchestration façade over one cluster + one policy.

    Owns the discrete-event engine: arrivals submitted with :meth:`submit`
    are planned with the pure policy API the moment they occur, applied via
    ``cluster.apply``, and executed against ground-truth interference/
    failure dynamics as the clock advances through :meth:`step`.
    """

    def __init__(
        self,
        cluster: ClusterState,
        policy: Union[str, Policy],
        *,
        seed: int = 0,
        noise_sigma: float = 0.10,
        churn=None,
        recovery: Union[str, RecoveryStrategy] = "fail_fast",
        detection_delay: Optional[float] = None,
        max_retries: Optional[int] = None,
        salvage: int = 0,
        track_intervals: bool = False,
        trace=None,
        **policy_kwargs,
    ):
        """``churn`` takes a :class:`repro.sim.churn.ChurnSchedule`: the
        engine then processes DEVICE_DOWN / DEVICE_UP events (in-flight
        replicas on a departing device are killed, capacity is returned and
        later re-admitted on rejoin), and the schedule's forecastable side
        (scripted windows, MLE rates) is installed as the cluster's
        availability forecast — the ``churn_aware`` policy's input.
        ``recovery`` names the registered
        :class:`~repro.core.recovery.RecoveryStrategy` applied when a task
        loses its last replica — ``fail_fast`` (the default) is
        bit-identical to the pre-churn engine.  ``salvage`` bounds
        partial-result salvage resubmissions per instance: a lost instance
        with completed stages is re-planned through
        ``orchestrate(pinned=...)`` instead of discarded (0 = off).
        ``trace`` takes a :class:`repro.obs.Tracer` (or ``True`` to
        construct one): every instance then gets a structured span trace
        for attribution and Chrome/Perfetto export (:mod:`repro.obs`);
        None = tracing off, zero overhead."""
        if trace is True:
            from .obs import Tracer

            trace = Tracer()
        elif not trace:                    # False/None both mean "off"
            trace = None
        if isinstance(policy, str):
            policy = make_policy(policy, seed=seed, **policy_kwargs)
        recovery_kw = {
            k: v for k, v in dict(
                detection_delay=detection_delay, max_retries=max_retries
            ).items() if v is not None
        }
        if isinstance(recovery, str):
            recovery = make_recovery(recovery, **recovery_kw)
        elif recovery_kw:
            raise ValueError(
                f"{sorted(recovery_kw)} only apply when `recovery` is a "
                "registered name; configure the RecoveryStrategy instance "
                "directly instead"
            )
        self.cluster = cluster
        self.policy = policy
        self.engine = Engine(
            cluster, policy, seed=seed, noise_sigma=noise_sigma,
            churn=churn, recovery=recovery, salvage=salvage,
            track_intervals=track_intervals, trace=trace,
        )

    # -- online interface -------------------------------------------------------
    def submit(self, app: AppDAG, t: float) -> "Orchestrator":
        """Enqueue one application instance arriving at absolute time ``t``."""
        self.engine.add_arrivals([app], [t])
        return self

    def submit_batch(
        self,
        apps: Sequence[AppDAG],
        times: Sequence[float],
        *,
        fused: bool = False,
    ) -> "Orchestrator":
        """Enqueue a burst of simultaneous/clustered arrivals (the paper's
        ~1000 instances inside 1.5 s).

        ``fused=False`` (default): each arrival is planned when its event
        fires, so later arrivals see earlier arrivals' provisional T_alloc
        occupancy — the sequential Fig. 8/9 semantics.

        ``fused=True``: the whole burst is planned NOW against the current
        cluster snapshot by :func:`orchestrate_batch` — one batched context
        and one fused ``decide_batch`` kernel call per wave-stage places all
        B instances at once (~10x+ placement throughput at B=1000; see
        ``benchmarks/bench_place.py``).  Plans are applied at each arrival's
        event time as usual.  Because the plans share one snapshot they do
        not see each other's provisional load, so a heavy burst concentrates
        onto the devices that look best in that snapshot — use the fused
        mode when planning throughput dominates (admission control, what-if
        sweeps, light-load waves), and the default sequential mode when
        load-aware spreading matters.
        """
        if len(apps) != len(times):
            raise ValueError("apps and times must have equal length")
        if fused:
            plans = orchestrate_batch(
                list(apps), self.cluster, self.policy, times=list(times)
            )
            self.engine.add_arrivals(list(apps), list(times), plans=plans)
        else:
            self.engine.add_arrivals(list(apps), list(times))
        return self

    def step(self, until: float) -> "Orchestrator":
        """Advance the event clock, processing every event with t <= until."""
        self.engine.run(until=until)
        return self

    def drain(self) -> "Orchestrator":
        """Run to quiescence: process every remaining event."""
        self.engine.drain()
        return self

    # -- two-phase planning (speculative / what-if) -----------------------------
    def plan(self, app: AppDAG, now: Optional[float] = None) -> Plan:
        """Pure planning against the current state (no mutation)."""
        return orchestrate(
            app, self.cluster, self.now if now is None else now, self.policy
        )

    def commit(self, plan: Plan) -> ApplyToken:
        """Apply a plan; the returned token undoes it via ``cluster.undo``."""
        return self.cluster.apply(plan)

    # -- results ----------------------------------------------------------------
    def result(self, scenario: str = "online", horizon: Optional[float] = None) -> SimResult:
        return self.engine.result(
            scenario=scenario, horizon=self.now if horizon is None else horizon
        )

    @property
    def now(self) -> float:
        return self.engine.now

    @property
    def records(self) -> List[InstanceRecord]:
        return self.engine.records

    @property
    def pending_events(self) -> int:
        return len(self.engine.events)

    @property
    def trace(self):
        """The engine's :class:`~repro.obs.Tracer` (None = tracing off)."""
        return self.engine.trace

    @property
    def stats(self):
        """Engine counters (a typed :class:`~repro.obs.EngineStats` over
        the frozen counter vocabulary; misspelled names raise
        AttributeError).  Instance ledger — ``admitted`` (instances whose
        ARRIVAL fired, plus stream-layer sheds), ``completed``, ``lost``
        (failed) and ``shed`` (dropped by admission control) satisfy
        ``admitted == completed + lost + shed``, asserted by :meth:`drain`.
        Churn-runtime counters: device_down/device_up, replica_deaths,
        task_failovers, replans, recovered (instances that survived a
        replica death), salvages (partial-result resubmissions) and
        salvaged (instances that completed after at least one salvage)."""
        return self.engine.stats


_LAZY = {
    "run_one": ("repro.sim.runner", "run_one"),
    "run_grid": ("repro.sim.runner", "run_grid"),
    "sweep_alpha": ("repro.sim.runner", "sweep_alpha"),
    "sweep_gamma": ("repro.sim.runner", "sweep_gamma"),
    "SimConfig": ("repro.sim.runner", "SimConfig"),
    "make_profile": ("repro.sim.profiles", "make_profile"),
    "make_cluster": ("repro.sim.profiles", "make_cluster"),
    "make_multi_tier_cluster": ("repro.sim.profiles", "make_multi_tier_cluster"),
    "EdgeProfile": ("repro.sim.profiles", "EdgeProfile"),
    "ServingFleet": ("repro.serve.scheduler", "ServingFleet"),
    "ChurnSchedule": ("repro.sim.churn", "ChurnSchedule"),
    "ChurnEvent": ("repro.sim.churn", "ChurnEvent"),
    "exponential_churn": ("repro.sim.churn", "exponential_churn"),
    "deterministic_churn": ("repro.sim.churn", "deterministic_churn"),
    "trace_churn": ("repro.sim.churn", "trace_churn"),
    "churn_from_monitor": ("repro.sim.churn", "churn_from_monitor"),
    "maintenance_windows": ("repro.sim.churn", "maintenance_windows"),
    "correlated_churn": ("repro.sim.churn", "correlated_churn"),
    "periodic_windows": ("repro.sim.churn", "periodic_windows"),
    "device_groups": ("repro.sim.churn", "device_groups"),
    "SurvivalForecast": ("repro.core.availability", "SurvivalForecast"),
    # always-on streaming service (repro.stream)
    "StreamingOrchestrator": ("repro.stream", "StreamingOrchestrator"),
    "StreamResult": ("repro.stream", "StreamResult"),
    "AdmissionConfig": ("repro.stream", "AdmissionConfig"),
    "AdmissionController": ("repro.stream", "AdmissionController"),
    "PlacementLatencyEstimator": ("repro.stream", "PlacementLatencyEstimator"),
    "ShedRecord": ("repro.stream", "ShedRecord"),
    "SLOClass": ("repro.stream", "SLOClass"),
    "LATENCY_CRITICAL": ("repro.stream", "LATENCY_CRITICAL"),
    "BEST_EFFORT": ("repro.stream", "BEST_EFFORT"),
    "AppStream": ("repro.stream", "AppStream"),
    "Arrival": ("repro.stream", "Arrival"),
    "default_streams": ("repro.stream", "default_streams"),
    "poisson_arrivals": ("repro.stream", "poisson_arrivals"),
    "diurnal_arrivals": ("repro.stream", "diurnal_arrivals"),
    "trace_replay": ("repro.stream", "trace_replay"),
    "MetricsRegistry": ("repro.stream", "MetricsRegistry"),
    # observability (repro.obs): tracing, attribution, exporters
    "Tracer": ("repro.obs", "Tracer"),
    "Span": ("repro.obs", "Span"),
    "SPAN_SCHEMA": ("repro.obs", "SPAN_SCHEMA"),
    "EngineStats": ("repro.obs", "EngineStats"),
    "ENGINE_COUNTERS": ("repro.obs", "ENGINE_COUNTERS"),
    "attribution_report": ("repro.obs", "attribution_report"),
    "instance_breakdown": ("repro.obs", "instance_breakdown"),
    "format_report": ("repro.obs", "format_report"),
    "to_chrome_trace": ("repro.obs", "to_chrome_trace"),
    "ledger_from_trace": ("repro.obs", "ledger_from_trace"),
    "validate_chrome_trace": ("repro.obs", "validate_chrome_trace"),
    "json_summary": ("repro.obs", "json_summary"),
}


def __getattr__(name: str):
    """Lazy re-exports of the grid runners and the serving fleet, so that
    ``repro.api`` stays import-light and free of circular imports (the
    runners themselves build :class:`Orchestrator` instances)."""
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module 'repro.api' has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod_name), attr)
