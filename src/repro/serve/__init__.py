"""Serving: a real continuous-batching engine (slot-based KV cache) and the
interference-aware fleet scheduler built on the IBDASH core."""
from .engine import ServingEngine, measure_interference
from .scheduler import RequestClass, ServingFleet, make_request_dag

__all__ = [
    "ServingEngine",
    "measure_interference",
    "ServingFleet",
    "RequestClass",
    "make_request_dag",
]
