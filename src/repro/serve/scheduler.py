"""Fleet-level serving scheduler: IBDASH over model replicas.

The mapping (DESIGN.md §Serving):
  edge device  -> model-replica group (a slice of pods serving one copy)
  task type    -> request class (prefill-heavy vs decode-heavy, ctx length)
  (m, c) plot  -> measured decode/prefill latency vs co-batched requests
                  (fit by serve.engine.measure_interference — real timings)
  model upload -> model/LoRA artifact load onto a replica (M_info = which
                  adapters are resident; LRU eviction under HBM pressure)
  failure      -> replica preemption (spot pods); exponential model
  replication  -> speculative duplicate dispatch of requests on flaky
                  replicas (first responder wins)

A request is itself a 2-task DAG: prefill -> decode, so the full Algorithm 1
machinery (stage barriers, transfer costs between stages placed on
different replicas = KV-cache migration cost) applies verbatim — the same
``repro.core`` code that reproduces the paper schedules the serving fleet.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..api import Orchestrator
from ..core.cluster import ClusterState, Device
from ..core.dag import AppDAG, TaskSpec
from ..core.interference import InterferenceModel
from ..core.policy import make_policy
from ..sim.engine import SimResult

__all__ = ["RequestClass", "make_request_dag", "ServingFleet"]

MB = 1e6

# Request classes = "task types" for the interference table.
#   0: prefill-short   1: prefill-long   2: decode-short   3: decode-long
N_REQUEST_TYPES = 4


@dataclass(frozen=True)
class RequestClass:
    name: str
    prefill_type: int
    decode_type: int
    kv_bytes: float              # KV-cache size moved if stages change replica
    adapter: Optional[str] = None
    adapter_bytes: float = 0.0


SHORT = RequestClass("short", 0, 2, kv_bytes=2 * MB)
LONG = RequestClass("long", 1, 3, kv_bytes=64 * MB,
                    adapter="lora-long", adapter_bytes=120 * MB)


def make_request_dag(req_id: str, rc: RequestClass) -> AppDAG:
    """prefill -> decode, with the KV cache as the inter-stage data."""
    return AppDAG.from_tasks(
        f"req-{rc.name}",
        [
            TaskSpec(
                f"prefill{req_id}", ttype=rc.prefill_type,
                out_bytes=rc.kv_bytes, model_id=rc.adapter,
                model_bytes=rc.adapter_bytes, mem_bytes=rc.kv_bytes,
            ),
            TaskSpec(
                f"decode{req_id}", ttype=rc.decode_type,
                deps=(f"prefill{req_id}",), out_bytes=0.1 * MB,
                model_id=rc.adapter, model_bytes=rc.adapter_bytes,
                mem_bytes=rc.kv_bytes,
            ),
        ],
    )


class ServingFleet:
    """A fleet of model replicas driven by any registered placement policy."""

    def __init__(
        self,
        interference: InterferenceModel,
        *,
        n_replicas: int = 16,
        replica_classes: Optional[Sequence[int]] = None,
        lams: Sequence[float] = (1e-5, 8e-4),      # (reserved, spot)
        hbm_bytes: float = 16e9,
        link_bw: float = 2e9,
        # Optional placement-domain topology: per-replica tier ids (same pod /
        # same rack / cross-zone) plus up/down rates and a (T, T) backhaul
        # matrix — KV-cache migration between stages is then priced over the
        # pairwise bw_eff[s, d] link instead of a flat fleet-wide rate.
        tiers: Optional[Sequence[int]] = None,
        up_bw: Optional[Sequence[float]] = None,
        down_bw: Optional[Sequence[float]] = None,
        backhaul: Optional[np.ndarray] = None,
        policy: str = "ibdash",
        alpha: float = 0.5,
        beta: float = 0.1,
        gamma: int = 2,
        seed: int = 0,
        horizon: float = 120.0,
        latency_budget: float = float("inf"),
        # -- churn runtime: replica preemption + recovery ----------------------
        # ``churn=True`` generates exponential preemption/re-provision cycles
        # over the replicas from their lams; or pass a ChurnSchedule.  When a
        # replica dies mid-request, ``recovery="replan"`` re-places the
        # in-flight stages on the survivors — the decode stage's KV cache is
        # re-sharded onto the new replica at the link-matrix transfer price.
        churn=None,
        recovery: str = "fail_fast",
        detection_delay: float = 0.1,
        max_retries: int = 2,
        mean_downtime: float = 15.0,
        churn_seed: int = 7,
    ):
        self.interference = interference
        classes = (
            list(replica_classes)
            if replica_classes is not None
            else [i % 2 for i in range(n_replicas)]   # alternate reserved/spot
        )
        rng = np.random.default_rng(seed)
        devices = []
        for i, cls in enumerate(classes):
            lam = float(lams[cls])
            lifetime = rng.exponential(1 / lam) if lam > 0 else float("inf")
            devices.append(Device(
                did=i, cls=cls, mem_total=hbm_bytes, lam=lam,
                alive_until=lifetime,
                tier=int(tiers[i]) if tiers is not None else 0,
                up_bw=float(up_bw[i]) if up_bw is not None else link_bw,
                down_bw=float(down_bw[i]) if down_bw is not None else link_bw,
            ))
        self.cluster = ClusterState(
            devices=devices, model=interference, horizon=horizon, dt=0.02,
            backhaul=backhaul,
        )
        if churn is True:
            from ..sim.churn import exponential_churn

            churn = exponential_churn(
                self.cluster, horizon=horizon, seed=churn_seed,
                rejoin=True, mean_downtime=mean_downtime,
            )
        # Every scheme comes out of the policy registry; the online flow is
        # the unified Orchestrator façade (submit -> step -> result).
        self.orchestrator = Orchestrator(
            self.cluster,
            make_policy(policy, alpha=alpha, beta=beta, gamma=gamma, seed=seed,
                        latency_budget=latency_budget),
            seed=seed,
            churn=churn,
            recovery=recovery,
            detection_delay=detection_delay,
            max_retries=max_retries,
        )
        self.horizon = horizon

    @property
    def policy(self):
        return self.orchestrator.policy

    @property
    def engine(self):
        """Back-compat alias for callers that poked at the raw engine."""
        return self.orchestrator.engine

    def run(
        self,
        n_requests: int = 500,
        long_frac: float = 0.3,
        arrival_window: float = 20.0,
        seed: int = 1,
        fused: bool = False,
        admission: Optional["AdmissionConfig"] = None,
        admission_slo: Tuple[float, float] = (2.0, 10.0),
        tick: float = 0.1,
    ) -> SimResult:
        """Serve a request stream.  ``fused=True`` admission-plans the whole
        wave with one batched ``decide_batch`` call per stage (prefill wave,
        then decode wave) — the bulk-admission mode for traffic spikes.

        Passing ``admission`` (an :class:`repro.stream.AdmissionConfig`)
        routes the request stream through the SAME bounded admission queue
        the simulator's streaming service uses: short requests become the
        ``latency_critical`` class, long requests ``best_effort``
        (``admission_slo`` gives their E2E deadlines in seconds), overload
        is deadline-shed/backpressured instead of queued forever, and the
        returned result carries the service's
        :class:`~repro.stream.StreamResult` as ``res.stream``."""
        rng = np.random.default_rng(seed)
        if admission is not None:
            return self._run_admitted(
                n_requests, long_frac, arrival_window, rng, admission,
                admission_slo, tick,
            )
        apps, times = [], []
        for i in range(n_requests):
            rc = LONG if rng.random() < long_frac else SHORT
            apps.append(make_request_dag(f"#{i}", rc))
            times.append(float(rng.uniform(0.0, arrival_window)))
        self.orchestrator.submit_batch(apps, sorted(times), fused=fused)
        self.orchestrator.step(until=self.horizon)
        return self.orchestrator.result(scenario="serving", horizon=self.horizon)

    def _run_admitted(
        self,
        n_requests: int,
        long_frac: float,
        arrival_window: float,
        rng: np.random.Generator,
        admission: "AdmissionConfig",
        admission_slo: Tuple[float, float],
        tick: float,
    ) -> SimResult:
        from ..stream import (
            Arrival,
            AppStream,
            SLOClass,
            StreamingOrchestrator,
        )

        short_slo = SLOClass("latency_critical", admission_slo[0], True)
        long_slo = SLOClass("best_effort", admission_slo[1], False)
        streams = {
            "short": AppStream(
                "short", lambda: make_request_dag("", SHORT), slo=short_slo
            ),
            "long": AppStream(
                "long", lambda: make_request_dag("", LONG), slo=long_slo
            ),
        }
        rows = []
        for _ in range(n_requests):
            name = "long" if rng.random() < long_frac else "short"
            rows.append((float(rng.uniform(0.0, arrival_window)), name))
        rows.sort(key=lambda r: r[0])
        arrivals = [
            Arrival(
                t=t, slo=streams[name].slo,
                deadline=t + streams[name].slo.deadline,
                stream=streams[name], uid=uid,
            )
            for uid, (t, name) in enumerate(rows)
        ]
        service = StreamingOrchestrator(
            self.orchestrator, admission=admission, tick=tick,
        )
        stream_res = service.run(arrivals)
        res = self.orchestrator.result(
            scenario="serving", horizon=self.horizon
        )
        res.stream = stream_res
        return res


def serving_interference_model(
    m_short: float = 0.004, c_short: float = 0.035,
    m_long: float = 0.012, c_long: float = 0.220,
    n_classes: int = 2, fast_factor: float = 0.6,
) -> InterferenceModel:
    """Build the replica interference table from measured (m, c) pairs
    (defaults match CPU measurements of the tiny-model engine; production
    would feed measure_interference outputs per hardware class)."""
    base = np.zeros((n_classes, N_REQUEST_TYPES))
    slope = np.zeros((n_classes, N_REQUEST_TYPES, N_REQUEST_TYPES))
    c = np.array([c_short, c_long, c_short * 0.5, c_long * 0.5])
    m = np.array([m_short, m_long, m_short, m_long])
    for cls in range(n_classes):
        f = 1.0 if cls == 0 else 1.0 / fast_factor   # class 1 = slower spot HW
        base[cls] = c * f
        # decode-vs-decode contention dominates; prefill adds compute bursts
        for i in range(N_REQUEST_TYPES):
            for j in range(N_REQUEST_TYPES):
                scale = 1.0 if (i >= 2) == (j >= 2) else 1.6
                slope[cls, i, j] = m[i] * scale * f
    return InterferenceModel(base=base, slope=slope)
