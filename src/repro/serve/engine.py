"""Single-replica continuous-batching engine.

A fixed-capacity slot array over a preallocated KV cache: requests are
prefilled into free slots, every ``step()`` decodes all active slots in one
jitted call, finished requests free their slots.  This is the real
(CPU-runnable) engine behind the serving example; it also provides
``measure_interference`` — the Fig.-4 analogue that fits the paper's linear
service-time model ``T = m*k + c`` to *measured* decode latencies as a
function of co-batched sequences, which the fleet scheduler then consumes.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.interference import fit_linear_interference
from ..models.transformer import LM

__all__ = ["ServingEngine"]


@dataclass
class _Slot:
    request_id: Optional[str] = None
    pos: int = 0
    remaining: int = 0
    generated: Optional[List[int]] = None


class ServingEngine:
    def __init__(self, model: LM, params, max_batch: int = 8, max_seq: int = 512):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.caches = model.init_cache(max_batch, max_seq)
        self.slots = [_Slot() for _ in range(max_batch)]
        self.tokens = jnp.zeros((max_batch,), jnp.int32)
        self.pos = jnp.zeros((max_batch,), jnp.int32)

        self._decode = jax.jit(model.decode_step, donate_argnums=(3,))
        self._prefill = jax.jit(model.prefill, donate_argnums=(2,))

    # -- request lifecycle ------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.request_id is None]

    @property
    def active(self) -> int:
        return sum(s.request_id is not None for s in self.slots)

    def add_request(self, request_id: str, prompt: Sequence[int],
                    max_new_tokens: int) -> int:
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slots")
        slot = free[0]
        prompt = np.asarray(prompt, dtype=np.int32)[None, :]   # (1, P)
        tmp_cache = self.model.init_cache(1, self.max_seq)
        logits, tmp_cache = self._prefill(
            self.params, {"tokens": jnp.asarray(prompt)}, tmp_cache
        )
        # splice the single-request cache into this slot
        def splice(full, one):
            if full is None:
                return None
            return full.at[:, slot].set(one[:, 0])
        self.caches = jax.tree.map(splice, self.caches, tmp_cache)
        first = int(jnp.argmax(logits[0]))
        st = self.slots[slot]
        st.request_id = request_id
        st.pos = prompt.shape[1]
        st.remaining = max_new_tokens
        st.generated = [first]
        self.tokens = self.tokens.at[slot].set(first)
        self.pos = self.pos.at[slot].set(st.pos)
        return slot

    def step(self) -> Dict[str, List[int]]:
        """One decode step for all active slots; returns finished requests."""
        logits, self.caches = self._decode(
            self.params, self.tokens, self.pos, self.caches
        )
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        finished: Dict[str, List[int]] = {}
        new_tokens = np.asarray(nxt)
        for i, st in enumerate(self.slots):
            if st.request_id is None:
                continue
            st.generated.append(int(new_tokens[i]))
            st.pos += 1
            st.remaining -= 1
            if st.remaining <= 0 or st.pos >= self.max_seq - 1:
                finished[st.request_id] = st.generated
                st.request_id = None
                st.generated = None
        self.tokens = jnp.asarray(new_tokens)
        self.pos = self.pos + 1
        return finished


# -- the Fig. 4 analogue ---------------------------------------------------------
def measure_interference(
    model: LM, params, batch_sizes: Sequence[int], *, max_seq: int = 256,
    iters: int = 20, warmup: int = 3, prompt_len: int = 8,
) -> Tuple[float, float, float, List[Tuple[int, float]]]:
    """Measure decode-step latency as a function of co-batched sequences and
    fit the paper's linear interference model ``T = m*k + c`` to REAL
    timings (the serving analogue of the paper's Fig. 4 verification).
    Returns (m, c, r2, samples)."""
    samples: List[Tuple[int, float]] = []
    rng = np.random.default_rng(0)
    for k in batch_sizes:
        eng = ServingEngine(model, params, max_batch=int(k), max_seq=max_seq)
        for j in range(int(k)):
            eng.add_request(
                f"probe{j}", rng.integers(0, model.cfg.vocab, prompt_len),
                max_new_tokens=10**9,
            )
        for _ in range(warmup):
            eng.step()
        t0 = time.perf_counter()
        for _ in range(iters):
            eng.step()
        dt = (time.perf_counter() - t0) / iters
        samples.append((int(k), dt))
    m, c, r2 = fit_linear_interference(
        [s[0] for s in samples], [s[1] for s in samples]
    )
    return m, c, r2, samples
