"""IBDASH core: DAG staging, interference model, availability prediction,
cluster state, and the pure policy/orchestration API.

This package is the paper's primary contribution.  Algorithm 1 and the five
baselines are pure ``decide(ctx) -> TaskDecision`` policies in
:mod:`repro.core.policy`; :func:`repro.core.orchestrator.orchestrate` builds
the array-native :class:`PolicyContext` per task and assembles a
:class:`Plan`; :meth:`repro.core.cluster.ClusterState.apply` is the single
mutation path (with undo tokens).  The same code is reused verbatim by the
distributed-training/serving runtime (:mod:`repro.ft`, :mod:`repro.serve`).
"""
from .availability import (
    LAMBDA_CED,
    LAMBDA_MIX,
    LAMBDA_PED,
    availability,
    fit_failure_rate,
    gang_failure_rate,
    prob_fail_during,
    sample_lifetime,
    young_daly_interval,
)
from .cluster import (
    TIER_CLOUD,
    TIER_DEVICE,
    TIER_EDGE_SERVER,
    TIER_NAMES,
    ApplyToken,
    ClusterState,
    Device,
)
from .dag import AppDAG, TaskSpec, app_stage, topological_order, validate_dag
from .interference import InterferenceModel, fit_linear_interference
from .orchestrator import (
    IBDASHConfig,
    Placement,
    Plan,
    Replica,
    TaskPlacement,
    orchestrate,
    orchestrate_batch,
)
from .recovery import (
    FailFastRecovery,
    FailoverRecovery,
    RecoveryStrategy,
    ReplanRecovery,
    available_recoveries,
    make_recovery,
    register_recovery,
)
from .policy import (
    IBDASHPolicy,
    LAVEAPolicy,
    LaTSModel,
    LaTSPolicy,
    PetrelPolicy,
    Policy,
    PolicyContext,
    RandomPolicy,
    RoundRobinPolicy,
    TaskDecision,
    TierEscalationPolicy,
    available_policies,
    make_policy,
    register_policy,
)

__all__ = [
    "AppDAG",
    "TaskSpec",
    "app_stage",
    "topological_order",
    "validate_dag",
    "InterferenceModel",
    "fit_linear_interference",
    "ApplyToken",
    "ClusterState",
    "Device",
    "TIER_DEVICE",
    "TIER_EDGE_SERVER",
    "TIER_CLOUD",
    "TIER_NAMES",
    "IBDASHConfig",
    "Placement",
    "Plan",
    "Replica",
    "TaskPlacement",
    "orchestrate",
    "orchestrate_batch",
    "Policy",
    "PolicyContext",
    "TaskDecision",
    "register_policy",
    "make_policy",
    "available_policies",
    "RecoveryStrategy",
    "FailFastRecovery",
    "FailoverRecovery",
    "ReplanRecovery",
    "register_recovery",
    "make_recovery",
    "available_recoveries",
    "IBDASHPolicy",
    "RandomPolicy",
    "RoundRobinPolicy",
    "LAVEAPolicy",
    "PetrelPolicy",
    "LaTSPolicy",
    "TierEscalationPolicy",
    "LaTSModel",
    "availability",
    "prob_fail_during",
    "sample_lifetime",
    "fit_failure_rate",
    "young_daly_interval",
    "gang_failure_rate",
    "LAMBDA_MIX",
    "LAMBDA_CED",
    "LAMBDA_PED",
]
