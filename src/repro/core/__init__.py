"""IBDASH core: DAG staging, interference model, availability prediction,
cluster state and the orchestration algorithm + baselines.

This package is the paper's primary contribution, implemented exactly as in
Algorithm 1 and reused verbatim by the distributed-training/serving runtime
(:mod:`repro.ft`, :mod:`repro.serve`).
"""
from .availability import (
    LAMBDA_CED,
    LAMBDA_MIX,
    LAMBDA_PED,
    availability,
    fit_failure_rate,
    gang_failure_rate,
    prob_fail_during,
    sample_lifetime,
    young_daly_interval,
)
from .baselines import LAVEA, LaTS, LaTSModel, Petrel, RandomScheduler, RoundRobinScheduler
from .cluster import ClusterState, Device
from .dag import AppDAG, TaskSpec, app_stage, topological_order, validate_dag
from .interference import InterferenceModel, fit_linear_interference
from .orchestrator import IBDASH, IBDASHConfig, Placement, Replica, Scheduler, TaskPlacement

__all__ = [
    "AppDAG",
    "TaskSpec",
    "app_stage",
    "topological_order",
    "validate_dag",
    "InterferenceModel",
    "fit_linear_interference",
    "ClusterState",
    "Device",
    "IBDASH",
    "IBDASHConfig",
    "Placement",
    "Replica",
    "Scheduler",
    "TaskPlacement",
    "RandomScheduler",
    "RoundRobinScheduler",
    "LAVEA",
    "Petrel",
    "LaTS",
    "LaTSModel",
    "availability",
    "prob_fail_during",
    "sample_lifetime",
    "fit_failure_rate",
    "young_daly_interval",
    "gang_failure_rate",
    "LAMBDA_MIX",
    "LAMBDA_CED",
    "LAMBDA_PED",
]
