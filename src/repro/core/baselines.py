"""DEPRECATED shims for the baseline schemes the paper compares against (§V-D).

The actual decision rules now live in :mod:`repro.core.policy` as pure
``decide(ctx) -> TaskDecision`` functions registered under their scheme
names ("random", "round_robin", "lavea", "petrel", "lats").  These classes
survive for one PR so existing imports keep working; each simply wraps its
policy in the pure :class:`~repro.core.orchestrator.Scheduler` shim.

Every baseline runs in the *same* environment as IBDASH: model uploads and
cross-device data transfers still cost time and T_alloc bookkeeping is kept
identically — the baselines simply don't reason about those costs (or about
failure probabilities / replication) when choosing devices.  Placement
bookkeeping estimates use the ground-truth interference model so that the
simulated environment is identical across schemes; only the *choice*
differs.
"""
from __future__ import annotations

from .orchestrator import Scheduler
from .policy import (
    LAVEAPolicy,
    LaTSModel,
    LaTSPolicy,
    PetrelPolicy,
    RandomPolicy,
    RoundRobinPolicy,
)

__all__ = [
    "RandomScheduler",
    "RoundRobinScheduler",
    "LAVEA",
    "Petrel",
    "LaTS",
    "LaTSModel",
]


class RandomScheduler(Scheduler):
    """DEPRECATED: use ``make_policy("random", seed=...)``."""

    def __init__(self, seed: int = 0):
        super().__init__(RandomPolicy(seed=seed))

    @property
    def rng(self):
        return self.policy.rng


class RoundRobinScheduler(Scheduler):
    """DEPRECATED: use ``make_policy("round_robin")``."""

    def __init__(self, seed: int = 0):
        super().__init__(RoundRobinPolicy(seed=seed))


class LAVEA(Scheduler):
    """DEPRECATED: use ``make_policy("lavea")`` (SQLF, best scheme of [6])."""

    def __init__(self, seed: int = 0):
        super().__init__(LAVEAPolicy(seed=seed))


class Petrel(Scheduler):
    """DEPRECATED: use ``make_policy("petrel", seed=...)``."""

    def __init__(self, seed: int = 0):
        super().__init__(PetrelPolicy(seed=seed))


class LaTS(Scheduler):
    """DEPRECATED: use ``make_policy("lats", lats_model=..., seed=...)``."""

    def __init__(self, model: LaTSModel, seed: int = 0):
        super().__init__(LaTSPolicy(lats_model=model, seed=seed))

    @property
    def model(self) -> LaTSModel:
        return self.policy.model
