"""Baseline orchestration schemes the paper compares against (§V-D).

  Random      — uniform random device per task.
  RoundRobin  — cyclic assignment.
  LAVEA       — Shortest Queue Length First (SQLF): fewest running tasks.
  Petrel      — power-of-two-choices: sample 2 devices, take the one with the
                lower expected service time.
  LaTS        — latency-aware: picks the device with the minimum latency
                predicted by a parametric log(latency) ~ CPU-usage model
                (the paper fits this linear-in-log model in Fig. 5).

Every baseline runs in the *same* environment as IBDASH: model uploads and
cross-device data transfers still cost time and T_alloc bookkeeping is kept
identically — the baselines simply don't reason about those costs (or, for
all of them, about failure probabilities / replication) when choosing
devices.  Placement bookkeeping estimates use the ground-truth interference
model so that the simulated environment is identical across schemes; only
the *choice* differs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .availability import prob_fail_during
from .cluster import ClusterState
from .dag import AppDAG
from .orchestrator import Placement, Replica, Scheduler, TaskPlacement

__all__ = ["RandomScheduler", "RoundRobinScheduler", "LAVEA", "Petrel", "LaTS"]


class _SingleChoiceScheduler(Scheduler):
    """Template: walk stages, pick one device per task via ``choose``."""

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def choose(
        self,
        feasible: np.ndarray,
        exec_lat: np.ndarray,
        cluster: ClusterState,
        t_start: float,
        ttype: int,
    ) -> int:
        raise NotImplementedError

    def place(self, app: AppDAG, cluster: ClusterState, now: float) -> Placement:
        placements: Dict[str, TaskPlacement] = {}
        bw = cluster.bandwidths()
        lams = cluster.lams()
        mem_total = cluster.mem_totals()
        stage_offset = 0.0
        for stage in app.stages:
            stage_latency = 0.0
            for tname in stage:
                spec = app.tasks[tname]
                t_start = now + stage_offset
                need = spec.mem_bytes + spec.model_bytes
                feasible = np.flatnonzero(mem_total >= need)
                if feasible.size == 0:
                    return Placement(
                        app_name=app.name, tasks=placements, est_latency=0.0,
                        feasible=False, infeasible_task=tname,
                    )
                exec_lat = cluster.estimate_exec(spec.ttype, t_start)
                did = int(self.choose(feasible, exec_lat, cluster, t_start, spec.ttype))
                dev = cluster.devices[did]
                up = self.upload_latency(app, tname, dev, bw[did])
                tr = self.transfer_latency(app, tname, did, placements, bw[did])
                total = float(exec_lat[did]) + up + tr
                window = (t_start - dev.join_time) + total
                rep = Replica(
                    did=did, est_exec=float(exec_lat[did]), est_upload=up,
                    est_transfer=tr,
                    pred_fail=prob_fail_during(lams[did], window),
                )
                tp = TaskPlacement(
                    task=tname, ttype=spec.ttype, replicas=[rep],
                    est_start=stage_offset, est_latency=total,
                )
                placements[tname] = tp
                stage_latency = max(stage_latency, total)
            stage_offset += stage_latency
        return self.commit(app, cluster, now, placements)


class RandomScheduler(_SingleChoiceScheduler):
    name = "random"

    def choose(self, feasible, exec_lat, cluster, t_start, ttype) -> int:
        return int(self.rng.choice(feasible))


class RoundRobinScheduler(_SingleChoiceScheduler):
    name = "round_robin"

    def __init__(self, seed: int = 0):
        super().__init__(seed)
        self._next = 0

    def choose(self, feasible, exec_lat, cluster, t_start, ttype) -> int:
        did = int(feasible[self._next % feasible.size])
        self._next += 1
        return did


class LAVEA(_SingleChoiceScheduler):
    """Shortest Queue Length First (best scheme of LAVEA [6])."""

    name = "lavea"

    def choose(self, feasible, exec_lat, cluster, t_start, ttype) -> int:
        q = cluster.queue_len_at(t_start)[feasible]
        return int(feasible[int(np.argmin(q))])


class Petrel(_SingleChoiceScheduler):
    """Power-of-two-choices randomized load balancing [7], [8]."""

    name = "petrel"

    def choose(self, feasible, exec_lat, cluster, t_start, ttype) -> int:
        if feasible.size == 1:
            return int(feasible[0])
        a, b = self.rng.choice(feasible, size=2, replace=False)
        return int(a if exec_lat[a] <= exec_lat[b] else b)


@dataclass
class LaTSModel:
    """Parametric latency model of LaTS [9]: log(latency) is linear in CPU
    usage (paper Fig. 5):  lat(cls, type, usage) = base * exp(b * usage).

    ``cpu_usage[cls, ttype]`` is the incremental CPU fraction one running
    task of ``ttype`` consumes on a class-``cls`` device; the device's total
    usage saturates at 1.0.
    """

    base: np.ndarray       # (P, N) unloaded latency per class/type
    b: np.ndarray          # (P,) fitted log-linear slope per class
    cpu_usage: np.ndarray  # (P, N)
    usage_cap: float = 4.0  # >1: oversubscribed CPU still adds latency signal

    def predict(self, classes: np.ndarray, ttype: int, counts: np.ndarray) -> np.ndarray:
        usage = np.minimum(
            (self.cpu_usage[classes] * counts).sum(axis=1), self.usage_cap
        )
        return self.base[classes, ttype] * np.exp(self.b[classes] * usage)


class LaTS(_SingleChoiceScheduler):
    """Latency-aware task scheduling via the latency–CPU-usage model.

    LaTS predicts execution latency well but ignores data-transfer and
    model-upload costs as well as failure probability — which is why (as in
    the paper) it concentrates load on the single fastest device."""

    name = "lats"

    def __init__(self, model: LaTSModel, seed: int = 0):
        super().__init__(seed)
        self.model = model

    def choose(self, feasible, exec_lat, cluster, t_start, ttype) -> int:
        counts = np.asarray(cluster.counts_at(t_start), dtype=np.float64)[feasible]
        pred = self.model.predict(cluster.classes()[feasible], ttype, counts)
        # Devices of the same class at saturated CPU usage produce identical
        # predictions; break ties randomly so LaTS spreads within its
        # favourite class instead of degenerating onto device 0.
        lo = pred.min()
        ties = np.flatnonzero(pred <= lo * (1.0 + 1e-9))
        return int(feasible[int(self.rng.choice(ties))])
