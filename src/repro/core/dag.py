"""DAG representation of applications and the paper's staging transform.

The paper (IBDASH, §IV-B) represents each application instance as a DAG
``G = (V, E)`` whose nodes are tasks and whose edges are execution/data
dependencies.  Before orchestration the DAG is *stagerized*: the stage of a
node is the length of the longest path from any source node ("modified
Breadth-First Search" in the paper).  All tasks inside one stage are
mutually independent and may run in parallel; stage ``i+1`` starts only
after stage ``i`` fully completes.

This module is pure Python (no JAX) — it is shared by the edge simulator
(the paper's own evaluation) and by the distributed-training runtime, which
re-uses the same staging logic to schedule pipeline/checkpoint/reduce task
graphs across pods.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "TaskSpec",
    "AppDAG",
    "app_stage",
    "topological_order",
    "validate_dag",
]


@dataclass(frozen=True)
class TaskSpec:
    """One task (node) of an application DAG.

    Attributes mirror the paper's notation (Table II):
      ttype       index into the task-type table ``T = {T_1..T_N}``
      deps        names of prerequisite tasks, ``D(T_i)``
      out_bytes   size of the task's output data ``T(i)_d`` handed to children
      model_id    required model artifact ``M(T_i)`` (None when task needs none)
      model_bytes size of ``M(T_i)`` (0 when ``model_id`` is None)
      mem_bytes   memory footprint ``H(T_i)`` (data + model resident set)
      work        abstract amount of compute (used by the profiler to derive
                  per-device base latencies; not part of the paper's notation)
    """

    name: str
    ttype: int
    deps: Tuple[str, ...] = ()
    out_bytes: float = 0.0
    model_id: Optional[str] = None
    model_bytes: float = 0.0
    mem_bytes: float = 0.0
    work: float = 1.0


@dataclass
class AppDAG:
    """An application instance: a named DAG of :class:`TaskSpec`."""

    name: str
    tasks: Dict[str, TaskSpec]
    # Filled in by ``finalize`` (cached staging results).
    stages: List[List[str]] = field(default_factory=list)
    stage_of: Dict[str, int] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if not self.stages:
            self.finalize()

    # -- construction helpers -------------------------------------------------
    @classmethod
    def from_tasks(cls, name: str, tasks: Iterable[TaskSpec]) -> "AppDAG":
        return cls(name=name, tasks={t.name: t for t in tasks})

    def finalize(self) -> "AppDAG":
        validate_dag(self.tasks)
        self.stage_of = app_stage(self.tasks)
        n_stages = 1 + max(self.stage_of.values()) if self.stage_of else 0
        self.stages = [[] for _ in range(n_stages)]
        for tname in topological_order(self.tasks):
            self.stages[self.stage_of[tname]].append(tname)
        return self

    # -- queries ---------------------------------------------------------------
    @property
    def n_tasks(self) -> int:
        return len(self.tasks)

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    def children(self, name: str) -> List[str]:
        return [t.name for t in self.tasks.values() if name in t.deps]

    def sources(self) -> List[str]:
        return [t.name for t in self.tasks.values() if not t.deps]

    def sinks(self) -> List[str]:
        have_child = {d for t in self.tasks.values() for d in t.deps}
        return [n for n in self.tasks if n not in have_child]

    def critical_path_len(self) -> int:
        """Number of stages == longest chain length (in tasks)."""
        return self.n_stages

    def relabel(self, suffix: str) -> "AppDAG":
        """Clone the DAG with every task renamed ``<name><suffix>`` (used to
        instantiate many concurrent application instances)."""
        remap = {n: n + suffix for n in self.tasks}
        tasks = {
            remap[n]: TaskSpec(
                name=remap[n],
                ttype=t.ttype,
                deps=tuple(remap[d] for d in t.deps),
                out_bytes=t.out_bytes,
                model_id=t.model_id,
                model_bytes=t.model_bytes,
                mem_bytes=t.mem_bytes,
                work=t.work,
            )
            for n, t in self.tasks.items()
        }
        return AppDAG(name=self.name, tasks=tasks)


def validate_dag(tasks: Dict[str, TaskSpec]) -> None:
    """Raise ``ValueError`` on dangling deps or cycles."""
    for t in tasks.values():
        for d in t.deps:
            if d not in tasks:
                raise ValueError(f"task {t.name!r} depends on unknown task {d!r}")
    # Kahn's algorithm to detect cycles.
    indeg = {n: len(t.deps) for n, t in tasks.items()}
    frontier = [n for n, d in indeg.items() if d == 0]
    seen = 0
    children: Dict[str, List[str]] = {n: [] for n in tasks}
    for t in tasks.values():
        for d in t.deps:
            children[d].append(t.name)
    while frontier:
        n = frontier.pop()
        seen += 1
        for c in children[n]:
            indeg[c] -= 1
            if indeg[c] == 0:
                frontier.append(c)
    if seen != len(tasks):
        raise ValueError("application graph contains a cycle")


def topological_order(tasks: Dict[str, TaskSpec]) -> List[str]:
    """Deterministic topological order (stable w.r.t. insertion order)."""
    order: List[str] = []
    indeg = {n: len(t.deps) for n, t in tasks.items()}
    children: Dict[str, List[str]] = {n: [] for n in tasks}
    for t in tasks.values():
        for d in t.deps:
            children[d].append(t.name)
    frontier = [n for n in tasks if indeg[n] == 0]  # insertion order
    while frontier:
        n = frontier.pop(0)
        order.append(n)
        for c in children[n]:
            indeg[c] -= 1
            if indeg[c] == 0:
                frontier.append(c)
    return order


def app_stage(tasks: Dict[str, TaskSpec]) -> Dict[str, int]:
    """Paper §IV-B: ``the stage of a node is the length of the longest path
    from the start node`` — computed with a DP over a topological order (the
    paper's 'modified BFS')."""
    stage: Dict[str, int] = {}
    for n in topological_order(tasks):
        deps = tasks[n].deps
        stage[n] = 0 if not deps else 1 + max(stage[d] for d in deps)
    return stage
