"""Recovery strategies: what the runtime does when a task loses its last
replica.

The paper stops at Eq. (4): an application instance fails as soon as any of
its tasks has every replica fail.  Proactive replication (Algorithm 1's
gamma loop) is the only defence — nothing in the system ever *reacts* to a
device leaving.  The dependability literature for edge fleets
(arXiv:1710.11222, arXiv:2110.07808) argues that detection + recovery is
what actually makes personal-device fleets usable, so this module adds a
pluggable recovery layer behind the simulator's churn runtime:

  * ``fail_fast``  — the paper's Eq. (4) verdict, bit-identical to the seed
    engine: the instance fails the moment a task's last replica dies.
  * ``failover``   — surviving sibling replicas absorb a loss for free
    (that already falls out of first-success semantics); when a task loses
    ALL replicas, the runtime notices after ``detection_delay`` seconds
    (missed heartbeats) and restarts the task on the best surviving
    feasible device by the same Eq. (2) cost it was placed with — a greedy
    hot-spare, no policy round-trip.  The instance fails only when no live
    device is feasible or ``max_retries`` restarts are exhausted.
  * ``replan``     — after the same detection delay, re-invoke the
    *placement policy* on the live sub-fleet for the dead task and every
    not-yet-started downstream stage, through the pure
    ``orchestrate(pinned=...)`` / ``cluster.apply`` machinery: completed
    and in-flight tasks keep their placements (and keep pricing downstream
    transfers), the doomed remainder is re-planned from scratch.

Recovery composes with the engine's partial-result salvage layer: every
``engine._finish_app(run, failed=True)`` verdict a strategy hands down —
``fail_fast``'s immediate one, or a ``failover``/``replan`` giving up after
``max_retries`` — is intercepted when ``Engine(salvage=...)`` is enabled
and the instance has completed stages: those stages' placements are pinned
through the same ``orchestrate(pinned=...)`` substrate ``replan`` uses and
only the unfinished remainder is re-planned, so giving up on a *task* no
longer always means discarding the whole instance's work.

Strategies are engine-agnostic: they react to ``on_task_dead`` callbacks
from :class:`repro.sim.engine.Engine` (fired both by the churn runtime's
DEVICE_DOWN kills and by the passive lands-on-a-dead-device failure path)
and drive recovery through the engine's public task-lifecycle helpers.
They hold only their own configuration — per-instance retry state lives on
the engine's run records — so one strategy instance can serve any number of
concurrent instances.

Registered by name (mirroring the policy registry) so the simulator, the
``Orchestrator`` façade, and the serving fleet construct them uniformly:
``make_recovery("replan", detection_delay=0.5)``.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Tuple, Type

import numpy as np

__all__ = [
    "RecoveryStrategy",
    "FailFastRecovery",
    "FailoverRecovery",
    "ReplanRecovery",
    "register_recovery",
    "make_recovery",
    "available_recoveries",
]


class RecoveryStrategy:
    """Reacts to task deaths.  ``on_task_dead`` fires when the LAST
    in-flight replica of a task has died (the moment Eq. (4) would fail the
    instance); ``recover`` fires when a recovery the strategy scheduled
    (via ``engine.schedule_recovery``) comes due after its detection delay.
    Implementations decide the instance's fate through
    ``engine._finish_app`` / the engine's task-restart helpers.
    """

    name: str = "base"

    def on_task_dead(self, engine, run, tname: str) -> None:
        raise NotImplementedError

    def recover(self, engine, run, tname: str) -> None:  # pragma: no cover
        raise NotImplementedError


# -- registry (mirrors the policy registry) -----------------------------------
_REGISTRY: "Dict[str, Type[RecoveryStrategy]]" = {}


def register_recovery(
    name: str,
) -> Callable[[Type[RecoveryStrategy]], Type[RecoveryStrategy]]:
    def deco(cls: Type[RecoveryStrategy]) -> Type[RecoveryStrategy]:
        if name in _REGISTRY:
            raise ValueError(f"recovery strategy {name!r} already registered")
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def make_recovery(name: str, **kwargs) -> RecoveryStrategy:
    """Instantiate a registered recovery strategy by name (every strategy
    accepts the full kwarg bundle and keeps what it needs)."""
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown recovery strategy {name!r}; available: "
            f"{sorted(_REGISTRY)}"
        ) from None
    return cls(**kwargs)


def available_recoveries() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


@register_recovery("fail_fast")
class FailFastRecovery(RecoveryStrategy):
    """The paper's Eq. (4) behaviour, bit-identical to the seed engine: a
    task with every replica dead fails its instance immediately."""

    def __init__(self, **_):
        pass

    def on_task_dead(self, engine, run, tname: str) -> None:
        engine._finish_app(run, failed=True)

    def recover(self, engine, run, tname: str) -> None:  # pragma: no cover
        raise RuntimeError("fail_fast never schedules a recovery")


class _DelayedRecovery(RecoveryStrategy):
    """Shared detection/retry plumbing: a death is only *noticed*
    ``detection_delay`` seconds later (missed heartbeats), and each task
    gets at most ``max_retries`` recovery attempts before its instance is
    declared lost."""

    def __init__(
        self,
        *,
        detection_delay: float = 0.25,
        max_retries: int = 2,
        **_,
    ):
        self.detection_delay = float(detection_delay)
        self.max_retries = int(max_retries)

    def on_task_dead(self, engine, run, tname: str) -> None:
        n = run.retries.get(tname, 0)
        if n >= self.max_retries:
            engine._finish_app(run, failed=True)
            return
        run.retries[tname] = n + 1
        engine.schedule_recovery(run, tname, engine.now + self.detection_delay)


@register_recovery("failover")
class FailoverRecovery(_DelayedRecovery):
    """Greedy hot-spare: restart the dead task on the surviving feasible
    device with the lowest Eq. (2) cost (execution + model upload + input
    transfer from its parents' actual hosts), no policy round-trip."""

    def recover(self, engine, run, tname: str) -> None:
        if run.failed or run.done.get(tname, False):
            return
        engine.stats.task_failovers += 1
        rep = _best_surviving_replica(engine, run, tname)
        if engine.trace is not None:
            engine.trace.event(
                run.rec.tid, "failover", engine.now, name=tname,
                ok=rep is not None,
                device=-1 if rep is None else rep.did,
            )
        if rep is None:
            engine._finish_app(run, failed=True)
            return
        run.placement.tasks[tname].replicas = [rep]
        engine._launch_replica(run, tname, rep)


@register_recovery("replan")
class ReplanRecovery(_DelayedRecovery):
    """Re-invoke the placement policy on the live sub-fleet for the dead
    task and every not-yet-started downstream stage.

    Completed and in-flight tasks are pinned (they keep their placements
    and keep pricing downstream transfer costs); the doomed remainder's
    provisional T_alloc occupancy is cancelled *before* planning so the
    policy prices the fleet as it will actually be, and the fresh plan is
    applied through the one blessed mutation path.  If even the live
    sub-fleet cannot host the remainder, the instance is lost."""

    def recover(self, engine, run, tname: str) -> None:
        from .orchestrator import orchestrate  # deferred: avoids cycle at import

        if run.failed or run.done.get(tname, False):
            return
        cluster, t = engine.cluster, engine.now
        unstarted = [k for k in run.placement.tasks if k not in run.started]
        pinned = {
            k: tp for k, tp in run.placement.tasks.items()
            if k in run.started and k != tname
        }
        # the doomed remainder's provisional occupancy must not distort the
        # replan's Eq. (1) estimates — cancel it first
        engine._cancel_provisional(run, tasks=unstarted)
        for k in unstarted:
            del run.placement.tasks[k]
        t0 = time.perf_counter()
        plan = orchestrate(run.app, cluster, t, engine.policy, pinned=pinned)
        engine.replan_time += time.perf_counter() - t0
        engine.stats.replans += 1
        if engine.trace is not None:
            engine.trace.event(
                run.rec.tid, "replan", t, name=tname, ok=plan.feasible,
            )
        if not plan.feasible:
            engine._finish_app(run, failed=True)
            return
        cluster.apply(plan)
        for k, tp in plan.placement.tasks.items():
            run.placement.tasks[k] = tp
            run.origins[k] = plan.now
        engine._start_task(run, tname)


def _best_surviving_replica(engine, run, tname: str):
    """The failover target: min Eq. (2) total over live, memory-feasible
    devices, with model-cache admission checked for real (a device whose
    cache cannot absorb the artifact is skipped, like ``apply`` would)."""
    from .orchestrator import Replica  # deferred: avoids cycle at import

    cluster, t = engine.cluster, engine.now
    spec = run.app.tasks[tname]
    feasible = np.asarray(cluster.alive_mask(t)) & (
        cluster.mem_totals() >= spec.mem_bytes + spec.model_bytes
    )
    if not feasible.any():
        return None
    exec_lat = cluster.estimate_exec(spec.ttype, t)
    if spec.model_id is not None:
        missing = np.array(
            [not d.has_model(spec.model_id) for d in cluster.devices]
        )
        upload = np.where(missing, spec.model_bytes / cluster.upload_bw(), 0.0)
    else:
        upload = np.zeros(cluster.n_devices)
    transfer = np.zeros(cluster.n_devices)
    for dep in spec.deps:
        parent = run.placement.tasks.get(dep)
        if parent is not None and parent.replicas:
            # the survivor re-shards the parent's output over the actual
            # link (for serving fleets: the KV-cache re-shard cost), priced
            # from the factorized model's lazily derived sender row
            transfer = transfer + (
                run.app.tasks[dep].out_bytes
                / cluster.link_row(parent.replicas[0].did)
            )
    total = exec_lat + upload + transfer
    order = np.argsort(np.where(feasible, total, np.inf), kind="stable")
    lams = cluster.lams()
    for did in order:
        did = int(did)
        if not feasible[did]:
            break
        dev = cluster.devices[did]
        if spec.model_id is not None and not dev.admit_model(
            spec.model_id, spec.model_bytes
        ):
            continue
        window = (t - dev.join_time) + float(total[did])
        pf = float(1.0 - np.exp(-lams[did] * max(window, 0.0)))
        return Replica(
            did=did,
            est_exec=float(exec_lat[did]),
            est_upload=float(upload[did]),
            est_transfer=float(transfer[did]),
            pred_fail=pf,
        )
    return None
