"""Batched, array-native substrate for placement policies.

PR 1 made each policy a pure function ``decide(ctx) -> TaskDecision`` of a
per-task :class:`~repro.core.policy.PolicyContext`.  That is the right
*semantics*, but a burst of ~1000 simultaneous application instances (the
paper's §V-G protocol) still pays a Python round-trip per task.  This module
introduces the batched counterparts:

  * :class:`FleetSnapshot` — a struct-of-arrays snapshot of the fleet at one
    planning instant: the static device vectors (classes, failure rates,
    bandwidths, memory, join times) plus the dynamic ``(D, N)`` Task_info
    counts that PR 1 scattered across ``Device`` objects and per-call
    ``ClusterState`` accessors.  Registered as a JAX pytree so it can flow
    through ``jit``/``vmap`` boundaries unchanged.
  * :class:`BatchedPolicyContext` — ``(B, D)``-shaped exec/upload/transfer/
    total/pf/feasible tensors for all B tasks of a stage or arrival wave,
    built once per wave by :func:`repro.core.orchestrator.orchestrate_batch`.
    ``row(b)`` recovers the exact scalar :class:`PolicyContext` of row ``b``,
    which is how the default ``Policy.decide_batch`` fallback and the parity
    tests tie the two APIs together.
  * :class:`BatchedDecision` — one device tuple per row, primary first.

The bottom half holds the fused ``jax.numpy`` decision kernels used by the
registered policies' ``decide_batch`` overrides: the IBDASH score-and-
replicate loop (Algorithm 1 lines 30-41) as a ``lax.scan`` over the sorted
candidate queue, vectorised over all B tasks and jitted with a static row
count (B is padded to a bounded shape set — powers of two, then multiples
of 1024 — so a 1000-instance burst compiles a handful of variants, not one
per wave size); LAVEA's masked argmin; and the round-robin gather.  All kernels run under ``jax.experimental
.enable_x64`` so their float64 arithmetic is **bit-identical** to the numpy
scalar path — parity is asserted, not approximate.  When JAX is unavailable
the same kernels fall back to equivalent vectorised numpy.
"""
from __future__ import annotations

from dataclasses import dataclass, fields
from functools import cached_property
from typing import List, Tuple

import numpy as np

__all__ = [
    "FLEET_SNAPSHOT_SCHEMA",
    "FleetSnapshot",
    "BatchedPolicyContext",
    "BatchedDecision",
    "HAVE_JAX",
    "BATCH_KERNEL_MIN_ROWS",
    "TOPK_PRUNE_MIN_DEVICES",
    "ibdash_decide_batch",
    "lavea_decide_batch",
    "round_robin_decide_batch",
    "tier_escalation_decide_batch",
]

# Below this many rows the fixed jit-dispatch cost exceeds the fused-kernel
# win, so decide_batch implementations fall back to the (bit-identical)
# per-row scalar rule.
BATCH_KERNEL_MIN_ROWS = 8

# Above this many devices the IBDASH candidate queue is pre-pruned with a
# partial selection (O(D) per row) instead of a full O(D log D) stable
# argsort — only the first n_scan + 1 queue entries are ever reachable, so
# decide_batch cost scales with candidates considered, not raw fleet size.
TOPK_PRUNE_MIN_DEVICES = 256

# THE declarative FleetSnapshot leaf schema — the single source of truth the
# dataclass declaration, the pytree flattener (which iterates ``fields()``,
# so field order IS leaf order), every construction site, and the
# ``snapshot-schema`` lint rule are all checked against.  The schema has
# drifted 12 -> 13 -> 15 -> 17 leaves across PRs 3-10; to add a leaf, extend
# this tuple AND the dataclass together, then let ``python -m repro.analysis``
# point at every construction site that needs the new keyword.
#
# PR 10 factorized the dense ``link_bw`` leaf out of the snapshot: the
# bottleneck rule bw_eff[s, d] = min(up[s], down[d], backhaul[tier[s],
# tier[d]]) is carried as its O(D) + O(T^2) factors (``up_bw``, ``down_bw``,
# ``backhaul`` + the existing ``tiers``), so a snapshot never holds O(D^2)
# state and 100k-device fleets fit.  Sender rows are derived lazily
# (:meth:`FleetSnapshot.link_row`).
FLEET_SNAPSHOT_SCHEMA: Tuple[str, ...] = (
    "t",
    "classes",
    "lams",
    "bandwidths",
    "tiers",
    "up_bw",
    "down_bw",
    "backhaul",
    "mem_total",
    "join_times",
    "alive",
    "surv_grid",
    "survival",
    "counts",
    "queue_len",
    "base",
    "slope",
)


@dataclass(frozen=True)
class FleetSnapshot:
    """Struct-of-arrays view of the whole fleet at one planning instant.

    Everything is indexed by device id (length ``D``); ``counts`` is the
    Task_info matrix at time ``t`` (the paper's "number of running tasks on
    each device at a certain time", §IV-A) and ``queue_len`` its row sum.
    ``base``/``slope`` carry the profiled ED_mc interference table so a
    snapshot is self-contained for Eq. (1) evaluation.  Snapshots are frozen
    and registered as JAX pytrees (arrays are leaves, see
    :func:`_register_pytrees`).
    """

    t: float                 # absolute time of the snapshot
    classes: np.ndarray      # (D,) device-class ids
    lams: np.ndarray         # (D,) failure rates (Table IV)
    bandwidths: np.ndarray   # (D,) DEPRECATED scalar bandwidths (see link_row)
    tiers: np.ndarray        # (D,) fleet tier ids (device/edge_server/cloud)
    # Factorized bottleneck link model (PR 10): bw_eff[s, d] = min(up_bw[s],
    # down_bw[d], backhaul[tiers[s], tiers[d]]), +inf on the diagonal.  The
    # dense (D, D) matrix is never a leaf — derive rows with ``link_row``.
    up_bw: np.ndarray        # (D,) sender uplink rates in bytes/s
    down_bw: np.ndarray      # (D,) receiver downlink rates in bytes/s
    backhaul: np.ndarray     # (T, T) inter-tier backhaul rates (inf = free)
    mem_total: np.ndarray    # (D,) H(ED) in bytes (memory-feasibility data)
    join_times: np.ndarray   # (D,) device join times
    alive: np.ndarray        # (D,) bool: not yet departed at t (churn mask)
    # Availability forecast sampled at t: survival[d, k] = P(device d stays
    # up throughout [t, t + surv_grid[k]]) — exact for scripted maintenance
    # windows, MLE-extrapolated for stochastic churn.  With no forecast
    # installed the leaves are the uniform (K=1) all-ones tensor.
    surv_grid: np.ndarray    # (K,) span offsets of the forecast grid
    survival: np.ndarray     # (D, K) survival probabilities over the grid
    counts: np.ndarray       # (D, N) Task_info at t
    queue_len: np.ndarray    # (D,) total running tasks per device
    base: np.ndarray         # (P, N) ED_mc base latencies c[p, i]
    slope: np.ndarray        # (P, N, N) ED_mc interference slopes m[p, i, j]

    @property
    def n_devices(self) -> int:
        return int(self.classes.shape[0])

    @property
    def n_types(self) -> int:
        return int(self.counts.shape[1])

    def link_row(self, s: int) -> np.ndarray:
        """(D,) sender row ``bw_eff[s, :]`` of the effective link matrix,
        derived from the O(D) factors: ``min(up_bw[s], down_bw[d],
        backhaul[tiers[s], tiers[d]])`` with ``+inf`` at ``d == s`` (a
        co-located transfer crosses no network hop).  Bit-identical to
        slicing the dense matrix the pre-factorization snapshots carried."""
        s = int(s)
        row = np.minimum(self.up_bw[s], self.down_bw)
        row = np.minimum(row, self.backhaul[self.tiers[s], self.tiers])
        row[s] = np.inf
        return row

    @cached_property
    def link_bw(self) -> np.ndarray:
        """(D, D) dense ``bw_eff`` matrix, materialized ON DEMAND from the
        factor leaves (and cached on the instance).  Debug / small-fleet
        convenience only: it is O(D^2) memory, is NOT a pytree leaf, and hot
        paths must slice :meth:`link_row` instead."""
        link = np.minimum(self.up_bw[:, None], self.down_bw[None, :])
        link = np.minimum(
            link, self.backhaul[self.tiers[:, None], self.tiers[None, :]]
        )
        np.fill_diagonal(link, np.inf)
        return link

    def validate(self) -> "FleetSnapshot":
        """Runtime twin of the ``snapshot-schema`` lint rule: assert this
        snapshot's leaf count and order match
        :data:`FLEET_SNAPSHOT_SCHEMA` exactly.

        The pytree flattener iterates ``fields()``, so dataclass field
        order IS pytree leaf order — checking the field tuple checks what
        every jitted kernel will see.  Called once per
        ``ClusterState.snapshot()`` under ``__debug__`` (``python -O``
        strips it from hot production runs).  Returns ``self`` so call
        sites can chain."""
        names = tuple(f.name for f in fields(self))
        if names != FLEET_SNAPSHOT_SCHEMA:
            raise TypeError(
                f"FleetSnapshot leaf drift: instance flattens to "
                f"{list(names)} but FLEET_SNAPSHOT_SCHEMA declares "
                f"{list(FLEET_SNAPSHOT_SCHEMA)}; update the schema, the "
                "dataclass, and every construction site together"
            )
        return self


@dataclass(frozen=True)
class BatchedPolicyContext:
    """Everything a policy may inspect to place B tasks at once.

    Row ``b`` is one task.  Rows of one batch were built against the same
    cluster state — a stage of one application, or a whole arrival wave —
    so a batched decision is defined to equal deciding the rows one by one
    in order (stateful policies consume their rng/cursor once per row; see
    ``Policy.decide_batch``).

    Storage is a deduplicated struct-of-arrays: a burst of ~1000 instances
    of a few application types produces waves whose rows are largely
    IDENTICAL (same task type, model, parents, bucketed start time), so the
    ``*_pool`` tensors hold only the G << B distinct context rows and
    ``row_pool`` maps each row to its pool entry.  The pool key covers
    everything a context row is a function of, so ``pool_row == row`` holds
    exactly — stateless policies may decide once per pool entry and fan the
    decision out (bit-identical memoisation of a pure function), while the
    classic ``(B, D)`` views (``exec_lat``, ``total``, ``pf``, ...)
    materialise lazily for stateful policies and the scalar ``row(b)``
    bridge.  ``fleet`` carries the shared static device vectors.
    """

    tasks: Tuple[str, ...]       # (B,) task names (error reporting)
    ttypes: np.ndarray           # (B,) task-type indices
    t_start: np.ndarray          # (B,) absolute estimated starts
    stage_offset: np.ndarray     # (B,) offsets from each app's arrival
    row_pool: np.ndarray         # (B,) row -> distinct-context pool entry
    pool_first: np.ndarray       # (G,) pool entry -> its first row
    exec_pool: np.ndarray        # (G, D) Eq. (1) execution latency
    upload_pool: np.ndarray      # (G, D) L(M(T_i)) model-upload latency
    transfer_pool: np.ndarray    # (G, D) L(T_i)_d input-transfer latency
    total_pool: np.ndarray       # (G, D) Eq. (2): exec + upload + transfer
    feasible_pool: np.ndarray    # (G, D) bool memory-feasibility mask
    pf_pool: np.ndarray          # (G, D) F(T_i) per device
    # Per-candidate forecast survival over each row's estimated execution
    # span: S_d(t_start, t_start + total[g, d]), evaluated EXACTLY from the
    # installed forecast (all-ones when none is installed, so policies fall
    # back bit-identically to the memoryless pf column).
    survival_pool: np.ndarray    # (G, D)
    # Task_info snapshots are pooled separately by T_alloc bucket.
    counts_pool: np.ndarray      # (Gc, D, N) distinct Task_info snapshots
    queue_pool: np.ndarray       # (Gc, D) their queue lengths
    bucket_inv: np.ndarray       # (B,) row -> counts/queue pool index
    # Shared fleet vectors.  NOTE: the snapshot is taken at the wave-stage's
    # FIRST row's start time — its static vectors (classes, lams, ...) hold
    # for every row, but in a multi-time wave its dynamic `counts`/
    # `queue_len` describe only that reference instant; per-row dynamic
    # state lives in `counts_pool`/`queue_pool`/`bucket_inv` (or the lazy
    # `counts`/`queue_len` views).
    fleet: FleetSnapshot

    # -- lazily materialised (B, D[, N]) views -------------------------------
    def _expand(self, pool: np.ndarray, inv: np.ndarray) -> np.ndarray:
        """Per-row view of a pool: broadcast when the pool is one entry,
        gather by ``inv`` otherwise."""
        if pool.shape[0] == 1:
            return np.broadcast_to(
                pool[0], (len(self.tasks),) + pool.shape[1:]
            )
        return pool[inv]

    @cached_property
    def exec_lat(self) -> np.ndarray:
        return self._expand(self.exec_pool, self.row_pool)

    @cached_property
    def upload(self) -> np.ndarray:
        return self._expand(self.upload_pool, self.row_pool)

    @cached_property
    def transfer(self) -> np.ndarray:
        return self._expand(self.transfer_pool, self.row_pool)

    @cached_property
    def total(self) -> np.ndarray:
        return self._expand(self.total_pool, self.row_pool)

    @cached_property
    def feasible(self) -> np.ndarray:
        return self._expand(self.feasible_pool, self.row_pool)

    @cached_property
    def pf(self) -> np.ndarray:
        return self._expand(self.pf_pool, self.row_pool)

    @cached_property
    def survival(self) -> np.ndarray:
        """(B, D) per-candidate forecast survival over each row's span."""
        return self._expand(self.survival_pool, self.row_pool)

    @cached_property
    def counts(self) -> np.ndarray:
        """(B, D, N) Task_info at each row's t_start (lazy; see pools)."""
        return self._expand(self.counts_pool, self.bucket_inv)

    @cached_property
    def queue_len(self) -> np.ndarray:
        """(B, D) LAVEA's SQLF signal per row (lazy; see pools)."""
        return self._expand(self.queue_pool, self.bucket_inv)

    @property
    def n_rows(self) -> int:
        return len(self.tasks)

    @property
    def n_devices(self) -> int:
        return int(self.exec_pool.shape[1])

    @property
    def n_distinct(self) -> int:
        """Number of distinct context rows (pool entries)."""
        return int(self.exec_pool.shape[0])

    # shared static fleet vectors, delegated for policy convenience ----------
    @property
    def classes(self) -> np.ndarray:
        return self.fleet.classes

    @property
    def lams(self) -> np.ndarray:
        return self.fleet.lams

    @property
    def join_times(self) -> np.ndarray:
        return self.fleet.join_times

    @property
    def bandwidths(self) -> np.ndarray:
        return self.fleet.bandwidths

    @property
    def tiers(self) -> np.ndarray:
        return self.fleet.tiers

    def link_row(self, s: int) -> np.ndarray:
        """(D,) sender row of the effective link matrix (factorized)."""
        return self.fleet.link_row(s)

    @property
    def link_bw(self) -> np.ndarray:
        """(D, D) dense bw_eff matrix, materialized on demand from the
        snapshot's factor leaves — debug/small-fleet only (O(D^2))."""
        return self.fleet.link_bw

    @property
    def mem_total(self) -> np.ndarray:
        return self.fleet.mem_total

    @property
    def alive(self) -> np.ndarray:
        """(D,) bool: devices not yet departed when the wave was planned.
        Already ANDed into ``feasible``; exposed for custom policies that
        build their own masks."""
        return self.fleet.alive

    def feasible_ids(self, b: int) -> np.ndarray:
        return np.flatnonzero(self.feasible_pool[self.row_pool[b]])

    def estimates_at(
        self, b: int, did: int
    ) -> Tuple[float, float, float, float]:
        """(exec, upload, transfer, pf) of device ``did`` for row ``b``."""
        g = self.row_pool[b]
        return (
            float(self.exec_pool[g, did]),
            float(self.upload_pool[g, did]),
            float(self.transfer_pool[g, did]),
            float(self.pf_pool[g, did]),
        )

    def primary_estimates(
        self, dids: np.ndarray
    ) -> Tuple[list, list, list, list]:
        """Bulk (exec, upload, transfer, pf) lists at one device per row
        (the chosen primaries) — four fused gathers instead of 4B scalar
        reads."""
        g = self.row_pool
        return (
            self.exec_pool[g, dids].tolist(),
            self.upload_pool[g, dids].tolist(),
            self.transfer_pool[g, dids].tolist(),
            self.pf_pool[g, dids].tolist(),
        )

    def row(self, b: int):
        """The exact scalar :class:`PolicyContext` of row ``b`` — the bridge
        between the batched and scalar APIs (used by the default
        ``decide_batch`` fallback and the parity tests)."""
        from .policy import PolicyContext  # deferred: policy imports us

        g = self.row_pool[b]
        gc = self.bucket_inv[b]
        feasible = self.feasible_pool[g]
        return PolicyContext(
            task=self.tasks[b],
            ttype=int(self.ttypes[b]),
            t_start=float(self.t_start[b]),
            stage_offset=float(self.stage_offset[b]),
            exec_lat=self.exec_pool[g],
            upload=self.upload_pool[g],
            transfer=self.transfer_pool[g],
            total=self.total_pool[g],
            feasible=feasible,
            feasible_ids=np.flatnonzero(feasible),
            pf=self.pf_pool[g],
            lams=self.fleet.lams,
            join_times=self.fleet.join_times,
            queue_len=self.queue_pool[gc],
            counts=self.counts_pool[gc],
            classes=self.fleet.classes,
            tiers=self.fleet.tiers,
            alive=self.fleet.alive,
            survival=self.survival_pool[g],
        )


@dataclass(frozen=True)
class BatchedDecision:
    """A policy's verdict for a whole batch: row-aligned device tuples,
    primary first; an empty tuple marks the row's task unplaceable."""

    devices: Tuple[Tuple[int, ...], ...]

    @property
    def n_rows(self) -> int:
        return len(self.devices)

    def __iter__(self):
        return iter(self.devices)

    def __getitem__(self, b: int) -> Tuple[int, ...]:
        return self.devices[b]


# -- JAX plumbing -------------------------------------------------------------
try:  # the image bakes in jax; guard anyway so core stays importable without it
    import jax as _jax_probe  # noqa: F401

    HAVE_JAX = True
except Exception:  # pragma: no cover - exercised on jax-less installs
    HAVE_JAX = False

_JAX_STATE: dict = {}


def _register_pytrees(jax) -> None:
    """Register the frozen context dataclasses as pytrees (arrays = leaves,
    task names = aux data) so snapshots/contexts pass through jax transforms."""
    from jax.tree_util import register_pytree_node

    def flatten_fleet(s: FleetSnapshot):
        names = [f.name for f in fields(FleetSnapshot)]
        return tuple(getattr(s, n) for n in names), tuple(names)

    def unflatten_fleet(names, vals):
        return FleetSnapshot(**dict(zip(names, vals)))

    def flatten_batch(c: BatchedPolicyContext):
        names = [f.name for f in fields(BatchedPolicyContext) if f.name != "tasks"]
        return tuple(getattr(c, n) for n in names), (tuple(names), c.tasks)

    def unflatten_batch(aux, vals):
        names, tasks = aux
        return BatchedPolicyContext(tasks=tasks, **dict(zip(names, vals)))

    register_pytree_node(FleetSnapshot, flatten_fleet, unflatten_fleet)
    register_pytree_node(BatchedPolicyContext, flatten_batch, unflatten_batch)


def _jax():
    """Import jax lazily (keeps ``repro.core`` import-light), register the
    pytrees once, and build the jitted kernels."""
    if "jnp" in _JAX_STATE:
        return _JAX_STATE
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    _register_pytrees(jax)

    def ibdash_scan_kernel(s_total, s_pf, n_feas, alpha, beta, gamma):
        """Algorithm 1's score-and-replicate loop (lines 29-41) for all B
        rows at once: a ``lax.scan`` over the pre-sorted candidate queue
        carrying one ``active`` lane per row — a lane goes (and stays)
        inactive exactly when the scalar ``while`` would have exited or hit
        its ``break``.

        Inputs are the first ``K = n_scan + 1`` columns of each task's
        priority queue (lines 16-18), already sorted ascending by total
        latency.  Every scalar iteration either accepts a replica (at most
        ``gamma`` times) or breaks, so ``n_scan = min(gamma + 1, D - 1)``
        steps cover every reachable state.  The sort itself stays in numpy:
        XLA's CPU sort/top_k measured ~5x slower than ``np.argsort`` at the
        (4096, 100) wave shapes this serves (flip to a jnp sort when
        running the kernel on an accelerator).
        """
        best = s_total[:, 0]
        l_ref = jnp.maximum(best, 1e-9)
        comb0 = s_pf[:, 0]
        w0 = alpha * (best / l_ref) + (1 - alpha) * comb0      # line 29
        n_rows = s_total.shape[0]
        n_scan = s_total.shape[1] - 1

        def step(carry, xs):
            active, comb, w_s, t_rep = carry
            qi, c_total, c_pf = xs
            cond = (active & (comb >= beta) & (t_rep < gamma)
                    & (qi < n_feas))                           # line 30
            new_fail = comb * c_pf
            w_new = alpha * (c_total / l_ref) + (1 - alpha) * new_fail
            accept = cond & (w_new <= w_s)                     # line 34
            comb = jnp.where(accept, new_fail, comb)
            w_s = jnp.where(accept, w_new, w_s)
            t_rep = t_rep + accept                             # line 37
            # rejection => break (line 39); cond failure => loop exit
            return (accept, comb, w_s, t_rep), accept

        qis = jnp.arange(1, n_scan + 1)
        _, accepts = jax.lax.scan(
            step,
            (jnp.ones(n_rows, bool), comb0, w0, jnp.zeros(n_rows, jnp.int32)),
            (qis, s_total[:, 1:].T, s_pf[:, 1:].T),
        )
        return accepts.T                                       # (B, n_scan)

    def lavea_kernel(queue_len, feasible):
        """Shortest Queue Length First: masked argmin per row."""
        return jnp.argmin(jnp.where(feasible, queue_len, jnp.inf), axis=1)

    def round_robin_kernel(feasible, targets):
        """Select each row's ``targets[b]``-th feasible device."""
        pos = jnp.cumsum(feasible, axis=1) - 1
        match = feasible & (pos == targets[:, None])
        return jnp.argmax(match, axis=1)

    def tier_escalation_kernel(total, feasible, tiers, budget, n_tiers):
        """Tier escalation for all B rows: per level L (device -> edge ->
        cloud) take the masked argmin over feasible devices at tiers <= L,
        accept the first level whose best candidate meets the latency
        budget, fall back to the global feasible argmin.  ``n_tiers`` is
        static so the tiny level loop unrolls."""
        B = total.shape[0]
        rows = jnp.arange(B)
        picked = jnp.zeros(B, jnp.int64)
        chosen = jnp.zeros(B, bool)
        for lv in range(n_tiers):
            masked = jnp.where(feasible & (tiers[None, :] <= lv), total, jnp.inf)
            best = jnp.argmin(masked, axis=1)
            best_val = masked[rows, best]
            take = ~chosen & jnp.isfinite(best_val) & (best_val <= budget)
            picked = jnp.where(take, best, picked)
            chosen = chosen | take
        gbest = jnp.argmin(jnp.where(feasible, total, jnp.inf), axis=1)
        return jnp.where(chosen, picked, gbest)

    _JAX_STATE.update(
        jnp=jnp,
        enable_x64=enable_x64,
        ibdash_scan_kernel=jax.jit(ibdash_scan_kernel),
        lavea_kernel=jax.jit(lavea_kernel),
        round_robin_kernel=jax.jit(round_robin_kernel),
        tier_escalation_kernel=jax.jit(
            tier_escalation_kernel, static_argnums=(4,)
        ),
    )
    return _JAX_STATE


def _pad_rows(arr: np.ndarray, n_pad: int, fill) -> np.ndarray:
    if n_pad == 0:
        return arr
    pad = np.full((n_pad,) + arr.shape[1:], fill, dtype=arr.dtype)
    return np.concatenate([arr, pad], axis=0)


def _padded(B: int) -> int:
    """Pad the row count to a bounded set of shapes so a burst's shrinking
    wave sizes reuse compiled kernels: powers of two up to 1024, then
    multiples of 1024 (tighter than pow2 for the big waves)."""
    if B <= 1024:
        return 1 << max(B - 1, 0).bit_length()
    return -(-B // 1024) * 1024


# -- fused decision kernels (numpy in, tuples out) ----------------------------
def _topk_stable(masked: np.ndarray, k: int) -> np.ndarray:
    """First ``k`` columns of the row-wise stable ascending argsort of
    ``masked``, without sorting all D columns.

    ``np.partition`` finds each row's k-th smallest value (the selection
    boundary) in O(D); everything strictly below the boundary survives, and
    boundary ties are resolved to the LOWEST device ids — exactly the
    entries a stable full sort would keep — so the result is bit-identical
    to ``np.argsort(masked, kind="stable")[:, :k]`` including tie-breaks.
    Only the <= k survivors are then sorted: O(D + k log k) per row."""
    B = masked.shape[0]
    boundary = np.partition(masked, k - 1, axis=1)[:, k - 1]
    out = np.empty((B, k), np.int64)
    for b in range(B):
        below = np.flatnonzero(masked[b] < boundary[b])
        ties = np.flatnonzero(masked[b] == boundary[b])[: k - below.size]
        cand = np.concatenate([below, ties])
        out[b] = cand[np.argsort(masked[b, cand], kind="stable")]
    return out


def ibdash_decide_batch(
    total: np.ndarray,
    pf: np.ndarray,
    feasible: np.ndarray,
    alpha: float,
    beta: float,
    gamma: int,
) -> List[Tuple[int, ...]]:
    """One fused call of the IBDASH score-and-replicate rule for B tasks.

    Bit-identical to looping the scalar rule: float64 arithmetic under
    ``enable_x64``, stable sorts, and the same IEEE expressions per step.
    """
    B, D = total.shape
    n_feas = feasible.sum(axis=1)
    n_scan = min(int(gamma) + 1, D - 1)  # a scalar iteration accepts or breaks
    # lines 16-18: the priority queue == stable ascending sort over L(T_i)
    # with infeasible devices pushed to +inf.  Only the first n_scan + 1
    # entries are reachable, so the rest of the permutation is discarded —
    # and on big fleets never even computed (partial selection, same order).
    masked = np.where(feasible, total, np.inf)
    if D > TOPK_PRUNE_MIN_DEVICES and n_scan + 1 < D:
        order = _topk_stable(masked, n_scan + 1)
    else:
        order = np.argsort(masked, axis=1, kind="stable")[:, : n_scan + 1]
    s_total = np.take_along_axis(total, order, axis=1)
    s_pf = np.take_along_axis(pf, order, axis=1)
    if HAVE_JAX and n_scan > 0:
        st = _jax()
        n_pad = _padded(B) - B
        with st["enable_x64"]():
            accepts = st["ibdash_scan_kernel"](
                _pad_rows(np.asarray(s_total, np.float64), n_pad, 1.0),
                _pad_rows(np.asarray(s_pf, np.float64), n_pad, 0.0),
                _pad_rows(np.asarray(n_feas, np.int64), n_pad, D),
                float(alpha), float(beta), int(gamma),
            )
        accepts = np.asarray(accepts)[:B]
    else:
        accepts = _ibdash_scan_numpy(
            s_total, s_pf, n_feas, alpha, beta, gamma
        )
    n_extra = accepts.sum(axis=1)
    primary = order[:, 0]
    out: List[Tuple[int, ...]] = []
    for b in range(B):
        if n_feas[b] == 0:
            out.append(())
        elif n_extra[b] == 0:                       # the common, no-replica row
            out.append((int(primary[b]),))
        else:
            extras = order[b, np.flatnonzero(accepts[b]) + 1]
            out.append((int(primary[b]), *(int(d) for d in extras)))
    return out


def _ibdash_scan_numpy(s_total, s_pf, n_feas, alpha, beta, gamma):
    """Vectorised numpy twin of the jax scan (jax-less fallback)."""
    B = s_total.shape[0]
    n_scan = s_total.shape[1] - 1
    best = s_total[:, 0]
    l_ref = np.maximum(best, 1e-9)
    comb = s_pf[:, 0].copy()
    w_s = alpha * (best / l_ref) + (1 - alpha) * comb
    active = np.ones(B, bool)
    t_rep = np.zeros(B, np.int64)
    accepts = np.zeros((B, n_scan), bool)
    for qi in range(1, n_scan + 1):
        cond = active & (comb >= beta) & (t_rep < gamma) & (qi < n_feas)
        if not cond.any():
            break
        new_fail = comb * s_pf[:, qi]
        w_new = alpha * (s_total[:, qi] / l_ref) + (1 - alpha) * new_fail
        accept = cond & (w_new <= w_s)
        comb = np.where(accept, new_fail, comb)
        w_s = np.where(accept, w_new, w_s)
        t_rep = t_rep + accept
        accepts[:, qi - 1] = accept
        active = accept
    return accepts


def lavea_decide_batch(
    queue_len: np.ndarray, feasible: np.ndarray
) -> List[Tuple[int, ...]]:
    """Fused SQLF for B tasks: masked argmin (first minimum, like the
    scalar ``ids[argmin(queue[ids])]``)."""
    n_feas = feasible.sum(axis=1)
    if HAVE_JAX and queue_len.shape[0] >= BATCH_KERNEL_MIN_ROWS:
        st = _jax()
        n_pad = _padded(queue_len.shape[0]) - queue_len.shape[0]
        with st["enable_x64"]():
            picked = st["lavea_kernel"](
                _pad_rows(np.asarray(queue_len, np.float64), n_pad, 0.0),
                _pad_rows(np.asarray(feasible, bool), n_pad, True),
            )
        picked = np.asarray(picked)[: queue_len.shape[0]]
    else:
        masked = np.where(feasible, queue_len, np.inf)
        picked = np.argmin(masked, axis=1)
    return [
        (int(picked[b]),) if n_feas[b] > 0 else ()
        for b in range(queue_len.shape[0])
    ]


def tier_escalation_decide_batch(
    total: np.ndarray,
    feasible: np.ndarray,
    tiers: np.ndarray,
    budget: float,
) -> List[Tuple[int, ...]]:
    """Fused tier-escalation rule for B tasks.

    For each row, widen the candidate set one tier level at a time (devices
    first, then edge servers, then cloud) and place on the min-``total``
    candidate of the first level whose best option meets ``budget``; if even
    the whole fleet misses the budget, place on the global feasible best.
    Bit-identical to looping the scalar rule (same float64 masked argmins,
    first-minimum tie-break)."""
    B, D = total.shape
    n_feas = feasible.sum(axis=1)
    n_tiers = int(tiers.max()) + 1 if tiers.size else 1
    if HAVE_JAX and B >= BATCH_KERNEL_MIN_ROWS:
        st = _jax()
        n_pad = _padded(B) - B
        with st["enable_x64"]():
            picked = st["tier_escalation_kernel"](
                _pad_rows(np.asarray(total, np.float64), n_pad, 1.0),
                _pad_rows(np.asarray(feasible, bool), n_pad, False),
                np.asarray(tiers, np.int64),
                float(budget),
                n_tiers,
            )
        picked = np.asarray(picked)[:B]
    else:
        rows = np.arange(B)
        picked = np.zeros(B, np.int64)
        chosen = np.zeros(B, bool)
        for lv in range(n_tiers):
            masked = np.where(feasible & (tiers[None, :] <= lv), total, np.inf)
            best = np.argmin(masked, axis=1)
            best_val = masked[rows, best]
            take = ~chosen & np.isfinite(best_val) & (best_val <= budget)
            picked = np.where(take, best, picked)
            chosen |= take
        gbest = np.argmin(np.where(feasible, total, np.inf), axis=1)
        picked = np.where(chosen, picked, gbest)
    return [(int(picked[b]),) if n_feas[b] > 0 else () for b in range(B)]


def round_robin_decide_batch(
    feasible: np.ndarray, cursor: int
) -> Tuple[List[Tuple[int, ...]], int]:
    """Fused cyclic assignment.  Batch semantics: rows are served in order
    and the cursor advances once per row with a non-empty feasible set —
    exactly what looping the scalar rule does.  Returns (decisions, new
    cursor)."""
    B = feasible.shape[0]
    sizes = feasible.sum(axis=1)
    nonempty = sizes > 0
    before = np.cumsum(nonempty) - nonempty          # non-empty rows before b
    targets = np.where(nonempty, (cursor + before) % np.maximum(sizes, 1), 0)
    if HAVE_JAX and B >= BATCH_KERNEL_MIN_ROWS:
        st = _jax()
        n_pad = _padded(B) - B
        with st["enable_x64"]():
            picked = st["round_robin_kernel"](
                _pad_rows(np.asarray(feasible, bool), n_pad, True),
                _pad_rows(np.asarray(targets, np.int64), n_pad, 0),
            )
        picked = np.asarray(picked)[:B]
    else:
        pos = np.cumsum(feasible, axis=1) - 1
        match = feasible & (pos == targets[:, None])
        picked = np.argmax(match, axis=1)
    decisions = [
        (int(picked[b]),) if nonempty[b] else () for b in range(B)
    ]
    return decisions, cursor + int(nonempty.sum())
