"""Device availability / failure prediction (paper §V-F, Fig. 7, Table IV).

The paper models the probability that an edge device is still available
``t`` seconds after it joined the platform as ``P(ED) = exp(-lambda * t)``,
with per-device failure rates ``lambda`` (Table IV: lambda_1 = mixed
PED+CED, lambda_2 = CED-only, lambda_3 = PED-only).  It validates the model
against a one-month campus mobility trace [13].

For the distributed-training runtime the same exponential model drives two
production decisions:

  * the probability that a (preemptible) pod dies during a task of length L
    — memoryless, so ``F = 1 - exp(-lambda * L)`` — which feeds the
    replication loop of Algorithm 1 and the straggler/backup-task policy;
  * the optimal checkpoint cadence: for exponential failures with MTBF
    ``1/lambda`` and checkpoint write cost ``C`` the Young/Daly interval
    ``sqrt(2 * C / lambda)`` minimises expected lost work.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property
from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "availability",
    "prob_fail_during",
    "sample_lifetime",
    "fit_failure_rate",
    "young_daly_interval",
    "expected_makespan_with_restarts",
    "SurvivalForecast",
    "LAMBDA_MIX",
    "LAMBDA_CED",
    "LAMBDA_PED",
]

# Table IV of the paper — failure rates per edge-device class ED0..ED7.
LAMBDA_MIX = np.array(
    [1.5e-6, 1.1e-4, 1.5e-4, 2.4e-5, 9e-6, 3.2e-6, 3.1e-5, 1e-7]
)
LAMBDA_CED = np.array(
    [1.5e-5, 1.1e-5, 1.5e-5, 1.1e-5, 1.8e-5, 1.2e-5, 1.0e-5, 2.0e-5]
)
LAMBDA_PED = np.array(
    [1.5e-4, 1.1e-4, 1.5e-4, 2.4e-4, 9e-4, 3.2e-5, 1.0e-4, 9.0e-4]
)


def availability(lam: float, t: float) -> float:
    """P(device still available ``t`` seconds after joining) = exp(-lam t)."""
    return float(np.exp(-lam * max(t, 0.0)))


def prob_fail_during(lam: float, duration: float) -> float:
    """``F(T_i)``: probability the device fails within ``duration`` seconds.

    The exponential law is memoryless, so the window's start does not
    matter — only its length."""
    return float(1.0 - np.exp(-lam * max(duration, 0.0)))


def prob_fail_during_vec(lam: np.ndarray, duration: np.ndarray) -> np.ndarray:
    return 1.0 - np.exp(-np.asarray(lam) * np.maximum(np.asarray(duration), 0.0))


def sample_lifetime(lam: float, rng: np.random.Generator) -> float:
    """Draw an exponential device lifetime (time from join until it leaves)."""
    if lam <= 0:
        return float("inf")
    return float(rng.exponential(1.0 / lam))


def fit_failure_rate(
    timestamps: Sequence[float], alive: Sequence[bool]
) -> float:
    """MLE of ``lambda`` from an availability trace.

    ``timestamps[i]`` is the elapsed time since join of observation ``i`` and
    ``alive[i]`` whether the device was still present.  Treats each device
    observation as a (possibly right-censored) exponential sample:
    lambda_hat = (#deaths) / (total observed alive-time).  This is what the
    paper fits on the CrowdBind mobility trace (Fig. 7a)."""
    t = np.asarray(timestamps, dtype=np.float64)
    a = np.asarray(alive, dtype=bool)
    if t.shape != a.shape or t.ndim != 1 or t.size == 0:
        raise ValueError("bad trace")
    deaths = int((~a).sum())
    exposure = float(t.sum())
    if exposure <= 0:
        raise ValueError("no exposure time in trace")
    return deaths / exposure


@dataclass(frozen=True)
class SurvivalForecast:
    """Per-device availability forecast: ``S_d(t, t + h)`` = probability that
    device ``d`` stays up throughout the span ``[t, t + h]`` given everything
    predictable at ``t``.

    The paper prices every future failure through the memoryless ``F(T_i)``
    term, yet personal-device departures are often *announced* (a maintenance
    calendar, a lecture timetable) — the mobility-aware orchestration line
    (arXiv:2110.07808) plans around exactly such forecastable departures.
    This object separates the two hazard components:

      * ``departures`` — per-device sorted KNOWN future departure times
        (scripted maintenance windows, calendars, trace replays).  Exact: a
        span reaching past the next known departure has survival 0.
      * ``lams`` — per-device residual stochastic hazard rates for the
        *unpredictable* component (MLE-extrapolated: individual exponential
        churn, shared-shock rates).  ``None`` = no stochastic hazard.

    A forecast is installed on a :class:`~repro.core.cluster.ClusterState`
    (usually by ``ChurnSchedule.install``) and surfaces to policies two ways:
    sampled on a ``(K,)`` horizon grid as the ``surv_grid``/``survival``
    :class:`FleetSnapshot` pytree leaves, and — exactly, per candidate — as
    the ``survival`` column of the policy contexts, evaluated over each
    task's estimated execution span.  The ``churn_aware`` policy replaces the
    memoryless ``pf`` with ``1 - S_d`` where the forecast knows better.
    """

    departures: Tuple[Tuple[float, ...], ...]   # per-device sorted times
    lams: Optional[Tuple[float, ...]] = None    # (D,) stochastic rates
    horizon: float = 30.0                       # grid span for sample()
    n_points: int = 16                          # grid resolution K

    @property
    def n_devices(self) -> int:
        return len(self.departures)

    @staticmethod
    def from_rates(lams: Sequence[float], **kwargs) -> "SurvivalForecast":
        """Pure-stochastic forecast (no scripted departures known)."""
        lams = tuple(float(l) for l in lams)
        return SurvivalForecast(
            departures=((),) * len(lams), lams=lams, **kwargs
        )

    @cached_property
    def _lams_arr(self) -> Optional[np.ndarray]:
        if self.lams is None:
            return None
        return np.asarray(self.lams, dtype=np.float64)

    def next_departure(self, t: float) -> np.ndarray:
        """(D,) first known departure strictly after ``t`` (+inf if none).
        A departure exactly at ``t`` is already visible as the device being
        down (``alive_mask``), so it does not bound future spans."""
        out = np.full(self.n_devices, np.inf)
        for d, deps in enumerate(self.departures):
            for tl in deps:                 # sorted: first hit wins
                if tl > t:
                    out[d] = tl
                    break
        return out

    def survival(self, t: float, spans: np.ndarray) -> np.ndarray:
        """(D,) survival over per-device spans: ``S_d(t, t + spans[d])``.

        Exact for the scripted component — survival is 1.0 up to (and
        including: the engine's ``ok = completion <= alive_until``) the next
        known departure, 0.0 past it — times the extrapolated stochastic
        survival ``exp(-lam_d * span)``."""
        spans = np.maximum(np.asarray(spans, dtype=np.float64), 0.0)
        if self._lams_arr is not None:
            s = np.exp(-self._lams_arr * spans)
        else:
            s = np.ones(self.n_devices)
        return np.where(t + spans <= self.next_departure(t), s, 0.0)

    def grid(self) -> np.ndarray:
        """(K,) span offsets the sampled tensor is evaluated at."""
        return np.linspace(0.0, self.horizon, self.n_points)

    def sample(self, t: float) -> np.ndarray:
        """(D, K) survival tensor over the horizon grid at instant ``t`` —
        the :class:`FleetSnapshot` ``survival`` leaf."""
        g = self.grid()
        if self._lams_arr is not None:
            s = np.exp(-self._lams_arr[:, None] * g[None, :])
        else:
            s = np.ones((self.n_devices, g.shape[0]))
        nxt = self.next_departure(t)
        return np.where(t + g[None, :] <= nxt[:, None], s, 0.0)


def young_daly_interval(lam: float, ckpt_cost: float) -> float:
    """Optimal checkpoint interval ``sqrt(2 C / lambda)`` for exponential
    failures (Young '74 / Daly '06).  ``lam`` is the failure rate of the
    *job* (sum of member-pod rates for a gang-scheduled job)."""
    if lam <= 0:
        return float("inf")
    if ckpt_cost < 0:
        raise ValueError("checkpoint cost must be >= 0")
    return float(np.sqrt(2.0 * ckpt_cost / lam))


def expected_makespan_with_restarts(
    work: float, lam: float, ckpt_cost: float, interval: Optional[float] = None,
    restart_cost: float = 0.0,
) -> float:
    """Expected wall-clock of ``work`` seconds of compute under exponential
    failures with rate ``lam``, checkpointing every ``interval`` seconds at
    cost ``ckpt_cost`` (Daly's first-order model).

    Used by the FT runtime to pick between checkpoint cadences and to price
    replication-vs-restart trade-offs, and by the tests as an oracle that
    the Young/Daly interval is (near-)optimal."""
    if lam <= 0:
        n_ckpt = 0 if interval in (None, float("inf")) else int(np.ceil(work / interval)) - 1
        return work + max(n_ckpt, 0) * ckpt_cost
    tau = young_daly_interval(lam, ckpt_cost) if interval is None else interval
    tau = min(tau, work)
    if tau <= 0:
        raise ValueError("interval must be positive")
    # Daly's first-order model: a segment holds tau useful seconds + a
    # checkpoint; expected #failures per segment is exp(lam*(tau+C)) - 1 and
    # the expected wall-clock per segment is (1/lam)(exp(lam*(tau+C)) - 1)
    # plus a restart cost per failure.
    fails = np.exp(lam * (tau + ckpt_cost)) - 1.0
    seg = (1.0 / lam) * fails + fails * restart_cost
    n_seg = work / tau
    return float(n_seg * seg)


def gang_failure_rate(lams: Sequence[float]) -> float:
    """A gang-scheduled job fails when *any* member fails: rates add."""
    return float(np.sum(np.asarray(lams, dtype=np.float64)))
