"""Device availability / failure prediction (paper §V-F, Fig. 7, Table IV).

The paper models the probability that an edge device is still available
``t`` seconds after it joined the platform as ``P(ED) = exp(-lambda * t)``,
with per-device failure rates ``lambda`` (Table IV: lambda_1 = mixed
PED+CED, lambda_2 = CED-only, lambda_3 = PED-only).  It validates the model
against a one-month campus mobility trace [13].

For the distributed-training runtime the same exponential model drives two
production decisions:

  * the probability that a (preemptible) pod dies during a task of length L
    — memoryless, so ``F = 1 - exp(-lambda * L)`` — which feeds the
    replication loop of Algorithm 1 and the straggler/backup-task policy;
  * the optimal checkpoint cadence: for exponential failures with MTBF
    ``1/lambda`` and checkpoint write cost ``C`` the Young/Daly interval
    ``sqrt(2 * C / lambda)`` minimises expected lost work.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "availability",
    "prob_fail_during",
    "sample_lifetime",
    "fit_failure_rate",
    "young_daly_interval",
    "expected_makespan_with_restarts",
    "LAMBDA_MIX",
    "LAMBDA_CED",
    "LAMBDA_PED",
]

# Table IV of the paper — failure rates per edge-device class ED0..ED7.
LAMBDA_MIX = np.array(
    [1.5e-6, 1.1e-4, 1.5e-4, 2.4e-5, 9e-6, 3.2e-6, 3.1e-5, 1e-7]
)
LAMBDA_CED = np.array(
    [1.5e-5, 1.1e-5, 1.5e-5, 1.1e-5, 1.8e-5, 1.2e-5, 1.0e-5, 2.0e-5]
)
LAMBDA_PED = np.array(
    [1.5e-4, 1.1e-4, 1.5e-4, 2.4e-4, 9e-4, 3.2e-5, 1.0e-4, 9.0e-4]
)


def availability(lam: float, t: float) -> float:
    """P(device still available ``t`` seconds after joining) = exp(-lam t)."""
    return float(np.exp(-lam * max(t, 0.0)))


def prob_fail_during(lam: float, duration: float) -> float:
    """``F(T_i)``: probability the device fails within ``duration`` seconds.

    The exponential law is memoryless, so the window's start does not
    matter — only its length."""
    return float(1.0 - np.exp(-lam * max(duration, 0.0)))


def prob_fail_during_vec(lam: np.ndarray, duration: np.ndarray) -> np.ndarray:
    return 1.0 - np.exp(-np.asarray(lam) * np.maximum(np.asarray(duration), 0.0))


def sample_lifetime(lam: float, rng: np.random.Generator) -> float:
    """Draw an exponential device lifetime (time from join until it leaves)."""
    if lam <= 0:
        return float("inf")
    return float(rng.exponential(1.0 / lam))


def fit_failure_rate(
    timestamps: Sequence[float], alive: Sequence[bool]
) -> float:
    """MLE of ``lambda`` from an availability trace.

    ``timestamps[i]`` is the elapsed time since join of observation ``i`` and
    ``alive[i]`` whether the device was still present.  Treats each device
    observation as a (possibly right-censored) exponential sample:
    lambda_hat = (#deaths) / (total observed alive-time).  This is what the
    paper fits on the CrowdBind mobility trace (Fig. 7a)."""
    t = np.asarray(timestamps, dtype=np.float64)
    a = np.asarray(alive, dtype=bool)
    if t.shape != a.shape or t.ndim != 1 or t.size == 0:
        raise ValueError("bad trace")
    deaths = int((~a).sum())
    exposure = float(t.sum())
    if exposure <= 0:
        raise ValueError("no exposure time in trace")
    return deaths / exposure


def young_daly_interval(lam: float, ckpt_cost: float) -> float:
    """Optimal checkpoint interval ``sqrt(2 C / lambda)`` for exponential
    failures (Young '74 / Daly '06).  ``lam`` is the failure rate of the
    *job* (sum of member-pod rates for a gang-scheduled job)."""
    if lam <= 0:
        return float("inf")
    if ckpt_cost < 0:
        raise ValueError("checkpoint cost must be >= 0")
    return float(np.sqrt(2.0 * ckpt_cost / lam))


def expected_makespan_with_restarts(
    work: float, lam: float, ckpt_cost: float, interval: Optional[float] = None,
    restart_cost: float = 0.0,
) -> float:
    """Expected wall-clock of ``work`` seconds of compute under exponential
    failures with rate ``lam``, checkpointing every ``interval`` seconds at
    cost ``ckpt_cost`` (Daly's first-order model).

    Used by the FT runtime to pick between checkpoint cadences and to price
    replication-vs-restart trade-offs, and by the tests as an oracle that
    the Young/Daly interval is (near-)optimal."""
    if lam <= 0:
        n_ckpt = 0 if interval in (None, float("inf")) else int(np.ceil(work / interval)) - 1
        return work + max(n_ckpt, 0) * ckpt_cost
    tau = young_daly_interval(lam, ckpt_cost) if interval is None else interval
    tau = min(tau, work)
    if tau <= 0:
        raise ValueError("interval must be positive")
    # Daly's first-order model: a segment holds tau useful seconds + a
    # checkpoint; expected #failures per segment is exp(lam*(tau+C)) - 1 and
    # the expected wall-clock per segment is (1/lam)(exp(lam*(tau+C)) - 1)
    # plus a restart cost per failure.
    fails = np.exp(lam * (tau + ckpt_cost)) - 1.0
    seg = (1.0 / lam) * fails + fails * restart_cost
    n_seg = work / tau
    return float(n_seg * seg)


def gang_failure_rate(lams: Sequence[float]) -> float:
    """A gang-scheduled job fails when *any* member fails: rates add."""
    return float(np.sum(np.asarray(lams, dtype=np.float64)))
