"""Orchestration driver — faithful implementation of Algorithm 1, split into
a pure planning phase and an explicit state-mutation phase.

Given an application DAG, the current cluster state (T_alloc / ED_info /
M_info) and the profiled interference table ED_mc, :func:`orchestrate`
produces a placement ``P(T_i)`` for every task that minimises

    L(T_i) = L(T_i)_{ED_p} + L(M(T_i))_{ED_p} + L(T_i)_d          (Eq. 2)

subject to bandwidth and memory constraints, and (for the IBDASH policy)
reduces the predicted probability of failure by replicating tasks whose
``F(T_i)`` exceeds the threshold ``beta``, for as long as the weighted
joint score

    WeightS = alpha * L~(T_i) + (1 - alpha) * F(T_i)              (line 29)

keeps improving and the replication degree stays below ``gamma``.

API shape (the redesign)
------------------------
* ``plan = orchestrate(app, cluster, now, policy)`` is PURE: it reads
  cluster state, builds one :class:`~repro.core.policy.PolicyContext` per
  task (sharing the expensive T_alloc snapshot + Eq. 1 evaluation across a
  stage's tasks), asks the policy to ``decide``, and assembles a
  :class:`Plan`.  Nothing is written back.
* ``token = cluster.apply(plan)`` records the provisional T_alloc occupancy
  intervals and admits model uploads into the per-device LRU caches —
  exactly the bookkeeping the paper's orchestrator performs — and returns
  an undo token so speculative planning and what-if sweeps can
  ``cluster.undo(token)`` without corrupting state.
* The legacy ``Scheduler.place`` entry point survives as a deprecated,
  now *pure* shim over ``orchestrate`` (it no longer mutates anything).

Notes on fidelity
-----------------
* Stage processing order, the per-task priority queue over devices, the LRU
  model-cache maintenance (lines 19-27) and the replication loop
  (lines 30-41) follow Algorithm 1 line by line.
* ``F(T_i)`` uses the exponential availability model of §V-F: the device
  must stay alive from the moment of allocation until the task's estimated
  completion (stage offset + task latency), and — because PEDs depart
  silently — the orchestrator does *not* get to condition on liveness at
  task start, matching Fig. 7's unconditional availability curves.
* The paper's WeightS mixes seconds with a probability; we normalise the
  latency term by the best candidate latency for the task so that ``alpha``
  sweeps the same [0, 1] range as the paper's Fig. 12a.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .cluster import ClusterState
from .dag import AppDAG
from .policy import (
    IBDASHConfig,
    IBDASHPolicy,
    Policy,
    PolicyContext,
    TaskDecision,
    make_policy,
)

__all__ = [
    "Replica",
    "TaskPlacement",
    "Placement",
    "Plan",
    "orchestrate",
    "Scheduler",
    "IBDASH",
    "IBDASHConfig",
]


@dataclass
class Replica:
    """One placed copy of a task."""

    did: int
    est_exec: float          # L(T_i)_{ED_p}: execution only (Eq. 1)
    est_upload: float        # L(M(T_i))_{ED_p}
    est_transfer: float      # L(T_i)_d
    pred_fail: float         # F(T_i) for this device

    @property
    def est_total(self) -> float:
        return self.est_exec + self.est_upload + self.est_transfer


@dataclass
class TaskPlacement:
    task: str
    ttype: int
    replicas: List[Replica]              # primary first
    est_start: float                     # offset from app arrival (stage barrier)
    # Estimated task latency = the primary replica's total: replicas start
    # concurrently and the task completes on the FIRST success, so extra
    # replicas cost fleet capacity (interference), not direct task latency.
    est_latency: float

    @property
    def pred_fail(self) -> float:
        """Combined failure probability: every replica must fail."""
        p = 1.0
        for r in self.replicas:
            p *= r.pred_fail
        return p


@dataclass
class Placement:
    app_name: str
    tasks: Dict[str, TaskPlacement]
    est_latency: float                   # L(G) = sum of stage maxima (Eq. 3)
    feasible: bool = True
    infeasible_task: Optional[str] = None

    @property
    def pred_app_fail(self) -> float:
        """P_f(G) = 1 - prod_i (1 - F(T_i))   (Eq. 4, independence approx)."""
        p = 1.0
        for tp in self.tasks.values():
            p *= 1.0 - tp.pred_fail
        return 1.0 - p

    def n_replicas(self) -> int:
        return sum(len(tp.replicas) - 1 for tp in self.tasks.values())


@dataclass
class Plan:
    """A pure placement proposal: everything ``ClusterState.apply`` needs to
    record the bookkeeping, and everything callers need to inspect it first.

    ``plan.placement`` is the paper-shaped result; ``plan.app`` / ``plan.now``
    carry the context ``apply`` requires (task specs for model ids and
    interval endpoints)."""

    app: AppDAG
    now: float
    placement: Placement

    # convenience pass-throughs -------------------------------------------------
    @property
    def feasible(self) -> bool:
        return self.placement.feasible

    @property
    def est_latency(self) -> float:
        return self.placement.est_latency

    @property
    def tasks(self) -> Dict[str, TaskPlacement]:
        return self.placement.tasks


def build_contexts(
    app: AppDAG, cluster: ClusterState, now: float
) -> "_ContextBuilder":
    """Incremental :class:`PolicyContext` factory for one application.

    Exposed for tooling (what-if scoring, future jit/vmap batching); the
    main consumer is :func:`orchestrate`."""
    return _ContextBuilder(app, cluster, now)


class _ContextBuilder:
    """Builds per-task PolicyContexts, amortising fleet-wide array work.

    The per-stage pieces — the T_alloc snapshot at the stage's start time,
    the queue-length vector, and the Eq. (1) execution-latency vector per
    task *type* — are computed once and shared by every task in the stage
    (the paper's burst of ~1000 simultaneous instances makes this the hot
    path).  Per-task pieces (upload/transfer vectors, feasibility, pf)
    depend on the task's model/deps and stay per-task.
    """

    def __init__(self, app: AppDAG, cluster: ClusterState, now: float):
        self.app = app
        self.cluster = cluster
        self.now = now
        self.bw = cluster.bandwidths()
        self.lams = cluster.lams()
        self.mem_total = cluster.mem_totals()
        self.classes = cluster.classes()
        self.join = np.array([d.join_time for d in cluster.devices])
        self.n_dev = cluster.n_devices
        # per-stage cache
        self._stage_t: Optional[float] = None
        self._counts: Optional[np.ndarray] = None
        self._queue_len: Optional[np.ndarray] = None
        self._exec_by_type: Dict[int, np.ndarray] = {}

    def begin_stage(self, stage_offset: float) -> None:
        """Refresh the shared snapshot for a stage starting at this offset."""
        t_start = self.now + stage_offset
        if self._stage_t == t_start and self._counts is not None:
            return
        self._stage_t = t_start
        self._counts = np.asarray(self.cluster.counts_at(t_start), dtype=np.float64)
        self._queue_len = self._counts.sum(axis=1)
        self._exec_by_type = {}

    def _exec_lat(self, ttype: int) -> np.ndarray:
        lat = self._exec_by_type.get(ttype)
        if lat is None:
            lat = self.cluster.model.estimate_devices(
                self.classes, ttype, self._counts
            )
            self._exec_by_type[ttype] = lat
        return lat

    def context(
        self,
        tname: str,
        stage_offset: float,
        chosen: Dict[str, TaskPlacement],
    ) -> PolicyContext:
        """The full array-native view for one task (Eq. 1/2 inputs + F(T_i))."""
        spec = self.app.tasks[tname]
        t_start = self._stage_t
        exec_lat = self._exec_lat(spec.ttype)

        # lines 7-10: model upload latency where M(T_i) is missing.
        up = np.zeros(self.n_dev)
        if spec.model_id is not None:
            for did in range(self.n_dev):
                if not self.cluster.devices[did].has_model(spec.model_id):
                    up[did] = spec.model_bytes / self.bw[did]
        # lines 11-14: input data transfer from parents' devices.
        tr = np.zeros(self.n_dev)
        for dep in spec.deps:
            parent = chosen.get(dep)
            if parent is None or not parent.replicas:
                continue
            pdid = parent.replicas[0].did
            add = self.app.tasks[dep].out_bytes / self.bw
            add[pdid] = 0.0
            tr += add
        total = exec_lat + up + tr                      # line 15

        # memory constraint H(T_i) <= H(ED_p) after LRU eviction of cached
        # models (lines 20-23 make cache space reclaimable, so the binding
        # constraint is total memory).
        feasible = self.mem_total >= (spec.mem_bytes + spec.model_bytes)

        # F(T_i): device must survive from allocation until the task's
        # estimated completion (it departs silently, so the orchestrator
        # cannot condition on liveness at start).
        window = (t_start - self.join) + total
        pf = 1.0 - np.exp(-self.lams * window)

        return PolicyContext(
            task=tname,
            ttype=spec.ttype,
            t_start=t_start,
            stage_offset=stage_offset,
            exec_lat=exec_lat,
            upload=up,
            transfer=tr,
            total=total,
            feasible=feasible,
            feasible_ids=np.flatnonzero(feasible),
            pf=pf,
            lams=self.lams,
            join_times=self.join,
            queue_len=self._queue_len,
            counts=self._counts,
            classes=self.classes,
        )


def orchestrate(
    app: AppDAG, cluster: ClusterState, now: float, policy: Policy
) -> Plan:
    """Pure planning: walk the staged DAG (Algorithm 1 lines 3-4), build one
    context per task, let ``policy.decide`` pick devices, and assemble the
    Plan.  Cluster state is only read — call ``cluster.apply(plan)`` to make
    the placement real (or discard the plan for free).
    """
    if isinstance(policy, str):
        policy = make_policy(policy)
    ctxs = _ContextBuilder(app, cluster, now)
    placements: Dict[str, TaskPlacement] = {}
    stage_offset = 0.0

    def infeasible(tname: str) -> Plan:
        return Plan(app=app, now=now, placement=Placement(
            app_name=app.name, tasks=placements, est_latency=0.0,
            feasible=False, infeasible_task=tname,
        ))

    for stage in app.stages:                            # line 3
        ctxs.begin_stage(stage_offset)
        stage_latency = 0.0
        for tname in stage:                             # line 4
            ctx = ctxs.context(tname, stage_offset, placements)
            if ctx.feasible_ids.size == 0:
                return infeasible(tname)
            decision = policy.decide(ctx)
            if not decision.devices:                    # e.g. avail_floor
                return infeasible(tname)
            replicas = [
                Replica(
                    did=int(did),
                    est_exec=float(ctx.exec_lat[did]),
                    est_upload=float(ctx.upload[did]),
                    est_transfer=float(ctx.transfer[did]),
                    pred_fail=float(ctx.pf[did]),
                )
                for did in decision.devices
            ]
            tp = TaskPlacement(
                task=tname,
                ttype=ctx.ttype,
                replicas=replicas,
                est_start=stage_offset,
                est_latency=replicas[0].est_total,
            )
            placements[tname] = tp                      # line 42
            stage_latency = max(stage_latency, tp.est_latency)  # line 44
        stage_offset += stage_latency

    # L(G) = sum of stage maxima (Eq. 3) == the final stage offset.
    return Plan(app=app, now=now, placement=Placement(
        app_name=app.name, tasks=placements, est_latency=stage_offset,
    ))


# -- deprecated one-PR compatibility shims -------------------------------------
class Scheduler:
    """DEPRECATED shim over the pure policy API (kept for one PR).

    ``place`` is now PURE: it plans via :func:`orchestrate` and returns the
    Placement without touching cluster state.  Mutation happens only through
    ``cluster.apply(plan)`` — use :class:`repro.api.Orchestrator` or the
    two-phase protocol directly in new code.
    """

    def __init__(self, policy: Policy):
        self.policy = policy

    @property
    def name(self) -> str:
        return self.policy.name

    def plan(self, app: AppDAG, cluster: ClusterState, now: float) -> Plan:
        return orchestrate(app, cluster, now, self.policy)

    def place(self, app: AppDAG, cluster: ClusterState, now: float) -> Placement:
        return self.plan(app, cluster, now).placement

    # -- legacy helpers (unchanged semantics, still pure) -----------------------
    @staticmethod
    def transfer_latency(
        app: AppDAG, task: str, did: int, chosen: Dict[str, TaskPlacement],
        bandwidth: float,
    ) -> float:
        """L(T_i)_d: move each parent's output from its primary device."""
        total = 0.0
        for dep in app.tasks[task].deps:
            parent = chosen.get(dep)
            if parent is None:
                continue
            if parent.replicas and parent.replicas[0].did != did:
                total += app.tasks[dep].out_bytes / bandwidth
        return total

    @staticmethod
    def upload_latency(
        app: AppDAG, task: str, device, bandwidth: float
    ) -> float:
        """L(M(T_i)): model upload when the artifact is not cached."""
        spec = app.tasks[task]
        if spec.model_id is None or device.has_model(spec.model_id):
            return 0.0
        return spec.model_bytes / bandwidth

    @staticmethod
    def commit(
        app: AppDAG,
        cluster: ClusterState,
        now: float,
        placements: Dict[str, TaskPlacement],
    ) -> Placement:
        """DEPRECATED: assemble a Placement and apply it via the one blessed
        mutation path, ``cluster.apply(plan)``."""
        est_latency = 0.0
        for stage in app.stages:
            stage_lat = 0.0
            for tname in stage:
                tp = placements.get(tname)
                if tp is not None:
                    stage_lat = max(stage_lat, tp.est_latency)
            est_latency += stage_lat
        placement = Placement(
            app_name=app.name, tasks=placements, est_latency=est_latency
        )
        cluster.apply(Plan(app=app, now=now, placement=placement))
        return placement


class IBDASH(Scheduler):
    """DEPRECATED shim: Algorithm 1 now lives in
    :class:`repro.core.policy.IBDASHPolicy`; construct via
    ``make_policy("ibdash", alpha=..., beta=..., gamma=...)``."""

    def __init__(self, config: Optional[IBDASHConfig] = None):
        super().__init__(IBDASHPolicy(config))

    @property
    def cfg(self) -> IBDASHConfig:
        return self.policy.cfg
