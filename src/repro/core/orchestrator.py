"""Orchestration driver — faithful implementation of Algorithm 1, split into
a pure planning phase and an explicit state-mutation phase.

Given an application DAG, the current cluster state (T_alloc / ED_info /
M_info) and the profiled interference table ED_mc, :func:`orchestrate`
produces a placement ``P(T_i)`` for every task that minimises

    L(T_i) = L(T_i)_{ED_p} + L(M(T_i))_{ED_p} + L(T_i)_d          (Eq. 2)

subject to bandwidth and memory constraints, and (for the IBDASH policy)
reduces the predicted probability of failure by replicating tasks whose
``F(T_i)`` exceeds the threshold ``beta``, for as long as the weighted
joint score

    WeightS = alpha * L~(T_i) + (1 - alpha) * F(T_i)              (line 29)

keeps improving and the replication degree stays below ``gamma``.

API shape (the redesign)
------------------------
* ``plan = orchestrate(app, cluster, now, policy)`` is PURE: it reads
  cluster state, builds one ``(B, D)``-shaped
  :class:`~repro.core.batched.BatchedPolicyContext` per stage (sharing the
  expensive T_alloc snapshot + Eq. 1 evaluation across the stage's tasks),
  asks the policy to ``decide_batch``, and assembles a :class:`Plan`.
  Nothing is written back.
* ``plans = orchestrate_batch(apps, cluster, policy, times=...)`` fuses a
  whole arrival wave: one batched context — and for the registered
  policies one jitted ``jax.numpy`` kernel call — per wave-stage places
  every task of ~1000 simultaneous instances at once, bit-identically to
  looping the scalar rule over the same rows.
* ``token = cluster.apply(plan)`` records the provisional T_alloc occupancy
  intervals and admits model uploads into the per-device LRU caches —
  exactly the bookkeeping the paper's orchestrator performs — and returns
  an undo token so speculative planning and what-if sweeps can
  ``cluster.undo(token)`` without corrupting state.
* The seed's mutate-inside-``place()`` ``Scheduler`` classes are gone:
  every scheme is a registry policy (``make_policy(name, ...)``) driven
  through this pure two-phase protocol.  The verbatim seed implementations
  survive only in ``tests/_legacy_reference.py`` for the parity tests.

Notes on fidelity
-----------------
* Stage processing order, the per-task priority queue over devices, the LRU
  model-cache maintenance (lines 19-27) and the replication loop
  (lines 30-41) follow Algorithm 1 line by line.
* ``F(T_i)`` uses the exponential availability model of §V-F: the device
  must stay alive from the moment of allocation until the task's estimated
  completion (stage offset + task latency), and — because PEDs depart
  silently — the orchestrator does *not* get to condition on liveness at
  task start, matching Fig. 7's unconditional availability curves.
* The paper's WeightS mixes seconds with a probability; we normalise the
  latency term by the best candidate latency for the task so that ``alpha``
  sweeps the same [0, 1] range as the paper's Fig. 12a.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .batched import BatchedPolicyContext, FleetSnapshot
from .cluster import ClusterState
from .dag import AppDAG
from .policy import (
    IBDASHConfig,
    Policy,
    PolicyContext,
    TaskDecision,
    make_policy,
)

__all__ = [
    "Replica",
    "TaskPlacement",
    "Placement",
    "Plan",
    "orchestrate",
    "orchestrate_batch",
    "IBDASHConfig",
]


@dataclass(slots=True)
class Replica:
    """One placed copy of a task."""

    did: int
    est_exec: float          # L(T_i)_{ED_p}: execution only (Eq. 1)
    est_upload: float        # L(M(T_i))_{ED_p}
    est_transfer: float      # L(T_i)_d
    pred_fail: float         # F(T_i) for this device

    @property
    def est_total(self) -> float:
        return self.est_exec + self.est_upload + self.est_transfer


@dataclass(slots=True)
class TaskPlacement:
    task: str
    ttype: int
    replicas: List[Replica]              # primary first
    est_start: float                     # offset from app arrival (stage barrier)
    # Estimated task latency = the primary replica's total: replicas start
    # concurrently and the task completes on the FIRST success, so extra
    # replicas cost fleet capacity (interference), not direct task latency.
    est_latency: float

    @property
    def pred_fail(self) -> float:
        """Combined failure probability: every replica must fail."""
        p = 1.0
        for r in self.replicas:
            p *= r.pred_fail
        return p


@dataclass
class Placement:
    app_name: str
    tasks: Dict[str, TaskPlacement]
    est_latency: float                   # L(G) = sum of stage maxima (Eq. 3)
    feasible: bool = True
    infeasible_task: Optional[str] = None

    @property
    def pred_app_fail(self) -> float:
        """P_f(G) = 1 - prod_i (1 - F(T_i))   (Eq. 4, independence approx)."""
        p = 1.0
        for tp in self.tasks.values():
            p *= 1.0 - tp.pred_fail
        return 1.0 - p

    def n_replicas(self) -> int:
        return sum(len(tp.replicas) - 1 for tp in self.tasks.values())


@dataclass
class Plan:
    """A pure placement proposal: everything ``ClusterState.apply`` needs to
    record the bookkeeping, and everything callers need to inspect it first.

    ``plan.placement`` is the paper-shaped result; ``plan.app`` / ``plan.now``
    carry the context ``apply`` requires (task specs for model ids and
    interval endpoints)."""

    app: AppDAG
    now: float
    placement: Placement

    # convenience pass-throughs -------------------------------------------------
    @property
    def feasible(self) -> bool:
        return self.placement.feasible

    @property
    def est_latency(self) -> float:
        return self.placement.est_latency

    @property
    def tasks(self) -> Dict[str, TaskPlacement]:
        return self.placement.tasks

    @property
    def infeasible_task(self) -> Optional[str]:
        return self.placement.infeasible_task


# A wave-stage row is the lightweight tuple (state, tname, t_start, bucket);
# at ~6000 rows per 1000-instance wave even dataclass construction overhead
# is measurable, so rows stay plain tuples.


@dataclass(slots=True)
class _AppPlanState:
    """Mutable planning state of one application inside a wave."""

    app: AppDAG
    arrival: float
    n_stages: int
    placements: Dict[str, TaskPlacement] = field(default_factory=dict)
    # Already-decided tasks (completed / in-flight on a replan): their
    # placements price downstream transfers but are never re-decided.
    pinned: frozenset = frozenset()
    stage_offset: float = 0.0
    stage_latency: float = 0.0
    alive: bool = True
    infeasible_task: Optional[str] = None


class _WaveContextBuilder:
    """Builds :class:`BatchedPolicyContext` tensors for a wave of tasks,
    amortising fleet-wide array work.

    The shared pieces — the T_alloc snapshot + queue lengths at each start
    time, the Eq. (1) execution-latency vector per ``(time, task type)``,
    and the per-model "not cached" masks — are computed once per wave and
    reused by every row (the paper's burst of ~1000 simultaneous instances
    makes this the hot path).  Per-row pieces (upload/transfer vectors,
    feasibility, pf) are assembled as ``(B, D)`` tensors in one shot.
    """

    def __init__(self, cluster: ClusterState, now: float = 0.0):
        self.cluster = cluster
        # the link model stays factorized: no (D, D) matrix is materialized
        # anywhere in a wave — transfer_vec slices per-sender rows lazily
        self.upload_bw = cluster.upload_bw() # (D,) artifact-path bandwidth
        self.lams = cluster.lams()
        self.mem_total = cluster.mem_totals()
        self.classes = cluster.classes()
        self.join = cluster.join_times()
        self.n_dev = cluster.n_devices
        # Devices already departed at the planning instant are masked out of
        # every feasibility row: the orchestrator can observe a PAST
        # departure (missed heartbeats), while future deaths remain priced
        # probabilistically through pf (silent-departure model).  Constant
        # for the whole wave — churn events cannot fire inside one pure
        # planning call (and would bump topology_version if they did).
        self.alive = np.asarray(cluster.alive_mask(float(now)), dtype=bool)
        # Installed availability forecast (None = uniform survival): per-
        # candidate survival over each task's span is priced EXACTLY from
        # it (the sampled snapshot tensor is only the pytree representation).
        self.forecast = getattr(cluster, "forecast", None)
        self._surv_sample: Dict[float, Tuple[np.ndarray, np.ndarray]] = {}
        # Wave-level caches, scoped to ONE snapshot (planning is pure:
        # cluster state cannot change under us, so cached vectors stay valid
        # for the whole wave; `_topo_version` makes any violation loud).
        # Time-dependent entries are keyed by T_alloc BUCKET, not by exact
        # time — `counts_at` only reads the bucket, so this is exact and
        # collapses the ~B distinct per-app stage offsets of a big wave onto
        # a handful of shared snapshots.
        self._topo_version = cluster.topology_version
        self._counts: Dict[int, np.ndarray] = {}
        self._queue: Dict[int, np.ndarray] = {}
        self._exec: Dict[Tuple[int, int], np.ndarray] = {}
        self._missing: Dict[str, np.ndarray] = {}
        self._upload: Dict[Tuple[str, float], np.ndarray] = {}
        self._transfer: Dict[Tuple[float, int], np.ndarray] = {}
        self._feasible: Dict[float, np.ndarray] = {}
        self._feasible_any: Dict[float, bool] = {}

    def counts_at_bucket(self, bkt: int) -> np.ndarray:
        c = self._counts.get(bkt)
        if c is None:
            c = np.maximum(self.cluster.alloc[:, :, bkt], 0.0).astype(np.float64)
            self._counts[bkt] = c
            self._queue[bkt] = c.sum(axis=1)
        return c

    def exec_lat(self, bkt: int, ttype: int) -> np.ndarray:
        key = (bkt, ttype)
        lat = self._exec.get(key)
        if lat is None:
            lat = self.cluster.model.estimate_devices(
                self.classes, ttype, self.counts_at_bucket(bkt)
            )
            self._exec[key] = lat
        return lat

    def missing_model(self, model_id: str) -> np.ndarray:
        """(D,) bool: devices that would have to upload ``model_id``."""
        m = self._missing.get(model_id)
        if m is None:
            m = np.array(
                [not d.has_model(model_id) for d in self.cluster.devices]
            )
            self._missing[model_id] = m
        return m

    def upload_row(self, model_id: str, model_bytes: float) -> np.ndarray:
        """(D,) model-upload latency vector (lines 7-10), cached per
        (model, size) — tasks may disagree on a shared artifact's size.
        Uploads travel the device <-> artifact-server link (the
        ``model_source`` row of the link matrix; each device's downlink on
        legacy fleets without one)."""
        key = (model_id, model_bytes)
        u = self._upload.get(key)
        if u is None:
            u = np.where(
                self.missing_model(model_id), model_bytes / self.upload_bw, 0.0
            )
            self._upload[key] = u
        return u

    def transfer_vec(self, out_bytes: float, src: int) -> np.ndarray:
        """(D,) transfer-cost row for one parent output moved FROM ``src``:
        ``out_bytes / bw_eff[src, d]`` — the sender's uplink, the receiver's
        downlink, and the tier backhaul all bound the link (Eq. 2's
        ``L(T_i)_d`` priced on the actual path, not the endpoint).  The
        sender row is derived lazily from the factorized link model
        (``cluster.link_row``); its ``src`` entry is +inf, so staying on
        ``src`` costs exactly 0."""
        key = (out_bytes, src)
        v = self._transfer.get(key)
        if v is None:
            v = out_bytes / self.cluster.link_row(src)
            self._transfer[key] = v
        return v

    def surv_leaves(self, t: float) -> Tuple[np.ndarray, np.ndarray]:
        """The snapshot's (surv_grid, survival) forecast leaves at ``t``,
        cached per planning instant (waves share a handful of times)."""
        cached = self._surv_sample.get(t)
        if cached is None:
            if self.forecast is None:
                cached = (np.zeros(1), np.ones((self.n_dev, 1)))
            else:
                cached = (self.forecast.grid(), self.forecast.sample(t))
            self._surv_sample[t] = cached
        return cached

    def fleet(self, t: float) -> FleetSnapshot:
        """Struct-of-arrays snapshot of the fleet at time ``t`` (delegates
        to the one construction site, reusing the wave's cached arrays)."""
        bkt = self.cluster.bucket(t)
        surv_grid, survival = self.surv_leaves(t)
        return self.cluster.snapshot(
            t, counts=self.counts_at_bucket(bkt), join_times=self.join,
            alive=self.alive, surv_grid=surv_grid, survival=survival,
        )

    def feasible_row(self, spec) -> np.ndarray:
        # memory constraint H(T_i) <= H(ED_p) after LRU eviction of cached
        # models (lines 20-23 make cache space reclaimable, so the binding
        # constraint is total memory).
        key = spec.mem_bytes + spec.model_bytes
        f = self._feasible.get(key)
        if f is None:
            f = (self.mem_total >= key) & self.alive
            self._feasible[key] = f
            self._feasible_any[key] = bool(f.any())
        return f

    def feasible_any(self, spec) -> bool:
        key = spec.mem_bytes + spec.model_bytes
        if key not in self._feasible_any:
            self.feasible_row(spec)
        return self._feasible_any[key]

    def batch(self, rows: List[tuple]) -> BatchedPolicyContext:
        """The deduplicated struct-of-arrays view for one wave-stage.

        One light Python pass per row resolves the cached ingredient
        vectors (execution by ``(bucket, ttype)``, upload by model,
        feasibility by memory footprint, transfer by parent output/device)
        and assigns each row to a pool entry keyed by the full ingredient
        tuple + exact start time — everything a context row is a function
        of.  The ``(G, D)`` pool tensors (G = distinct rows, typically a
        handful per wave of a 1000-instance burst) are then assembled once;
        per-row ``(B, D)`` views materialise lazily only if a policy needs
        them.
        """
        if self.cluster.topology_version != self._topo_version:
            raise RuntimeError(
                "cluster topology changed under a live wave builder; the "
                "builder's caches are scoped to one snapshot — plan the next "
                "wave with a fresh orchestrate/orchestrate_batch call"
            )
        B, D = len(rows), self.n_dev
        tasks = []
        ttypes = np.empty(B, dtype=np.int64)
        t_start = np.fromiter((r[2] for r in rows), np.float64, count=B)
        stage_offset = np.fromiter(
            (r[0].stage_offset for r in rows), np.float64, count=B
        )
        buckets = np.fromiter((r[3] for r in rows), np.int64, count=B)

        exec_keys: Dict[Tuple[int, int], int] = {}
        up_keys: Dict[Tuple[Optional[str], float], int] = {(None, 0.0): 0}
        feas_keys: Dict[float, int] = {}
        tvec_keys: Dict[Tuple[float, int], int] = {}
        pool_keys: Dict[tuple, int] = {}
        exec_mats: List[np.ndarray] = []
        up_mats: List[np.ndarray] = [np.zeros(D)]
        feas_mats: List[np.ndarray] = []
        tvecs: List[np.ndarray] = []
        pool_specs: List[tuple] = []      # (exec_i, up_i, feas_i, contrib, t)
        pool_first: List[int] = []
        row_pool = np.empty(B, np.int64)

        for b, (state, tname, t, bkt) in enumerate(rows):
            spec = state.app.tasks[tname]
            tasks.append(tname)
            ttypes[b] = spec.ttype
            k = (bkt, spec.ttype)
            ei = exec_keys.get(k)
            if ei is None:
                ei = exec_keys[k] = len(exec_mats)
                exec_mats.append(self.exec_lat(bkt, spec.ttype))
            # lines 7-10: model upload latency where M(T_i) is missing.
            mid = spec.model_id
            uk = (mid, spec.model_bytes) if mid is not None else (None, 0.0)
            ui = up_keys.get(uk)
            if ui is None:
                ui = up_keys[uk] = len(up_mats)
                up_mats.append(self.upload_row(mid, spec.model_bytes))
            mk = spec.mem_bytes + spec.model_bytes
            fi = feas_keys.get(mk)
            if fi is None:
                fi = feas_keys[mk] = len(feas_mats)
                feas_mats.append(self.feasible_row(spec))
            # lines 11-14: input data transfer from parents' devices, each
            # priced over the sender's row of the link matrix.
            contrib: Tuple[int, ...] = ()
            if spec.deps:
                chosen = state.placements
                acc = []
                for dep in spec.deps:
                    parent = chosen.get(dep)
                    if parent is None or not parent.replicas:
                        continue
                    ob = state.app.tasks[dep].out_bytes
                    pdid = parent.replicas[0].did
                    vk = (ob, pdid)
                    vi = tvec_keys.get(vk)
                    if vi is None:
                        vi = tvec_keys[vk] = len(tvecs)
                        tvecs.append(self.transfer_vec(ob, pdid))
                    acc.append(vi)
                contrib = tuple(acc)
            kk = (ei, ui, fi, contrib, t)
            g = pool_keys.get(kk)
            if g is None:
                g = pool_keys[kk] = len(pool_specs)
                pool_specs.append(kk)
                pool_first.append(b)
            row_pool[b] = g

        G = len(pool_specs)
        exec_pool = np.stack([exec_mats[s[0]] for s in pool_specs])
        upload_pool = np.stack([up_mats[s[1]] for s in pool_specs])
        feasible_pool = np.stack([feas_mats[s[2]] for s in pool_specs])
        transfer_pool = np.zeros((G, D))
        for g, (_ei, _ui, _fi, contrib, _t) in enumerate(pool_specs):
            for vi in contrib:
                # the link-matrix diagonal is +inf, so the sender's own
                # entry is already an exact 0.0 — no copy-and-zero needed
                transfer_pool[g] += tvecs[vi]

        total_pool = exec_pool + upload_pool + transfer_pool    # line 15

        # F(T_i): device must survive from allocation until the task's
        # estimated completion (it departs silently, so the orchestrator
        # cannot condition on liveness at start).
        pool_first_arr = np.asarray(pool_first, dtype=np.int64)
        t_pool = t_start[pool_first_arr]
        window = (t_pool[:, None] - self.join[None, :]) + total_pool
        pf_pool = 1.0 - np.exp(-self.lams[None, :] * window)

        # Forecast survival over each candidate's estimated execution span,
        # evaluated exactly (scripted windows are step functions — sampling
        # a grid would smear the cliff the churn_aware guard relies on).
        if self.forecast is None:
            survival_pool = np.ones_like(total_pool)
        else:
            survival_pool = np.empty_like(total_pool)
            for g in range(G):
                survival_pool[g] = self.forecast.survival(
                    float(t_pool[g]), total_pool[g]
                )

        # Per-row Task_info snapshots: rows sharing a T_alloc bucket share
        # one pool entry; (B, D, N) views materialise lazily on access.
        uniq, inv = np.unique(buckets, return_inverse=True)
        counts_pool = np.stack([self.counts_at_bucket(int(u)) for u in uniq])
        queue_pool = np.stack([self._queue[int(u)] for u in uniq])

        return BatchedPolicyContext(
            tasks=tuple(tasks),
            ttypes=ttypes,
            t_start=t_start,
            stage_offset=stage_offset,
            row_pool=row_pool,
            pool_first=pool_first_arr,
            exec_pool=exec_pool,
            upload_pool=upload_pool,
            transfer_pool=transfer_pool,
            total_pool=total_pool,
            feasible_pool=feasible_pool,
            pf_pool=pf_pool,
            survival_pool=survival_pool,
            counts_pool=counts_pool,
            queue_pool=queue_pool,
            bucket_inv=inv,
            fleet=self.fleet(rows[0][2]),
        )


def orchestrate_batch(
    apps: Sequence[AppDAG],
    cluster: ClusterState,
    policy: Policy,
    *,
    now: float = 0.0,
    times: Optional[Sequence[float]] = None,
    batched: bool = True,
    pinned: Optional[Sequence[Optional[Dict[str, TaskPlacement]]]] = None,
) -> List[Plan]:
    """Pure fused planning for a whole arrival wave of B applications.

    Walks all apps' staged DAGs in lock-step (wave-stage s = stage s of
    every app), builds ONE :class:`BatchedPolicyContext` per wave-stage, and
    lets ``policy.decide_batch`` place every task of the wave in one fused
    call.  Cluster state is only read; apply each returned plan (or none)
    explicitly.

    Semantics: every plan is computed against the SAME cluster snapshot —
    plans do not see each other's provisional T_alloc occupancy, which is
    exactly the "burst of simultaneous arrivals" reading of the paper's
    §V-G protocol (for arrivals far apart in time, plan sequentially and
    apply in between instead).  Rows are ordered app-major within each
    wave-stage, and stateful policies consume their rng/cursor state once
    per row in that order, so ``batched=False`` (loop ``policy.decide`` over
    the same rows) is bit-identical — that is the parity contract the tests
    pin down.  For stateless policies the result also equals looping
    ``orchestrate`` per app without intermediate applies.

    An application whose task has no memory-feasible live device is marked
    infeasible at that task and drops out of later wave-stages; its rows
    are screened out *before* the policy sees the batch, so stateful
    policies consume nothing for them (matching the scalar path, which
    returns before calling ``decide``).  Devices already departed at the
    wave's planning instant (the earliest arrival) are masked infeasible
    for every row — a policy can never select a dead device.

    ``pinned`` (aligned with ``apps``; entries may be None) carries task
    placements that are already decided — completed or in-flight tasks of a
    partially-executed instance.  Pinned tasks are not re-decided and emit
    no rows (stateful policies consume nothing for them), but their chosen
    devices still price the transfer costs of downstream tasks, and the
    returned plan contains ONLY the newly planned tasks — this is the
    replan recovery strategy's substrate (re-place a dead task and the
    not-yet-started remainder of its DAG on the live sub-fleet).
    """
    if isinstance(policy, str):
        policy = make_policy(policy)
    if times is None:
        times = [float(now)] * len(apps)
    elif len(times) != len(apps):
        raise ValueError("apps and times must have equal length")
    if pinned is None:
        pinned = [None] * len(apps)
    elif len(pinned) != len(apps):
        raise ValueError("apps and pinned must have equal length")

    builder = _WaveContextBuilder(
        cluster, now=min(times, default=float(now))
    )
    bucket = cluster.bucket
    states = [
        _AppPlanState(
            app=app, arrival=float(t), n_stages=app.n_stages,
            placements=dict(pin) if pin else {},
            pinned=frozenset(pin) if pin else frozenset(),
        )
        for app, t, pin in zip(apps, times, pinned)
    ]
    max_stages = max((st.n_stages for st in states), default=0)

    for s in range(max_stages):                         # line 3 (per wave)
        rows: List[tuple] = []
        for st in states:
            if not st.alive or s >= st.n_stages:
                continue
            st.stage_latency = 0.0
            t_start = st.arrival + st.stage_offset
            bkt = bucket(t_start)
            for tname in st.app.stages[s]:              # line 4
                if tname not in st.pinned:
                    rows.append((st, tname, t_start, bkt))

        # Screen memory-infeasible rows before the policy sees the batch:
        # the app dies at its first infeasible task and its later rows are
        # excluded (stateful policies must not consume state for them).
        kept: List[tuple] = []
        for row in rows:
            st = row[0]
            if not st.alive:
                continue
            if not builder.feasible_any(st.app.tasks[row[1]]):
                st.alive = False
                st.infeasible_task = row[1]
            else:
                kept.append(row)
        if not kept:
            continue

        batch = builder.batch(kept)
        if batched:
            decisions = policy.decide_batch(batch).devices
        else:
            # the scalar reference: same rows, same order, one decide() each
            decisions = tuple(
                policy.decide(batch.row(b)).devices
                for b in range(batch.n_rows)
            )

        # Bulk-extract the primary replica's estimate columns (one gather +
        # one C-level tolist per tensor instead of 4B numpy scalar reads).
        Bk = len(kept)
        prim = np.fromiter(
            (d[0] if d else 0 for d in decisions), np.int64, count=Bk
        )
        ex_p, up_p, tr_p, pf_p = batch.primary_estimates(prim)
        ttypes_l = batch.ttypes.tolist()

        # Apps that died during SCREENING still record their earlier kept
        # rows (the scalar path places a stage's tasks one by one and keeps
        # them when a later task turns out infeasible); apps that die here,
        # on an empty DECISION, skip their remaining rows.
        dead_in_record = set()
        for b, row in enumerate(kept):
            st = row[0]
            if id(st) in dead_in_record:
                continue                 # app died at an earlier row
            devs = decisions[b]
            if not devs:                 # e.g. the IBDASH avail_floor guard
                st.alive = False
                st.infeasible_task = row[1]
                dead_in_record.add(id(st))
                continue
            replicas = [Replica(int(devs[0]), ex_p[b], up_p[b], tr_p[b], pf_p[b])]
            for did in devs[1:]:
                replicas.append(Replica(int(did), *batch.estimates_at(b, did)))
            tp = TaskPlacement(
                task=row[1],
                ttype=ttypes_l[b],
                replicas=replicas,
                est_start=st.stage_offset,
                est_latency=replicas[0].est_total,
            )
            st.placements[row[1]] = tp                  # line 42
            st.stage_latency = max(st.stage_latency, tp.est_latency)  # l.44

        for st in states:
            if st.alive and s < st.n_stages:
                st.stage_offset += st.stage_latency

    # L(G) = sum of stage maxima (Eq. 3) == the final stage offset.  On a
    # replan, pinned tasks drop out: the plan holds only the newly placed
    # remainder (apply must not re-record the pinned tasks' occupancy).
    return [
        Plan(app=st.app, now=st.arrival, placement=Placement(
            app_name=st.app.name,
            tasks=(
                {k: v for k, v in st.placements.items() if k not in st.pinned}
                if st.pinned else st.placements
            ),
            est_latency=st.stage_offset if st.alive else 0.0,
            feasible=st.alive,
            infeasible_task=st.infeasible_task,
        ))
        for st in states
    ]


def orchestrate(
    app: AppDAG, cluster: ClusterState, now: float, policy: Policy,
    *, batched: bool = True,
    pinned: Optional[Dict[str, TaskPlacement]] = None,
) -> Plan:
    """Pure planning: walk the staged DAG (Algorithm 1 lines 3-4), build one
    batched context per stage, let the policy pick devices (one
    ``decide_batch`` call per stage, or ``decide`` per task with
    ``batched=False`` — the two are bit-identical), and assemble the Plan.
    Cluster state is only read — call ``cluster.apply(plan)`` to make the
    placement real (or discard the plan for free).  ``pinned`` placements
    are kept as-is and only the remaining tasks are planned (the replan
    recovery path; see :func:`orchestrate_batch`).
    """
    return orchestrate_batch(
        [app], cluster, policy, times=[now], batched=batched,
        pinned=[pinned] if pinned else None,
    )[0]

