"""IBDASH orchestration — faithful implementation of Algorithm 1.

Given an application DAG, the current cluster state (T_alloc / ED_info /
M_info) and the profiled interference table ED_mc, produce a placement
``P(T_i)`` for every task that greedily minimises

    L(T_i) = L(T_i)_{ED_p} + L(M(T_i))_{ED_p} + L(T_i)_d          (Eq. 2)

subject to bandwidth and memory constraints, then reduces the predicted
probability of failure by replicating tasks whose ``F(T_i)`` exceeds the
threshold ``beta`` onto the next-best devices, for as long as the weighted
joint score

    WeightS = alpha * L~(T_i) + (1 - alpha) * F(T_i)              (line 29)

keeps improving and the replication degree stays below ``gamma``.

Notes on fidelity
-----------------
* Stage processing order, the per-task priority queue over devices, the LRU
  model-cache maintenance (lines 19-27) and the replication loop
  (lines 30-41) follow Algorithm 1 line by line.
* ``F(T_i)`` uses the exponential availability model of §V-F: the device
  must stay alive from the moment of allocation until the task's estimated
  completion (stage offset + task latency), and — because PEDs depart
  silently — the orchestrator does *not* get to condition on liveness at
  task start, matching Fig. 7's unconditional availability curves.
* The paper's WeightS mixes seconds with a probability; we normalise the
  latency term by the best candidate latency for the task so that ``alpha``
  sweeps the same [0, 1] range as the paper's Fig. 12a.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .availability import prob_fail_during
from .cluster import ClusterState
from .dag import AppDAG

__all__ = ["Replica", "TaskPlacement", "Placement", "Scheduler", "IBDASH"]


@dataclass
class Replica:
    """One placed copy of a task."""

    did: int
    est_exec: float          # L(T_i)_{ED_p}: execution only (Eq. 1)
    est_upload: float        # L(M(T_i))_{ED_p}
    est_transfer: float      # L(T_i)_d
    pred_fail: float         # F(T_i) for this device

    @property
    def est_total(self) -> float:
        return self.est_exec + self.est_upload + self.est_transfer


@dataclass
class TaskPlacement:
    task: str
    ttype: int
    replicas: List[Replica]              # primary first
    est_start: float                     # offset from app arrival (stage barrier)
    # Estimated task latency = the primary replica's total: replicas start
    # concurrently and the task completes on the FIRST success, so extra
    # replicas cost fleet capacity (interference), not direct task latency.
    est_latency: float

    @property
    def pred_fail(self) -> float:
        """Combined failure probability: every replica must fail."""
        p = 1.0
        for r in self.replicas:
            p *= r.pred_fail
        return p


@dataclass
class Placement:
    app_name: str
    tasks: Dict[str, TaskPlacement]
    est_latency: float                   # L(G) = sum of stage maxima (Eq. 3)
    feasible: bool = True
    infeasible_task: Optional[str] = None

    @property
    def pred_app_fail(self) -> float:
        """P_f(G) = 1 - prod_i (1 - F(T_i))   (Eq. 4, independence approx)."""
        p = 1.0
        for tp in self.tasks.values():
            p *= 1.0 - tp.pred_fail
        return 1.0 - p

    def n_replicas(self) -> int:
        return sum(len(tp.replicas) - 1 for tp in self.tasks.values())


class Scheduler:
    """Interface shared by IBDASH and every baseline.

    ``place`` may mutate cluster state: it records provisional occupancy
    intervals in T_alloc (exactly the paper's bookkeeping) and admits model
    uploads into the per-device LRU caches."""

    name: str = "base"

    def place(self, app: AppDAG, cluster: ClusterState, now: float) -> Placement:
        raise NotImplementedError

    # -- shared helpers ---------------------------------------------------------
    @staticmethod
    def transfer_latency(
        app: AppDAG, task: str, did: int, chosen: Dict[str, TaskPlacement],
        bandwidth: float,
    ) -> float:
        """L(T_i)_d: move each parent's output from its primary device."""
        total = 0.0
        for dep in app.tasks[task].deps:
            parent = chosen.get(dep)
            if parent is None:
                continue
            if parent.replicas and parent.replicas[0].did != did:
                total += app.tasks[dep].out_bytes / bandwidth
        return total

    @staticmethod
    def upload_latency(
        app: AppDAG, task: str, device, bandwidth: float
    ) -> float:
        """L(M(T_i)): model upload when the artifact is not cached."""
        spec = app.tasks[task]
        if spec.model_id is None or device.has_model(spec.model_id):
            return 0.0
        return spec.model_bytes / bandwidth

    @staticmethod
    def commit(
        app: AppDAG,
        cluster: ClusterState,
        now: float,
        placements: Dict[str, TaskPlacement],
    ) -> Placement:
        """Record occupancy intervals + model-cache effects for a finished
        placement and assemble the Placement result."""
        est_latency = 0.0
        stage_offsets: Dict[int, float] = {}
        offset = 0.0
        for si, stage in enumerate(app.stages):
            stage_offsets[si] = offset
            stage_lat = 0.0
            for tname in stage:
                tp = placements.get(tname)
                if tp is None:
                    continue
                stage_lat = max(stage_lat, tp.est_latency)
            offset += stage_lat
        est_latency = offset
        for tname, tp in placements.items():
            spec = app.tasks[tname]
            start = now + tp.est_start
            for rep in tp.replicas:
                cluster.add_interval(
                    rep.did, spec.ttype, start, start + rep.est_total
                )
                dev = cluster.devices[rep.did]
                if spec.model_id is not None:
                    dev.admit_model(spec.model_id, spec.model_bytes)
        return Placement(app_name=app.name, tasks=placements, est_latency=est_latency)


@dataclass
class IBDASHConfig:
    alpha: float = 0.5     # joint optimisation weight (Eq. 5)
    beta: float = 0.1      # probability-of-failure threshold
    gamma: int = 3         # replication degree cap
    # When True the orchestrator drops devices whose *predicted* availability
    # is below ``avail_floor`` from the candidate set entirely (a beyond-paper
    # guard; disabled by default to stay faithful).
    avail_floor: float = 0.0


class IBDASH(Scheduler):
    """Algorithm 1."""

    name = "ibdash"

    def __init__(self, config: Optional[IBDASHConfig] = None):
        self.cfg = config or IBDASHConfig()

    def place(self, app: AppDAG, cluster: ClusterState, now: float) -> Placement:
        cfg = self.cfg
        placements: Dict[str, TaskPlacement] = {}
        bw = cluster.bandwidths()
        lams = cluster.lams()
        stage_offset = 0.0

        mem_total = cluster.mem_totals()
        join = np.array([d.join_time for d in cluster.devices])
        n_dev = cluster.n_devices

        for si, stage in enumerate(app.stages):                 # line 3
            stage_latency = 0.0
            for tname in stage:                                 # line 4
                spec = app.tasks[tname]
                t_start = now + stage_offset
                # Eq. (1) for every device at the task's estimated start
                # (lines 5-6, vectorised over the fleet).
                exec_lat = cluster.estimate_exec(spec.ttype, t_start)

                # lines 7-10: model upload latency where M(T_i) is missing.
                up = np.zeros(n_dev)
                if spec.model_id is not None:
                    for did in range(n_dev):
                        if not cluster.devices[did].has_model(spec.model_id):
                            up[did] = spec.model_bytes / bw[did]
                # lines 11-14: input data transfer from parents' devices.
                tr = np.zeros(n_dev)
                for dep in spec.deps:
                    parent = placements.get(dep)
                    if parent is None or not parent.replicas:
                        continue
                    pdid = parent.replicas[0].did
                    add = app.tasks[dep].out_bytes / bw
                    add[pdid] = 0.0
                    tr += add
                total = exec_lat + up + tr                      # line 15

                # memory constraint H(T_i) <= H(ED_p) after LRU eviction of
                # cached models (lines 20-23 make cache space reclaimable, so
                # the binding constraint is total memory).
                feasible = mem_total >= (spec.mem_bytes + spec.model_bytes)
                if cfg.avail_floor > 0.0:
                    feasible &= np.exp(-lams * (t_start - join)) >= cfg.avail_floor
                if not feasible.any():
                    return Placement(
                        app_name=app.name, tasks=placements, est_latency=0.0,
                        feasible=False, infeasible_task=tname,
                    )

                # F(T_i): device must survive from allocation until the
                # task's estimated completion (it departs silently, so the
                # orchestrator cannot condition on liveness at start).
                window = (t_start - join) + total
                pf = 1.0 - np.exp(-lams * window)

                # line 16-18: priority queue == ascending order over L(T_i).
                cand = np.flatnonzero(feasible)
                order = cand[np.argsort(total[cand], kind="stable")]

                def mk(did: int) -> Replica:
                    return Replica(
                        did=int(did), est_exec=float(exec_lat[did]),
                        est_upload=float(up[did]), est_transfer=float(tr[did]),
                        pred_fail=float(pf[did]),
                    )

                best = mk(order[0])                             # line 18
                best_total = float(total[order[0]])
                l_ref = max(best_total, 1e-9)
                replicas = [best]
                comb_fail = best.pred_fail
                # line 29: weighted joint score, latency normalised by the
                # best candidate so alpha sweeps [0,1] meaningfully.
                weight_s = cfg.alpha * (best_total / l_ref) + (1 - cfg.alpha) * comb_fail

                t_rep = 0
                qi = 1
                while comb_fail >= cfg.beta and t_rep < cfg.gamma and qi < order.size:  # line 30
                    did = order[qi]                             # line 31
                    qi += 1
                    cand_total = float(total[did])
                    new_fail = comb_fail * float(pf[did])
                    weight_new = cfg.alpha * (cand_total / l_ref) + (1 - cfg.alpha) * new_fail
                    if weight_new <= weight_s:                  # line 34
                        replicas.append(mk(did))                # line 35
                        comb_fail = new_fail
                        weight_s = weight_new
                        t_rep += 1                              # line 37
                    else:
                        break                                   # line 39

                tp = TaskPlacement(
                    task=tname,
                    ttype=spec.ttype,
                    replicas=replicas,
                    est_start=stage_offset,
                    est_latency=replicas[0].est_total,
                )
                placements[tname] = tp                          # line 42
                stage_latency = max(stage_latency, tp.est_latency)  # line 44
            stage_offset += stage_latency
        return self.commit(app, cluster, now, placements)       # line 46/48
