"""Cluster state visible to the orchestrator.

Mirrors the bookkeeping structures of the paper (Table II):
  ED_info   — total and free memory on each edge device
  M_info    — which model artifacts are cached on each device (LRU order)
  Task_info — number of running tasks of each type on each device
  T_alloc   — "the allocation of each task and the estimated time it will be
               on that edge device", so the orchestrator "can calculate the
               number of running tasks on each device at a certain time by a
               simple summation" (§IV-A).

``T_alloc`` is realised as a time-bucketed occupancy tensor
``alloc[device, task_type, bucket]`` so that Eq. (1) estimates at any time t
are O(1) slices; the summation the paper describes is a range-add here.

The same structures describe a fleet of TPU pods to the training runtime:
"models" become checkpoint shards / compiled-program caches, "memory"
becomes HBM headroom, and task types become job classes.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .interference import InterferenceModel

__all__ = ["Device", "ClusterState"]


@dataclass
class Device:
    """One edge device (or pod)."""

    did: int
    cls: int                      # index into the device-class/profile table
    mem_total: float              # H(ED) in bytes
    lam: float                    # failure rate lambda (Table IV)
    bandwidth: float              # link bandwidth B in bytes/s
    join_time: float = 0.0
    alive_until: float = float("inf")  # sampled ground-truth lifetime (sim only)

    # dynamic state ------------------------------------------------------------
    mem_free: float = 0.0
    # model_id -> bytes; least-recently-used first (we evict from the front;
    # the paper keeps MRU at the front and evicts from the end — same policy).
    model_cache: "OrderedDict[str, float]" = field(default_factory=OrderedDict)

    def init_dynamic(self) -> None:
        self.mem_free = self.mem_total
        self.model_cache = OrderedDict()

    # -- model cache (Algorithm 1, lines 19-27) -------------------------------
    def has_model(self, model_id: Optional[str]) -> bool:
        return model_id is None or model_id in self.model_cache

    def touch_model(self, model_id: str) -> None:
        """moveFront(M(T_i)) — mark most recently used."""
        self.model_cache.move_to_end(model_id)

    def admit_model(self, model_id: str, size: float) -> bool:
        """Upload a model, LRU-evicting (removeEnd) until it fits.

        Returns False when the model cannot fit even on an empty device."""
        if model_id in self.model_cache:
            self.touch_model(model_id)
            return True
        if size > self.mem_total:
            return False
        while self.mem_free < size and self.model_cache:
            _, evicted = self.model_cache.popitem(last=False)
            self.mem_free += evicted
        if self.mem_free < size:
            return False
        self.model_cache[model_id] = size
        self.mem_free -= size
        return True

    def alive(self, now: float) -> bool:
        return now < self.alive_until


@dataclass
class ClusterState:
    """The orchestrator's view of the fleet + the profiled ED_mc table."""

    devices: List[Device]
    model: InterferenceModel
    horizon: float = 300.0        # total simulated time covered by T_alloc
    dt: float = 0.05              # T_alloc bucket width (seconds)

    def __post_init__(self) -> None:
        for d in self.devices:
            d.init_dynamic()
        self._classes = np.array([d.cls for d in self.devices], dtype=np.int64)
        self._lams = np.array([d.lam for d in self.devices], dtype=np.float64)
        self._bw = np.array([d.bandwidth for d in self.devices], dtype=np.float64)
        self._mem_total = np.array(
            [d.mem_total for d in self.devices], dtype=np.float64
        )
        self.n_buckets = int(np.ceil(self.horizon / self.dt)) + 1
        # T_alloc: (devices, task types, time buckets)
        self.alloc = np.zeros(
            (len(self.devices), self.model.n_types, self.n_buckets),
            dtype=np.float32,
        )

    # -- static fleet views ------------------------------------------------------
    @property
    def n_devices(self) -> int:
        return len(self.devices)

    @property
    def n_types(self) -> int:
        return self.model.n_types

    def classes(self) -> np.ndarray:
        return self._classes

    def lams(self) -> np.ndarray:
        return self._lams

    def bandwidths(self) -> np.ndarray:
        return self._bw

    def mem_totals(self) -> np.ndarray:
        return self._mem_total

    # -- T_alloc ------------------------------------------------------------------
    def bucket(self, t: float) -> int:
        return min(max(int(t / self.dt), 0), self.n_buckets - 1)

    def add_interval(
        self, did: int, ttype: int, t0: float, t1: float, w: float = 1.0
    ) -> None:
        """Record that a ``ttype`` task occupies device ``did`` over [t0, t1)."""
        b0 = self.bucket(t0)
        b1 = max(self.bucket(t1), b0 + 1)  # at least one bucket
        self.alloc[did, ttype, b0:b1] += w

    def counts_at(self, t: float) -> np.ndarray:
        """Task_info snapshot at time t: (D, N) running-task counts.

        Clipped at zero: the engine replaces provisional placement-time
        intervals with actual execution intervals by subtraction, which can
        transiently leave small negative residue in individual buckets."""
        return np.maximum(self.alloc[:, :, self.bucket(t)], 0.0)

    def device_counts_at(self, did: int, t: float) -> np.ndarray:
        return self.alloc[did, :, self.bucket(t)]

    # -- Eq. (1) across the fleet ---------------------------------------------
    def estimate_exec(self, ttype: int, t: float) -> np.ndarray:
        """(D,) expected execution latency of a new ``ttype`` task started at
        time ``t`` on every device, given T_alloc."""
        return self.model.estimate_devices(
            self._classes, ttype, np.asarray(self.counts_at(t), dtype=np.float64)
        )

    def queue_len_at(self, t: float) -> np.ndarray:
        """(D,) total running tasks per device (LAVEA's SQLF signal)."""
        return np.asarray(self.counts_at(t), dtype=np.float64).sum(axis=1)
