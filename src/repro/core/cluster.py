"""Cluster state visible to the orchestrator.

Mirrors the bookkeeping structures of the paper (Table II):
  ED_info   — total and free memory on each edge device
  M_info    — which model artifacts are cached on each device (LRU order)
  Task_info — number of running tasks of each type on each device
  T_alloc   — "the allocation of each task and the estimated time it will be
               on that edge device", so the orchestrator "can calculate the
               number of running tasks on each device at a certain time by a
               simple summation" (§IV-A).

``T_alloc`` is realised as a time-bucketed occupancy tensor
``alloc[device, task_type, bucket]`` so that Eq. (1) estimates at any time t
are O(1) slices; the summation the paper describes is a range-add here.

The same structures describe a fleet of TPU pods to the training runtime:
"models" become checkpoint shards / compiled-program caches, "memory"
becomes HBM headroom, and task types become job classes.
"""
from __future__ import annotations

import warnings
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .batched import FleetSnapshot
from .interference import InterferenceModel

__all__ = [
    "Device",
    "ClusterState",
    "ApplyToken",
    "TIER_DEVICE",
    "TIER_EDGE_SERVER",
    "TIER_CLOUD",
    "TIER_NAMES",
]

# Fleet tiers (the multi-tier DAG-scheduling extension of arXiv:2409.10839):
# end devices -> edge servers -> cloud.  Tier ids index the backhaul matrix.
TIER_DEVICE, TIER_EDGE_SERVER, TIER_CLOUD = 0, 1, 2
TIER_NAMES = ("device", "edge_server", "cloud")


@dataclass
class Device:
    """One edge device (or pod)."""

    did: int
    cls: int                      # index into the device-class/profile table
    mem_total: float              # H(ED) in bytes
    lam: float                    # failure rate lambda (Table IV)
    # DEPRECATED scalar link bandwidth in bytes/s.  Kept as a symmetric shim:
    # when ``up_bw``/``down_bw`` are not given they both default to it, so
    # existing profiles load unchanged.  New code should set the directional
    # rates (phone uplinks are much slower than their downlinks).
    bandwidth: Optional[float] = None
    join_time: float = 0.0
    alive_until: float = float("inf")  # sampled ground-truth lifetime (sim only)
    tier: int = TIER_DEVICE       # fleet tier (indexes the backhaul matrix)
    up_bw: Optional[float] = None    # uplink rate in bytes/s (device -> net)
    down_bw: Optional[float] = None  # downlink rate in bytes/s (net -> device)

    # dynamic state ------------------------------------------------------------
    mem_free: float = 0.0
    # model_id -> bytes; least-recently-used first (we evict from the front;
    # the paper keeps MRU at the front and evicts from the end — same policy).
    model_cache: "OrderedDict[str, float]" = field(default_factory=OrderedDict)

    def __post_init__(self) -> None:
        if self.bandwidth is None and (self.up_bw is None or self.down_bw is None):
            raise ValueError(
                "Device needs either the deprecated scalar `bandwidth` or "
                "both `up_bw` and `down_bw`"
            )
        if self.up_bw is None:
            self.up_bw = float(self.bandwidth)
        if self.down_bw is None:
            self.down_bw = float(self.bandwidth)
        if self.bandwidth is None:
            self.bandwidth = float(min(self.up_bw, self.down_bw))

    def init_dynamic(self) -> None:
        self.mem_free = self.mem_total
        self.model_cache = OrderedDict()

    # -- model cache (Algorithm 1, lines 19-27) -------------------------------
    def has_model(self, model_id: Optional[str]) -> bool:
        return model_id is None or model_id in self.model_cache

    def touch_model(self, model_id: str) -> None:
        """moveFront(M(T_i)) — mark most recently used."""
        self.model_cache.move_to_end(model_id)

    def admit_model(self, model_id: str, size: float) -> bool:
        """Upload a model, LRU-evicting (removeEnd) until it fits.

        Returns False when the model cannot fit even on an empty device."""
        if model_id in self.model_cache:
            self.touch_model(model_id)
            return True
        if size > self.mem_total:
            return False
        while self.mem_free < size and self.model_cache:
            _, evicted = self.model_cache.popitem(last=False)
            self.mem_free += evicted
        if self.mem_free < size:
            return False
        self.model_cache[model_id] = size
        self.mem_free -= size
        return True

    def alive(self, now: float) -> bool:
        return now < self.alive_until


@dataclass
class ApplyToken:
    """Undo record for one ``ClusterState.apply`` call.

    Captures the occupancy intervals that were added and, for every device
    whose model cache was touched, an exact snapshot of its prior
    ``(mem_free, model_cache)`` — LRU order included — so speculative plans
    and what-if sweeps can be rolled back bit-exactly with
    ``cluster.undo(token)``.
    """

    intervals: List[Tuple[int, int, float, float, float]] = field(
        default_factory=list
    )  # (did, ttype, t0, t1, w)
    cache_snaps: Dict[int, Tuple[float, "OrderedDict[str, float]"]] = field(
        default_factory=dict
    )
    applied: bool = False       # False for infeasible / rejected plans
    undone: bool = False


@dataclass
class ClusterState:
    """The orchestrator's view of the fleet + the profiled ED_mc table."""

    devices: List[Device]
    model: InterferenceModel
    horizon: float = 300.0        # total simulated time covered by T_alloc
    dt: float = 0.05              # T_alloc bucket width (seconds)
    # (T, T) inter-tier backhaul rates in bytes/s (T = number of tiers);
    # None = unconstrained (single-tier fleets).
    backhaul: Optional[np.ndarray] = None
    # Device id hosting the model artifacts (an edge server / registry node):
    # uploads to device d are charged over the bw_eff[model_source, d] link.
    # None = legacy semantics (artifacts arrive at each device's downlink).
    model_source: Optional[int] = None

    def __post_init__(self) -> None:
        for d in self.devices:
            d.init_dynamic()
        # Optional availability forecast (repro.core.availability
        # .SurvivalForecast), installed by ChurnSchedule.install or
        # install_forecast; None = no forecast -> snapshots carry the
        # uniform all-ones survival leaf and policies fall back to F(T_i).
        self.forecast = None
        self.topology_version = -1
        self.refresh_topology()
        self.n_buckets = int(np.ceil(self.horizon / self.dt)) + 1
        # T_alloc: (devices, task types, time buckets).  float64 like all
        # pricing: apply/undo/cancel cycles add and subtract the SAME
        # values, which cancel exactly in float64 (a float32 accumulator
        # rounds the f64 interval weights on entry, leaving residue that
        # the counts_at clip then silently masks).
        self.alloc = np.zeros(
            (len(self.devices), self.model.n_types, self.n_buckets),
            dtype=np.float64,
        )
        self._horizon_warned = False

    # Fleet vectors handed out to frozen snapshots as shared (zero-copy)
    # pytree leaves.  When `_leased` is set, the next in-place mutation
    # copies them first (copy-on-write), so already-taken snapshots stay
    # immutable without re-deriving O(D) state on every wave.
    _LEAF_VECTORS = (
        "_classes", "_lams", "_bw", "_mem_total", "_tiers", "_up", "_down",
        "_join_times",
    )

    def refresh_topology(self) -> None:
        """(Re)build the static O(D) fleet vectors from the current
        ``Device`` attributes, validate the backhaul matrix, and bump
        ``topology_version`` so snapshot-scoped caches (the wave context
        builder) can detect staleness.

        The bottleneck rule prices the *link*, not the endpoint:

            bw_eff[s, d] = min(up[s], down[d], backhaul[tier[s], tier[d]])

        — the sender's uplink, the receiver's downlink, and the inter-tier
        backhaul all bound a transfer.  The diagonal is +inf (a co-located
        transfer crosses no network hop).  The dense ``(D, D)`` matrix is
        never built here: snapshots carry only the factors and sender rows
        are derived lazily by :meth:`link_row` (the factorization that
        scales the fleet to 100k devices).  Call this after mutating device
        attributes wholesale; for a single device use :meth:`set_bandwidth`,
        which is O(D) instead of a full rebuild."""
        devs = self.devices
        self._classes = np.array([d.cls for d in devs], dtype=np.int64)
        self._lams = np.array([d.lam for d in devs], dtype=np.float64)
        self._alive_until = np.array(
            [d.alive_until for d in devs], dtype=np.float64
        )
        self._bw = np.array([d.bandwidth for d in devs], dtype=np.float64)
        self._mem_total = np.array([d.mem_total for d in devs], dtype=np.float64)
        self._tiers = np.array([d.tier for d in devs], dtype=np.int64)
        self._up = np.array([d.up_bw for d in devs], dtype=np.float64)
        self._down = np.array([d.down_bw for d in devs], dtype=np.float64)
        self._join_times = np.array(
            [d.join_time for d in devs], dtype=np.float64
        )
        max_tier = int(self._tiers.max()) if self._tiers.size else 0
        if self.backhaul is None:
            # unconstrained single-/multi-tier fleet: an all-inf matrix is
            # the identity of the min, so the factorized rule degenerates to
            # min(up[s], down[d]) exactly as before
            self._backhaul = np.full((max_tier + 1, max_tier + 1), np.inf)
        else:
            bh = np.asarray(self.backhaul, dtype=np.float64)
            if bh.ndim != 2 or bh.shape[0] != bh.shape[1]:
                raise ValueError(
                    f"backhaul matrix must be square (T, T), got {bh.shape}"
                )
            if self._tiers.size and bh.shape[0] <= max_tier:
                raise ValueError(
                    f"backhaul matrix {bh.shape} too small for tier "
                    f"{max_tier}"
                )
            self._backhaul = bh
        self._link_rows: Dict[int, np.ndarray] = {}
        self._leased = False
        self.topology_version += 1

    def _cow(self) -> None:
        """Copy-on-write the leased fleet vectors before an in-place
        mutation, so frozen snapshots taken earlier keep their values."""
        if not self._leased:
            return
        for name in self._LEAF_VECTORS:
            setattr(self, name, getattr(self, name).copy())
        self._leased = False

    def set_bandwidth(
        self,
        did: int,
        *,
        up: Optional[float] = None,
        down: Optional[float] = None,
        tier: Optional[int] = None,
    ) -> None:
        """Update one device's link rates / tier incrementally (the blessed
        way to change topology between planning waves).

        Touches only that device's entries in the O(D) factor vectors
        (copy-on-write when snapshots hold them) and invalidates the cached
        link rows — no O(D^2) state exists to rebuild, and no other
        device's leaves are re-derived.  Still bumps ``topology_version``
        so live wave builders raise instead of mixing topologies."""
        d = self.devices[did]
        if up is not None:
            d.up_bw = float(up)
        if down is not None:
            d.down_bw = float(down)
        if tier is not None:
            d.tier = int(tier)
            if d.tier >= self._backhaul.shape[0]:
                if self.backhaul is not None:
                    raise ValueError(
                        f"backhaul matrix {self._backhaul.shape} too small "
                        f"for tier {d.tier}"
                    )
                # unconstrained fleet: grow the all-inf matrix to cover the
                # new tier id
                self._backhaul = np.full((d.tier + 1, d.tier + 1), np.inf)
        if up is not None or down is not None:
            d.bandwidth = float(min(d.up_bw, d.down_bw))
        self._cow()
        self._up[did] = d.up_bw
        self._down[did] = d.down_bw
        self._tiers[did] = d.tier
        self._bw[did] = d.bandwidth
        self._link_rows = {}
        self.topology_version += 1

    def install_forecast(self, forecast) -> None:
        """Install (or clear, with ``None``) an availability forecast
        (:class:`~repro.core.availability.SurvivalForecast`).  Snapshots
        taken afterwards carry its ``(D, K)`` survival tensor as the
        ``surv_grid``/``survival`` pytree leaves and the wave context
        builder prices per-candidate survival from it; the topology version
        bumps so a live wave builder raises instead of mixing forecasts."""
        if forecast is not None and forecast.n_devices != len(self.devices):
            raise ValueError(
                f"forecast covers {forecast.n_devices} devices, fleet has "
                f"{len(self.devices)}"
            )
        self.forecast = forecast
        self.topology_version += 1

    # -- device lifecycle (the churn runtime's view) ----------------------------
    def alive_mask(self, t: float) -> np.ndarray:
        """(D,) bool: devices that have not departed as of time ``t``.

        A device past its ``alive_until`` has already left the network, so
        the orchestrator can observe the departure (missed heartbeats) and
        MUST NOT place onto it — :meth:`snapshot` and the wave context
        builder bake this mask into every policy's feasibility.  Future
        departures stay invisible: ``alive_until > t`` is indistinguishable
        from immortal, exactly the paper's silent-departure model (the
        orchestrator only ever prices future deaths probabilistically via
        ``F(T_i)``)."""
        return t < self._alive_until

    def mark_down(self, did: int, t: float) -> None:
        """Record that device ``did`` left the network at time ``t`` (the
        churn runtime's DEVICE_DOWN).  Snapshots taken at or after ``t``
        mask it infeasible; the topology version bumps so a live wave
        builder raises instead of planning onto the departed device."""
        dev = self.devices[did]
        dev.alive_until = min(dev.alive_until, float(t))
        self._alive_until[did] = dev.alive_until
        self.topology_version += 1

    def mark_up(
        self, did: int, t: float, alive_until: float = float("inf")
    ) -> None:
        """Re-admit device ``did`` at time ``t`` (the churn runtime's
        DEVICE_UP): it rejoins empty — free memory, cold model cache, a
        fresh ``join_time`` (its availability clock restarts) — and stays
        until ``alive_until`` (its next scheduled departure)."""
        dev = self.devices[did]
        dev.join_time = float(t)
        dev.alive_until = float(alive_until)
        dev.init_dynamic()
        self._alive_until[did] = dev.alive_until
        self._cow()
        self._join_times[did] = dev.join_time
        self.topology_version += 1

    # -- static fleet views ------------------------------------------------------
    @property
    def n_devices(self) -> int:
        return len(self.devices)

    @property
    def n_types(self) -> int:
        return self.model.n_types

    def classes(self) -> np.ndarray:
        return self._classes

    def lams(self) -> np.ndarray:
        return self._lams

    def bandwidths(self) -> np.ndarray:
        """DEPRECATED (D,) scalar bandwidths — use :meth:`link_bw`."""
        return self._bw

    def tiers(self) -> np.ndarray:
        return self._tiers

    def up_bandwidths(self) -> np.ndarray:
        return self._up

    def down_bandwidths(self) -> np.ndarray:
        return self._down

    def backhaul_bw(self) -> np.ndarray:
        """(T, T) inter-tier backhaul rates (all-inf when unconstrained)."""
        return self._backhaul

    def join_times(self) -> np.ndarray:
        """(D,) device join times (the availability-clock epochs)."""
        return self._join_times

    def link_row(self, s: int) -> np.ndarray:
        """(D,) sender row of the effective link-bandwidth matrix:
        ``bw_eff[s, d] = min(up[s], down[d], backhaul[tier[s], tier[d]])``,
        +inf at ``d == s``.

        Derived lazily from the O(D) factors and cached per sender until
        the topology changes — only rows of devices that actually *send*
        (DAG parents, the model source) are ever built, so planning cost
        scales with senders, not D^2."""
        s = int(s)
        row = self._link_rows.get(s)
        if row is None:
            row = np.minimum(self._up[s], self._down)
            row = np.minimum(
                row, self._backhaul[self._tiers[s], self._tiers]
            )
            row[s] = np.inf
            self._link_rows[s] = row
        return row

    def link_bw(self) -> np.ndarray:
        """(D, D) effective link bandwidth: ``bw_eff[s, d] = min(up[s],
        down[d], backhaul[tier[s], tier[d]])``, +inf on the diagonal.

        Materialized on demand from the factors — O(D^2) memory, for
        debugging and small-fleet inspection only; hot paths (the wave
        builder's transfer vectors, recovery repricing) slice
        :meth:`link_row` instead."""
        link = np.minimum(self._up[:, None], self._down[None, :])
        link = np.minimum(
            link, self._backhaul[self._tiers[:, None], self._tiers[None, :]]
        )
        np.fill_diagonal(link, np.inf)
        return link

    def upload_bw(self) -> np.ndarray:
        """(D,) effective model-upload bandwidth per device: the link row
        from ``model_source`` (artifacts live on that node) or, when no
        source is declared, each device's downlink — which equals the
        deprecated scalar ``bandwidth`` on shimmed fleets, preserving the
        legacy upload pricing exactly."""
        if self.model_source is None:
            return self._down
        return self.link_row(self.model_source)

    def mem_totals(self) -> np.ndarray:
        return self._mem_total

    # -- T_alloc ------------------------------------------------------------------
    def bucket(self, t: float) -> int:
        return min(max(int(t / self.dt), 0), self.n_buckets - 1)

    def add_interval(
        self, did: int, ttype: int, t0: float, t1: float, w: float = 1.0
    ) -> None:
        """Record that a ``ttype`` task occupies device ``did`` over [t0, t1).

        Intervals reaching past ``horizon`` are clipped to it (with a
        one-time warning) instead of being silently clamped into the final
        T_alloc bucket, where their occupancy would otherwise pile up and
        corrupt late-horizon Eq. (1) estimates.  Clipping is a pure function
        of ``(t0, t1)``, so undo/replacement passes (negative ``w``) cancel
        the exact same buckets.
        """
        if t1 > self.horizon:
            self._warn_horizon(t1)
            t1 = self.horizon
        if t0 >= self.horizon:
            return                      # entirely past the recorded window
        b0 = self.bucket(t0)
        b1 = max(self.bucket(t1), b0 + 1)  # at least one bucket
        self.alloc[did, ttype, b0:b1] += w

    def _warn_horizon(self, t1: float) -> None:
        if self._horizon_warned:
            return
        self._horizon_warned = True
        warnings.warn(
            f"T_alloc interval extends to t={t1:.2f}s past horizon="
            f"{self.horizon:.2f}s; clipping occupancy at the horizon "
            "(build the cluster with a larger `horizon` to track it)",
            RuntimeWarning,
            stacklevel=3,
        )

    def cancel_from(
        self, did: int, ttype: int, t0: float, t1: float, t_cut: float,
        w: float = 1.0,
    ) -> None:
        """Remove the ``[t_cut, t1)`` tail of a previously recorded
        ``[t0, t1)`` occupancy interval, bucket-exactly.

        Used when a replica is killed mid-flight (device departure, app
        failure): the capacity it would have held from the cut onward is
        returned to T_alloc.  Operates on the *same* buckets the original
        :meth:`add_interval` touched — the partial bucket containing the
        cut is removed with the tail — so a cancelled interval can never
        leave negative residue, whatever the bucket alignment."""
        if t1 > self.horizon:
            t1 = self.horizon
        if t0 >= self.horizon or t_cut >= t1:
            return
        b0 = self.bucket(t0)
        b1 = max(self.bucket(t1), b0 + 1)
        bc = min(max(self.bucket(t_cut), b0), b1)
        self.alloc[did, ttype, bc:b1] -= w

    def counts_at(self, t: float) -> np.ndarray:
        """Task_info snapshot at time t: (D, N) running-task counts.

        Clipped at zero: the engine replaces provisional placement-time
        intervals with actual execution intervals by subtraction, which can
        transiently leave small negative residue in individual buckets."""
        return np.maximum(self.alloc[:, :, self.bucket(t)], 0.0)

    def device_counts_at(self, did: int, t: float) -> np.ndarray:
        """One device's Task_info row at time t, clipped at zero like
        ``counts_at`` (provisional-interval subtraction can leave small
        negative residue that must not shrink interference estimates)."""
        return np.maximum(self.alloc[did, :, self.bucket(t)], 0.0)

    # -- Eq. (1) across the fleet ---------------------------------------------
    def estimate_exec(self, ttype: int, t: float) -> np.ndarray:
        """(D,) expected execution latency of a new ``ttype`` task started at
        time ``t`` on every device, given T_alloc."""
        return self.model.estimate_devices(
            self._classes, ttype, np.asarray(self.counts_at(t), dtype=np.float64)
        )

    def queue_len_at(self, t: float) -> np.ndarray:
        """(D,) total running tasks per device (LAVEA's SQLF signal)."""
        return np.asarray(self.counts_at(t), dtype=np.float64).sum(axis=1)

    def snapshot(
        self,
        t: float,
        *,
        counts: Optional[np.ndarray] = None,
        join_times: Optional[np.ndarray] = None,
        alive: Optional[np.ndarray] = None,
        surv_grid: Optional[np.ndarray] = None,
        survival: Optional[np.ndarray] = None,
    ) -> FleetSnapshot:
        """Struct-of-arrays :class:`FleetSnapshot` of the fleet at time
        ``t``: the static device vectors plus the Task_info counts — the
        batched policies' whole world view, as one pytree.

        ``counts``/``join_times``/``surv_grid``/``survival`` let hot callers
        (the wave context builder) pass their cached copies; this stays the
        single construction site for snapshots.  The link model is carried
        as its O(D) factors (``up_bw``/``down_bw``/``backhaul`` + ``tiers``)
        — never the dense ``(D, D)`` matrix — so a snapshot of a 100k-device
        fleet is still O(D) memory.  The fleet vectors are shared zero-copy;
        the next in-place mutation copies them first (see :meth:`_cow`)."""
        if counts is None:
            counts = np.asarray(self.counts_at(t), dtype=np.float64)
        if join_times is None:
            join_times = self._join_times
        if alive is None:
            alive = self.alive_mask(t)
        if (survival is None) != (surv_grid is None):
            # catch the half-supplied forecast HERE, not in the __debug__
            # twin (silently wrong under python -O otherwise): a (D, K)
            # survival tensor is meaningless without its (K,) span grid
            raise ValueError(
                "snapshot() needs `survival` and `surv_grid` together "
                f"(got survival={'set' if survival is not None else 'None'}, "
                f"surv_grid={'set' if surv_grid is not None else 'None'})"
            )
        if survival is None:
            if self.forecast is None:
                # no forecast installed: the uniform leaf — every policy
                # falls back bit-identically to the memoryless F(T_i)
                surv_grid = np.zeros(1)
                survival = np.ones((len(self.devices), 1))
            else:
                surv_grid = self.forecast.grid()
                survival = self.forecast.sample(t)
        snap = FleetSnapshot(
            t=t,
            classes=self._classes,
            lams=self._lams,
            bandwidths=self._bw,
            tiers=self._tiers,
            up_bw=self._up,
            down_bw=self._down,
            backhaul=self._backhaul,
            mem_total=self._mem_total,
            join_times=join_times,
            alive=alive,
            surv_grid=surv_grid,
            survival=survival,
            counts=counts,
            queue_len=counts.sum(axis=1),
            base=self.model.base,
            slope=self.model.slope,
        )
        if __debug__:
            # runtime twin of the snapshot-schema lint rule: leaf drift
            # fails HERE, not as a wrong tensor inside a jitted kernel
            snap.validate()
        self._leased = True
        return snap

    # -- the one blessed mutation path ----------------------------------------
    def apply(self, plan) -> ApplyToken:
        """Make a :class:`~repro.core.orchestrator.Plan` real.

        Records the provisional T_alloc occupancy interval of every replica
        and admits required model artifacts into the per-device LRU caches
        (Algorithm 1 lines 19-27) — exactly the bookkeeping the seed's
        scheduler commit step performed, but as an explicit, undoable step.

        Returns an :class:`ApplyToken`; pass it to :meth:`undo` to roll the
        state back exactly (speculative planning, alpha/gamma what-if
        sweeps).  Infeasible plans are a no-op.

        If a required model cannot fit on its chosen device even after LRU
        eviction, the whole application is rolled back and the plan is
        marked infeasible at that task (mirroring the memory-constraint
        branch of the planning phase) instead of silently treating the
        model as cached.
        """
        token = ApplyToken()
        placement = plan.placement
        if not placement.feasible:
            return token
        app, now = plan.app, plan.now
        for tname, tp in placement.tasks.items():
            spec = app.tasks[tname]
            start = now + tp.est_start
            for rep in tp.replicas:
                self.add_interval(
                    rep.did, spec.ttype, start, start + rep.est_total
                )
                token.intervals.append(
                    (rep.did, spec.ttype, start, start + rep.est_total, 1.0)
                )
                dev = self.devices[rep.did]
                if spec.model_id is not None:
                    if rep.did not in token.cache_snaps:
                        token.cache_snaps[rep.did] = (
                            dev.mem_free, OrderedDict(dev.model_cache)
                        )
                    if not dev.admit_model(spec.model_id, spec.model_bytes):
                        # the model cannot fit even after evicting the whole
                        # cache: surface it instead of pretending it loaded
                        self.undo(token)
                        placement.feasible = False
                        placement.infeasible_task = tname
                        return ApplyToken()
        token.applied = True
        return token

    def undo(self, token: ApplyToken) -> None:
        """Roll back one :meth:`apply` exactly (idempotent per token)."""
        if token.undone:
            return
        for did, ttype, t0, t1, w in reversed(token.intervals):
            self.add_interval(did, ttype, t0, t1, w=-w)
        for did, (mem_free, cache) in token.cache_snaps.items():
            dev = self.devices[did]
            dev.mem_free = mem_free
            dev.model_cache = OrderedDict(cache)
        token.undone = True
