"""Pure, array-native placement policies (the redesigned orchestration API).

The paper's Algorithm 1 is, at heart, a *scoring rule*: blend the latency
estimate of Eq. (2) with the failure probability of Eq. (4) using the
weight of Eq. (5) and pick devices.  The seed buried that rule inside
``Scheduler.place``, which also mutated cluster state — so policies could
not be composed, batched, or replayed.  This module splits the two concerns:

  * :class:`PolicyContext` — a frozen, array-shaped snapshot of everything a
    policy may look at for ONE task: the per-device execution-latency vector
    (Eq. 1 across the fleet), upload/transfer cost vectors, the feasibility
    mask, per-device failure probabilities, queue lengths and running-task
    counts.  It is precomputed once per task (and the expensive pieces once
    per *stage*) by :func:`repro.core.orchestrator.orchestrate`.
  * :class:`TaskDecision` — the policy's entire output: an ordered tuple of
    device ids (primary first; extras are replicas).
  * ``decide(ctx) -> TaskDecision`` — a pure function of the context (plus,
    for the randomized baselines, the policy's own rng stream).  IBDASH and
    all five baselines are each ~10-30 lines.

Policies are registered by name with :func:`register_policy` and built with
:func:`make_policy`, replacing the per-scheme if-chains that previously
lived in ``sim.runner`` and ``serve.scheduler.ServingFleet``.  Every
factory accepts the full keyword bundle (``alpha``, ``beta``, ``gamma``,
``seed``, ``lats_model``, ...) and picks out what it needs, so callers can
construct any scheme uniformly.

State mutation is *not* a policy concern: ``orchestrate`` returns a
:class:`~repro.core.orchestrator.Plan` and the caller decides whether to
``cluster.apply(plan)`` (which returns an undo token for speculative
what-if planning).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Optional, Tuple, Type

import numpy as np

from .batched import (
    BatchedDecision,
    BatchedPolicyContext,
    FleetSnapshot,
    BATCH_KERNEL_MIN_ROWS,
    ibdash_decide_batch,
    lavea_decide_batch,
    round_robin_decide_batch,
    tier_escalation_decide_batch,
)

__all__ = [
    "PolicyContext",
    "TaskDecision",
    "FleetSnapshot",
    "BatchedPolicyContext",
    "BatchedDecision",
    "Policy",
    "register_policy",
    "make_policy",
    "available_policies",
    "IBDASHConfig",
    "IBDASHPolicy",
    "RandomPolicy",
    "RoundRobinPolicy",
    "LAVEAPolicy",
    "PetrelPolicy",
    "LaTSModel",
    "LaTSPolicy",
    "TierEscalationPolicy",
    "ChurnAwarePolicy",
]


@dataclass(frozen=True)
class PolicyContext:
    """Everything a policy may inspect to place ONE task — all array-shaped.

    Vectors are indexed by device id (length ``n_devices``); ``counts`` is
    the ``(D, N)`` running-task matrix (Task_info at ``t_start``).  The
    context is built from :class:`~repro.core.cluster.ClusterState` by the
    ``orchestrate`` driver and never mutated; policies must treat the arrays
    as read-only.
    """

    task: str                    # task name (for error reporting)
    ttype: int                   # index into the task-type table
    t_start: float               # absolute estimated start (now + stage offset)
    stage_offset: float          # offset from app arrival (stage barrier)
    exec_lat: np.ndarray         # (D,) Eq. (1) execution latency per device
    upload: np.ndarray           # (D,) L(M(T_i)) model-upload latency
    transfer: np.ndarray         # (D,) L(T_i)_d input-transfer latency
    total: np.ndarray            # (D,) Eq. (2): exec + upload + transfer
    feasible: np.ndarray         # (D,) bool memory-feasibility mask
    feasible_ids: np.ndarray     # (D',) int ids where feasible
    pf: np.ndarray               # (D,) F(T_i): P(device dies before completion)
    lams: np.ndarray             # (D,) failure rates
    join_times: np.ndarray       # (D,) device join times
    queue_len: np.ndarray        # (D,) total running tasks (LAVEA's SQLF signal)
    counts: np.ndarray           # (D, N) per-type running-task counts
    classes: np.ndarray          # (D,) device-class ids
    # (D,) fleet tier ids (0=device, 1=edge server, 2=cloud); None on
    # contexts built before multi-tier fleets existed == single-tier.
    tiers: Optional[np.ndarray] = None
    # (D,) bool churn mask: devices not yet departed when the plan was made.
    # Already ANDed into ``feasible``; None on hand-built contexts == all up.
    alive: Optional[np.ndarray] = None
    # (D,) forecast survival over THIS task's estimated execution span:
    # S_d(t_start, t_start + total[d]).  All-ones when no availability
    # forecast is installed; None on hand-built contexts == no forecast.
    # Only forecast-aware policies (churn_aware) read it — the paper's six
    # keep pricing failures through the memoryless ``pf``.
    survival: Optional[np.ndarray] = None

    @property
    def n_devices(self) -> int:
        return int(self.exec_lat.shape[0])


@dataclass(frozen=True)
class TaskDecision:
    """A policy's verdict for one task: devices to run it on, primary first.

    An empty tuple means the policy found no acceptable device (e.g. the
    IBDASH availability floor filtered every candidate); the orchestrator
    marks the plan infeasible at this task.
    """

    devices: Tuple[int, ...]

    @property
    def primary(self) -> int:
        return self.devices[0]

    @property
    def n_replicas(self) -> int:
        return max(len(self.devices) - 1, 0)


class Policy:
    """A pure placement policy: ``decide`` maps a context to a decision.

    Implementations hold only configuration and (for randomized schemes)
    their own rng / cursor state — never cluster state.

    ``decide_batch`` is the fused entry point: one call decides all B rows
    of a :class:`~repro.core.batched.BatchedPolicyContext`.  Batch semantics
    are DEFINED as processing the rows in order, exactly as if ``decide``
    were called once per row — stateful policies (rng streams, the
    round-robin cursor) consume their state once per row with a non-empty
    feasible set, in row order.  The default implementation is that loop;
    registered policies override it with vectorised (jax.numpy / numpy)
    implementations that are bit-identical to the loop.
    """

    name: str = "base"

    def decide(self, ctx: PolicyContext) -> TaskDecision:
        raise NotImplementedError

    def decide_batch(self, batch: BatchedPolicyContext) -> BatchedDecision:
        return BatchedDecision(devices=tuple(
            self.decide(batch.row(b)).devices for b in range(batch.n_rows)
        ))


# -- registry -----------------------------------------------------------------
_REGISTRY: "Dict[str, Type[Policy]]" = {}


def register_policy(name: str) -> Callable[[Type[Policy]], Type[Policy]]:
    """Class decorator: register a policy under ``name`` (kebab/snake case).

    The registered class must accept keyword-only construction; extra
    keywords it does not understand are ignored (``**_``) so that
    :func:`make_policy` can pass one uniform kwarg bundle to every scheme.
    """

    def deco(cls: Type[Policy]) -> Type[Policy]:
        if name in _REGISTRY:
            raise ValueError(f"policy {name!r} already registered")
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def make_policy(name: str, **kwargs) -> Policy:
    """Instantiate a registered policy by name.

    All callers pass the same kwarg bundle (alpha/beta/gamma/seed/
    lats_model/...); each policy keeps what it needs.
    """
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r}; available: {sorted(_REGISTRY)}"
        ) from None
    return cls(**kwargs)


def available_policies() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


# -- IBDASH (Algorithm 1's scoring + replication rule) ------------------------
@dataclass
class IBDASHConfig:
    alpha: float = 0.5     # joint optimisation weight (Eq. 5)
    beta: float = 0.1      # probability-of-failure threshold
    gamma: int = 3         # replication degree cap
    # When True the orchestrator drops devices whose *predicted* availability
    # is below ``avail_floor`` from the candidate set entirely (a beyond-paper
    # guard; disabled by default to stay faithful).
    avail_floor: float = 0.0


@register_policy("ibdash")
class IBDASHPolicy(Policy):
    """Algorithm 1, lines 16-41, as a pure function of the context."""

    def __init__(
        self,
        config: Optional[IBDASHConfig] = None,
        *,
        alpha: Optional[float] = None,
        beta: Optional[float] = None,
        gamma: Optional[int] = None,
        avail_floor: Optional[float] = None,
        **_,
    ):
        cfg = config or IBDASHConfig()
        over = {k: v for k, v in dict(
            alpha=alpha, beta=beta, gamma=gamma, avail_floor=avail_floor
        ).items() if v is not None}
        self.cfg = replace(cfg, **over) if over else cfg

    def _columns(
        self, ctx: PolicyContext
    ) -> Tuple[np.ndarray, np.ndarray]:
        """The (pf, feasible) columns the scoring rule runs over — the
        override hook for forecast-aware variants (ChurnAwarePolicy)."""
        cfg = self.cfg
        feasible = ctx.feasible
        if cfg.avail_floor > 0.0:
            avail = np.exp(-ctx.lams * (ctx.t_start - ctx.join_times))
            feasible = feasible & (avail >= cfg.avail_floor)
        return ctx.pf, feasible

    def _batch_columns(
        self, batch: BatchedPolicyContext
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(G, D) twin of :meth:`_columns` for the pooled batch tensors."""
        cfg = self.cfg
        feasible = batch.feasible_pool
        if cfg.avail_floor > 0.0:
            t_pool = batch.t_start[batch.pool_first]
            avail = np.exp(
                -batch.lams[None, :]
                * (t_pool[:, None] - batch.join_times[None, :])
            )
            feasible = feasible & (avail >= cfg.avail_floor)
        return batch.pf_pool, feasible

    def decide(self, ctx: PolicyContext) -> TaskDecision:
        pf, feasible = self._columns(ctx)
        return TaskDecision(devices=self._score(ctx.total, pf, feasible))

    def decide_batch(self, batch: BatchedPolicyContext) -> BatchedDecision:
        """All B rows in one fused call: the scoring + replication loop as a
        jitted ``lax.scan`` vmapped over rows (see
        :func:`repro.core.batched.ibdash_decide_batch`).  Bit-identical to
        looping :meth:`decide`.

        IBDASH is stateless, so it decides once per DISTINCT context row
        (the batch's pool) and fans the decision out — a 1000-instance
        burst of a few app types collapses to a handful of scored rows.
        Small pools take the scalar loop directly (jit dispatch would
        dominate)."""
        cfg = self.cfg
        pf, feasible = self._batch_columns(batch)
        if batch.n_distinct < BATCH_KERNEL_MIN_ROWS:
            pool_dec = [
                self._score(batch.total_pool[g], pf[g], feasible[g])
                for g in range(batch.n_distinct)
            ]
        else:
            pool_dec = ibdash_decide_batch(
                batch.total_pool, pf, feasible,
                cfg.alpha, cfg.beta, cfg.gamma,
            )
        return BatchedDecision(devices=tuple(
            pool_dec[g] for g in batch.row_pool.tolist()
        ))

    def _score(
        self, total: np.ndarray, pf: np.ndarray, feasible: np.ndarray
    ) -> Tuple[int, ...]:
        """Algorithm 1 lines 16-41 for ONE task (the scalar reference)."""
        cfg = self.cfg
        cand = np.flatnonzero(feasible)
        if cand.size == 0:
            return ()

        # lines 16-18: priority queue == ascending order over L(T_i).
        order = cand[np.argsort(total[cand], kind="stable")]
        best_total = float(total[order[0]])
        l_ref = max(best_total, 1e-9)
        devices = [int(order[0])]
        comb_fail = float(pf[order[0]])
        # line 29: weighted joint score, latency normalised by the best
        # candidate so alpha sweeps [0,1] meaningfully.
        weight_s = cfg.alpha * (best_total / l_ref) + (1 - cfg.alpha) * comb_fail

        t_rep = 0
        qi = 1
        while comb_fail >= cfg.beta and t_rep < cfg.gamma and qi < order.size:  # line 30
            did = order[qi]                                 # line 31
            qi += 1
            cand_total = float(total[did])
            new_fail = comb_fail * float(pf[did])
            weight_new = cfg.alpha * (cand_total / l_ref) + (1 - cfg.alpha) * new_fail
            if weight_new <= weight_s:                      # line 34
                devices.append(int(did))                    # line 35
                comb_fail = new_fail
                weight_s = weight_new
                t_rep += 1                                  # line 37
            else:
                break                                       # line 39
        return tuple(devices)


# -- baselines (§V-D) ---------------------------------------------------------
# All baselines return an empty decision on an empty feasible set (like
# IBDASH) so the orchestrator can mark the plan infeasible instead of the
# policy crashing on an unguarded ``feasible_ids`` index.
@register_policy("random")
class RandomPolicy(Policy):
    """Uniform random feasible device."""

    def __init__(self, *, seed: int = 0, **_):
        self.rng = np.random.default_rng(seed)

    def decide(self, ctx: PolicyContext) -> TaskDecision:
        ids = ctx.feasible_ids
        if ids.size == 0:
            return TaskDecision(devices=())
        return TaskDecision(devices=(int(self.rng.choice(ids)),))

    def decide_batch(self, batch: BatchedPolicyContext) -> BatchedDecision:
        # One rng draw per non-empty row, in row order: the draws themselves
        # must replay the scalar numpy stream, so only the feasibility scan
        # is vectorised.
        out = []
        for b in range(batch.n_rows):
            ids = batch.feasible_ids(b)
            out.append(
                () if ids.size == 0 else (int(self.rng.choice(ids)),)
            )
        return BatchedDecision(devices=tuple(out))


@register_policy("round_robin")
class RoundRobinPolicy(Policy):
    """Cyclic assignment over the feasible set."""

    def __init__(self, *, seed: int = 0, **_):
        self._next = 0

    def decide(self, ctx: PolicyContext) -> TaskDecision:
        ids = ctx.feasible_ids
        if ids.size == 0:
            return TaskDecision(devices=())
        did = int(ids[self._next % ids.size])
        self._next += 1
        return TaskDecision(devices=(did,))

    def decide_batch(self, batch: BatchedPolicyContext) -> BatchedDecision:
        # Cursor semantics under batching: the cursor advances once per
        # non-empty row, in row order (== looping ``decide``); the gather of
        # each row's k-th feasible device is one fused kernel call.
        devices, self._next = round_robin_decide_batch(
            batch.feasible, self._next
        )
        return BatchedDecision(devices=tuple(devices))


@register_policy("lavea")
class LAVEAPolicy(Policy):
    """Shortest Queue Length First (best scheme of LAVEA [6])."""

    def __init__(self, *, seed: int = 0, **_):
        pass

    def decide(self, ctx: PolicyContext) -> TaskDecision:
        ids = ctx.feasible_ids
        if ids.size == 0:
            return TaskDecision(devices=())
        q = ctx.queue_len[ids]
        return TaskDecision(devices=(int(ids[int(np.argmin(q))]),))

    def decide_batch(self, batch: BatchedPolicyContext) -> BatchedDecision:
        # SQLF is stateless: argmin once per distinct context row, fan out.
        q_pool = batch.queue_pool[batch.bucket_inv[batch.pool_first]]
        pool_dec = lavea_decide_batch(q_pool, batch.feasible_pool)
        return BatchedDecision(devices=tuple(
            pool_dec[g] for g in batch.row_pool.tolist()
        ))


@register_policy("petrel")
class PetrelPolicy(Policy):
    """Power-of-two-choices randomized load balancing [7], [8]."""

    def __init__(self, *, seed: int = 0, **_):
        self.rng = np.random.default_rng(seed)

    def decide(self, ctx: PolicyContext) -> TaskDecision:
        ids = ctx.feasible_ids
        if ids.size == 0:
            return TaskDecision(devices=())
        if ids.size == 1:
            return TaskDecision(devices=(int(ids[0]),))
        a, b = self.rng.choice(ids, size=2, replace=False)
        pick = a if ctx.exec_lat[a] <= ctx.exec_lat[b] else b
        return TaskDecision(devices=(int(pick),))

    def decide_batch(self, batch: BatchedPolicyContext) -> BatchedDecision:
        # Two-sample draws replay the scalar stream row by row (rows with
        # zero/one feasible device consume no randomness, like ``decide``).
        out = []
        exec_pool = batch.exec_pool
        row_pool = batch.row_pool
        for b in range(batch.n_rows):
            ids = batch.feasible_ids(b)
            if ids.size == 0:
                out.append(())
            elif ids.size == 1:
                out.append((int(ids[0]),))
            else:
                a, c = self.rng.choice(ids, size=2, replace=False)
                g = row_pool[b]
                pick = a if exec_pool[g, a] <= exec_pool[g, c] else c
                out.append((int(pick),))
        return BatchedDecision(devices=tuple(out))


@dataclass
class LaTSModel:
    """Parametric latency model of LaTS [9]: log(latency) is linear in CPU
    usage (paper Fig. 5):  lat(cls, type, usage) = base * exp(b * usage).

    ``cpu_usage[cls, ttype]`` is the incremental CPU fraction one running
    task of ``ttype`` consumes on a class-``cls`` device; the device's total
    usage saturates at 1.0.
    """

    base: np.ndarray       # (P, N) unloaded latency per class/type
    b: np.ndarray          # (P,) fitted log-linear slope per class
    cpu_usage: np.ndarray  # (P, N)
    usage_cap: float = 4.0  # >1: oversubscribed CPU still adds latency signal

    def predict(self, classes: np.ndarray, ttype: int, counts: np.ndarray) -> np.ndarray:
        usage = np.minimum(
            (self.cpu_usage[classes] * counts).sum(axis=1), self.usage_cap
        )
        return self.base[classes, ttype] * np.exp(self.b[classes] * usage)


@register_policy("lats")
class LaTSPolicy(Policy):
    """Latency-aware task scheduling via the latency–CPU-usage model.

    LaTS predicts execution latency well but ignores data-transfer and
    model-upload costs as well as failure probability — which is why (as in
    the paper) it concentrates load on the single fastest device."""

    def __init__(
        self,
        *,
        lats_model: Optional[LaTSModel] = None,
        model: Optional[LaTSModel] = None,
        seed: int = 0,
        **_,
    ):
        self.model = lats_model if lats_model is not None else model
        if self.model is None:
            raise ValueError("LaTS needs a fitted LaTSModel (lats_model=...)")
        self.rng = np.random.default_rng(seed)

    def decide(self, ctx: PolicyContext) -> TaskDecision:
        ids = ctx.feasible_ids
        if ids.size == 0:
            return TaskDecision(devices=())
        pred = self.model.predict(ctx.classes[ids], ctx.ttype, ctx.counts[ids])
        # Devices of the same class at saturated CPU usage produce identical
        # predictions; break ties randomly so LaTS spreads within its
        # favourite class instead of degenerating onto device 0.
        lo = pred.min()
        ties = np.flatnonzero(pred <= lo * (1.0 + 1e-9))
        return TaskDecision(devices=(int(ids[int(self.rng.choice(ties))]),))

    def decide_batch(self, batch: BatchedPolicyContext) -> BatchedDecision:
        # The latency model is evaluated once per DISTINCT context row in
        # one vectorised shot; only the per-row tie-break draw stays
        # sequential (it must replay the scalar rng stream).
        model = self.model
        classes = batch.classes
        counts_g = batch.counts_pool[batch.bucket_inv[batch.pool_first]]
        tt_g = batch.ttypes[batch.pool_first]               # (G,)
        usage = np.minimum(
            (model.cpu_usage[classes][None, :, :] * counts_g).sum(axis=2),
            model.usage_cap,
        )                                                   # (G, D)
        pred = model.base[classes[None, :], tt_g[:, None]] * np.exp(
            model.b[classes][None, :] * usage
        )                                                   # (G, D)
        row_pool = batch.row_pool
        out = []
        for b in range(batch.n_rows):
            ids = batch.feasible_ids(b)
            if ids.size == 0:
                out.append(())
                continue
            pred_sub = pred[row_pool[b], ids]
            lo = pred_sub.min()
            ties = np.flatnonzero(pred_sub <= lo * (1.0 + 1e-9))
            out.append((int(ids[int(self.rng.choice(ties))]),))
        return BatchedDecision(devices=tuple(out))


# -- multi-tier fleets (arXiv:2409.10839's device -> edge -> cloud extension) --
@register_policy("tier_escalation")
class TierEscalationPolicy(Policy):
    """Prefer same-tier placement, escalate device -> edge server -> cloud.

    Tasks originate on the end-device tier; the policy places on the
    min-``total``-latency feasible device of the lowest tier level whose
    best candidate meets ``latency_budget`` (Eq. 2 latency, which already
    prices transfers over the tier-aware link matrix).  A tier level is
    escalated past when it has no memory-feasible device or its best
    candidate blows the budget; if even the cloud misses the budget, the
    globally best feasible device wins.  Stateless, so the batched path
    decides once per distinct context row and fans out."""

    def __init__(self, *, latency_budget: float = float("inf"), **_):
        self.latency_budget = float(latency_budget)

    def _tiers_of(self, tiers: Optional[np.ndarray], n: int) -> np.ndarray:
        if tiers is None:
            return np.zeros(n, dtype=np.int64)
        return tiers

    def decide(self, ctx: PolicyContext) -> TaskDecision:
        tiers = self._tiers_of(ctx.tiers, ctx.n_devices)
        return TaskDecision(
            devices=self._pick(ctx.total, ctx.feasible, tiers)
        )

    def decide_batch(self, batch: BatchedPolicyContext) -> BatchedDecision:
        tiers = self._tiers_of(batch.tiers, batch.n_devices)
        if batch.n_distinct < BATCH_KERNEL_MIN_ROWS:
            pool_dec = [
                self._pick(batch.total_pool[g], batch.feasible_pool[g], tiers)
                for g in range(batch.n_distinct)
            ]
        else:
            pool_dec = tier_escalation_decide_batch(
                batch.total_pool, batch.feasible_pool, tiers,
                self.latency_budget,
            )
        return BatchedDecision(devices=tuple(
            pool_dec[g] for g in batch.row_pool.tolist()
        ))

    def _pick(
        self, total: np.ndarray, feasible: np.ndarray, tiers: np.ndarray
    ) -> Tuple[int, ...]:
        """The scalar reference rule (the fused kernel's bit-exact twin)."""
        if not feasible.any():
            return ()
        budget = self.latency_budget
        for lv in range(int(tiers.max()) + 1):
            masked = np.where(feasible & (tiers <= lv), total, np.inf)
            best = int(np.argmin(masked))
            if np.isfinite(masked[best]) and masked[best] <= budget:
                return (best,)
        return (int(np.argmin(np.where(feasible, total, np.inf))),)


# -- churn-aware planning (the availability forecast as a policy input) --------
@register_policy("churn_aware")
class ChurnAwarePolicy(IBDASHPolicy):
    """IBDASH scoring over forecast-adjusted failure probabilities.

    The paper prices future departures only through the memoryless
    ``F(T_i)`` (Eq. 3), but scripted maintenance windows and predicted
    departures are *knowable in advance* (the mobility-aware orchestration
    premise of arXiv:2110.07808).  When an availability forecast is
    installed (``ChurnSchedule.install`` / ``ClusterState.install_forecast``)
    the contexts carry each candidate's survival over the task's estimated
    execution span, and this policy:

      * drops candidates whose survival is at or below ``surv_floor``
        (default 0.0 — i.e. candidates the forecast says WILL depart before
        the task completes) whenever at least one feasible survivor exists,
        so a task is never knowingly placed across a maintenance window;
      * replaces the memoryless ``pf`` with the compound hazard
        ``1 - S_d * (1 - pf_d)`` — the device must dodge both the forecast
        hazard and the residual memoryless one — and runs Algorithm 1's
        score-and-replicate rule unchanged over it.

    With no forecast installed (or the uniform all-ones forecast) both
    adjustments are exact no-ops — ``np.where(S >= 1, pf, ...)`` keeps the
    pf column bit-identical — so placements equal registry ``ibdash``
    bit-for-bit (pinned by the parity suite).  Stateless; the batched path
    reuses the jitted IBDASH scan kernel over the adjusted columns and is
    bit-identical to the scalar twin.
    """

    def __init__(self, *, surv_floor: float = 0.0, **kwargs):
        super().__init__(**kwargs)
        self.surv_floor = float(surv_floor)

    def _adjust(
        self, pf: np.ndarray, feasible: np.ndarray, surv: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(pf_eff, feasible_eff) for one row or a whole (G, D) pool."""
        # exact no-op where the forecast is uniform: 1 - 1*(1 - pf) is NOT
        # bit-identical to pf in IEEE arithmetic, so branch on S >= 1
        pf_eff = np.where(surv >= 1.0, pf, 1.0 - surv * (1.0 - pf))
        ok = feasible & (surv > self.surv_floor)
        if ok.ndim == 1:
            feas_eff = ok if ok.any() else feasible
        else:
            has = ok.any(axis=1)
            feas_eff = np.where(has[:, None], ok, feasible)
        return pf_eff, feas_eff

    def _columns(
        self, ctx: PolicyContext
    ) -> Tuple[np.ndarray, np.ndarray]:
        pf, feasible = super()._columns(ctx)
        if ctx.survival is None:        # hand-built context: no forecast
            return pf, feasible
        return self._adjust(pf, feasible, ctx.survival)

    def _batch_columns(
        self, batch: BatchedPolicyContext
    ) -> Tuple[np.ndarray, np.ndarray]:
        pf, feasible = super()._batch_columns(batch)
        return self._adjust(pf, feasible, batch.survival_pool)
