"""Interference service-time model (paper §IV-A).

The paper characterises interference on an edge device with *linear service
time plots*: running a new task of type ``T_i`` on device ``ED_p`` while
``k`` tasks of type ``T_j`` are already co-located costs

    f_ij(T_i, k * T_j) = m[p, i, j] * k + c[p, i]

and the patterns are assumed **independent and additive** (verified in the
paper's Fig. 4), so with running-task counts ``alpha = (a_1..a_N)``:

    f_i(T_i, alpha) = c[p, i] + sum_j m[p, i, j] * a_j              (Eq. 1)

``c`` depends only on (device, task type) — it is the unloaded base latency —
while the pairwise slopes ``m`` form the N^2 interference-coefficient matrix
``ED_mc`` of the paper.

The same linear law is reused by the serving scheduler
(:mod:`repro.serve.scheduler`): decode-step latency of a continuously-batched
replica grows linearly in the number of co-resident sequences, so each model
replica is a "device" and each request class a "task type".
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

__all__ = ["InterferenceModel", "fit_linear_interference"]


@dataclass
class InterferenceModel:
    """Vectorised ``ED_mc`` table.

    base  : (n_classes, n_types)            -- c[p, i]
    slope : (n_classes, n_types, n_types)   -- m[p, i, j]
    """

    base: np.ndarray
    slope: np.ndarray

    def __post_init__(self) -> None:
        self.base = np.asarray(self.base, dtype=np.float64)
        self.slope = np.asarray(self.slope, dtype=np.float64)
        if self.base.ndim != 2 or self.slope.ndim != 3:
            raise ValueError("base must be (P,N), slope must be (P,N,N)")
        p, n = self.base.shape
        if self.slope.shape != (p, n, n):
            raise ValueError(
                f"slope shape {self.slope.shape} inconsistent with base {self.base.shape}"
            )
        if (self.base < 0).any() or (self.slope < 0).any():
            raise ValueError("negative interference coefficients")

    @property
    def n_classes(self) -> int:
        return self.base.shape[0]

    @property
    def n_types(self) -> int:
        return self.base.shape[1]

    # -- Eq. (1) ---------------------------------------------------------------
    def estimate(self, cls: int, ttype: int, counts: np.ndarray) -> float:
        """Expected service time of a new ``ttype`` task on a class-``cls``
        device currently running ``counts[j]`` tasks of each type."""
        return float(self.base[cls, ttype] + self.slope[cls, ttype] @ counts)

    def estimate_all_classes(self, ttype: int, counts_per_class: np.ndarray) -> np.ndarray:
        """Vectorised Eq. (1) across every device class at once.

        counts_per_class: (P, N) running-task counts for one device of each
        class.  Returns (P,) expected service times.
        """
        return self.base[:, ttype] + np.einsum(
            "pj,pj->p", self.slope[:, ttype, :], counts_per_class
        )

    def estimate_devices(
        self, classes: np.ndarray, ttype: int, counts: np.ndarray
    ) -> np.ndarray:
        """Eq. (1) for a fleet: ``classes`` is (D,) class ids, ``counts`` is
        (D, N) per-device running-task counts.  Returns (D,) estimates."""
        return self.base[classes, ttype] + np.einsum(
            "dj,dj->d", self.slope[classes, ttype, :], counts
        )

    def pair_plot(self, cls: int, i: int, j: int, k_max: int = 10) -> np.ndarray:
        """The raw 'interference plot' f_ij(T_i, k*T_j) for k = 0..k_max
        (paper Fig. 2a / Fig. 4)."""
        k = np.arange(k_max + 1, dtype=np.float64)
        return self.base[cls, i] + self.slope[cls, i, j] * k


def fit_linear_interference(
    k_counts: Sequence[float], latencies: Sequence[float]
) -> tuple:
    """Least-squares fit of one interference plot ``lat = m*k + c``.

    Used both by the offline profiler of the edge simulator and by the
    serving scheduler when it calibrates decode-latency-vs-batch-size from
    real measurements.  Returns ``(m, c, r2)``.
    """
    k = np.asarray(k_counts, dtype=np.float64)
    y = np.asarray(latencies, dtype=np.float64)
    if k.shape != y.shape or k.ndim != 1 or k.size < 2:
        raise ValueError("need >=2 paired samples")
    A = np.stack([k, np.ones_like(k)], axis=1)
    (m, c), *_ = np.linalg.lstsq(A, y, rcond=None)
    pred = m * k + c
    ss_res = float(((y - pred) ** 2).sum())
    ss_tot = float(((y - y.mean()) ** 2).sum())
    # a (numerically) constant line is a perfect fit, not an undefined one
    if ss_tot <= 1e-12 * max(1.0, float((y * y).sum())):
        r2 = 1.0
    else:
        r2 = 1.0 - ss_res / ss_tot
    return float(m), float(c), r2
