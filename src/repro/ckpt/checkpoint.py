"""Checkpoint save/restore.

Production requirements implemented here:
  * atomic writes (tmp + rename) with a JSON manifest carrying step, tree
    structure and per-leaf CRC32 checksums — a torn write can never be
    mistaken for a valid checkpoint;
  * REPLICATION across k independent directories ("devices" in the paper's
    sense): the paper's insight — replicate work placed on failure-prone
    resources — applied to checkpoint durability.  Restore scans replicas
    in recency order and takes the first that passes checksum validation;
  * async mode: the save runs on a background thread over a host snapshot
    of the arrays, overlapping serialization with the next train steps;
  * ``CheckpointManager.maybe_save`` implements the Young/Daly cadence
    ``tau = sqrt(2 C / lambda)`` from the fleet failure rate (paper's
    Table-IV exponential model), re-estimated online from observed write
    costs.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from ..core.availability import gang_failure_rate, young_daly_interval

__all__ = ["save_checkpoint", "load_checkpoint", "CheckpointManager"]

# Manifest timestamps come from an injectable clock so tests (and replayed
# sims, which own virtual time) can produce bit-identical checkpoints;
# ``time.time`` stays the production default.
Clock = Callable[[], float]


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    arrs = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    return arrs, treedef


def _crc(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a).tobytes())


def save_checkpoint(path: str, tree: Any, step: int,
                    extra: Optional[Dict[str, Any]] = None, *,
                    clock: Clock = time.time) -> str:
    """Atomically write one checkpoint directory ``<path>/step_<n>``."""
    os.makedirs(path, exist_ok=True)
    final = os.path.join(path, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=".ckpt_tmp_", dir=path)
    try:
        arrs, _ = _flatten(tree)
        manifest = {
            "step": int(step),
            "time": float(clock()),
            "leaves": {
                k: {"shape": list(v.shape), "dtype": str(v.dtype), "crc": _crc(v)}
                for k, v in arrs.items()
            },
            "extra": extra or {},
        }
        np.savez(os.path.join(tmp, "arrays.npz"), **arrs)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def _validate_and_load(ckpt_dir: str, like: Any) -> Tuple[Any, int, Dict]:
    with open(os.path.join(ckpt_dir, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(ckpt_dir, "arrays.npz"))
    leaves, treedef = jax.tree.flatten(like)
    out = []
    for i, ref_leaf in enumerate(leaves):
        key = f"leaf_{i}"
        a = data[key]
        meta = manifest["leaves"][key]
        if _crc(a) != meta["crc"]:
            raise IOError(f"checksum mismatch in {ckpt_dir}:{key}")
        if list(a.shape) != list(ref_leaf.shape):
            raise IOError(
                f"shape mismatch in {ckpt_dir}:{key}: "
                f"{a.shape} vs {ref_leaf.shape}"
            )
        out.append(a)
    return treedef.unflatten(out), manifest["step"], manifest.get("extra", {})


def load_checkpoint(paths: Sequence[str], like: Any
                    ) -> Tuple[Any, int, Dict[str, Any]]:
    """Restore the newest VALID checkpoint across every replica directory.

    Corrupted/torn replicas are skipped (checksums); raises FileNotFoundError
    when no valid checkpoint exists anywhere."""
    candidates: List[Tuple[int, str]] = []
    for root in paths:
        if not os.path.isdir(root):
            continue
        for name in os.listdir(root):
            if name.startswith("step_"):
                try:
                    candidates.append((int(name.split("_")[1]), os.path.join(root, name)))
                except ValueError:
                    continue
    candidates.sort(reverse=True)
    errors = []
    for step, d in candidates:
        try:
            return _validate_and_load(d, like)
        except Exception as e:  # torn/corrupt replica: try the next one
            errors.append(f"{d}: {e}")
    raise FileNotFoundError(
        "no valid checkpoint found" + (f"; errors: {errors}" if errors else "")
    )


@dataclass
class CheckpointManager:
    """Replicated, optionally async checkpointing with Young/Daly cadence.

    replica_dirs : k independent directories (ideally on independent failure
                   domains).  The replication degree is the paper's gamma.
    fleet_lams   : per-pod failure rates; the JOB fails if any pod fails, so
                   rates add (gang_failure_rate).
    """

    replica_dirs: Sequence[str]
    fleet_lams: Sequence[float] = (1e-5,)
    async_save: bool = False
    keep: int = 3
    clock: Clock = time.time      # manifest timestamps (inject for tests)

    _last_save_t: float = field(default=0.0, init=False)
    _write_cost: float = field(default=30.0, init=False)   # prior estimate, s
    _thread: Optional[threading.Thread] = field(default=None, init=False)
    _errors: List[str] = field(default_factory=list, init=False)

    @property
    def interval(self) -> float:
        lam = gang_failure_rate(self.fleet_lams)
        return young_daly_interval(lam, self._write_cost)

    def due(self, now: Optional[float] = None) -> bool:
        now = time.monotonic() if now is None else now
        return (now - self._last_save_t) >= self.interval

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._errors:
            errs, self._errors = self._errors, []
            raise IOError(f"async checkpoint failed: {errs}")

    def _write_all(self, host_tree: Any, step: int, extra) -> None:
        t0 = time.monotonic()
        try:
            for d in self.replica_dirs:
                save_checkpoint(d, host_tree, step, extra, clock=self.clock)
                self._gc(d)
        except Exception as e:
            self._errors.append(str(e))
            return
        # online estimate of the write cost drives the Young/Daly interval
        self._write_cost = 0.5 * self._write_cost + 0.5 * max(
            time.monotonic() - t0, 1e-3
        )

    def save(self, tree: Any, step: int, extra: Optional[Dict] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # device->host snapshot
        self._last_save_t = time.monotonic()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write_all, args=(host_tree, step, extra), daemon=True
            )
            self._thread.start()
        else:
            self._write_all(host_tree, step, extra)
            if self._errors:
                errs, self._errors = self._errors, []
                raise IOError(f"checkpoint failed: {errs}")

    def maybe_save(self, tree: Any, step: int, extra: Optional[Dict] = None) -> bool:
        if not self.due():
            return False
        self.save(tree, step, extra)
        return True

    def restore(self, like: Any) -> Tuple[Any, int, Dict[str, Any]]:
        return load_checkpoint(self.replica_dirs, like)

    def _gc(self, root: str) -> None:
        steps = sorted(
            (n for n in os.listdir(root) if n.startswith("step_")), reverse=True
        )
        for name in steps[self.keep:]:
            shutil.rmtree(os.path.join(root, name), ignore_errors=True)
