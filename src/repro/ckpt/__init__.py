"""Checkpointing: atomic, checksummed, replicated, async — cadence driven by
the paper's exponential availability model (Young/Daly interval)."""
from .checkpoint import CheckpointManager, load_checkpoint, save_checkpoint

__all__ = ["CheckpointManager", "save_checkpoint", "load_checkpoint"]
