"""Optimizers (AdamW, Adafactor), LR schedules, gradient clipping and
gradient compression — all hand-rolled in JAX (no optax dependency)."""
from .optimizers import AdamW, Adafactor, Optimizer, clip_by_global_norm, global_norm
from .schedules import constant, cosine_with_warmup, linear_warmup
from .compression import compress_gradients, decompress_gradients, int8_quantize, int8_dequantize

__all__ = [
    "Optimizer",
    "AdamW",
    "Adafactor",
    "clip_by_global_norm",
    "global_norm",
    "constant",
    "cosine_with_warmup",
    "linear_warmup",
    "compress_gradients",
    "decompress_gradients",
    "int8_quantize",
    "int8_dequantize",
]
