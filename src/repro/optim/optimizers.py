"""AdamW and Adafactor, pytree-native.

Design notes for the 512-chip configs:
  * Optimizer state inherits the parameter sharding (states are created with
    ``jax.tree.map`` over params inside the jitted train step, so GSPMD
    propagates the param PartitionSpecs — ZeRO-style sharded states for free).
  * ``state_dtype`` lets the huge configs keep m/v in bf16.
  * Adafactor factors the second moment of rank>=2 leaves into row/col
    statistics — O(n+m) instead of O(n*m) state — which is what lets
    DeepSeek-V3 (671B) train within 16 GB/chip HBM (see EXPERIMENTS.md).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "AdamW", "Adafactor", "clip_by_global_norm", "global_norm"]

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


def _lr_at(lr: Schedule, step: jnp.ndarray) -> jnp.ndarray:
    return lr(step) if callable(lr) else jnp.asarray(lr, dtype=jnp.float32)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (n + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), n


class Optimizer:
    """init(params) -> state;  update(grads, state, params) -> (params, state)."""

    def init(self, params) -> Dict[str, Any]:
        raise NotImplementedError

    def update(self, grads, state, params):
        raise NotImplementedError


@dataclass(frozen=True)
class AdamW(Optimizer):
    lr: Schedule = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    state_dtype: Optional[str] = None   # None = param dtype; "bfloat16" to halve state
    clip_norm: Optional[float] = 1.0

    def _sd(self, p):
        return jnp.dtype(self.state_dtype) if self.state_dtype else p.dtype

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, dtype=self._sd(p))
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(self, grads, state, params):
        if self.clip_norm is not None:
            grads, gn = clip_by_global_norm(grads, self.clip_norm)
        step = state["step"] + 1
        lr = _lr_at(self.lr, step)
        c1 = 1.0 - self.b1 ** step.astype(jnp.float32)
        c2 = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            mf = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * gf
            vf = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * gf * gf
            u = (mf / c1) / (jnp.sqrt(vf / c2) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            return newp, mf.astype(m.dtype), vf.astype(v.dtype)

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        newp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        newm = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        newv = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return newp, {"step": step, "m": newm, "v": newv}


@dataclass(frozen=True)
class Adafactor(Optimizer):
    """Adafactor (Shazeer & Stern '18) with factored second moments, no
    momentum, update clipping — the memory-lean choice for >=100B configs."""

    lr: Schedule = 1e-3
    decay: float = 0.8        # beta2_t = 1 - step^-decay
    eps1: float = 1e-30
    eps2: float = 1e-3
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    min_dim_size_to_factor: int = 128

    def _factored(self, p) -> bool:
        return (
            p.ndim >= 2
            and p.shape[-1] >= self.min_dim_size_to_factor
            and p.shape[-2] >= self.min_dim_size_to_factor
        )

    def init(self, params):
        def st(p):
            if self._factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], dtype=jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], dtype=jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, dtype=jnp.float32)}

        return {"step": jnp.zeros((), jnp.int32), "v": jax.tree.map(
            st, params, is_leaf=lambda x: isinstance(x, jnp.ndarray) or hasattr(x, "shape")
        )}

    def update(self, grads, state, params):
        step = state["step"] + 1
        sf = step.astype(jnp.float32)
        beta2 = 1.0 - sf ** (-self.decay)
        lr = _lr_at(self.lr, step)

        def upd(p, g, v):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + self.eps1
            if "vr" in v:
                vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(axis=-1)
                vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(axis=-2)
                denom = vr.mean(axis=-1, keepdims=True)
                r = (vr / jnp.maximum(denom, self.eps1))[..., None]
                c = vc[..., None, :]
                u = gf * jax.lax.rsqrt(jnp.maximum(r * c, self.eps1))
                newv = {"vr": vr, "vc": vc}
            else:
                vf = beta2 * v["v"] + (1 - beta2) * g2
                u = gf * jax.lax.rsqrt(jnp.maximum(vf, self.eps1))
                newv = {"v": vf}
            # update clipping (RMS(u) <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            scale = lr * jnp.maximum(self.eps2, 1.0)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - scale * u).astype(p.dtype), newv

        leaves_p, treedef = jax.tree.flatten(params)
        leaves_g = treedef.flatten_up_to(grads)
        leaves_v = treedef.flatten_up_to(state["v"])
        outs = [upd(p, g, v) for p, g, v in zip(leaves_p, leaves_g, leaves_v)]
        newp = treedef.unflatten([o[0] for o in outs])
        newv = treedef.unflatten([o[1] for o in outs])
        return newp, {"step": step, "v": newv}
