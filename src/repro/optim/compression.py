"""Gradient compression for slow inter-pod links.

At 512+ chips the pod-to-pod all-reduce rides DCN-class links that are an
order of magnitude slower than in-pod ICI.  ``compress_gradients`` performs
per-leaf int8 quantisation with a float32 per-leaf max-abs scale (stochastic
rounding optional) so the cross-pod all-reduce moves 4x fewer bytes; the
receiver dequantises and the (bf16) in-pod reduction stays exact.

This is a *distributed-optimization trick* layer: the train step exposes
``grad_compression='int8'|'none'`` and the dry-run shows the collective-byte
delta in the roofline table.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["int8_quantize", "int8_dequantize", "compress_gradients", "decompress_gradients"]


def int8_quantize(x: jnp.ndarray, rng: Optional[jax.Array] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantisation. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    y = xf / scale
    if rng is not None:  # stochastic rounding: unbiased gradient estimate
        y = jnp.floor(y + jax.random.uniform(rng, y.shape))
    else:
        y = jnp.round(y)
    return jnp.clip(y, -127, 127).astype(jnp.int8), scale


def int8_dequantize(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_gradients(grads: Any, rng: Optional[jax.Array] = None) -> Any:
    """Quantise every leaf; returns a pytree of (q, scale) dicts."""
    leaves, treedef = jax.tree.flatten(grads)
    if rng is not None:
        rngs = jax.random.split(rng, len(leaves))
    else:
        rngs = [None] * len(leaves)
    out = []
    for leaf, r in zip(leaves, rngs):
        q, s = int8_quantize(leaf, r)
        out.append({"q": q, "scale": s})
    return treedef.unflatten(out)


def decompress_gradients(cgrads: Any, like: Any) -> Any:
    return jax.tree.map(
        lambda c, p: int8_dequantize(c["q"], c["scale"], p.dtype),
        cgrads, like,
        is_leaf=lambda x: isinstance(x, dict) and "q" in x,
    )
