"""Learning-rate schedules (callables of the int32 step)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["constant", "linear_warmup", "cosine_with_warmup"]


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup(lr: float, warmup: int):
    def f(step):
        s = step.astype(jnp.float32)
        return lr * jnp.minimum(1.0, s / max(warmup, 1))
    return f


def cosine_with_warmup(lr: float, warmup: int, total: int, final_frac: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, s / max(warmup, 1))
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * warm * cos
    return f
