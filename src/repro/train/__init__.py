"""Training: step factory (grad accumulation, cross-pod compressed
reduction), remat policies, and the pipeline-parallel demo schedule."""
from .step import TrainState, make_train_step, make_eval_step

__all__ = ["TrainState", "make_train_step", "make_eval_step"]
