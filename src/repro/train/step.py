"""Train-step factory.

Features:
  * gradient accumulation over ``microbatches`` via ``lax.scan`` (constant
    memory in the number of microbatches);
  * optional int8-compressed cross-pod gradient reduction: gradients are
    computed per-pod under ``shard_map`` (manual over the slow "pod" axis,
    auto over in-pod "data"/"model"), quantised, all-gathered across pods as
    int8 and averaged — 4x fewer bytes on the DCN-class inter-pod links;
  * donated params/opt-state for in-place updates.

The returned function is pure and jit-able; callers (launcher / dry-run)
attach in/out shardings.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models.transformer import LM
from ..optim.compression import int8_dequantize, int8_quantize
from ..optim.optimizers import Optimizer, global_norm

__all__ = ["TrainState", "make_train_step", "make_eval_step"]


@dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: int = 0


def _split_microbatches(batch: Dict[str, jnp.ndarray], m: int) -> Dict[str, jnp.ndarray]:
    def sp(x):
        if x.ndim >= 2 and x.shape[0] % m == 0:
            return x.reshape((m, x.shape[0] // m) + x.shape[1:])
        if x.ndim >= 2 and x.shape[1] % m == 0:   # (3,B,S) position_ids
            return jnp.swapaxes(
                x.reshape((x.shape[0], m, x.shape[1] // m) + x.shape[2:]), 0, 1
            )
        raise ValueError(f"cannot split leading batch dim {x.shape} into {m}")
    return jax.tree.map(sp, batch)


def _cross_pod_int8_mean(grads, mesh, rng):
    """Quantise local-pod gradients, all-gather int8 across 'pod', average.

    Runs inside shard_map (manual over 'pod'); each leaf is the pod-local
    gradient. Returns the dequantised cross-pod mean."""
    npod = mesh.shape["pod"]

    def reduce_leaf(g, key):
        q, scale = int8_quantize(g, key)
        qs = jax.lax.all_gather(q, "pod")                  # (npod, ...)
        ss = jax.lax.all_gather(scale, "pod")              # (npod,)
        deq = (qs.astype(jnp.float32) * ss.reshape((npod,) + (1,) * g.ndim)).sum(0)
        return (deq / npod).astype(g.dtype)

    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(rng, len(leaves))
    return treedef.unflatten([reduce_leaf(g, k) for g, k in zip(leaves, keys)])


def make_train_step(
    model: LM,
    optimizer: Optimizer,
    *,
    microbatches: int = 1,
    grad_compression: str = "none",     # "none" | "int8" (cross-pod)
    mesh=None,
) -> Callable:
    """Returns ``step(params, opt_state, batch) -> (params, opt_state, metrics)``."""

    def grads_of(params, batch):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        return grads, loss, metrics

    def accumulate(params, batch):
        if microbatches == 1:
            return grads_of(params, batch)
        mb = _split_microbatches(batch, microbatches)

        def body(carry, mbatch):
            acc, loss_sum = carry
            g, loss, _ = grads_of(params, mbatch)
            acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), acc, g)
            return (acc, loss_sum + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (acc, loss_sum), _ = jax.lax.scan(body, (zeros, jnp.float32(0)), mb)
        grads = jax.tree.map(lambda g: (g / microbatches), acc)
        return grads, loss_sum / microbatches, {}

    use_compression = grad_compression == "int8"
    if use_compression and (mesh is None or "pod" not in mesh.axis_names):
        raise ValueError("int8 grad compression needs a mesh with a 'pod' axis")

    def plain_step(params, opt_state, batch):
        grads, loss, metrics = accumulate(params, batch)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        out = {"loss": loss, "grad_norm": global_norm(grads)}
        for k, v in (metrics or {}).items():
            out[k] = v
        return new_params, new_opt, out

    if not use_compression:
        return plain_step

    # ---- compressed cross-pod variant -----------------------------------------
    batch_dims = {"tokens": 0, "labels": 0, "frames": 0, "position_ids": 1}

    def compressed_step(params, opt_state, batch, rng):
        in_batch_specs = {
            k: P(*([None] * batch_dims.get(k, 0) + ["pod"]))
            for k in batch
        }

        def per_pod(params, batch, rng):
            grads, loss, _ = accumulate(params, batch)
            grads = _cross_pod_int8_mean(grads, mesh, rng)
            loss = jax.lax.pmean(loss, "pod")
            return grads, loss

        # manual over the slow "pod" axis only; "data"/"model" stay auto
        grads, loss = jax.shard_map(
            per_pod, mesh=mesh,
            in_specs=(P(), in_batch_specs, P()),
            out_specs=(P(), P()),
            axis_names={"pod"},
            check_vma=False,
        )(params, batch, rng)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, {"loss": loss, "grad_norm": global_norm(grads)}

    return compressed_step


def make_eval_step(model: LM) -> Callable:
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch)
        return {"loss": loss, **metrics}
    return eval_step
