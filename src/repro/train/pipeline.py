"""Pipeline parallelism over a "stage" mesh axis (GPipe schedule).

Production framing: on a 2-pod mesh the "pod" axis can carry pipeline
stages instead of pure DP — inter-pod links then carry only the (mb, S, d)
activation edge per tick instead of full gradient all-reduces.  This module
implements the schedule with ``shard_map`` + ``jax.lax.ppermute``:

  * layer-stacked params are reshaped (L, ...) -> (P, L/P, ...) and sharded
    over "stage";
  * microbatches enter stage 0, flow P-1 hops of ppermute, and the loss is
    computed (masked) on the last stage;
  * the whole schedule is differentiable (ppermute transposes to the
    reverse ppermute), so ``jax.grad`` through the shard_map yields the
    1F1B-equivalent backward wave for free;
  * bubble fraction = (P-1)/(M+P-1), reported by ``pipeline_efficiency``.

SPMD caveat (DESIGN.md): under shard_map every stage executes the same
program, so stage-0-only work (embedding) and last-stage-only work (head)
are computed-and-masked on all stages.  MPMD pipelining would remove that;
it is orthogonal to the schedule shown here.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["pipeline_loss_fn", "pipeline_efficiency", "split_stages"]


def pipeline_efficiency(n_micro: int, n_stages: int) -> float:
    """Fraction of non-bubble ticks in the GPipe schedule."""
    return n_micro / (n_micro + n_stages - 1)


def split_stages(stacked_params: Any, n_stages: int) -> Any:
    """(L, ...) layer-stacked params -> (P, L/P, ...)."""
    def re(x):
        L = x.shape[0]
        if L % n_stages:
            raise ValueError(f"{L} layers not divisible into {n_stages} stages")
        return x.reshape((n_stages, L // n_stages) + x.shape[1:])
    return jax.tree.map(re, stacked_params)


def pipeline_loss_fn(
    mesh: Mesh,
    block_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
    embed_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
    loss_fn: Callable[[Any, jnp.ndarray, jnp.ndarray], jnp.ndarray],
    *,
    axis: str = "stage",
) -> Callable:
    """Build ``loss(params, batch) -> scalar`` running the GPipe schedule.

    params = {"stages": (P, L/P, ...) stacked block params,
              "embed":  embedding params        (replicated),
              "head":   head/loss params         (replicated)}
    batch  = {"tokens": (M, mb, S), "labels": (M, mb, S)} — M microbatches.
    """
    n_stages = mesh.shape[axis]

    def staged(params, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        M = tokens.shape[0]
        sid = jax.lax.axis_index(axis)
        stage_params = jax.tree.map(lambda x: x[0], params["stages"])
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def run_stage(x):
            def body(carry, lp):
                return block_fn(lp, carry), None
            y, _ = jax.lax.scan(body, x, stage_params)
            return y

        n_ticks = M + n_stages - 1
        mb, S = tokens.shape[1], tokens.shape[2]
        d = embed_fn(params["embed"], tokens[0]).shape[-1]
        buf = jnp.zeros((mb, S, d), embed_fn(params["embed"], tokens[0]).dtype)

        def tick(carry, t):
            buf, loss_sum, denom = carry
            # stage 0 injects microbatch t (clamped; masked by validity)
            m_in = jnp.clip(t, 0, M - 1)
            injected = embed_fn(params["embed"], jax.lax.dynamic_index_in_dim(
                tokens, m_in, axis=0, keepdims=False))
            x = jnp.where(sid == 0, injected, buf)
            y = run_stage(x)
            # last stage computes the loss for microbatch t - (P-1)
            m_out = t - (n_stages - 1)
            valid = jnp.logical_and(m_out >= 0, m_out < M)
            lbl = jax.lax.dynamic_index_in_dim(
                labels, jnp.clip(m_out, 0, M - 1), axis=0, keepdims=False)
            l = loss_fn(params["head"], y, lbl)
            is_last = sid == n_stages - 1
            loss_sum = loss_sum + jnp.where(valid & is_last, l, 0.0)
            denom = denom + jnp.where(valid & is_last, 1.0, 0.0)
            # ship activations one stage downstream
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, loss_sum, denom), None

        (buf, loss_sum, denom), _ = jax.lax.scan(
            tick, (buf, jnp.float32(0), jnp.float32(0)), jnp.arange(n_ticks)
        )
        # only the last stage holds the loss; share it with everyone
        total = jax.lax.psum(loss_sum, axis)
        count = jax.lax.psum(denom, axis)
        return total / jnp.maximum(count, 1.0)

    in_specs = (
        {"stages": P(axis), "embed": P(), "head": P()},
        {"tokens": P(), "labels": P()},
    )
    return shard_map(staged, mesh=mesh, in_specs=in_specs, out_specs=P(),
                     check_rep=False)
