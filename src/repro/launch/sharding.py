"""Sharding rules: parameter, optimizer-state, batch and cache PartitionSpecs.

Strategy (per DESIGN.md §Distribution):
  * "model" axis — tensor/expert parallelism: attention head projections,
    FFN hidden dims, expert dim (or expert-FFN dim when E doesn't divide),
    vocab dim of embeddings/heads, cache sequence dim (sequence-parallel
    split-KV decode).
  * "data" axis — batch + ZeRO-3/FSDP sharding of any large parameter on its
    largest still-unsharded divisible dim.
  * "pod" axis — pure data parallelism across pods (slow links carry only
    gradient all-reduce; optionally int8-compressed).

Every rule checks divisibility and silently degrades to replication on that
dim (e.g. Whisper's vocab 51865 is odd, so its embedding shards d_model
instead of vocab).  This keeps one rule-set valid across all 10 assigned
architectures x 4 input shapes x both meshes.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from .mesh import dp_axes, mesh_axis_sizes

__all__ = [
    "param_pspec",
    "param_shardings",
    "batch_shardings",
    "cache_shardings",
    "tree_shardings",
    "replicated",
]

# (regex over the flattened param path, trailing-dims spec template).
# The template applies to the LAST len(template) dims; leading (scan-stack)
# dims are None.  "data" entries are FSDP hints; all entries are dropped when
# the dim is not divisible by the axis size.
_PARAM_RULES: List[Tuple[str, Tuple[Optional[str], ...]]] = [
    # embeddings / head: shard vocab on model, d on data(FSDP)
    (r"embed/embedding$", ("model", "data")),
    (r"lm_head/w$", ("data", "model")),
    # MoE experts: (E, d, f) / (E, f, d) — expert parallelism on E,
    # FSDP on the middle dim
    (r"experts/wi$", ("model", "data", None)),
    (r"experts/wg$", ("model", "data", None)),
    (r"experts/wo$", ("model", None, "data")),
    (r"router/w$", (None, None)),
    (r"shared/wi/w$", ("data", "model")),
    (r"shared/wg/w$", ("data", "model")),
    (r"shared/wo/w$", ("model", "data")),
    # attention projections (column-parallel in, row-parallel out)
    (r"attn/wq/w$", ("data", "model")),
    (r"attn/wk/w$", ("data", "model")),
    (r"attn/wv/w$", ("data", "model")),
    (r"attn/wo/w$", ("model", "data")),
    (r"attn/w[qkv]/b$", ("model",)),
    (r"self_attn/w[qkv]/w$", ("data", "model")),
    (r"self_attn/wo/w$", ("model", "data")),
    (r"cross_attn/w[qkv]/w$", ("data", "model")),
    (r"cross_attn/wo/w$", ("model", "data")),
    # MLA
    (r"attn/wdq/w$", ("data", "model")),
    (r"attn/wuq/w$", (None, "model")),
    (r"attn/wdkv/w$", ("data", None)),
    (r"attn/wuk/w$", (None, "model")),
    (r"attn/wuv/w$", (None, "model")),
    # dense MLP
    (r"ffn/wi/w$", ("data", "model")),
    (r"ffn/wg/w$", ("data", "model")),
    (r"ffn/wo/w$", ("model", "data")),
    # RWKV6
    (r"block/w[rkvg]/w$", ("data", "model")),
    (r"block/wo/w$", ("model", "data")),
    (r"block/cm_k/w$", ("data", "model")),
    (r"block/cm_v/w$", ("model", "data")),
    (r"block/cm_r/w$", ("data", "model")),
    (r"block/w[AB]$", (None, None)),
    # RG-LRU
    (r"rec/proj_x/w$", ("data", "model")),
    (r"rec/proj_g/w$", ("data", "model")),
    (r"rec/proj_out/w$", ("model", "data")),
    (r"rec/conv$", (None, "model")),
    (r"rec/w[ax]$", ("model", None, None)),
]

FSDP_MIN_SIZE = 1 << 22   # 4M elements: smaller leaves stay replicated on "data"

# Inference ("weight-stationary") overrides: at decode, FSDP weight
# all-gathers repeat EVERY token step and dwarf the math (measured: 26 GB
# of f32 weight gathers per decode step on command-r-plus).  For serving,
# weights shard over "model" only; MoE experts move their second shard to
# the expert-FFN dim so cross-"data" traffic becomes activation-sized
# partial-sum reductions instead of weight gathers.
_INFER_RULES: List[Tuple[str, Tuple[Optional[str], ...]]] = [
    (r"embed/embedding$", ("model", None)),
    (r"lm_head/w$", (None, "model")),
    (r"experts/wi$", ("model", None, "data")),
    (r"experts/wg$", ("model", None, "data")),
    (r"experts/wo$", ("model", "data", None)),
    (r"router/w$", (None, None)),
    (r"shared/wi/w$", (None, "model")),
    (r"shared/wg/w$", (None, "model")),
    (r"shared/wo/w$", ("model", None)),
    (r"attn/w[qkv]/w$", (None, "model")),
    (r"attn/wo/w$", ("model", None)),
    (r"attn/w[qkv]/b$", ("model",)),
    (r"self_attn/w[qkv]/w$", (None, "model")),
    (r"self_attn/wo/w$", ("model", None)),
    (r"cross_attn/w[qkv]/w$", (None, "model")),
    (r"cross_attn/wo/w$", ("model", None)),
    (r"attn/wdq/w$", (None, "model")),
    (r"attn/wuq/w$", (None, "model")),
    (r"attn/wdkv/w$", (None, None)),
    (r"attn/wuk/w$", (None, "model")),
    (r"attn/wuv/w$", (None, "model")),
    (r"ffn/wi/w$", (None, "model")),
    (r"ffn/wg/w$", (None, "model")),
    (r"ffn/wo/w$", ("model", None)),
    (r"block/w[rkvg]/w$", (None, "model")),
    (r"block/wo/w$", ("model", None)),
    (r"block/cm_k/w$", (None, "model")),
    (r"block/cm_v/w$", ("model", None)),
    (r"block/cm_r/w$", (None, "model")),
    (r"block/w[AB]$", (None, None)),
    (r"rec/proj_x/w$", (None, "model")),
    (r"rec/proj_g/w$", (None, "model")),
    (r"rec/proj_out/w$", ("model", None)),
    (r"rec/conv$", (None, "model")),
    (r"rec/w[ax]$", ("model", None, None)),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


# Fully-expert-sharded training mode ("train_ep"): the expert dim spans
# model x data (256 experts over 256 chips) — expert weights never move;
# routed tokens all-to-all to their expert's chip instead.  Activation-sized
# traffic replaces weight-sized FSDP gathers (hillclimb #2, EXPERIMENTS.md).
_EP_FULL_OVERRIDES: List[Tuple[str, Tuple[Any, ...]]] = [
    (r"experts/wi$", (("model", "data"), None, None)),
    (r"experts/wg$", (("model", "data"), None, None)),
    (r"experts/wo$", (("model", "data"), None, None)),
]


def param_pspec(path_str: str, shape: Tuple[int, ...], mesh: Mesh,
                mode: str = "train") -> P:
    sizes = mesh_axis_sizes(mesh)
    msize = sizes.get("model", 1)
    dsize = sizes.get("data", 1)
    nd = len(shape)

    if mode == "train_ep":
        for pat, tmpl in _EP_FULL_OVERRIDES:
            if re.search(pat, path_str):
                off = nd - len(tmpl)
                spec = [None] * nd
                if shape[off] % (msize * dsize) == 0:
                    spec[off] = ("model", "data")
                    return P(*spec)
                break
        mode = "train"

    rules = _PARAM_RULES if mode == "train" else _INFER_RULES
    template: Optional[Tuple[Optional[str], ...]] = None
    for pat, tmpl in rules:
        if re.search(pat, path_str):
            template = tmpl
            break
    spec: List[Optional[str]] = [None] * nd
    if template is not None and nd >= len(template):
        off = nd - len(template)
        for i, ax in enumerate(template):
            if ax is None:
                continue
            axsize = msize if ax == "model" else dsize
            if ax in spec:                       # axis already used
                continue
            if shape[off + i] % axsize == 0 and axsize > 1:
                spec[off + i] = ax

    # FSDP fallback (train only): big leaf with "data" unused -> shard the
    # largest divisible dim
    n_elem = int(np.prod(shape)) if shape else 0
    if (mode == "train" and "data" not in spec and dsize > 1
            and n_elem >= FSDP_MIN_SIZE):
        order = sorted(range(nd), key=lambda i: -shape[i])
        for i in order:
            if spec[i] is None and shape[i] % dsize == 0:
                spec[i] = "data"
                break
    return P(*spec)


def param_shardings(params_shapes: Any, mesh: Mesh, mode: str = "train") -> Any:
    """Tree of NamedShardings matching an eval_shape'd params pytree."""

    def one(path, leaf):
        return NamedSharding(mesh, param_pspec(_path_str(path), leaf.shape, mesh, mode))

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def tree_shardings(shapes: Any, mesh: Mesh, spec_fn) -> Any:
    def one(path, leaf):
        return NamedSharding(mesh, spec_fn(_path_str(path), leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, shapes)


def _dp_for(batch: int, mesh: Mesh) -> Tuple[str, ...]:
    """Largest prefix of the dp axes that divides the batch."""
    axes = []
    prod = 1
    for a in dp_axes(mesh):
        size = mesh_axis_sizes(mesh)[a]
        if batch % (prod * size) == 0:
            axes.append(a)
            prod *= size
    return tuple(axes)


def batch_shardings(specs: Dict[str, jax.ShapeDtypeStruct], mesh: Mesh) -> Dict[str, NamedSharding]:
    """Input-batch shardings: batch dim over ("pod","data"), rest replicated."""
    out = {}
    for k, sds in specs.items():
        if k == "position_ids":            # (3, B, S)
            dp = _dp_for(sds.shape[1], mesh)
            spec = P(None, dp if dp else None)
        else:                               # (B, ...)
            dp = _dp_for(sds.shape[0], mesh)
            spec = P(dp if dp else None)
        out[k] = NamedSharding(mesh, spec)
    return out


def cache_pspec(path_str: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Decode-cache sharding.

    KV caches (L, B, C, hk, hd): batch over dp axes, the *sequence* dim C
    over "model" — split-KV (sequence-parallel) decode, where partial
    softmax stats reduce over the model axis.  Recurrent states shard their
    width/head dims over "model" when divisible.
    """
    sizes = mesh_axis_sizes(mesh)
    msize = sizes.get("model", 1)
    nd = len(shape)
    spec: List[Optional[str]] = [None] * nd
    if nd >= 2:
        dp = _dp_for(shape[1], mesh)
        if dp:
            spec[1] = dp if len(dp) > 1 else dp[0]
    name = path_str.rsplit("/", 1)[-1]
    if name in ("k", "v", "ckv", "krope") and nd >= 3:
        if shape[2] % msize == 0 and msize > 1:
            spec[2] = "model"
    elif name == "pos" and nd >= 3:
        if shape[2] % msize == 0 and msize > 1:
            spec[2] = "model"
    elif name in ("S",):                    # rwkv state (L,B,H,N,N): shard N(k-dim)
        if nd >= 4 and shape[-2] % msize == 0 and msize > 1:
            spec[-2] = "model"
    elif name in ("h", "conv", "ts_tm", "ts_cm"):   # width-sharded recurrent state
        if shape[-1] % msize == 0 and msize > 1:
            spec[-1] = "model"
    return P(*spec)


def cache_shardings(cache_shapes: Any, mesh: Mesh) -> Any:
    return tree_shardings(cache_shapes, mesh, cache_pspec)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
