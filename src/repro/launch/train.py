"""End-to-end training driver.

Runs a real training loop on whatever devices exist (CPU here, TPU pod in
production): synthetic-but-learnable LM data through the Prefetcher, jitted
train step with the production sharding rules, replicated checkpointing on
the Young/Daly cadence, and crash-restart resume.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --reduced \
      --steps 60 --batch 8 --seq 128

``--simulate-failure N`` kills-and-restores at step N to exercise the
restart path end-to-end.
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..ckpt.checkpoint import CheckpointManager
from ..configs import ARCHS, get_config
from ..data.pipeline import Prefetcher
from ..data.synthetic import SyntheticLM
from ..models import LM, reduced
from ..optim.optimizers import AdamW
from ..optim.schedules import cosine_with_warmup
from .mesh import make_host_mesh
from .sharding import batch_shardings, param_shardings
from ..train.step import make_train_step

__all__ = ["train", "main"]


def train(
    arch: str = "qwen1.5-0.5b",
    *,
    use_reduced: bool = True,
    steps: int = 60,
    batch: int = 8,
    seq: int = 128,
    lr: float = 3e-3,
    microbatches: int = 1,
    ckpt_dirs=("/tmp/repro_ckpt/a", "/tmp/repro_ckpt/b"),
    async_ckpt: bool = True,
    resume: bool = False,
    log_every: int = 10,
    simulate_failure: Optional[int] = None,
    seed: int = 0,
) -> Dict[str, Any]:
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg, vocab=min(cfg.vocab, 2048))
    model = LM(cfg)
    mesh = make_host_mesh(data=len(jax.devices()))

    optimizer = AdamW(lr=cosine_with_warmup(lr, warmup=max(steps // 10, 1),
                                            total=steps))
    step_fn = make_train_step(model, optimizer, microbatches=microbatches)

    rng = jax.random.PRNGKey(seed)
    params = model.init(rng)
    opt_state = optimizer.init(params)
    start_step = 0

    mgr = CheckpointManager(replica_dirs=list(ckpt_dirs), fleet_lams=[2e-4],
                            async_save=async_ckpt, keep=2)
    if resume:
        try:
            (params, opt_state), start_step, _ = mgr.restore((params, opt_state))
            print(f"[train] resumed from step {start_step}")
        except FileNotFoundError:
            print("[train] no checkpoint found; starting fresh")

    data = Prefetcher(SyntheticLM(cfg.vocab, batch, seq, seed=seed), depth=2)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    losses = []
    t0 = time.perf_counter()
    s = start_step
    it = iter(data)
    while s < steps:
        batch_np = next(it)
        if cfg.needs_position_ids:
            batch_np = dict(batch_np)
            batch_np["position_ids"] = np.broadcast_to(
                np.arange(seq, dtype=np.int32), (3, batch, seq)).copy()
        if cfg.enc_dec:
            batch_np = dict(batch_np)
            batch_np["frames"] = np.zeros(
                (batch, cfg.enc_len, cfg.d_model), dtype=np.float32)
        params, opt_state, metrics = jit_step(params, opt_state, batch_np)
        loss = float(metrics["loss"])
        losses.append(loss)
        s += 1
        if s % log_every == 0 or s == steps:
            dt = (time.perf_counter() - t0) / max(s - start_step, 1)
            print(f"[train] step {s:5d}  loss {loss:7.4f}  "
                  f"grad_norm {float(metrics['grad_norm']):8.3f}  {dt*1e3:7.1f} ms/step")
        if mgr.maybe_save((params, opt_state), s):
            print(f"[train] checkpoint @ step {s} (Young-Daly interval "
                  f"{mgr.interval:.0f}s, {len(mgr.replica_dirs)} replicas)")
        if simulate_failure is not None and s == simulate_failure:
            print(f"[train] !! simulated failure at step {s}: dropping state, "
                  f"restoring from replicated checkpoint")
            mgr.wait()
            mgr.save((params, opt_state), s)   # pretend last ckpt was here
            params = model.init(jax.random.PRNGKey(seed + 99))   # "lost" state
            opt_state = optimizer.init(params)
            (params, opt_state), s, _ = mgr.restore((params, opt_state))
            simulate_failure = None
    mgr.wait()
    data.close()
    return {
        "first_loss": losses[0],
        "final_loss": float(np.mean(losses[-5:])),
        "losses": losses,
        "steps": steps,
        "params": params,
        "config": cfg,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=ARCHS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--simulate-failure", type=int, default=None)
    args = ap.parse_args()
    out = train(
        args.arch, use_reduced=args.reduced, steps=args.steps,
        batch=args.batch, seq=args.seq, lr=args.lr,
        microbatches=args.microbatches, resume=args.resume,
        simulate_failure=args.simulate_failure,
    )
    print(f"[train] loss {out['first_loss']:.4f} -> {out['final_loss']:.4f} "
          f"over {out['steps']} steps")


if __name__ == "__main__":
    main()
