"""Roofline analysis over the dry-run grid (TPU v5e targets).

Per (arch x shape x mesh) cell, derive the three roofline terms from the
compiled artifact (per-device numbers; the dry-run already extrapolated
scan trip counts):

    compute    = HLO_flops_per_device / peak_flops          (197 TFLOP/s bf16)
    memory     = HLO_bytes_per_device / hbm_bw              (819 GB/s)
    collective = collective_bytes_per_device / ici_bw       (~50 GB/s/link)

The step-time lower bound is max(terms); the dominant term is the
bottleneck the §Perf loop iterates on.  Also reported:

    MODEL_FLOPS  = k*N*D  (k = 6 train / 2 inference, N = params or active
                   params for MoE, D = tokens processed)
    useful_ratio = MODEL_FLOPS / (HLO_flops * chips) — how much of compiled
                   compute is "useful" (catches remat/redundancy waste)
    mfu_bound    = MODEL_FLOPS / (chips * peak * max(terms)) — the MFU this
                   cell could reach if it hit its own roofline bound.

Caveat (documented): "bytes accessed" comes from CPU-backend HLO whose
fusion differs from TPU; it over-counts HBM traffic, so the memory term is
an upper bound — cross-cell and before/after comparisons remain valid.
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List, Optional

from ..configs.shapes import SHAPES

__all__ = ["PEAK_FLOPS", "HBM_BW", "ICI_BW", "roofline_row", "build_table", "main"]

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link


def model_flops(rec: Dict[str, Any]) -> float:
    shape = SHAPES[rec["shape"]]
    n = rec.get("active_params") or rec.get("params")
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch          # decode: one token per sequence
    return 2.0 * n * tokens


def roofline_row(rec: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    if rec.get("status") != "ok":
        return None
    chips = rec["chips"]
    compute = rec["flops_per_device"] / PEAK_FLOPS
    memory = rec["bytes_per_device"] / HBM_BW
    coll = rec["collectives"]["total_bytes"] / ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(rec)
    useful = mf / max(rec["flops_per_device"] * chips, 1e-30)
    mfu_bound = mf / (chips * PEAK_FLOPS * max(bound, 1e-30))
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "dominant": dominant, "bound_s": bound,
        "model_flops": mf, "useful_ratio": useful, "mfu_bound": mfu_bound,
        "hbm_temp_gib": rec.get("memory", {}).get("temp_size_in_bytes", 0) / 2**30,
        "variant": rec.get("variant", {}),
    }


def build_table(results: Dict[str, Any], mesh: str = "single",
                include_variants: bool = False) -> List[Dict[str, Any]]:
    rows = []
    for key, rec in sorted(results.items()):
        if rec.get("mesh") != mesh:
            continue
        if not include_variants and rec.get("variant"):
            if set(rec["variant"].keys()) - {"remat"}:
                continue
        row = roofline_row(rec)
        if row:
            rows.append(row)
    return rows


def format_table(rows: List[Dict[str, Any]]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
           f"{'collect':>9s} {'dominant':>10s} {'useful':>7s} {'mfu<=':>6s}")
    out = [hdr, "-" * len(hdr)]
    for r in rows:
        out.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['compute_s']:9.3g} "
            f"{r['memory_s']:9.3g} {r['collective_s']:9.3g} "
            f"{r['dominant']:>10s} {r['useful_ratio']:7.2f} {r['mfu_bound']:6.2f}"
        )
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--results", default="dryrun_results.json")
    ap.add_argument("--mesh", default="single", choices=("single", "multi"))
    ap.add_argument("--variants", action="store_true")
    args = ap.parse_args()
    with open(args.results) as f:
        results = json.load(f)
    rows = build_table(results, mesh=args.mesh, include_variants=args.variants)
    print(format_table(rows))


if __name__ == "__main__":
    main()
