"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state.  The single-pod mesh is
16x16 = 256 chips ("data", "model"); the multi-pod mesh is 2x16x16 = 512
chips ("pod", "data", "model") — the "pod" axis rides slow DCN-class links
and therefore carries only data parallelism (+ optionally int8-compressed
gradient reduction), while "data" (FSDP) and "model" (TP/EP/SP) stay on
in-pod ICI.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "dp_axes", "mesh_axis_sizes"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(min(model, n // data), 1)
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> Tuple[str, ...]:
    """Axes that carry the batch (data-parallel) dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
