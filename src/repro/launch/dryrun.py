import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
initialisation, and the dry-run needs 512 placeholder host devices so
``jax.make_mesh`` can build the production meshes.  Everything else (smoke
tests, benches) sees the real single CPU device because this module is the
only place the flag is set.

Per cell this script:
  1. builds the exact published config + ShapeDtypeStruct inputs
     (``input_specs`` — no allocation),
  2. ``jax.jit(step, in_shardings, out_shardings).lower(...).compile()``
     under the production mesh,
  3. records ``memory_analysis()`` (fits-in-HBM proof),
     ``cost_analysis()`` (FLOPs / bytes) and the per-kind collective bytes
     parsed from the optimized HLO — the roofline inputs (EXPERIMENTS.md).

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out dryrun_results.json
Variant flags (--remat/--dispatch/--xent-chunk/--compression/--opt) tag the
cell key, supporting the §Perf hillclimb before/after comparisons.
"""
import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_config
from ..configs.shapes import SHAPES, ShapeSpec, cell_applicable
from ..data.synthetic import batch_specs
from ..models.transformer import LM
from ..optim.optimizers import Adafactor, AdamW
from ..train.step import make_train_step
from .mesh import make_production_mesh
from .sharding import (
    batch_shardings,
    cache_shardings,
    param_pspec,
    param_shardings,
    replicated,
    tree_shardings,
)

__all__ = ["run_cell", "input_specs", "main", "collective_bytes_from_hlo"]

# Big configs use Adafactor (factored second moments) so optimizer state
# fits 16 GB/chip; everything else uses AdamW.
ADAFACTOR_ARCHS = {"deepseek-v3-671b", "command-r-plus-104b", "qwen2-vl-72b"}


def pick_optimizer(arch: str, name: str = "auto"):
    if name == "adamw" or (name == "auto" and arch not in ADAFACTOR_ARCHS):
        return AdamW(lr=3e-4, state_dtype="bfloat16")
    return Adafactor(lr=1e-3)


def input_specs(cfg, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    if shape.kind == "train":
        return batch_specs(cfg, shape.global_batch, shape.seq_len, mode="train")
    if shape.kind == "prefill":
        return batch_specs(cfg, shape.global_batch, shape.seq_len, mode="prefill")
    # decode: one new token against a seq_len cache
    B = shape.global_batch
    specs = {
        "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
    }
    if cfg.needs_position_ids:
        specs["position_ids"] = jax.ShapeDtypeStruct((3, B, 1), jnp.int32)
    return specs


_SHAPE_RE = re.compile(r"\b(pred|s4|s8|s16|s32|u8|u16|u32|u64|f8\w*|bf16|f16|f32|f64|c64|c128)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, Any]:
    """Sum result-shape bytes of every collective op in optimized HLO.

    These are PER-DEVICE bytes (post-SPMD-partitioning HLO is the per-device
    program).  Fusion-internal ops don't occur for collectives, so a simple
    line scan is exact for op *instances*."""
    out = {k: {"bytes": 0, "count": 0} for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?\S+\s*=\s*(\(.*?\)|\S+\[\S*\]\S*)\s+(\S+)\(", ls)
        if not m:
            continue
        opname = m.group(2)
        for kind in _COLL_KINDS:
            if opname == kind or opname.startswith(kind + "-"):
                out[kind]["bytes"] += _shape_bytes(m.group(1))
                out[kind]["count"] += 1
                break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items() if isinstance(v, dict))
    return out


def _opt_state_shardings(opt_sds, mesh, mode="train"):
    def spec_fn(path_str: str, shape, mesh):
        # m/v mirror the param tree: strip the state prefix and reuse rules
        stripped = re.sub(r"^(m|v)/", "", path_str)
        stripped = re.sub(r"/v$|/vr$|/vc$", "", stripped)
        if path_str.endswith(("/vr", "/vc")) or len(shape) == 0:
            return jax.sharding.PartitionSpec()
        return param_pspec(stripped, shape, mesh, mode=mode)

    return tree_shardings(opt_sds, mesh, spec_fn)


def make_cell_config(arch: str, shape: ShapeSpec, *,
                     dispatch: Optional[str] = None, remat: str = "block",
                     xent_chunk: int = 0, kv_dtype: Optional[str] = None,
                     group_size: int = 0):
    overrides: Dict[str, Any] = {"dtype": "bfloat16"}
    if shape.kind == "train":
        overrides["remat"] = remat
        overrides["xent_chunk"] = xent_chunk
    if kv_dtype:
        overrides["kv_dtype"] = kv_dtype
    cfg = get_config(arch, **overrides)
    if cfg.moe is not None and (dispatch or group_size):
        moe_over = {}
        if dispatch:
            moe_over["dispatch"] = dispatch
        if group_size:
            moe_over["group_size"] = group_size
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, **moe_over))
    return cfg


def probe_configs(cfg):
    """Small UNROLLED configs whose per-segment layer counts span a basis,
    for trip-count-aware cost extrapolation (XLA's cost_analysis counts a
    while-loop body once, so the full compile underreports scanned work).

    Returns a list of configs; the caller extrapolates linearly in the
    segment counts to the true config."""
    probes = []

    def mk(**kw):
        c = dataclasses.replace(cfg, scan_unroll=True, **kw)
        probes.append(c)

    if cfg.family == "moe" and cfg.moe.n_dense_layers > 0:
        moe1 = dataclasses.replace(cfg.moe, n_dense_layers=1)
        moe2 = dataclasses.replace(cfg.moe, n_dense_layers=2)
        mk(n_layers=2, moe=moe1)
        mk(n_layers=3, moe=moe2)
        mk(n_layers=3, moe=moe1)
    elif cfg.family == "hybrid":
        plen = len(cfg.recurrent.pattern)
        tail = cfg.n_layers % plen
        mk(n_layers=plen + tail)
        mk(n_layers=2 * plen + tail)
    else:
        mk(n_layers=1)
        mk(n_layers=2)
    return probes


def extrapolate_costs(probe_counts, probe_values, true_counts):
    """Solve value = fixed + sum_i slope_i * counts_i (least squares; exact
    in the identified directions) and predict at the true counts."""
    A = np.array([[1.0] + list(c) for c in probe_counts], dtype=np.float64)
    y = np.array(probe_values, dtype=np.float64)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    pred = float(coef[0] + np.dot(coef[1:], np.array(true_counts, dtype=np.float64)))
    return max(pred, 0.0)


def build_lowerable(arch: str, shape_name: str, mesh, *,
                    opt: str = "auto", dispatch: Optional[str] = None,
                    remat: str = "block", xent_chunk: int = 0,
                    compression: str = "none", microbatches: int = 1,
                    infer_shard: str = "fsdp", kv_dtype: Optional[str] = None,
                    group_size: int = 0, moe_shard: str = "fsdp",
                    seq_shard: str = "sp", batch_override: int = 0, cfg=None):
    """Returns (jitted_fn, example_args) ready to .lower(*args)."""
    shape = SHAPES[shape_name]
    if batch_override:
        shape = dataclasses.replace(shape, global_batch=batch_override)
    if cfg is None:
        cfg = make_cell_config(arch, shape, dispatch=dispatch, remat=remat,
                               xent_chunk=xent_chunk, kv_dtype=kv_dtype,
                               group_size=group_size)
    ok, reason = cell_applicable(cfg, shape)
    if not ok:
        raise SkipCell(reason)
    model = LM(cfg)
    # constrain the activation stream: batch over the dp axes
    from jax.sharding import NamedSharding, PartitionSpec as P
    from .sharding import _dp_for
    dp = _dp_for(shape.global_batch, mesh)
    if compression == "int8":
        # inside the shard_map (manual over "pod") constraints must not
        # reference the pod axis — batch is already pod-local there
        dp = tuple(a for a in dp if a != "pod")
    # Sequence parallelism: at block boundaries the (B,S,d) stream is sharded
    # batch x sequence; with remat this shrinks saved residuals by the model-
    # axis size (measured 292 GiB -> ~20 GiB on command-r-plus train_4k).
    seq_ax = "model" if (shape.kind != "decode" and seq_shard == "sp"
                         and shape.seq_len % mesh.shape["model"] == 0) else None
    model.act_sharding = NamedSharding(mesh, P(dp if dp else None, seq_ax))
    vshard = "model" if cfg.vocab % mesh.shape["model"] == 0 else None
    model.logits_sharding = NamedSharding(mesh, P(dp if dp else None, None, vshard))
    rng = jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(model.init, rng)
    # weight-stationary TP sharding for inference cells when requested
    if shape.kind == "train":
        pmode = "train_ep" if moe_shard == "ep_full" else "train"
    else:
        pmode = "infer" if infer_shard == "tp" else "train"
    param_sh = param_shardings(params_sds, mesh, mode=pmode)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        optimizer = pick_optimizer(arch, opt)
        opt_sds = jax.eval_shape(optimizer.init, params_sds)
        opt_sh = _opt_state_shardings(opt_sds, mesh, mode=pmode)
        batch_sh = batch_shardings(specs, mesh)
        step = make_train_step(
            model, optimizer, mesh=mesh,
            grad_compression=compression, microbatches=microbatches,
        )
        if compression == "int8":
            rng_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
            fn = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, batch_sh, replicated(mesh)),
                out_shardings=(param_sh, opt_sh, None),
                donate_argnums=(0, 1),
            )
            args = (params_sds, opt_sds, specs, rng_spec)
        else:
            fn = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, batch_sh),
                out_shardings=(param_sh, opt_sh, None),
                donate_argnums=(0, 1),
            )
            args = (params_sds, opt_sds, specs)
        info = {"param_bytes_per_device": sharded_bytes_per_device(params_sds, param_sh, mesh),
                "opt_bytes_per_device": sharded_bytes_per_device(opt_sds, opt_sh, mesh)}
        return cfg, fn, args, info

    if shape.kind == "prefill":
        cache_sds = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len)
        )
        cache_sh = cache_shardings(cache_sds, mesh)
        batch_sh = batch_shardings(specs, mesh)
        fn = jax.jit(
            model.prefill,
            in_shardings=(param_sh, batch_sh, cache_sh),
            out_shardings=(None, cache_sh),
            donate_argnums=(2,),
        )
        info = {"param_bytes_per_device": sharded_bytes_per_device(params_sds, param_sh, mesh),
                "cache_bytes_per_device": sharded_bytes_per_device(cache_sds, cache_sh, mesh)}
        return cfg, fn, (params_sds, specs, cache_sds), info

    # decode
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len)
    )
    cache_sh = cache_shardings(cache_sds, mesh)
    batch_sh = batch_shardings(
        {k: v for k, v in specs.items() if k in ("tokens", "pos", "position_ids")}, mesh
    )

    if cfg.needs_position_ids:
        def serve_step(params, tokens, pos, caches, position_ids):
            return model.decode_step(params, tokens, pos, caches, position_ids)
        fn = jax.jit(
            serve_step,
            in_shardings=(param_sh, batch_sh["tokens"], batch_sh["pos"],
                          cache_sh, batch_sh["position_ids"]),
            out_shardings=(None, cache_sh),
            donate_argnums=(3,),
        )
        args = (params_sds, specs["tokens"], specs["pos"], cache_sds,
                specs["position_ids"])
        info = {"param_bytes_per_device": sharded_bytes_per_device(params_sds, param_sh, mesh),
                "cache_bytes_per_device": sharded_bytes_per_device(cache_sds, cache_sh, mesh)}
        return cfg, fn, args, info
    else:
        def serve_step(params, tokens, pos, caches):
            return model.decode_step(params, tokens, pos, caches)
        fn = jax.jit(
            serve_step,
            in_shardings=(param_sh, batch_sh["tokens"], batch_sh["pos"], cache_sh),
            out_shardings=(None, cache_sh),
            donate_argnums=(3,),
        )
        args = (params_sds, specs["tokens"], specs["pos"], cache_sds)
    info = {"param_bytes_per_device": sharded_bytes_per_device(params_sds, param_sh, mesh),
            "cache_bytes_per_device": sharded_bytes_per_device(cache_sds, cache_sh, mesh)}
    return cfg, fn, args, info


class SkipCell(Exception):
    pass


def sharded_bytes_per_device(shapes_tree, shardings_tree, mesh) -> float:
    """Sum of leaf bytes divided by the #devices each leaf is sharded over
    (replication across unused axes does NOT reduce per-device bytes)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = 0.0
    for leaf, sh in zip(jax.tree.leaves(shapes_tree), jax.tree.leaves(
            shardings_tree, is_leaf=lambda x: hasattr(x, "spec"))):
        denom = 1
        for entry in sh.spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                denom *= sizes[a]
        total += np.prod(leaf.shape) * jnp.dtype(leaf.dtype).itemsize / denom
    return float(total)


def _analyze(compiled) -> Dict[str, Any]:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    coll = collective_bytes_from_hlo(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
    }


def _seg_counts(cfg) -> Tuple[int, ...]:
    from ..models.transformer import build_segments
    return tuple(s.n for s in build_segments(cfg))


def run_cell(arch: str, shape_name: str, mesh_kind: str, probes: bool = True,
             **variant) -> Dict[str, Any]:
    t0 = time.perf_counter()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(mesh.devices.shape))
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "chips": n_chips, "variant": {k: v for k, v in variant.items() if v not in (None, "none", 0, 1, "auto", "fsdp", "sp")},
    }
    try:
        with mesh:
            # ---- full compile: the dry-run proof (sharding + memory) --------
            cfg, fn, args, info = build_lowerable(arch, shape_name, mesh, **variant)
            lowered = fn.lower(*args)
            t_lower = time.perf_counter()
            compiled = lowered.compile()
            t_compile = time.perf_counter()
            mem = compiled.memory_analysis()
            full = _analyze(compiled)

            # ---- probe compiles: trip-count-correct cost extrapolation ------
            extrap: Dict[str, float] = {}
            coll_extrap: Dict[str, Any] = {}
            if variant.get("microbatches", 1) > 1:
                # grad-accum scan body is counted once by cost_analysis; use
                # the microbatches=1 sibling cell for flops — this cell is
                # for the memory proof.
                probes = False
            if probes:
                counts, values = [], []
                for pcfg in probe_configs(cfg):
                    _, pfn, pargs, _pi = build_lowerable(
                        arch, shape_name, mesh, cfg=pcfg,
                        **{k: v for k, v in variant.items() if k != "cfg"})
                    pa = _analyze(pfn.lower(*pargs).compile())
                    counts.append(_seg_counts(pcfg))
                    values.append(pa)
                true_counts = _seg_counts(cfg)
                for key in ("flops", "bytes"):
                    extrap[key] = extrapolate_costs(
                        counts, [v[key] for v in values], true_counts)
                coll_extrap = {"total_bytes": extrapolate_costs(
                    counts, [v["coll"]["total_bytes"] for v in values], true_counts)}
                for kind in _COLL_KINDS:
                    coll_extrap[kind] = {
                        "bytes": extrapolate_costs(
                            counts, [v["coll"][kind]["bytes"] for v in values],
                            true_counts),
                        "count": extrapolate_costs(
                            counts, [v["coll"][kind]["count"] for v in values],
                            true_counts),
                    }

        rec.update({
            "status": "ok",
            "lower_s": round(t_lower - t0, 2),
            "compile_s": round(t_compile - t_lower, 2),
            # raw full-compile numbers (scan bodies counted once — see probes)
            "flops_per_device_raw": full["flops"],
            "bytes_per_device_raw": full["bytes"],
            "collectives_raw": full["coll"],
            # trip-count-corrected per-device numbers (the roofline inputs)
            "flops_per_device": extrap.get("flops", full["flops"]),
            "bytes_per_device": extrap.get("bytes", full["bytes"]),
            "collectives": coll_extrap or full["coll"],
            "memory": {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "alias_size_in_bytes",
                          "generated_code_size_in_bytes")
                if hasattr(mem, k)
            },
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "resident": info,
        })
    except SkipCell as e:
        rec.update({"status": "skip", "reason": str(e)})
    except Exception as e:  # failures here are bugs in the system
        rec.update({"status": "fail", "error": f"{type(e).__name__}: {e}",
                    "trace": traceback.format_exc()[-2000:]})
    rec["total_s"] = round(time.perf_counter() - t0, 2)
    return rec


def cell_key(arch, shape, mesh_kind, variant) -> str:
    tag = ",".join(f"{k}={v}" for k, v in sorted(variant.items())
                   if v not in (None, "none", 0, 1, "auto", "fsdp", "sp"))
    return f"{arch}|{shape}|{mesh_kind}" + (f"|{tag}" if tag else "")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--all", action="store_true", help="run the full grid")
    ap.add_argument("--out", default=None, help="incremental JSON results path")
    ap.add_argument("--opt", default="auto", choices=("auto", "adamw", "adafactor"))
    ap.add_argument("--dispatch", default=None, choices=(None, "einsum", "sort"))
    ap.add_argument("--remat", default="block", choices=("none", "block", "dots"))
    ap.add_argument("--xent-chunk", type=int, default=0)
    ap.add_argument("--compression", default="none", choices=("none", "int8"))
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--infer-shard", default="fsdp", choices=("fsdp", "tp"))
    ap.add_argument("--kv-dtype", default=None)
    ap.add_argument("--group-size", type=int, default=0)
    ap.add_argument("--moe-shard", default="fsdp", choices=("fsdp", "ep_full"))
    ap.add_argument("--seq-shard", default="sp", choices=("sp", "none"))
    ap.add_argument("--batch-override", type=int, default=0)
    ap.add_argument("--force", action="store_true", help="recompute existing cells")
    args = ap.parse_args()

    variant = dict(opt=args.opt, dispatch=args.dispatch, remat=args.remat,
                   xent_chunk=args.xent_chunk, compression=args.compression,
                   microbatches=args.microbatches, infer_shard=args.infer_shard,
                   kv_dtype=args.kv_dtype, group_size=args.group_size,
                   moe_shard=args.moe_shard, seq_shard=args.seq_shard,
                   batch_override=args.batch_override)

    cells = []
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                for mk in meshes:
                    cells.append((arch, shape, mk))
    else:
        if not (args.arch and args.shape):
            ap.error("need --arch and --shape (or --all)")
        cells = [(args.arch, args.shape, mk) for mk in meshes]

    results: Dict[str, Any] = {}
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    failures = 0
    for arch, shape, mk in cells:
        key = cell_key(arch, shape, mk, variant)
        if key in results and results[key].get("status") == "ok" and not args.force:
            print(f"[cached] {key}")
            continue
        print(f"[dryrun] {key} ...", flush=True)
        rec = run_cell(arch, shape, mk, **variant)
        results[key] = rec
        status = rec["status"]
        extra = ""
        if status == "ok":
            extra = (f" flops/dev={rec['flops_per_device']:.3e}"
                     f" coll={rec['collectives']['total_bytes']:.3e}B"
                     f" compile={rec['compile_s']}s")
        elif status == "fail":
            failures += 1
            extra = " " + rec["error"]
        print(f"  -> {status}{extra}", flush=True)
        if args.out:
            tmp = args.out + ".tmp"
            with open(tmp, "w") as f:
                json.dump(results, f, indent=1, sort_keys=True)
            os.replace(tmp, args.out)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
