"""End-to-end serving driver (the paper's kind: orchestration/serving).

Serves a small model with batched requests through the slot-based
continuous-batching engine, measures the decode-latency-vs-occupancy
interference line on REAL timings (the paper's Fig.-4 linearity check,
transplanted to serving), then drives the IBDASH fleet scheduler with the
measured coefficients and compares policies.

  PYTHONPATH=src python -m repro.launch.serve --requests 64
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import ARCHS, get_config
from ..models import LM, reduced
from ..serve.engine import ServingEngine, measure_interference
from ..serve.scheduler import ServingFleet, serving_interference_model

__all__ = ["main", "serve_demo"]


def serve_demo(arch: str = "qwen1.5-0.5b", n_requests: int = 64,
               max_batch: int = 8, max_seq: int = 128, seed: int = 0):
    cfg = reduced(get_config(arch), n_layers=2, vocab=512)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)

    # -- 1) real engine, batched requests --------------------------------------
    eng = ServingEngine(model, params, max_batch=max_batch, max_seq=max_seq)
    pending = [
        (f"req{i}", rng.integers(0, cfg.vocab, int(rng.integers(4, 16))).tolist(),
         int(rng.integers(8, 32)))
        for i in range(n_requests)
    ]
    done = {}
    t0 = time.perf_counter()
    steps = 0
    while len(done) < n_requests:
        while pending and eng.free_slots():
            rid, prompt, n_new = pending.pop()
            eng.add_request(rid, prompt, n_new)
        done.update(eng.step())
        steps += 1
    wall = time.perf_counter() - t0
    n_tok = sum(len(v) for v in done.values())
    print(f"[serve] {n_requests} requests, {n_tok} tokens in {wall:.2f}s "
          f"({n_tok/wall:.1f} tok/s, {steps} engine steps, "
          f"batch occupancy {n_tok/steps:.2f})")

    # -- 2) interference linearity on real timings ------------------------------
    m, c, r2, samples = measure_interference(
        model, params, batch_sizes=(1, 2, 4, 8), max_seq=max_seq, iters=10)
    print(f"[serve] decode-step latency fits T = m*k + c: "
          f"m={m*1e3:.3f} ms/seq, c={c*1e3:.3f} ms, R^2={r2:.4f}")
    for k, dt in samples:
        print(f"         k={k}: {dt*1e3:.2f} ms  (fit {(m*k+c)*1e3:.2f} ms)")

    # -- 3) fleet scheduling with the measured coefficients ---------------------
    im = serving_interference_model(m_short=m, c_short=c,
                                    m_long=3 * m, c_long=6 * c)
    print("[serve] fleet policy comparison (16 replicas, 50% spot):")
    rows = {}
    for pol in ("ibdash", "petrel", "lavea", "round_robin"):
        fleet = ServingFleet(im, policy=pol, n_replicas=16, seed=seed)
        res = fleet.run(n_requests=600, arrival_window=8.0, seed=seed + 1)
        rows[pol] = (res.avg_service_time, res.prob_failure)
        print(f"         {pol:12s} avg latency {res.avg_service_time*1e3:7.1f} ms"
              f"   failure rate {res.prob_failure:6.3f}")
    return {"throughput_tok_s": n_tok / wall, "interference": (m, c, r2),
            "fleet": rows}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=ARCHS)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=8)
    args = ap.parse_args()
    serve_demo(args.arch, n_requests=args.requests, max_batch=args.max_batch)


if __name__ == "__main__":
    main()
