"""Launch layer: production meshes, sharding rules, the multi-pod dry-run,
roofline analysis and the train/serve drivers.

NOTE: never import ``repro.launch.dryrun`` from library code — it sets
``XLA_FLAGS`` for 512 host devices at import time (by design, for the
dry-run CLI only).
"""
from .mesh import dp_axes, make_host_mesh, make_production_mesh, mesh_axis_sizes
from .sharding import (
    batch_shardings,
    cache_shardings,
    param_pspec,
    param_shardings,
    replicated,
)

__all__ = [
    "make_production_mesh",
    "make_host_mesh",
    "dp_axes",
    "mesh_axis_sizes",
    "param_pspec",
    "param_shardings",
    "batch_shardings",
    "cache_shardings",
    "replicated",
]
