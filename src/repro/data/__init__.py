"""Data pipeline: deterministic synthetic LM streams + sharded host loading."""
from .synthetic import SyntheticLM, batch_specs
from .pipeline import Prefetcher, shard_batch

__all__ = ["SyntheticLM", "batch_specs", "Prefetcher", "shard_batch"]
