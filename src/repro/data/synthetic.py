"""Deterministic synthetic LM data.

A reproducible token stream built from a seeded Philox generator, with a
Markov-ish structure (next token = hash of previous + noise) so that a
trained model's loss actually *decreases* — the end-to-end training example
uses this to demonstrate learning without any external dataset.

``batch_specs(cfg, shape)`` also provides the ShapeDtypeStruct stand-ins
(weak-type-correct, no allocation) used by the multi-pod dry-run for every
model input, including the audio-frame / M-RoPE stubs for the [audio]/[vlm]
architectures.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig

__all__ = ["SyntheticLM", "batch_specs"]


@dataclass
class SyntheticLM:
    """Infinite deterministic stream of (tokens, labels) LM batches."""

    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    structure: float = 0.7   # fraction of deterministically-predictable tokens

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(self.seed)
        mult = 6364136223846793005
        while True:
            x = np.empty((self.batch, self.seq_len + 1), dtype=np.int64)
            x[:, 0] = rng.integers(0, self.vocab, self.batch)
            noise = rng.random((self.batch, self.seq_len))
            rand_tok = rng.integers(0, self.vocab, (self.batch, self.seq_len))
            for t in range(self.seq_len):
                nxt = (x[:, t] * mult + 1442695040888963407) % self.vocab
                x[:, t + 1] = np.where(noise[:, t] < self.structure, nxt, rand_tok[:, t])
            yield {
                "tokens": x[:, :-1].astype(np.int32),
                "labels": x[:, 1:].astype(np.int32),
            }


def batch_specs(
    cfg: ModelConfig, batch: int, seq_len: int, mode: str = "train"
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (dry-run pattern:
    weak-type-correct, shardable, zero allocation).

    mode: "train" (tokens+labels), "prefill" (tokens only).
    Adds the modality-frontend stubs:
      [audio] frames         (B, enc_len, d_model)  — conv frontend output
      [vlm]   position_ids   (3, B, S)              — fused M-RoPE positions
    """
    i32 = jnp.int32
    specs: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": jax.ShapeDtypeStruct((batch, seq_len), i32),
    }
    if mode == "train":
        specs["labels"] = jax.ShapeDtypeStruct((batch, seq_len), i32)
    if cfg.enc_dec:
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.enc_len, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    if cfg.needs_position_ids:
        specs["position_ids"] = jax.ShapeDtypeStruct((3, batch, seq_len), i32)
    return specs


def materialize_batch(
    cfg: ModelConfig, batch: int, seq_len: int, seed: int = 0, mode: str = "train"
) -> Dict[str, np.ndarray]:
    """Concrete host batch matching ``batch_specs`` (for smoke tests /
    the end-to-end training example)."""
    rng = np.random.default_rng(seed)
    out: Dict[str, np.ndarray] = {}
    stream = iter(SyntheticLM(cfg.vocab, batch, seq_len, seed=seed))
    b = next(stream)
    out["tokens"] = b["tokens"]
    if mode == "train":
        out["labels"] = b["labels"]
    if cfg.enc_dec:
        out["frames"] = rng.standard_normal(
            (batch, cfg.enc_len, cfg.d_model), dtype=np.float32
        ).astype(jnp.dtype(cfg.dtype).name if cfg.dtype != "bfloat16" else "float32")
    if cfg.needs_position_ids:
        pos = np.broadcast_to(np.arange(seq_len, dtype=np.int32), (3, batch, seq_len))
        out["position_ids"] = np.ascontiguousarray(pos)
    return out
