"""Host-side input pipeline: background prefetch + device placement.

``Prefetcher`` overlaps host batch synthesis/IO with device compute (a
single producer thread and a bounded queue — the standard input-pipeline
pattern).  ``shard_batch`` places a global host batch onto the mesh
according to the step function's input shardings.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterable, Iterator, Optional

import jax
import numpy as np

__all__ = ["Prefetcher", "shard_batch"]


def shard_batch(batch: Dict[str, np.ndarray], shardings: Dict[str, Any]):
    """Place host arrays onto devices per the given NamedShardings."""
    return {
        k: jax.device_put(v, shardings[k]) if k in shardings else jax.device_put(v)
        for k, v in batch.items()
    }


class Prefetcher:
    """Wrap an iterator with a background producer thread + bounded queue."""

    _SENTINEL = object()

    def __init__(self, it: Iterable, depth: int = 2,
                 shardings: Optional[Dict[str, Any]] = None):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._shardings = shardings
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(
            target=self._produce, args=(iter(it),), daemon=True
        )
        self._stopped = threading.Event()
        self._thread.start()

    def _produce(self, it: Iterator):
        try:
            for item in it:
                if self._stopped.is_set():
                    return
                if self._shardings is not None:
                    item = shard_batch(item, self._shardings)
                self._q.put(item)
        except BaseException as e:  # surfaced on next __next__
            self._err = e
        finally:
            self._q.put(self._SENTINEL)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._SENTINEL:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def close(self):
        self._stopped.set()
        while not self._q.empty():
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
