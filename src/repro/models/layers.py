"""Shared neural-net layers: norms, gated MLPs, rotary embeddings.

Pure-functional JAX: every layer is an ``init(rng, cfg) -> params`` plus an
``apply(params, x) -> y`` pair operating on pytrees of jnp arrays, so the
whole model works under ``jax.eval_shape`` (the multi-pod dry-run never
materialises weights) and under ``jax.lax.scan`` over stacked layer params.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

__all__ = [
    "Dense",
    "dense_init",
    "dense_apply",
    "norm_init",
    "norm_apply",
    "mlp_init",
    "mlp_apply",
    "embed_init",
    "rope_freqs",
    "apply_rope",
    "apply_mrope",
    "activation",
]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# -- dense ----------------------------------------------------------------------
def dense_init(rng, in_dim: int, out_dim: int, dtype, bias: bool = False,
               scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    w = (jax.random.normal(rng, (in_dim, out_dim), dtype=jnp.float32) * scale).astype(dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype=dtype)
    return p


def dense_apply(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


class Dense:
    """Thin namespace so call-sites read ``Dense.init`` / ``Dense.apply``."""

    init = staticmethod(dense_init)
    apply = staticmethod(dense_apply)


# -- normalisation ----------------------------------------------------------------
def norm_init(cfg: ModelConfig, dim: Optional[int] = None):
    dim = dim or cfg.d_model
    dt = _dtype(cfg)
    if cfg.norm == "nonparametric":        # OLMo-style non-parametric LN
        return {}
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((dim,), dtype=dt)}
    p = {"scale": jnp.ones((dim,), dtype=dt)}
    if cfg.norm == "layernorm":            # with bias
        p["bias"] = jnp.zeros((dim,), dtype=dt)
    return p


def norm_apply(cfg: ModelConfig, p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y.astype(x.dtype)
    if "scale" in p:
        y = y * p["scale"]
    if "bias" in p:
        y = y + p["bias"]
    return y


# -- activations / MLP -------------------------------------------------------------
def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return partial(jax.nn.gelu, approximate=True)
    if name == "relu2":  # squared ReLU (Nemotron / Minitron family)
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


def mlp_init(rng, cfg: ModelConfig, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    dt = _dtype(cfg)
    r1, r2, r3 = jax.random.split(rng, 3)
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "wi": dense_init(r1, cfg.d_model, d_ff, dt),
            "wg": dense_init(r2, cfg.d_model, d_ff, dt),
            "wo": dense_init(r3, d_ff, cfg.d_model, dt),
        }
    return {
        "wi": dense_init(r1, cfg.d_model, d_ff, dt),
        "wo": dense_init(r3, d_ff, cfg.d_model, dt),
    }


def mlp_apply(cfg: ModelConfig, p, x):
    act = activation("gelu" if cfg.mlp == "geglu" else cfg.act)
    h = dense_apply(p["wi"], x)
    if "wg" in p:
        h = act(dense_apply(p["wg"], x)) * h
    else:
        h = act(h)
    return dense_apply(p["wo"], h)


# -- embeddings ----------------------------------------------------------------------
def embed_init(rng, vocab: int, dim: int, dtype):
    w = (jax.random.normal(rng, (vocab, dim), dtype=jnp.float32) * 0.02).astype(dtype)
    return {"embedding": w}


# -- rotary embeddings ----------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float, positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for given integer positions.

    positions: (..., S) int32 -> returns cos,sin of shape (..., S, head_dim//2).
    """
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., S, half)
    return jnp.cos(ang), jnp.sin(ang)


def _rotate(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, n_heads, head_dim); cos/sin broadcastable to (..., S, 1, half)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def apply_rope(q: jnp.ndarray, k: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Standard RoPE.  q: (B,S,Hq,D), k: (B,S,Hk,D), positions: (B,S)."""
    cos, sin = rope_freqs(q.shape[-1], theta, positions)  # (B,S,half)
    cos, sin = cos[..., None, :], sin[..., None, :]       # (B,S,1,half)
    return _rotate(q, cos, sin), _rotate(k, cos, sin)


def apply_mrope(
    q: jnp.ndarray, k: jnp.ndarray, position_ids: jnp.ndarray,
    theta: float, sections: Tuple[int, ...],
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Qwen2-VL multimodal RoPE (arXiv:2409.12191).

    position_ids: (3, B, S) — temporal / height / width position per token.
    The head_dim/2 frequency slots are split into ``sections`` (t, h, w) and
    each section takes its angle from the corresponding position stream.
    For pure text the three streams are identical and M-RoPE == RoPE.
    """
    half = q.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    cos_parts, sin_parts = [], []
    lo = 0
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    for s, sec in enumerate(sections):
        pos = position_ids[s].astype(jnp.float32)        # (B,S)
        ang = pos[..., None] * inv[lo:lo + sec]           # (B,S,sec)
        cos_parts.append(jnp.cos(ang))
        sin_parts.append(jnp.sin(ang))
        lo += sec
    cos = jnp.concatenate(cos_parts, axis=-1)[..., None, :]  # (B,S,1,half)
    sin = jnp.concatenate(sin_parts, axis=-1)[..., None, :]
    return _rotate(q, cos, sin), _rotate(k, cos, sin)
