"""Model assembly: one ``LM`` class covering every assigned architecture.

The model is a sequence of *segments*; each segment is a homogeneous stack
of blocks run under ``jax.lax.scan`` over stacked parameters (small HLO,
fast multi-pod compiles even at 80 layers):

  dense/vlm : [attn x L]
  moe       : [attn+dense x n_dense, attn+moe x (L-n_dense)]   (attn may be MLA)
  ssm       : [rwkv6 x L]
  hybrid    : [(rec,rec,attn) x G, (rec,rec) x 1]              (RecurrentGemma 2:1)
  audio     : encoder [attn x L] + decoder [self+cross attn x L]

Training (no cache), prefill (bulk cache write) and decode (single token)
all run the same segment machinery; caches/states are stacked over the
segment's scan axis so they ride along as scan xs/ys.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .attention import (
    gqa_apply,
    gqa_init,
    make_cache,
    make_mla_cache,
    mla_apply,
    mla_init,
)
from .config import ModelConfig
from .layers import embed_init, mlp_apply, mlp_init, norm_apply, norm_init
from .moe import moe_apply, moe_init
from .recurrent import (
    rglru_apply,
    rglru_init,
    rglru_state,
    rwkv6_apply,
    rwkv6_init,
    rwkv6_state,
)

__all__ = ["Segment", "LM", "build_segments", "sinusoidal_embed"]

MOE_AUX_WEIGHT = 0.01


@dataclass(frozen=True)
class Segment:
    kind: str            # "attn" | "rwkv" | "group" | "enc" | "dec"
    n: int               # scan length (layers, or groups for "group")
    moe: bool = False
    window: Optional[int] = None
    n_rec: int = 0       # recurrent blocks per group (hybrid)
    has_attn: bool = True  # group contains an attention block


def build_segments(cfg: ModelConfig) -> List[Segment]:
    w = cfg.attn_window
    if cfg.family in ("dense", "vlm"):
        return [Segment("attn", cfg.n_layers, window=w)]
    if cfg.family == "moe":
        m = cfg.moe
        segs = []
        if m.n_dense_layers:
            segs.append(Segment("attn", m.n_dense_layers, window=w))
        segs.append(Segment("attn", cfg.n_layers - m.n_dense_layers, moe=True, window=w))
        return segs
    if cfg.family == "ssm":
        return [Segment("rwkv", cfg.n_layers)]
    if cfg.family == "hybrid":
        pat = cfg.recurrent.pattern
        plen = len(pat)
        n_rec = sum(1 for k in pat if k == "rec")
        groups, tail = divmod(cfg.n_layers, plen)
        segs = [Segment("group", groups, window=w, n_rec=n_rec, has_attn="attn" in pat)]
        if tail:
            segs.append(Segment("group", 1, window=w, n_rec=tail, has_attn=False))
        return segs
    if cfg.family == "audio":
        return [Segment("enc", cfg.n_layers), Segment("dec", cfg.n_layers)]
    raise ValueError(cfg.family)


def sinusoidal_embed(positions: jnp.ndarray, dim: int) -> jnp.ndarray:
    """(..., S) int -> (..., S, dim) float32 sinusoidal embedding."""
    half = dim // 2
    freq = jnp.exp(-np.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _stack_init(fn, rng, n: int):
    keys = jax.random.split(rng, n)
    return jax.vmap(fn)(keys)


class LM:
    """Pure-functional language model over ``ModelConfig``.

    Public surface:
      init(rng) -> params
      loss(params, batch) -> (scalar, metrics)           [training]
      prefill(params, batch, caches) -> (logits, caches) [serve]
      decode_step(params, tokens, pos, caches, ...) -> (logits, caches)
      init_cache(batch, capacity) -> caches
    """

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.segments = build_segments(cfg)
        # Optional NamedSharding for the (B, S, d) activation stream.  Set by
        # the launcher/dry-run (mesh-dependent); applied at the embedding
        # output and at every block boundary so GSPMD keeps the batch dim
        # sharded over the DP axes instead of replicating compute.
        self.act_sharding = None
        # Optional NamedSharding for (B, S, vocab) logits — batch over dp,
        # vocab over "model" (vocab-parallel softmax cross-entropy).
        self.logits_sharding = None

    def _wsc(self, x):
        if self.act_sharding is not None:
            return jax.lax.with_sharding_constraint(x, self.act_sharding)
        return x

    # ------------------------------------------------------------------ init --
    def _block_init(self, seg: Segment):
        cfg = self.cfg

        def attn_one(key):
            ks = jax.random.split(key, 2)
            p = {
                "norm1": norm_init(cfg),
                "norm2": norm_init(cfg),
                "attn": mla_init(ks[0], cfg) if cfg.attention == "mla" else gqa_init(ks[0], cfg),
            }
            p["ffn"] = moe_init(ks[1], cfg) if seg.moe else mlp_init(ks[1], cfg)
            return p

        def rwkv_one(key):
            return {"block": rwkv6_init(key, cfg)}

        def rec_one(key):
            ks = jax.random.split(key, 2)
            return {
                "norm1": norm_init(cfg),
                "rec": rglru_init(ks[0], cfg),
                "norm2": norm_init(cfg),
                "ffn": mlp_init(ks[1], cfg),
            }

        def group_one(key):
            ks = jax.random.split(key, 2)
            p = {"rec": _stack_init(rec_one, ks[0], seg.n_rec)}
            if seg.has_attn:
                ka = jax.random.split(ks[1], 2)
                p["attn"] = {
                    "norm1": norm_init(cfg),
                    "norm2": norm_init(cfg),
                    "attn": gqa_init(ka[0], cfg),
                    "ffn": mlp_init(ka[1], cfg),
                }
            return p

        def dec_one(key):
            ks = jax.random.split(key, 3)
            return {
                "norm1": norm_init(cfg),
                "self_attn": gqa_init(ks[0], cfg),
                "norm_x": norm_init(cfg),
                "cross_attn": gqa_init(ks[1], cfg, cross=True),
                "norm2": norm_init(cfg),
                "ffn": mlp_init(ks[2], cfg),
            }

        return {
            "attn": attn_one, "rwkv": rwkv_one, "group": group_one,
            "enc": attn_one, "dec": dec_one,
        }[seg.kind]

    def init(self, rng) -> Dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        keys = jax.random.split(rng, len(self.segments) + 3)
        params: Dict[str, Any] = {
            "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, dt),
            "final_norm": norm_init(cfg),
            "segments": [
                _stack_init(self._block_init(seg), keys[i + 1], seg.n)
                for i, seg in enumerate(self.segments)
            ],
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = {
                "w": (jax.random.normal(keys[-2], (cfg.d_model, cfg.vocab),
                                        dtype=jnp.float32) * 0.02).astype(dt)
            }
        if cfg.enc_dec:
            params["enc_final_norm"] = norm_init(cfg)
        return params

    # ------------------------------------------------------------------ cache --
    def init_cache(self, batch: int, capacity: int) -> List[Any]:
        """Per-segment decode caches/states, stacked over each scan axis."""
        cfg = self.cfg
        caches: List[Any] = []
        for seg in self.segments:
            if seg.kind == "attn":
                if cfg.attention == "mla":
                    caches.append(make_mla_cache(cfg, batch, capacity, seg.n))
                else:
                    cap = min(capacity, seg.window) if seg.window else capacity
                    caches.append(make_cache(cfg, batch, cap, seg.n))
            elif seg.kind == "rwkv":
                caches.append(rwkv6_state(cfg, batch, seg.n))
            elif seg.kind == "group":
                c: Dict[str, Any] = {
                    "rec": jax.tree.map(
                        lambda a: a.reshape((seg.n, seg.n_rec) + a.shape[1:]),
                        rglru_state(cfg, batch, seg.n * seg.n_rec),
                    )
                }
                if seg.has_attn:
                    cap = min(capacity, seg.window) if seg.window else capacity
                    c["attn"] = make_cache(cfg, batch, cap, seg.n)
                caches.append(c)
            elif seg.kind == "enc":
                caches.append(None)
            elif seg.kind == "dec":
                caches.append({
                    "self": make_cache(cfg, batch, capacity, seg.n),
                    "cross": make_cache(cfg, batch, cfg.enc_len, seg.n),
                })
        return caches

    # ----------------------------------------------------------------- blocks --
    def _apply_attn_block(self, seg: Segment, p, x, positions, cache,
                          position_ids, aux, causal=True):
        cfg = self.cfg
        h = norm_apply(cfg, p["norm1"], x)
        if cfg.attention == "mla":
            a, new_cache = mla_apply(cfg, p["attn"], h, positions, cache=cache)
        else:
            a, new_cache = gqa_apply(
                cfg, p["attn"], h, positions, cache=cache, causal=causal,
                window=seg.window, position_ids=position_ids,
            )
        x = x + a
        h2 = norm_apply(cfg, p["norm2"], x)
        if seg.moe:
            f, aux_l = moe_apply(cfg, p["ffn"], h2)
            aux = aux + aux_l
        else:
            f = mlp_apply(cfg, p["ffn"], h2)
        return x + f, new_cache, aux

    def _apply_rec_block(self, p, x, state):
        cfg = self.cfg
        h = norm_apply(cfg, p["norm1"], x)
        r, new_state = rglru_apply(cfg, p["rec"], h, state)
        x = x + r
        h2 = norm_apply(cfg, p["norm2"], x)
        return x + mlp_apply(cfg, p["ffn"], h2), new_state

    def _apply_dec_block(self, p, x, positions, cache, enc_out, enc_positions, has_cache):
        cfg = self.cfg
        h = norm_apply(cfg, p["norm1"], x)
        a, new_self = gqa_apply(
            cfg, p["self_attn"], h, positions,
            cache=cache["self"] if has_cache else None,
        )
        x = x + a
        hx = norm_apply(cfg, p["norm_x"], x)
        if enc_out is not None:
            cxa, new_cross = gqa_apply(
                cfg, p["cross_attn"], hx, positions,
                kv_x=enc_out, kv_positions=enc_positions,
                cache=cache["cross"] if has_cache else None, causal=False,
            )
        else:
            cxa, new_cross = gqa_apply(
                cfg, p["cross_attn"], hx, positions,
                cache=cache["cross"], cache_read_only=True, causal=False,
            )
        x = x + cxa
        h2 = norm_apply(cfg, p["norm2"], x)
        x = x + mlp_apply(cfg, p["ffn"], h2)
        new_c = {"self": new_self, "cross": new_cross} if has_cache else None
        return x, new_c

    # ----------------------------------------------------------------- driver --
    def _segment_scan(self, seg: Segment, seg_params, x, positions, cache,
                      position_ids, aux, enc_out=None, enc_positions=None):
        """Run one segment under lax.scan.  Returns (x, new_cache, aux)."""
        cfg = self.cfg
        has_cache = cache is not None

        def body(carry, xs):
            x, aux = carry
            p, c = xs if has_cache else (xs, None)
            if seg.kind in ("attn", "enc"):
                x, new_c, aux = self._apply_attn_block(
                    seg, p, x, positions, c, position_ids, aux,
                    causal=(seg.kind == "attn"),
                )
            elif seg.kind == "rwkv":
                x, new_c = rwkv6_apply(cfg, p["block"], x, c)
            elif seg.kind == "group":
                rec_p, rec_c = p["rec"], (c["rec"] if has_cache else None)
                new_rec = []
                for i in range(seg.n_rec):
                    pi = jax.tree.map(lambda a: a[i], rec_p)
                    ci = jax.tree.map(lambda a: a[i], rec_c) if has_cache else None
                    x, nci = self._apply_rec_block(pi, x, ci)
                    new_rec.append(nci)
                new_c = None
                if has_cache:
                    new_c = {"rec": jax.tree.map(lambda *a: jnp.stack(a), *new_rec)}
                if seg.has_attn:
                    ac = c.get("attn") if has_cache else None
                    x, new_ac, aux = self._apply_attn_block(
                        dataclasses.replace(seg, moe=False), p["attn"], x,
                        positions, ac, position_ids, aux,
                    )
                    if has_cache:
                        new_c["attn"] = new_ac
            elif seg.kind == "dec":
                x, new_c = self._apply_dec_block(
                    p, x, positions, c, enc_out, enc_positions, has_cache
                )
            else:
                raise ValueError(seg.kind)
            return (self._wsc(x), aux), new_c

        unroll = seg.n if cfg.scan_unroll else 1
        if not has_cache:
            wrapped = body
            if cfg.remat == "block":
                wrapped = jax.checkpoint(body)
            elif cfg.remat == "dots":
                wrapped = jax.checkpoint(
                    body,
                    policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                )
            (x, aux), _ = jax.lax.scan(wrapped, (x, aux), seg_params, unroll=unroll)
            return x, None, aux
        (x, aux), new_cache = jax.lax.scan(body, (x, aux), (seg_params, cache),
                                           unroll=unroll)
        return x, new_cache, aux

    def encode(self, params, frames: jnp.ndarray) -> jnp.ndarray:
        """Whisper encoder over precomputed (stub-frontend) frame embeddings."""
        cfg = self.cfg
        B, T, _ = frames.shape
        pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        x = frames.astype(jnp.dtype(cfg.dtype)) + sinusoidal_embed(pos, cfg.d_model).astype(cfg.dtype)
        x = self._wsc(x)
        for i, seg in enumerate(self.segments):
            if seg.kind != "enc":
                continue
            x, _, _ = self._segment_scan(seg, params["segments"][i], x, pos, None, None, jnp.float32(0))
        return norm_apply(cfg, params["enc_final_norm"], x)

    def backbone(
        self, params, tokens, positions, caches=None, position_ids=None,
        enc_out=None, enc_positions=None, run_encoder_segments=False,
    ):
        """Shared trunk: embed -> segments -> final norm.

        Returns (hidden (B,S,d), new_caches, aux)."""
        cfg = self.cfg
        x = self._wsc(params["embed"]["embedding"][tokens])
        if cfg.enc_dec:
            x = x + sinusoidal_embed(positions, cfg.d_model).astype(x.dtype)
        aux = jnp.float32(0)
        new_caches: List[Any] = [None] * len(self.segments)
        for i, seg in enumerate(self.segments):
            if seg.kind == "enc":
                new_caches[i] = None if caches is None else caches[i]
                continue
            cache_i = caches[i] if caches is not None else None
            x, nc, aux = self._segment_scan(
                seg, params["segments"][i], x, positions, cache_i,
                position_ids, aux, enc_out=enc_out, enc_positions=enc_positions,
            )
            new_caches[i] = nc
        x = norm_apply(cfg, params["final_norm"], x)
        return x, (new_caches if caches is not None else None), aux

    # ------------------------------------------------------------------ heads --
    def logits(self, params, hidden: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        w = (params["embed"]["embedding"].T if cfg.tie_embeddings
             else params["lm_head"]["w"])
        lg = jnp.einsum(
            "bsd,dv->bsv", hidden, w, preferred_element_type=jnp.dtype(cfg.logits_dtype)
        )
        if self.logits_sharding is not None:
            lg = jax.lax.with_sharding_constraint(lg, self.logits_sharding)
        return lg

    def _xent(self, params, hidden, labels) -> jnp.ndarray:
        """Mean cross-entropy over a vocab-sharded (vocab-parallel) softmax;
        optionally chunked over the sequence axis so only (B, S/chunks, V)
        logits are ever alive (beyond-paper memory optimisation for 256k
        vocabularies).

        The gold logit is extracted with an iota==label mask instead of
        take_along_axis: a gather over the model-sharded vocab dim would
        force GSPMD to replicate the logits (measured: ~16x temp memory on
        command-r-plus)."""
        cfg = self.cfg
        nc = cfg.xent_chunk

        def ce(h, y):
            lg = self.logits(params, h)
            m = jax.lax.stop_gradient(lg.max(axis=-1, keepdims=True))
            logz = jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1)) + m[..., 0]
            iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
            gold = jnp.sum(jnp.where(iota == y[..., None], lg, 0.0), axis=-1)
            return (logz - gold).sum()

        B, S, _ = hidden.shape
        if nc and nc > 1 and S % nc == 0:
            hs = hidden.reshape(B, nc, S // nc, -1).swapaxes(0, 1)
            ys = labels.reshape(B, nc, S // nc).swapaxes(0, 1)
            total = jax.lax.map(lambda hy: jax.remat(ce)(hy[0], hy[1]), (hs, ys)).sum()
        else:
            total = ce(hidden, labels)
        return total / (B * S)

    # -------------------------------------------------------------------- API --
    def loss(self, params, batch: Dict[str, jnp.ndarray]):
        """batch: tokens (B,S), labels (B,S) [+ frames / position_ids]."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        enc_out = enc_pos = None
        if cfg.enc_dec:
            enc_out = self.encode(params, batch["frames"])
            enc_pos = jnp.broadcast_to(
                jnp.arange(enc_out.shape[1], dtype=jnp.int32)[None], enc_out.shape[:2]
            )
        hidden, _, aux = self.backbone(
            params, tokens, positions,
            position_ids=batch.get("position_ids"),
            enc_out=enc_out, enc_positions=enc_pos,
        )
        xent = self._xent(params, hidden, batch["labels"])
        loss = xent + MOE_AUX_WEIGHT * aux
        return loss, {"xent": xent, "moe_aux": aux}

    def prefill(self, params, batch: Dict[str, jnp.ndarray], caches):
        """Bulk-process a prompt, filling caches.  Returns last-token logits."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        enc_out = enc_pos = None
        if cfg.enc_dec:
            enc_out = self.encode(params, batch["frames"])
            enc_pos = jnp.broadcast_to(
                jnp.arange(enc_out.shape[1], dtype=jnp.int32)[None], enc_out.shape[:2]
            )
        hidden, caches, _ = self.backbone(
            params, tokens, positions, caches=caches,
            position_ids=batch.get("position_ids"),
            enc_out=enc_out, enc_positions=enc_pos,
        )
        return self.logits(params, hidden[:, -1:, :])[:, 0], caches

    def decode_step(self, params, tokens: jnp.ndarray, pos: jnp.ndarray, caches,
                    position_ids=None):
        """One decode step.  tokens: (B,), pos: (B,) absolute position."""
        positions = pos[:, None].astype(jnp.int32)
        hidden, caches, _ = self.backbone(
            params, tokens[:, None], positions, caches=caches,
            position_ids=position_ids,
        )
        return self.logits(params, hidden)[:, 0], caches
