"""Model substrate: configs, layers, attention (GQA/MLA), MoE, recurrent
blocks (RWKV6 / RG-LRU) and the unified ``LM`` assembly."""
from .config import MLAConfig, ModelConfig, MoEConfig, RecurrentConfig, reduced
from .transformer import LM, Segment, build_segments, sinusoidal_embed

__all__ = [
    "ModelConfig",
    "MoEConfig",
    "MLAConfig",
    "RecurrentConfig",
    "reduced",
    "LM",
    "Segment",
    "build_segments",
    "sinusoidal_embed",
]
