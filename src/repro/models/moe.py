"""Mixture-of-Experts layer: shared + routed experts, two dispatch modes.

Supports Qwen1.5-MoE-A2.7B (4 shared + 60 routed, top-4, softmax router)
and DeepSeek-V3 (1 shared + 256 routed, top-8, sigmoid router with
normalised gates).

Dispatch modes
--------------
``einsum``  t5x/Flaxformer-style capacity dispatch: tokens are grouped, a
            one-hot dispatch tensor (G, s, E, C) routes them into per-expert
            buffers via einsum.  Simple, fully dense, SPMD-friendly — but
            the dispatch/combine einsums cost O(T * s * top_k * cf * d)
            FLOPs, which becomes material at E=256 (DeepSeek).
``sort``    Beyond-paper optimisation: tokens are argsorted by expert id,
            scattered into (E*C, d) buffers via computed slots, and combined
            with a scatter-add.  Dispatch costs O(T log T) comparisons plus
            O(T * K * d) bytes moved — no matmul FLOPs at all.  This is the
            TPU-native analogue of a GPU radix-sort MoE dispatch.

Both modes drop tokens routed beyond an expert's capacity
``C = ceil(tokens_per_group * top_k * capacity_factor / E)`` — the standard
capacity discipline that keeps shapes static for XLA.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import Dense, activation

__all__ = ["moe_init", "moe_apply"]


def moe_init(rng, cfg: ModelConfig) -> Dict:
    m = cfg.moe
    dt = jnp.dtype(cfg.dtype)
    d, E, f = cfg.d_model, m.n_experts, m.d_expert
    r = jax.random.split(rng, 6)
    scale = 1.0 / np.sqrt(d)
    p = {
        "router": {
            "w": (jax.random.normal(r[0], (d, E), dtype=jnp.float32) * scale).astype(dt)
        },
        "experts": {
            "wi": (jax.random.normal(r[1], (E, d, f), dtype=jnp.float32) * scale).astype(dt),
            "wg": (jax.random.normal(r[2], (E, d, f), dtype=jnp.float32) * scale).astype(dt),
            "wo": (jax.random.normal(r[3], (E, f, d), dtype=jnp.float32) / np.sqrt(f)).astype(dt),
        },
    }
    if m.n_shared_experts:
        fs = m.n_shared_experts * f
        p["shared"] = {
            "wi": Dense.init(r[4], d, fs, dt),
            "wg": Dense.init(jax.random.fold_in(r[4], 1), d, fs, dt),
            "wo": Dense.init(r[5], fs, d, dt),
        }
    return p


def _router(cfg: ModelConfig, p, x2d: jnp.ndarray):
    """x2d: (T, d) -> (gates (T,K) in x dtype, idx (T,K) int32, probs (T,E) f32)."""
    m = cfg.moe
    logits = (x2d.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32))
    if m.router_act == "sigmoid":                      # DeepSeek-V3
        probs = jax.nn.sigmoid(logits)
        gates, idx = jax.lax.top_k(probs, m.top_k)
        gates = gates / (gates.sum(axis=-1, keepdims=True) + 1e-9)
    else:                                              # softmax (Qwen)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, m.top_k)
        gates = gates / (gates.sum(axis=-1, keepdims=True) + 1e-9)
    return gates.astype(x2d.dtype), idx.astype(jnp.int32), probs


def _expert_ffn(cfg: ModelConfig, experts: Dict, xe: jnp.ndarray) -> jnp.ndarray:
    """Batched per-expert FFN. xe: (E, C, d) -> (E, C, d)."""
    act = activation(cfg.act)
    h = jnp.einsum("ecd,edf->ecf", xe, experts["wi"])
    g = jnp.einsum("ecd,edf->ecf", xe, experts["wg"])
    h = act(g) * h
    return jnp.einsum("ecf,efd->ecd", h, experts["wo"])


def _aux_loss(probs: jnp.ndarray, idx: jnp.ndarray, E: int) -> jnp.ndarray:
    """Switch-style load-balance loss: E * sum_e f_e * p_e  (f32)."""
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)        # (T,K,E)
    f = onehot.sum(axis=(0, 1)) / jnp.maximum(onehot.sum(), 1.0)
    pbar = probs.mean(axis=0) / jnp.maximum(probs.mean(axis=0).sum(), 1e-9)
    return E * jnp.sum(f * pbar)


def _dispatch_einsum(cfg: ModelConfig, p, x2d, gates, idx):
    m = cfg.moe
    T, d = x2d.shape
    E, K = m.n_experts, m.top_k
    s = min(m.group_size, T)
    while T % s != 0:           # static: shapes known at trace time
        s -= 1
    G = T // s
    C = max(int(np.ceil(s * K * m.capacity_factor / E)), 1)

    xg = x2d.reshape(G, s, d)
    idx_g = idx.reshape(G, s, K)
    gates_g = gates.reshape(G, s, K)

    # position of each (token, k) claim inside its expert, priority = (k, s)
    mask = jax.nn.one_hot(idx_g, E, dtype=jnp.int32)           # (G,s,K,E)
    mask_kf = jnp.swapaxes(mask, 1, 2).reshape(G, K * s, E)    # k-major priority
    pos_kf = jnp.cumsum(mask_kf, axis=1) * mask_kf - 1         # (G,Ks,E)
    pos = jnp.swapaxes(pos_kf.reshape(G, K, s, E), 1, 2)       # (G,s,K,E)
    keep = (pos >= 0) & (pos < C)

    disp = jax.nn.one_hot(pos, C, dtype=x2d.dtype) * keep[..., None]   # (G,s,K,E,C)
    disp_se = disp.sum(axis=2)                                  # (G,s,E,C)
    comb = (disp * gates_g[..., None, None]).sum(axis=2)        # (G,s,E,C)

    xe = jnp.einsum("gsec,gsd->gecd", disp_se, xg)              # (G,E,C,d)
    xe = jnp.swapaxes(xe, 0, 1).reshape(E, G * C, d)
    ye = _expert_ffn(cfg, p["experts"], xe)
    ye = jnp.swapaxes(ye.reshape(E, G, C, d), 0, 1)             # (G,E,C,d)
    y = jnp.einsum("gsec,gecd->gsd", comb, ye)
    return y.reshape(T, d)


SORT_GROUPS = 32   # aligned with the max dp extent (pod*data batch shards)


def _dispatch_sort(cfg: ModelConfig, p, x2d, gates, idx):
    """Sort-based dispatch, GROUP-LOCAL so GSPMD never communicates the sort:
    tokens are reshaped to (G, s, d) with G a multiple of the dp sharding,
    each group argsorts its own (s*K,) expert ids and scatters into its own
    (E, C, d) buffer (vmap over G).  Only the batched expert matmul touches
    the model-sharded expert weights (expert-parallel collective), never the
    dispatch itself."""
    m = cfg.moe
    T, d = x2d.shape
    E, K = m.n_experts, m.top_k
    G = SORT_GROUPS
    while T % G != 0:
        G //= 2
    s = T // G
    C = max(int(np.ceil(s * K * m.capacity_factor / E)), 1)

    xg = x2d.reshape(G, s, d)
    idx_g = idx.reshape(G, s, K)
    gates_g = gates.reshape(G, s, K)

    def one_group(xs, idxs, gats):
        eid = idxs.reshape(s * K)
        tok = jnp.repeat(jnp.arange(s, dtype=jnp.int32), K)
        gat = gats.reshape(s * K)
        order = jnp.argsort(eid, stable=True)
        s_eid, s_tok, s_gat = eid[order], tok[order], gat[order]
        seg_start = jnp.searchsorted(s_eid, jnp.arange(E, dtype=s_eid.dtype))
        pos_in_seg = jnp.arange(s * K, dtype=jnp.int32) - seg_start[s_eid].astype(jnp.int32)
        valid = pos_in_seg < C
        slot = jnp.where(valid, s_eid * C + pos_in_seg, E * C)   # E*C = dropped
        buf = jnp.zeros((E * C, d), dtype=xs.dtype)
        buf = buf.at[slot].set(xs[s_tok], mode="drop")           # data movement only
        return buf.reshape(E, C, d), (slot, s_tok, s_gat, valid)

    bufs, meta = jax.vmap(one_group)(xg, idx_g, gates_g)         # (G,E,C,d)
    # batched expert FFN: (E, G*C, d) x (E, d, f) — expert-parallel matmul
    xe = jnp.swapaxes(bufs, 0, 1).reshape(E, G * C, d)
    ye = _expert_ffn(cfg, p["experts"], xe)
    ye = jnp.swapaxes(ye.reshape(E, G, C, d), 0, 1).reshape(G, E * C, d)

    def combine(ye_g, xs, m_):
        slot, s_tok, s_gat, valid = m_
        gathered = jnp.where(
            valid[:, None], ye_g.at[slot].get(mode="fill", fill_value=0.0), 0.0
        )
        y = jnp.zeros((s, d), dtype=xs.dtype)
        return y.at[s_tok].add(gathered * s_gat[:, None])

    y = jax.vmap(combine)(ye, xg, meta)
    return y.reshape(T, d)


def moe_apply(
    cfg: ModelConfig, p: Dict, x: jnp.ndarray, dispatch: Optional[str] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y (B,S,d), aux_loss scalar f32)."""
    m = cfg.moe
    B, S, d = x.shape
    x2d = x.reshape(B * S, d)
    gates, idx, probs = _router(cfg, p, x2d)
    mode = dispatch or m.dispatch
    if mode == "sort":
        y = _dispatch_sort(cfg, p, x2d, gates, idx)
    else:
        y = _dispatch_einsum(cfg, p, x2d, gates, idx)
    if "shared" in p:
        act = activation(cfg.act)
        h = Dense.apply(p["shared"]["wi"], x2d)
        g = Dense.apply(p["shared"]["wg"], x2d)
        y = y + Dense.apply(p["shared"]["wo"], act(g) * h)
    aux = _aux_loss(probs, idx, m.n_experts)
    return y.reshape(B, S, d), aux
