"""Unified model configuration covering all assigned architecture families.

One ``ModelConfig`` describes dense GQA transformers, MoE (shared+routed,
MLA), RWKV6-style SSMs, RecurrentGemma-style hybrids, encoder-decoder audio
backbones, and VLM backbones (M-RoPE).  Every assigned architecture in
:mod:`repro.configs` instantiates this dataclass with its published values.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

__all__ = ["ModelConfig", "MoEConfig", "MLAConfig", "RecurrentConfig", "reduced"]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0            # routed experts
    n_shared_experts: int = 0     # always-on shared experts (Qwen-MoE/DeepSeek)
    top_k: int = 2
    d_expert: int = 0             # per-expert FFN hidden size
    n_dense_layers: int = 0       # leading layers that use a dense FFN (DeepSeek-V3: 3)
    capacity_factor: float = 1.25
    group_size: int = 256         # tokens per dispatch group (einsum mode)
    dispatch: str = "einsum"      # "einsum" | "sort"  (sort = beyond-paper opt)
    router_dtype: str = "float32"
    # DeepSeek-V3 uses sigmoid routing with bias-based aux-free balancing;
    # Qwen uses softmax.  "softmax" | "sigmoid"
    router_act: str = "softmax"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 Multi-head Latent Attention dims (arXiv:2412.19437)."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class RecurrentConfig:
    """RWKV6 / RG-LRU settings."""

    kind: str = "rwkv6"           # "rwkv6" | "rglru"
    head_size: int = 64           # rwkv6 head size
    conv_width: int = 4           # rg-lru temporal conv width
    lru_width: Optional[int] = None  # rg-lru recurrent width (default d_model)
    # hybrid block pattern, e.g. ("rec", "rec", "attn") for RecurrentGemma
    pattern: Tuple[str, ...] = ("rec",)


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"         # dense | moe | ssm | hybrid | audio | vlm

    n_layers: int = 4
    d_model: int = 512
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: Optional[int] = None   # default d_model // n_heads
    d_ff: int = 2048
    vocab: int = 32000

    act: str = "silu"             # silu | gelu
    mlp: str = "swiglu"           # swiglu | geglu | mlp (plain 2-matrix)
    norm: str = "rmsnorm"         # rmsnorm | layernorm | layernorm_nobias | nonparametric
    qkv_bias: bool = False        # Qwen1.5-style QKV bias
    attn_logit_softcap: Optional[float] = None
    tie_embeddings: bool = False

    rope: str = "rope"            # rope | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = (16, 24, 24)  # Qwen2-VL t/h/w split

    attention: str = "full"       # full | local | mla | none
    attn_window: Optional[int] = None   # local-attention window

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    recurrent: Optional[RecurrentConfig] = None

    # encoder-decoder (Whisper): n_layers applies to BOTH encoder and decoder
    enc_dec: bool = False
    enc_len: int = 1500           # encoder frames (Whisper 30 s @ 50 Hz)

    # VLM backbone: expects fused M-RoPE position ids as an input
    needs_position_ids: bool = False

    # numerics
    dtype: str = "bfloat16"       # activation/param dtype
    logits_dtype: str = "float32"
    # KV-cache storage dtype (None = activation dtype).  "float8_e4m3fn"
    # halves decode cache bandwidth (beyond-paper serving optimisation).
    kv_dtype: Optional[str] = None
    # Attention inner implementation: "xla" (einsum softmax — the dry-run /
    # CPU path), "kernel" (Pallas flash attention on TPU),
    # "kernel_interpret" (Pallas body interpreted on CPU, for validation).
    attention_impl: str = "xla"
    remat: str = "none"           # none | block | dots  (activation ckpt policy)
    # vocab-chunked cross-entropy (beyond-paper memory optimisation)
    xent_chunk: int = 0           # 0 = unchunked
    # Fully unroll layer scans.  Used by the dry-run's cost-probe compiles:
    # XLA's cost_analysis counts a while-loop body ONCE regardless of trip
    # count, so roofline FLOPs are extrapolated from small unrolled probes.
    scan_unroll: bool = False

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_heads % max(self.n_kv_heads, 1) != 0:
            raise ValueError("n_heads must be a multiple of n_kv_heads")
        if self.attention == "local" and not self.attn_window:
            raise ValueError("local attention requires attn_window")
        if self.family == "moe" and self.moe is None:
            raise ValueError("moe family requires MoEConfig")

    # -- derived ---------------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def sub_quadratic(self) -> bool:
        """True when the arch supports O(S) / windowed decode at 500k ctx."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.attention == "local"

    def has_decode(self) -> bool:
        """Encoder-only models have no decode step; everything else decodes."""
        return True  # all assigned archs are decoder or enc-dec

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head), used for
        MODEL_FLOPS = 6*N*D in the roofline analysis."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        hd = self.head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        total = emb
        rec = self.recurrent

        def attn_params() -> int:
            if self.attention == "mla":
                m = self.mla
                qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
                p = d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk_head
                p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                p += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                p += self.n_heads * m.v_head_dim * d
                return p
            qp = d * self.n_heads * hd
            kvp = 2 * d * self.n_kv_heads * hd
            op = self.n_heads * hd * d
            bias = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
            return qp + kvp + op + bias

        def ffn_params(dff: int) -> int:
            mats = 3 if self.mlp in ("swiglu", "geglu") else 2
            return mats * d * dff

        def rec_params() -> int:
            if rec is None:
                return 0
            if rec.kind == "rwkv6":
                # time-mix: r,k,v,g,o (5 d*d) + decay lora + mix params
                return 5 * d * d + 2 * d * 64 + 6 * d
            w = rec.lru_width or d
            # rg-lru: in/out proj + conv + gates
            return 2 * d * w + rec.conv_width * w + 2 * w * (w // 8) + w

        norm_p = 0 if self.norm == "nonparametric" else d
        for i in range(L):
            kind = "rec"
            if self.family in ("dense", "moe", "audio", "vlm"):
                kind = "attn"
            elif self.family == "hybrid":
                kind = rec.pattern[i % len(rec.pattern)]
            if kind == "attn":
                total += attn_params()
            else:
                total += rec_params()
            # FFN / MoE
            if self.moe is not None and i >= self.moe.n_dense_layers:
                total += self.moe.n_experts * ffn_params(self.moe.d_expert)
                total += self.moe.n_shared_experts * ffn_params(self.moe.d_expert)
                total += d * self.moe.n_experts  # router
            elif self.family != "ssm" or rec.kind != "rwkv6":
                total += ffn_params(self.d_ff)
            else:
                total += 2 * d * self.d_ff  # rwkv channel-mix (2 matrices)
            total += 2 * norm_p
        if self.enc_dec:  # decoder side (cross-attn + self-attn + ffn)
            for _ in range(L):
                total += 2 * attn_params() + ffn_params(self.d_ff) + 3 * norm_p
        return int(total)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        m = self.moe
        n_moe_layers = self.n_layers - m.n_dense_layers
        mats = 3 if self.mlp in ("swiglu", "geglu") else 2
        per_expert = mats * self.d_model * m.d_expert
        inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
        return int(full - inactive)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Shrink a config to smoke-test scale, preserving family/topology."""
    small: Dict = dict(
        n_layers=min(cfg.n_layers, 2 if not cfg.recurrent else len((cfg.recurrent.pattern or ("rec",))) * 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab=512,
        dtype="float32",
        logits_dtype="float32",
        enc_len=32 if cfg.enc_dec else cfg.enc_len,
    )
    if cfg.moe is not None:
        small["moe"] = dataclasses.replace(
            cfg.moe, n_experts=min(cfg.moe.n_experts, 8), d_expert=64,
            n_dense_layers=min(cfg.moe.n_dense_layers, 1),
            top_k=min(cfg.moe.top_k, 2), group_size=16,
        )
    if cfg.mla is not None:
        small["mla"] = MLAConfig(
            q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32,
            qk_rope_head_dim=16, v_head_dim=32,
        )
    if cfg.recurrent is not None:
        small["recurrent"] = dataclasses.replace(
            cfg.recurrent, head_size=32,
            lru_width=128 if cfg.recurrent.lru_width else None,
        )
    if cfg.attn_window:
        small["attn_window"] = 16
    if cfg.rope == "mrope":
        # rescale the t/h/w frequency sections to the reduced head_dim
        half = small.get("head_dim", cfg.head_dim) // 2
        tot = sum(cfg.mrope_sections)
        secs = [max(1, s * half // tot) for s in cfg.mrope_sections]
        secs[0] += half - sum(secs)
        small["mrope_sections"] = tuple(secs)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
