"""Recurrent sequence-mixing blocks: RWKV6 (Finch) and RG-LRU (Griffin /
RecurrentGemma).

TPU adaptation notes (see DESIGN.md): the RG-LRU recurrence
``h_t = a_t * h_{t-1} + b_t`` is elementwise-linear, so training/prefill use
``jax.lax.associative_scan`` (log-depth, MXU-free) instead of a sequential
CUDA scan kernel.  The RWKV6 state update is a per-head rank-1 outer-product
accumulation with per-channel data-dependent decay; the exact sequential
``lax.scan`` here is the reference semantics, and
:mod:`repro.kernels.rwkv6_scan` provides the chunked Pallas kernel used on
TPU for training/prefill.

State layout (per layer, stacked over layers by the model):
  rwkv6 : {"ts_tm": (B,d), "ts_cm": (B,d), "S": (B,H,N,N)}
  rglru : {"conv": (B, conv_width-1, W), "h": (B, W)}
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import Dense

__all__ = [
    "rwkv6_init",
    "rwkv6_state",
    "rwkv6_apply",
    "rglru_init",
    "rglru_state",
    "rglru_apply",
    "rwkv6_mix_ref",
]


# ---------------------------------------------------------------------------
# RWKV6 (Finch, arXiv:2404.05892)
# ---------------------------------------------------------------------------
def rwkv6_init(rng, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    N = cfg.recurrent.head_size
    H = d // N
    r = jax.random.split(rng, 10)
    lora = 64
    scale = 1.0 / np.sqrt(d)

    def mat(key, din, dout, s=None):
        s = s if s is not None else 1.0 / np.sqrt(din)
        return (jax.random.normal(key, (din, dout), dtype=jnp.float32) * s).astype(dt)

    return {
        # pre-norms for the two sub-blocks (RWKV uses LayerNorm; we use the
        # config's norm so the block composes with any family)
        "ln1": {"scale": jnp.ones((d,), dtype=dt), "bias": jnp.zeros((d,), dtype=dt)},
        "ln2": {"scale": jnp.ones((d,), dtype=dt), "bias": jnp.zeros((d,), dtype=dt)},
        # token-shift lerp coefficients (static part of ddlerp)
        "mu": {k: jnp.full((d,), 0.5, dtype=dt) for k in ("r", "k", "v", "g", "w")},
        "wr": {"w": mat(r[0], d, d)},
        "wk": {"w": mat(r[1], d, d)},
        "wv": {"w": mat(r[2], d, d)},
        "wg": {"w": mat(r[3], d, d)},
        "wo": {"w": mat(r[4], d, d)},
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + tanh(xw A) B))
        "w0": jnp.full((d,), -2.0, dtype=jnp.float32),
        "wA": mat(r[5], d, lora, s=0.01),
        "wB": mat(r[6], lora, d, s=0.01),
        "u": (jax.random.normal(r[7], (d,), dtype=jnp.float32) * 0.1).astype(jnp.float32),
        # per-head group norm on the attention output
        "ln_x": {"scale": jnp.ones((d,), dtype=dt), "bias": jnp.zeros((d,), dtype=dt)},
        # channel mix
        "mu_cm": {k: jnp.full((d,), 0.5, dtype=dt) for k in ("k", "r")},
        "cm_k": {"w": mat(r[8], d, cfg.d_ff)},
        "cm_v": {"w": mat(r[9], cfg.d_ff, d)},
        "cm_r": {"w": mat(jax.random.fold_in(r[8], 7), d, d)},
    }


def rwkv6_state(cfg: ModelConfig, batch: int, n_layers: int) -> Dict:
    d = cfg.d_model
    N = cfg.recurrent.head_size
    H = d // N
    dt = jnp.dtype(cfg.dtype)
    return {
        "ts_tm": jnp.zeros((n_layers, batch, d), dtype=dt),
        "ts_cm": jnp.zeros((n_layers, batch, d), dtype=dt),
        "S": jnp.zeros((n_layers, batch, H, N, N), dtype=jnp.float32),
    }


def rwkv6_mix_ref(
    r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
    u: jnp.ndarray, S0: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact sequential RWKV6 WKV recurrence (the pure-jnp oracle).

    r,k,v,w: (B,S,H,N) — w is the per-channel decay in (0,1); u: (H,N);
    S0: (B,H,N,N) state with layout [k-dim, v-dim].  Returns (y, S_T).
    """

    def step(S, inp):
        rt, kt, vt, wt = inp                      # (B,H,N) each
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,N,N)
        y = jnp.einsum("bhi,bhij->bhj", rt, S + u[..., :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, y

    rs = jnp.moveaxis(r, 1, 0).astype(jnp.float32)
    ks = jnp.moveaxis(k, 1, 0).astype(jnp.float32)
    vs = jnp.moveaxis(v, 1, 0).astype(jnp.float32)
    ws = jnp.moveaxis(w, 1, 0).astype(jnp.float32)
    S_T, ys = jax.lax.scan(step, S0.astype(jnp.float32), (rs, ks, vs, ws))
    return jnp.moveaxis(ys, 0, 1), S_T


def _group_norm(x: jnp.ndarray, H: int, scale, bias, eps=1e-5):
    """GroupNorm over each head's channels. x: (B,S,d)."""
    B, S, d = x.shape
    xh = x.reshape(B, S, H, d // H).astype(jnp.float32)
    mu = xh.mean(axis=-1, keepdims=True)
    var = xh.var(axis=-1, keepdims=True)
    xn = (xh - mu) * jax.lax.rsqrt(var + eps)
    return xn.reshape(B, S, d).astype(x.dtype) * scale + bias


def rwkv6_apply(
    cfg: ModelConfig, p: Dict, x: jnp.ndarray, state: Optional[Dict],
    mix_fn=None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Full RWKV6 block (pre-norms included):

        x = x + time_mix(ln1(x));  x = x + channel_mix(ln2(x))

    x: (B,S,d).  ``state=None`` means training (zero initial state, no state
    returned).  Token-shift states hold the last *normed* token of each
    sub-block's input, so decode continues exactly where prefill stopped.
    ``mix_fn`` overrides the WKV inner loop (e.g. the Pallas chunked
    kernel); defaults to the exact sequential reference.
    """
    B, S, d = x.shape
    N = cfg.recurrent.head_size
    H = d // N
    mix = mix_fn or rwkv6_mix_ref
    if mix_fn is None and cfg.attention_impl != "xla" and S > 1 and S % 16 == 0:
        # chunked Pallas WKV kernel for train/prefill (oracle backward)
        from ..kernels.rwkv6_scan import rwkv6_scan_trainable

        def mix(r, k, v, w, u, S0, _interp=(cfg.attention_impl == "kernel_interpret")):
            chunk = 64 if S % 64 == 0 else 16
            return rwkv6_scan_trainable(r, k, v, w, u, S0, chunk=chunk,
                                        interpret=_interp)

    # ---- time mix -------------------------------------------------------------
    xn = _layer_norm(x, p["ln1"]["scale"], p["ln1"]["bias"])
    prev_tm = state["ts_tm"] if state is not None else jnp.zeros_like(xn[:, 0])
    xs = jnp.concatenate([prev_tm[:, None, :], xn[:, :-1, :]], axis=1)

    def lerp(mu):
        return xn + (xs - xn) * mu

    r = Dense.apply(p["wr"], lerp(p["mu"]["r"])).reshape(B, S, H, N)
    k = Dense.apply(p["wk"], lerp(p["mu"]["k"])).reshape(B, S, H, N)
    v = Dense.apply(p["wv"], lerp(p["mu"]["v"])).reshape(B, S, H, N)
    g = Dense.apply(p["wg"], lerp(p["mu"]["g"]))
    xw = lerp(p["mu"]["w"]).astype(jnp.float32)
    decay_in = p["w0"] + jnp.tanh(xw @ p["wA"].astype(jnp.float32)) @ p["wB"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(decay_in)).reshape(B, S, H, N)       # (0,1) decay

    S0 = (
        state["S"] if state is not None
        else jnp.zeros((B, H, N, N), dtype=jnp.float32)
    )
    u = p["u"].reshape(H, N)
    y, S_T = mix(r, k, v, w, u, S0)
    y = _group_norm(y.reshape(B, S, d), H, p["ln_x"]["scale"], p["ln_x"]["bias"])
    y = y * jax.nn.silu(g)
    x = x + Dense.apply(p["wo"], y.astype(x.dtype))

    # ---- channel mix ------------------------------------------------------------
    hn = _layer_norm(x, p["ln2"]["scale"], p["ln2"]["bias"])
    prev_cm = state["ts_cm"] if state is not None else jnp.zeros_like(hn[:, 0])
    hs = jnp.concatenate([prev_cm[:, None, :], hn[:, :-1, :]], axis=1)

    def lerp_cm(mu):
        return hn + (hs - hn) * mu

    kk = jnp.square(jax.nn.relu(Dense.apply(p["cm_k"], lerp_cm(p["mu_cm"]["k"]))))
    cm = jax.nn.sigmoid(Dense.apply(p["cm_r"], lerp_cm(p["mu_cm"]["r"]))) * Dense.apply(p["cm_v"], kk)
    out = x + cm

    new_state = None
    if state is not None:
        new_state = {"ts_tm": xn[:, -1, :], "ts_cm": hn[:, -1, :], "S": S_T}
    return out, new_state


def _layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = xf.var(axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale + bias


# ---------------------------------------------------------------------------
# RG-LRU (Griffin, arXiv:2402.19427) — RecurrentGemma temporal block
# ---------------------------------------------------------------------------
N_GATE_BLOCKS = 16
RGLRU_C = 8.0


def rglru_init(rng, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    W = cfg.recurrent.lru_width or d
    cw = cfg.recurrent.conv_width
    dt = jnp.dtype(cfg.dtype)
    r = jax.random.split(rng, 7)
    nb = N_GATE_BLOCKS
    bs = W // nb

    def blockmat(key):
        return (jax.random.normal(key, (nb, bs, bs), dtype=jnp.float32) / np.sqrt(bs)).astype(dt)

    # Lambda init so that a = sigmoid(lam) ^ c spans ~(0.9, 0.999) (Griffin §2.4)
    lam = jnp.log(jnp.expm1(jnp.linspace(0.35, 0.9, W))).astype(jnp.float32)
    return {
        "proj_x": Dense.init(r[0], d, W, dt),
        "proj_g": Dense.init(r[1], d, W, dt),
        "proj_out": Dense.init(r[2], W, d, dt),
        "conv": (jax.random.normal(r[3], (cw, W), dtype=jnp.float32) / np.sqrt(cw)).astype(dt),
        "conv_b": jnp.zeros((W,), dtype=dt),
        "wa": blockmat(r[4]),
        "ba": jnp.zeros((W,), dtype=jnp.float32),
        "wx": blockmat(r[5]),
        "bx": jnp.zeros((W,), dtype=jnp.float32),
        "lam": lam,
    }


def rglru_state(cfg: ModelConfig, batch: int, n_layers: int) -> Dict:
    W = cfg.recurrent.lru_width or cfg.d_model
    cw = cfg.recurrent.conv_width
    dt = jnp.dtype(cfg.dtype)
    return {
        "conv": jnp.zeros((n_layers, batch, cw - 1, W), dtype=dt),
        "h": jnp.zeros((n_layers, batch, W), dtype=jnp.float32),
    }


def _block_diag_mm(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: (B,S,W), w: (nb, bs, bs) block-diagonal -> (B,S,W)."""
    B, S, W = x.shape
    nb, bs, _ = w.shape
    xb = x.reshape(B, S, nb, bs)
    yb = jnp.einsum("bsnd,nde->bsne", xb, w)
    return yb.reshape(B, S, W)


def _causal_conv(x: jnp.ndarray, kernel: jnp.ndarray, bias: jnp.ndarray,
                 prev: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv. x: (B,S,W), kernel: (cw,W), prev: (B,cw-1,W)."""
    cw = kernel.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), dtype=x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)                    # (B, S+cw-1, W)
    y = sum(
        xp[:, i : i + x.shape[1], :] * kernel[i] for i in range(cw)
    ) + bias
    return y.astype(x.dtype), xp[:, -(cw - 1):, :]


def rglru_apply(
    cfg: ModelConfig, p: Dict, x: jnp.ndarray, state: Optional[Dict]
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Griffin recurrent block: proj -> conv -> RG-LRU, gated by GeLU branch."""
    B, S, d = x.shape
    xb = Dense.apply(p["proj_x"], x)                           # (B,S,W)
    gb = Dense.apply(p["proj_g"], x)

    conv_prev = state["conv"] if state is not None else None
    xc, conv_state = _causal_conv(xb, p["conv"], p["conv_b"], conv_prev)

    # RG-LRU gates (block-diagonal input projections)
    rgate = jax.nn.sigmoid(_block_diag_mm(xc, p["wa"]).astype(jnp.float32) + p["ba"])
    igate = jax.nn.sigmoid(_block_diag_mm(xc, p["wx"]).astype(jnp.float32) + p["bx"])
    log_a = -RGLRU_C * rgate * jax.nn.softplus(p["lam"])       # log a_t  (B,S,W)
    a = jnp.exp(log_a)
    gated_x = igate * xc.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x

    h0 = state["h"] if state is not None else jnp.zeros((B, xb.shape[-1]), jnp.float32)
    if S == 1:
        h = a[:, 0] * h0 + b[:, 0]
        hs = h[:, None, :]
        h_last = h
    else:
        # linear recurrence via associative scan (TPU-native, log-depth);
        # fold the incoming state into the first step's offset.
        b0 = b.at[:, 0, :].add(a[:, 0, :] * h0)
        aa, bb = jax.lax.associative_scan(
            lambda l, r: (l[0] * r[0], r[0] * l[1] + r[1]), (a, b0), axis=1
        )
        hs = bb
        h_last = bb[:, -1, :]

    y = hs.astype(x.dtype) * jax.nn.gelu(gb, approximate=True)
    out = Dense.apply(p["proj_out"], y)

    new_state = None
    if state is not None:
        new_state = {"conv": conv_state, "h": h_last}
    return out, new_state
