"""Attention variants: GQA (full / local / cross) and DeepSeek-V3 MLA.

Unified cache design
--------------------
A per-layer cache is a dict of arrays::

    {"k": (B, C, Hk, D), "v": (B, C, Hk, D), "pos": (B, C) int32}

``C`` is the cache capacity — the full context for global attention, or the
window size for local attention, in which case the cache is a *ring buffer*
indexed by ``position % window``.  ``pos`` stores the absolute position of
each slot (-1 = empty), so masking is computed purely from positions:

    valid(q, k) = (pos_k >= 0) & (pos_k <= pos_q) [& (pos_k > pos_q - w)]

This one rule covers train (no cache), prefill (bulk write), decode (single
write) and 500k-token sliding-window decode without special cases.

MLA (Multi-head Latent Attention, arXiv:2412.19437 §2.1) caches only the
compressed latent ``c_kv`` (+ the shared RoPE key), and decode runs in the
*absorbed* form: scores and values are computed directly in latent space so
per-token decode cost is O(H * rank), independent of head count re-expansion.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import Dense, apply_mrope, apply_rope, norm_apply, norm_init

__all__ = [
    "gqa_init",
    "gqa_apply",
    "mla_init",
    "mla_apply",
    "make_cache",
    "make_mla_cache",
]

NEG_INF = -1e30


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------
def gqa_init(rng, cfg: ModelConfig, cross: bool = False) -> Dict:
    dt = _dt(cfg)
    d, hq, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    rq, rk, rv, ro = jax.random.split(rng, 4)
    return {
        "wq": Dense.init(rq, d, hq * hd, dt, bias=cfg.qkv_bias),
        "wk": Dense.init(rk, d, hk * hd, dt, bias=cfg.qkv_bias),
        "wv": Dense.init(rv, d, hk * hd, dt, bias=cfg.qkv_bias),
        "wo": Dense.init(ro, hq * hd, d, dt, bias=False),
    }


def make_cache(cfg: ModelConfig, batch: int, capacity: int, n_layers: int,
               dtype=None) -> Dict:
    """Stacked-over-layers KV cache (leading axis = layer, for lax.scan)."""
    dt = dtype or (jnp.dtype(cfg.kv_dtype) if cfg.kv_dtype else _dt(cfg))
    hk, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((n_layers, batch, capacity, hk, hd), dtype=dt),
        "v": jnp.zeros((n_layers, batch, capacity, hk, hd), dtype=dt),
        "pos": jnp.full((n_layers, batch, capacity), -1, dtype=jnp.int32),
    }


def _mask_bias(pos_q: jnp.ndarray, pos_k: jnp.ndarray, causal: bool,
               window: Optional[int]) -> jnp.ndarray:
    """(B, S_q, S_k) additive f32 bias from absolute positions."""
    valid = pos_k[:, None, :] >= 0
    if causal:
        valid &= pos_k[:, None, :] <= pos_q[:, :, None]
    if window is not None:
        valid &= pos_k[:, None, :] > (pos_q[:, :, None] - window)
    return jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa(q, k, v, bias, softcap: Optional[float]) -> jnp.ndarray:
    """q: (B,Sq,Hk,G,D)  k/v: (B,Sk,Hk,D)  bias: (B,Sq,Sk)."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = jnp.einsum(
        "bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32
    ) * scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = logits + bias[:, None, None, :, :]
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", w, v)


def _ring_write(cache_leaf: jnp.ndarray, new: jnp.ndarray, slots: jnp.ndarray):
    """Scatter ``new`` (B,S,...) into ``cache_leaf`` (B,C,...) at ``slots`` (B,S)."""
    b_idx = jnp.arange(cache_leaf.shape[0])[:, None]
    return cache_leaf.at[b_idx, slots].set(new.astype(cache_leaf.dtype))


def gqa_apply(
    cfg: ModelConfig,
    p: Dict,
    x: jnp.ndarray,                      # (B, S, d)
    positions: jnp.ndarray,              # (B, S) absolute positions
    *,
    cache: Optional[Dict] = None,        # per-layer cache slice (no layer axis)
    cache_read_only: bool = False,       # decode-time cross-attn: K/V from cache
    kv_x: Optional[jnp.ndarray] = None,  # cross-attention source (B, Sk, d)
    kv_positions: Optional[jnp.ndarray] = None,
    causal: bool = True,
    window: Optional[int] = None,
    position_ids: Optional[jnp.ndarray] = None,  # (3, B, S) for M-RoPE
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, S, d = x.shape
    hq, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = hq // hk

    q = Dense.apply(p["wq"], x).reshape(B, S, hq, hd)

    if cache_read_only:
        # Cross-attention decode: K/V were projected (un-roped, matching the
        # kv_x write path) and cached at prefill; only Q is computed here.
        assert cache is not None and kv_x is None
        k, v, k_pos = cache["k"].astype(q.dtype), cache["v"].astype(q.dtype), cache["pos"]
        new_cache = cache
        bias = _mask_bias(positions, k_pos, causal=causal, window=window)
        qg = q.reshape(B, S, hk, g, hd)
        out = _sdpa(qg, k, v, bias, cfg.attn_logit_softcap)
        return Dense.apply(p["wo"], out.reshape(B, S, hq * hd)), new_cache

    src = x if kv_x is None else kv_x
    k = Dense.apply(p["wk"], src).reshape(B, -1, hk, hd)
    v = Dense.apply(p["wv"], src).reshape(B, -1, hk, hd)

    k_pos = positions if kv_x is None else kv_positions
    if cfg.rope != "none" and kv_x is None:
        if cfg.rope == "mrope" and position_ids is not None:
            q, k = apply_mrope(q, k, position_ids, cfg.rope_theta, cfg.mrope_sections)
        else:
            q, k = apply_rope(q, k, positions, cfg.rope_theta)

    # Flash-attention kernel fast path: train/prefill-without-cache, causal,
    # contiguous positions (the standard training layout).
    if (cfg.attention_impl != "xla" and cache is None and kv_x is None
            and causal and cfg.attn_logit_softcap is None and S > 1):
        from ..kernels.flash_attention import flash_attention_trainable

        bq = 128 if S % 128 == 0 else S
        bk = 128 if S % 128 == 0 else S
        out = flash_attention_trainable(
            q, k, v, causal=True, window=window, block_q=bq, block_k=bk,
            interpret=(cfg.attention_impl == "kernel_interpret"),
        )
        return Dense.apply(p["wo"], out.reshape(B, S, hq * hd)), None

    new_cache = None
    if cache is not None:
        C = cache["k"].shape[1]
        slots = k_pos % C if window is not None else jnp.clip(k_pos, 0, C - 1)
        new_cache = {
            "k": _ring_write(cache["k"], k, slots),
            "v": _ring_write(cache["v"], v, slots),
            "pos": _ring_write(cache["pos"], k_pos, slots),
        }
        # cache may be stored in a narrower dtype (e.g. fp8): read-cast back
        k = new_cache["k"].astype(q.dtype)
        v = new_cache["v"].astype(q.dtype)
        k_pos = new_cache["pos"]

    bias = _mask_bias(positions, k_pos, causal=causal and kv_x is None,
                      window=window)
    qg = q.reshape(B, S, hk, g, hd)
    out = _sdpa(qg, k, v, bias, cfg.attn_logit_softcap)
    out = out.reshape(B, S, hq * hd)
    return Dense.apply(p["wo"], out), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ---------------------------------------------------------------------------
def mla_init(rng, cfg: ModelConfig) -> Dict:
    m = cfg.mla
    dt = _dt(cfg)
    d, H = cfg.d_model, cfg.n_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    r = jax.random.split(rng, 8)
    return {
        "wdq": Dense.init(r[0], d, m.q_lora_rank, dt),
        "q_norm": {"scale": jnp.ones((m.q_lora_rank,), dtype=dt)},
        "wuq": Dense.init(r[1], m.q_lora_rank, H * qk_head, dt),
        "wdkv": Dense.init(r[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dt),
        "kv_norm": {"scale": jnp.ones((m.kv_lora_rank,), dtype=dt)},
        "wuk": Dense.init(r[3], m.kv_lora_rank, H * m.qk_nope_head_dim, dt),
        "wuv": Dense.init(r[4], m.kv_lora_rank, H * m.v_head_dim, dt),
        "wo": Dense.init(r[5], H * m.v_head_dim, d, dt),
    }


def make_mla_cache(cfg: ModelConfig, batch: int, capacity: int, n_layers: int,
                   dtype=None) -> Dict:
    m = cfg.mla
    dt = dtype or _dt(cfg)
    return {
        "ckv": jnp.zeros((n_layers, batch, capacity, m.kv_lora_rank), dtype=dt),
        "krope": jnp.zeros((n_layers, batch, capacity, m.qk_rope_head_dim), dtype=dt),
        "pos": jnp.full((n_layers, batch, capacity), -1, dtype=jnp.int32),
    }


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y.astype(x.dtype)) * scale


def mla_apply(
    cfg: ModelConfig,
    p: Dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    cache: Optional[Dict] = None,
    absorbed: Optional[bool] = None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """MLA attention.  ``absorbed=None`` auto-selects: expanded form for
    prefill/train (S > 1), absorbed latent-space form for decode (S == 1)."""
    m = cfg.mla
    B, S, d = x.shape
    H = cfg.n_heads
    dn, dr, dv, rank = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim, m.kv_lora_rank
    if absorbed is None:
        absorbed = S == 1 and cache is not None

    # -- queries ------------------------------------------------------------------
    cq = _rms(Dense.apply(p["wdq"], x), p["q_norm"]["scale"])
    q = Dense.apply(p["wuq"], cq).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]

    # -- compressed KV -------------------------------------------------------------
    dkv = Dense.apply(p["wdkv"], x)
    ckv = _rms(dkv[..., :rank], p["kv_norm"]["scale"])         # (B,S,rank)
    k_rope_new = dkv[..., rank:]                               # (B,S,dr)

    # RoPE: decoupled — applied to q_rope and the single shared k_rope.
    q_rope, k_rope_new = apply_rope(
        q_rope, k_rope_new[..., None, :], positions, cfg.rope_theta
    )
    k_rope_new = k_rope_new[..., 0, :]

    k_pos = positions
    if cache is not None:
        C = cache["ckv"].shape[1]
        slots = jnp.clip(k_pos, 0, C - 1)
        cache = {
            "ckv": _ring_write(cache["ckv"], ckv, slots),
            "krope": _ring_write(cache["krope"], k_rope_new, slots),
            "pos": _ring_write(cache["pos"], k_pos, slots),
        }
        ckv_all, k_rope_all, k_pos = cache["ckv"], cache["krope"], cache["pos"]
    else:
        ckv_all, k_rope_all = ckv, k_rope_new

    bias = _mask_bias(positions, k_pos, causal=True, window=None)
    scale = 1.0 / np.sqrt(dn + dr)
    wuk = p["wuk"]["w"].reshape(rank, H, dn)
    wuv = p["wuv"]["w"].reshape(rank, H, dv)

    if absorbed:
        # scores: q_nope^T k_nope = (W_uk^T q_nope)^T c_kv — stay in rank space
        q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, wuk)       # (B,S,H,rank)
        s_nope = jnp.einsum("bshr,bkr->bhsk", q_lat, ckv_all,
                            preferred_element_type=jnp.float32)
        s_rope = jnp.einsum("bshd,bkd->bhsk", q_rope, k_rope_all,
                            preferred_element_type=jnp.float32)
        logits = (s_nope + s_rope) * scale + bias[:, None, :, :]
        w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        ctx_lat = jnp.einsum("bhsk,bkr->bshr", w, ckv_all)       # (B,S,H,rank)
        out = jnp.einsum("bshr,rhd->bshd", ctx_lat, wuv)         # (B,S,H,dv)
    else:
        k_nope = jnp.einsum("bkr,rhd->bkhd", ckv_all, wuk)       # (B,K,H,dn)
        vv = jnp.einsum("bkr,rhd->bkhd", ckv_all, wuv)           # (B,K,H,dv)
        s_nope = jnp.einsum("bshd,bkhd->bhsk", q_nope, k_nope,
                            preferred_element_type=jnp.float32)
        s_rope = jnp.einsum("bshd,bkd->bhsk", q_rope, k_rope_all,
                            preferred_element_type=jnp.float32)
        logits = (s_nope + s_rope) * scale + bias[:, None, :, :]
        w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhsk,bkhd->bshd", w, vv)

    out = out.reshape(B, S, H * dv)
    return Dense.apply(p["wo"], out), cache
