"""Trace exporters: Chrome/Perfetto ``trace_event`` JSON and a summary.

``to_chrome_trace`` renders a :class:`~repro.obs.tracing.Tracer` as the
Trace Event Format consumed by ``chrome://tracing`` and
https://ui.perfetto.dev — two process rows:

  * **pid 0 "instances"** — one thread per instance trace: the instance
    envelope as a complete ("X") event with its admission-queue /
    recovery-wait child intervals stacked inside, and plan / failover /
    replan / salvage / shed instants ("i").
  * **pid 1 "devices"** — one thread per device: every replica exec
    window as an "X" event (model-upload / parent-transfer sub-windows
    nested at its head), so device occupancy, churn kills and the
    paper's interference crowding are directly visible.  Fleet
    device_down / device_up events land on their device's row.

Flow events ("s"/"t", one id per instance) stitch each instance row to
the device rows its replicas ran on.

Timestamps: sim-clock seconds scaled to microseconds (the format's unit).
The export is lossless for accounting purposes —
:func:`ledger_from_trace` recomputes the engine's conservation identity
``admitted == completed + lost + shed`` from the JSON alone, which the
test suite pins against the live engine counters.
"""
from __future__ import annotations

import json
import math
from typing import Any, Dict, List, Optional, Union

from .metrics import MetricsRegistry
from .tracing import FLEET_TID, Tracer

__all__ = [
    "to_chrome_trace",
    "ledger_from_trace",
    "validate_chrome_trace",
    "json_summary",
]

_US = 1e6                      # sim seconds -> trace microseconds

# span kinds rendered as instants on the instance row
_INSTANT_KINDS = ("plan", "failover", "replan", "salvage", "shed")
# span kinds rendered as intervals on the instance row
_INSTANCE_INTERVALS = ("admission_queue", "recovery_wait")
# span kinds rendered as intervals on the device row
_DEVICE_INTERVALS = ("exec", "model_upload", "parent_transfer")


def _clean(attrs: Dict[str, Any]) -> Dict[str, Any]:
    """JSON-safe args: NaN/inf become strings (strict JSON has neither)."""
    out: Dict[str, Any] = {}
    for k, v in attrs.items():
        if isinstance(v, float) and not math.isfinite(v):
            out[k] = repr(v)
        else:
            out[k] = v
    return out


def to_chrome_trace(
    tracer: Tracer, path: Optional[str] = None
) -> Dict[str, Any]:
    """Render the trace; optionally write it to ``path`` as JSON."""
    ev: List[Dict[str, Any]] = [
        {"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
         "args": {"name": "instances"}},
        {"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
         "args": {"name": "devices"}},
    ]
    named_devices = set()

    def device_thread(did: int) -> None:
        if did not in named_devices:
            named_devices.add(did)
            ev.append({
                "name": "thread_name", "ph": "M", "pid": 1, "tid": did,
                "args": {"name": f"dev{did}"},
            })

    for span in tracer.spans:
        if not span.closed:
            raise ValueError(
                f"open {span.kind!r} span at t0={span.t0}: drain the engine "
                "before exporting"
            )
        args = _clean(span.attrs)
        if span.kind == "instance":
            ev.append({
                "name": span.name or f"instance{span.tid}",
                "cat": "instance", "ph": "X", "pid": 0, "tid": span.tid,
                "ts": span.t0 * _US, "dur": span.dur * _US, "args": args,
            })
            ev.append({
                "name": "thread_name", "ph": "M", "pid": 0, "tid": span.tid,
                "args": {"name": f"#{span.tid} {span.name}"},
            })
            ev.append({
                "name": "lifetime", "cat": "flow", "ph": "s",
                "pid": 0, "tid": span.tid, "ts": span.t0 * _US,
                "id": span.tid,
            })
        elif span.kind in _INSTANCE_INTERVALS:
            ev.append({
                "name": span.kind, "cat": span.kind, "ph": "X",
                "pid": 0, "tid": span.tid,
                "ts": span.t0 * _US, "dur": span.dur * _US, "args": args,
            })
        elif span.kind in _INSTANT_KINDS:
            ev.append({
                "name": span.name or span.kind, "cat": span.kind,
                "ph": "i", "s": "t", "pid": 0, "tid": max(span.tid, 0),
                "ts": span.t0 * _US, "args": args,
            })
        elif span.kind in _DEVICE_INTERVALS:
            did = int(span.attrs.get("device", 0))
            device_thread(did)
            ev.append({
                "name": f"{span.name}:{span.kind}" if span.kind != "exec"
                        else (span.name or "exec"),
                "cat": span.kind, "ph": "X", "pid": 1, "tid": did,
                "ts": span.t0 * _US, "dur": span.dur * _US, "args": args,
            })
            if span.kind == "exec" and span.tid != FLEET_TID:
                ev.append({
                    "name": "lifetime", "cat": "flow", "ph": "t",
                    "pid": 1, "tid": did, "ts": span.t0 * _US,
                    "id": span.tid,
                })
        else:                         # device_down / device_up fleet events
            did = int(span.attrs.get("device", 0))
            device_thread(did)
            ev.append({
                "name": span.kind, "cat": "churn", "ph": "i", "s": "t",
                "pid": 1, "tid": did, "ts": span.t0 * _US, "args": args,
            })
    doc = {"traceEvents": ev, "displayTimeUnit": "ms"}
    if path is not None:
        with open(path, "w") as f:
            json.dump(doc, f)
    return doc


def _events(doc: Union[Dict[str, Any], List[Dict[str, Any]]]
            ) -> List[Dict[str, Any]]:
    return doc["traceEvents"] if isinstance(doc, dict) else doc


def ledger_from_trace(
    doc: Union[Dict[str, Any], List[Dict[str, Any]]]
) -> Dict[str, int]:
    """Recompute the conservation ledger from an exported trace alone:
    every ``cat == "instance"`` complete event is one admitted instance,
    its ``args.outcome`` names its terminal bucket.  The result must
    satisfy ``admitted == completed + lost + shed`` for any trace of a
    drained engine — the round-trip check the test suite pins."""
    out = {"admitted": 0, "completed": 0, "lost": 0, "shed": 0}
    for e in _events(doc):
        if e.get("cat") == "instance" and e.get("ph") == "X":
            out["admitted"] += 1
            outcome = e.get("args", {}).get("outcome")
            if outcome not in ("completed", "lost", "shed"):
                raise ValueError(
                    f"instance event {e.get('name')!r} has no terminal "
                    f"outcome (got {outcome!r})"
                )
            out[outcome] += 1
    return out


def validate_chrome_trace(
    doc: Union[Dict[str, Any], List[Dict[str, Any]]]
) -> int:
    """Structural validation of a trace_event document; returns the event
    count.  Raises ValueError on anything chrome://tracing would choke
    on: missing keys, non-finite or negative timestamps/durations, or a
    document that does not survive strict JSON round-tripping."""
    events = _events(json.loads(json.dumps(doc, allow_nan=False)))
    if not events:
        raise ValueError("empty trace")
    for e in events:
        for key in ("name", "ph", "pid", "tid"):
            if key not in e:
                raise ValueError(f"event missing {key!r}: {e}")
        if e["ph"] == "M":
            continue
        ts = e.get("ts")
        if ts is None or not math.isfinite(ts) or ts < 0:
            raise ValueError(f"bad ts in event: {e}")
        if e["ph"] == "X":
            dur = e.get("dur")
            if dur is None or not math.isfinite(dur) or dur < 0:
                raise ValueError(f"bad dur in complete event: {e}")
    return len(events)


def json_summary(
    tracer: Tracer,
    registry: Optional[MetricsRegistry] = None,
    path: Optional[str] = None,
) -> Dict[str, Any]:
    """Compact JSON export: the trace-side ledger, span counts by kind,
    and (optionally) a full metrics-registry snapshot."""
    by_kind: Dict[str, int] = {}
    for span in tracer.spans:
        by_kind[span.kind] = by_kind.get(span.kind, 0) + 1
    out: Dict[str, Any] = {
        "ledger": tracer.outcome_counts(),
        "n_instances": tracer.n_instances,
        "n_spans": len(tracer.spans),
        "spans_by_kind": dict(sorted(by_kind.items())),
    }
    if registry is not None:
        out["metrics"] = registry.snapshot()
    if path is not None:
        with open(path, "w") as f:
            json.dump(out, f, indent=2)
    return out
