"""The unified metrics layer: counters / gauges / exact-quantile
histograms, the get-or-create registry, and the engine's typed counter
view.

This is the one home for metrics primitives — :mod:`repro.stream.metrics`
re-exports from here for backward compatibility.  Two kinds of consumer:

  * the **streaming service** registers free-form named series in a
    :class:`MetricsRegistry` (admitted/shed per SLO class, e2e latency
    histograms, queue-depth samples) and exports them as JSON;
  * the **engine** keeps its instance/churn ledger in :class:`EngineStats`
    — a *typed* counter bundle over the frozen :data:`ENGINE_COUNTERS`
    name set.  A misspelled counter name raises ``AttributeError`` at the
    point of use instead of silently minting a new key, and the
    conservation identity ``admitted == completed + lost + shed`` is
    checked in exactly one place (:meth:`EngineStats.check_conservation`).

Histograms store raw observations (the service sees at most a few hundred
thousand instances per run) so quantiles are exact rather than
sketch-approximate; ``summary()`` reduces them to the export shape.
"""
from __future__ import annotations

import json
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "ENGINE_COUNTERS",
    "EngineStats",
]


class Counter:
    """Monotone event count."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Exact-quantile histogram over raw observations."""

    __slots__ = ("name", "values")

    def __init__(self, name: str):
        self.name = name
        self.values: List[float] = []

    def observe(self, v: float) -> None:
        self.values.append(float(v))

    @property
    def count(self) -> int:
        return len(self.values)

    def quantile(self, q: float) -> float:
        if not self.values:
            return float("nan")
        return float(np.quantile(np.asarray(self.values), q))

    def summary(self) -> Dict[str, float]:
        if not self.values:
            return {"count": 0}
        arr = np.asarray(self.values)
        return {
            "count": int(arr.size),
            "mean": float(arr.mean()),
            "p50": float(np.quantile(arr, 0.50)),
            "p99": float(np.quantile(arr, 0.99)),
            "p999": float(np.quantile(arr, 0.999)),
            "max": float(arr.max()),
        }


class MetricsRegistry:
    """Get-or-create registry + interval sampler.

    ``sample(t)`` appends one row — every counter and gauge value at
    instant ``t`` — to :attr:`samples`; the service calls it on its
    configured interval so the export carries the time series, not just
    the final totals."""

    def __init__(self):
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}
        self.samples: List[Dict[str, float]] = []

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(name)
        return h

    def sample(self, t: float) -> Dict[str, float]:
        row: Dict[str, float] = {"t": float(t)}
        for name, c in self.counters.items():
            row[name] = c.value
        for name, g in self.gauges.items():
            row[name] = g.value
        self.samples.append(row)
        return row

    def snapshot(self) -> dict:
        """The full export shape (JSON-serialisable)."""
        return {
            "counters": {k: c.value for k, c in sorted(self.counters.items())},
            "gauges": {k: g.value for k, g in sorted(self.gauges.items())},
            "histograms": {
                k: h.summary() for k, h in sorted(self.histograms.items())
            },
            "samples": self.samples,
        }

    def to_json(self, path: Optional[str] = None, indent: int = 2) -> str:
        text = json.dumps(self.snapshot(), indent=indent)
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text


# The engine's complete counter vocabulary, frozen.  Instance ledger:
# admitted == completed + lost + shed (shed is charged by the stream
# admission layer).  The rest are churn-runtime counters.
ENGINE_COUNTERS: Tuple[str, ...] = (
    "admitted",
    "completed",
    "shed",
    "device_down",
    "device_up",
    "replica_deaths",
    "task_failovers",
    "replans",
    "recovered",
    "lost",
    "salvages",
    "salvaged",
)


class EngineStats:
    """Typed view over the engine's counters.

    ``__slots__`` over :data:`ENGINE_COUNTERS` makes every counter a plain
    ``int`` attribute — ``stats.completed += 1`` — and turns a misspelled
    name into an immediate ``AttributeError`` on both read and write
    (where a plain dict would silently mint a new key and drift the
    conservation ledger).  Mapping-style access (``stats["lost"]``,
    ``dict(stats)``, iteration) is kept for existing consumers, with the
    same typo behaviour.
    """

    __slots__ = ENGINE_COUNTERS

    def __init__(self, **initial: int):
        for key in ENGINE_COUNTERS:
            setattr(self, key, 0)
        for key, v in initial.items():
            setattr(self, key, int(v))      # unknown key -> AttributeError

    # -- mapping compatibility --------------------------------------------------
    def __getitem__(self, key: str) -> int:
        return getattr(self, key)

    def __setitem__(self, key: str, value: int) -> None:
        setattr(self, key, value)

    def __contains__(self, key: object) -> bool:
        return key in ENGINE_COUNTERS

    def __iter__(self) -> Iterator[str]:
        return iter(ENGINE_COUNTERS)

    def __len__(self) -> int:
        return len(ENGINE_COUNTERS)

    def keys(self) -> Tuple[str, ...]:
        return ENGINE_COUNTERS

    def items(self) -> Iterator[Tuple[str, int]]:
        return ((k, getattr(self, k)) for k in ENGINE_COUNTERS)

    def values(self) -> Iterator[int]:
        return (getattr(self, k) for k in ENGINE_COUNTERS)

    def as_dict(self) -> Dict[str, int]:
        return {k: getattr(self, k) for k in ENGINE_COUNTERS}

    def __eq__(self, other: object) -> bool:
        if isinstance(other, EngineStats):
            return self.as_dict() == other.as_dict()
        if isinstance(other, dict):
            return self.as_dict() == other
        return NotImplemented

    def __repr__(self) -> str:
        body = ", ".join(f"{k}={getattr(self, k)}" for k in ENGINE_COUNTERS)
        return f"EngineStats({body})"

    # -- the conservation identity, checked in one place ------------------------
    def check_conservation(self) -> None:
        """``admitted == completed + lost + shed``; RuntimeError on drift —
        the regression guard for the counter bookkeeping (asserted by
        ``Engine.drain`` and recomputable from traces alone via
        :func:`repro.obs.export.ledger_from_trace`)."""
        settled = self.completed + self.lost + self.shed
        if self.admitted != settled:
            raise RuntimeError(
                f"instance-counter drift: admitted {self.admitted} != "
                f"completed {self.completed} + lost {self.lost} + shed "
                f"{self.shed}"
            )

    def to_registry(self, registry: MetricsRegistry,
                    prefix: str = "engine_") -> None:
        """Publish the current counter values into a unified registry (the
        stream service calls this before exporting, so one snapshot
        carries service metrics AND the engine ledger)."""
        for key in ENGINE_COUNTERS:
            registry.counter(prefix + key).value = getattr(self, key)
