"""Structured spans & traces for the orchestration pipeline.

Every application instance the engine takes accounting responsibility for
gets one *trace*: an ``instance`` span from admission to its terminal
outcome (``completed`` / ``lost`` / ``shed``), plus structured child spans
for each pipeline stage it passes through — the admission queue, the
planning decision, per-replica model upload / parent transfer / execution,
recovery waits, failover / replan / salvage actions.  Fleet-level events
(device churn) hang off the reserved :data:`FLEET_TID` trace.

Design constraints (the lint rules stay green):

  * **sim-clock only** — every timestamp is an engine ``now`` value; the
    tracer never reads a wall clock, so traces are deterministic and
    replayable (same seed, same trace, byte for byte).
  * **zero overhead when disabled** — emitters hold ``trace=None`` by
    default and guard every call site with ``if self.trace is not None``;
    the tracer itself is only ever constructed by opting in
    (``Orchestrator(trace=...)`` / ``SimConfig(trace=True)``).
  * **predicted next to realized** — ``exec`` spans carry the planner's
    Eq. (2) terms (``pred_exec`` / ``pred_upload`` / ``pred_transfer``)
    and per-replica ``pred_fail`` from the very
    :class:`~repro.core.orchestrator.Replica` the policy produced, so
    :mod:`repro.obs.attribution` can score calibration without joining
    back to planner state.
  * **literal span kinds** — call sites pass the ``kind`` as a string
    literal drawn from :data:`SPAN_SCHEMA`; the ``span-parity`` lint rule
    statically cross-checks every emitted kind against the schema and the
    test suite, and :meth:`Tracer._span` rejects unknown kinds at runtime.

See ``src/repro/obs/README.md`` for the full span schema with a worked
trace example.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Tuple

__all__ = ["Span", "Tracer", "SPAN_SCHEMA", "FLEET_TID"]

# Fleet-scoped events (device churn) do not belong to any one instance;
# they are recorded against this reserved trace id.
FLEET_TID = -1

# kind -> one-line contract.  The span-parity lint rule requires every kind
# emitted in src/repro to appear here AND to be named in the test suite;
# the tracer enforces membership at runtime.  Extend this table (and
# obs/README.md) when adding a kind.
SPAN_SCHEMA: Dict[str, str] = {
    "instance": "whole-instance envelope: admission to terminal outcome "
                "(attrs: outcome=completed|lost|shed)",
    "admission_queue": "true arrival to dispatch wave (stream layer; "
                       "attrs: slo, degraded, deadline)",
    "plan": "placement decision instant (attrs: policy, pred_latency, "
            "pred_fail, feasible)",
    "model_upload": "predicted model-artifact upload window at the head "
                    "of a replica's execution (attrs: device, task)",
    "parent_transfer": "predicted parent-output transfer window after "
                       "upload (attrs: device, task)",
    "exec": "one replica occupying one device, open at launch / closed at "
            "end or kill (attrs: device, tier, task, ttype, stage, "
            "sched_end, pred_* terms, real_exec, outcome)",
    "recovery_wait": "death detected -> recovery fires (detection delay)",
    "failover": "hot-spare restart attempt instant (attrs: task, ok)",
    "replan": "policy replan attempt instant (attrs: task, ok)",
    "salvage": "partial-result resubmission instant (attrs: ok, pinned)",
    "shed": "admission-control drop instant (attrs: reason)",
    "device_down": "fleet event: device departs (attrs: device)",
    "device_up": "fleet event: device rejoins (attrs: device, until)",
}

_OPEN = float("nan")


@dataclass
class Span:
    """One timestamped interval (or instant, ``t0 == t1``) in a trace."""

    kind: str
    tid: int                    # owning trace (instance) id; FLEET_TID = fleet
    t0: float
    t1: float                   # NaN while the span is still open
    name: str = ""
    attrs: Dict[str, Any] = field(default_factory=dict)

    @property
    def closed(self) -> bool:
        return self.t1 == self.t1          # not NaN

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


class Tracer:
    """Append-only span collector with sequential instance ids.

    Emission API (what :class:`~repro.sim.engine.Engine`, the stream
    service and the recovery strategies call):

      * ``tid = begin_instance(name, t, **attrs)`` — open a trace
      * ``end_instance(tid, t, outcome, **attrs)`` — close it
      * ``add_span(tid, kind, t0, t1, **attrs)`` — completed interval
      * ``sid = open_span(tid, kind, t0, **attrs)`` / ``close_span(sid,
        t1, **attrs)`` — interval whose end is not yet known
      * ``event(tid, kind, t, **attrs)`` — instant

    Query API (what attribution / export read): :meth:`instances`,
    :meth:`instance`, :meth:`spans_of`, :meth:`outcome_counts`.
    """

    __slots__ = ("spans", "_next_tid", "_inst_sid")

    def __init__(self) -> None:
        self.spans: List[Span] = []
        self._next_tid = 0
        self._inst_sid: Dict[int, int] = {}     # tid -> instance span index

    # -- emission ---------------------------------------------------------------
    def _span(self, kind: str, tid: int, t0: float, t1: float,
              name: str, attrs: Dict[str, Any]) -> int:
        if kind not in SPAN_SCHEMA:
            raise ValueError(
                f"unknown span kind {kind!r}; add it to SPAN_SCHEMA "
                f"(and obs/README.md) first"
            )
        self.spans.append(Span(kind, tid, float(t0), float(t1), name, attrs))
        return len(self.spans) - 1

    def begin_instance(self, name: str, t: float, **attrs) -> int:
        tid = self._next_tid
        self._next_tid += 1
        self._inst_sid[tid] = self._span("instance", tid, t, _OPEN, name, attrs)
        return tid

    def end_instance(self, tid: int, t: float, outcome: str, **attrs) -> None:
        span = self.spans[self._inst_sid[tid]]
        if span.closed:
            raise RuntimeError(f"instance trace {tid} ended twice")
        span.t1 = float(t)
        span.attrs["outcome"] = outcome
        span.attrs.update(attrs)

    def add_span(self, tid: int, kind: str, t0: float, t1: float,
                 name: str = "", **attrs) -> int:
        return self._span(kind, tid, t0, t1, name, attrs)

    def open_span(self, tid: int, kind: str, t0: float,
                  name: str = "", **attrs) -> int:
        return self._span(kind, tid, t0, _OPEN, name, attrs)

    def close_span(self, sid: int, t1: float, **attrs) -> None:
        span = self.spans[sid]
        if span.closed:
            raise RuntimeError(f"span {sid} ({span.kind}) closed twice")
        span.t1 = float(t1)
        span.attrs.update(attrs)

    def event(self, tid: int, kind: str, t: float,
              name: str = "", **attrs) -> int:
        return self._span(kind, tid, t, t, name, attrs)

    # -- queries ----------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.spans)

    @property
    def n_instances(self) -> int:
        return self._next_tid

    def instance(self, tid: int) -> Span:
        """The ``instance`` envelope span of trace ``tid``."""
        return self.spans[self._inst_sid[tid]]

    def instances(self) -> Iterator[Span]:
        """Every instance envelope, in admission order."""
        for tid in range(self._next_tid):
            yield self.spans[self._inst_sid[tid]]

    def spans_of(self, tid: int) -> List[Span]:
        """All non-envelope spans of one trace, in emission order."""
        return [s for s in self.spans
                if s.tid == tid and s.kind != "instance"]

    def by_kind(self, kind: str) -> List[Span]:
        return [s for s in self.spans if s.kind == kind]

    def outcome_counts(self) -> Dict[str, int]:
        """Terminal outcomes over all instance envelopes — the trace-side
        half of the conservation ledger (open envelopes count as
        ``open``; a drained engine leaves none)."""
        out: Dict[str, int] = {}
        for span in self.instances():
            key = span.attrs.get("outcome", "open") if span.closed else "open"
            out[key] = out.get(key, 0) + 1
        return out

    def check_closed(self) -> None:
        """Raise if any span is still open (drain-time invariant)."""
        dangling: List[Tuple[int, str]] = [
            (i, s.kind) for i, s in enumerate(self.spans) if not s.closed
        ]
        if dangling:
            raise RuntimeError(
                f"{len(dangling)} spans still open after drain: "
                f"{dangling[:5]}"
            )
