"""repro.obs — end-to-end observability for the orchestration pipeline.

  * :mod:`repro.obs.tracing` — per-instance traces of structured,
    sim-clock-timestamped spans (:data:`SPAN_SCHEMA`), emitted by the
    engine / stream service / recovery strategies through a
    zero-overhead-when-disabled :class:`Tracer`;
  * :mod:`repro.obs.metrics` — the unified counters / gauges /
    exact-quantile histograms registry (:mod:`repro.stream.metrics`
    re-exports from here) and :class:`EngineStats`, the engine's typed
    counter ledger with the conservation identity checked in one place;
  * :mod:`repro.obs.attribution` — predicted-vs-actual cost attribution:
    critical-path breakdowns, Eq. (2) / P_f calibration per policy /
    tier / device, slow- and lost-instance reports;
  * :mod:`repro.obs.export` — Chrome/Perfetto ``trace_event`` JSON
    (device rows + instance flows) and summary exports, with the
    instance ledger recomputable from the exported trace alone.

Enable via ``Orchestrator(cluster, policy, trace=Tracer())`` or
``SimConfig(trace=True)``; see ``src/repro/obs/README.md`` for the span
schema and a worked example.
"""
from .attribution import (
    attribution_report,
    calibration,
    format_report,
    instance_breakdown,
    lost_instances,
    slow_instances,
)
from .export import (
    json_summary,
    ledger_from_trace,
    to_chrome_trace,
    validate_chrome_trace,
)
from .metrics import (
    ENGINE_COUNTERS,
    Counter,
    EngineStats,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from .tracing import FLEET_TID, SPAN_SCHEMA, Span, Tracer

__all__ = [
    "Span",
    "Tracer",
    "SPAN_SCHEMA",
    "FLEET_TID",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "ENGINE_COUNTERS",
    "EngineStats",
    "instance_breakdown",
    "calibration",
    "slow_instances",
    "lost_instances",
    "attribution_report",
    "format_report",
    "to_chrome_trace",
    "ledger_from_trace",
    "validate_chrome_trace",
    "json_summary",
]
