"""Predicted-vs-actual cost attribution over traces.

Answers the three questions the aggregate counters cannot:

  * **where did the latency go?** — per-instance critical-path breakdown:
    admission-queue wait, executing time (the union of replica exec
    windows), model-upload / parent-transfer totals, recovery waits, and
    the unattributed stall remainder; per stage, the critical (latest
    finishing) replica decomposed into its Eq. (2) terms.
  * **how wrong was the planner?** — calibration of the Eq. (2) estimates
    the placement was chosen by: per policy, predicted vs realized E2E
    latency and predicted P_f vs the empirical failure rate; per device
    and per tier, predicted vs realized replica duration and predicted
    per-replica failure probability vs the observed death rate.
  * **why was this instance slow / lost?** — ranked reports over the
    worst offenders with their breakdowns and recovery/salvage history.

Everything reads only :class:`~repro.obs.tracing.Tracer` spans — the
attrs each emitter attached are the whole data model, so these reports
work on exported traces as well as live runs.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .tracing import Span, Tracer

__all__ = [
    "instance_breakdown",
    "calibration",
    "slow_instances",
    "lost_instances",
    "attribution_report",
    "format_report",
]


def _union_len(intervals: Sequence[Tuple[float, float]]) -> float:
    """Total length of the union of (t0, t1) intervals."""
    if not intervals:
        return 0.0
    total = 0.0
    lo = hi = None
    for t0, t1 in sorted(intervals):
        if hi is None or t0 > hi:
            if hi is not None:
                total += hi - lo
            lo, hi = t0, t1
        else:
            hi = max(hi, t1)
    total += hi - lo
    return total


def instance_breakdown(tracer: Tracer, tid: int) -> Dict[str, Any]:
    """Critical-path breakdown of one instance trace.

    ``e2e`` runs from the TRUE arrival (the admission-queue span's start,
    when the stream layer is in play; the engine arrival otherwise) to the
    terminal outcome.  ``exec_busy`` is the union of replica exec windows
    (overlapping replicas are not double counted); ``stall`` is whatever
    the queue, exec and recovery unions leave unexplained — stage-barrier
    gaps and detection lags outside recorded waits land there.
    """
    inst = tracer.instance(tid)
    spans = tracer.spans_of(tid)
    queue = [s for s in spans if s.kind == "admission_queue"]
    execs = [s for s in spans if s.kind == "exec" and s.closed]
    waits = [s for s in spans if s.kind == "recovery_wait"]
    arrival = min([s.t0 for s in queue] + [inst.t0])
    end = inst.t1 if inst.closed else float("nan")
    e2e = end - arrival
    queue_wait = sum(s.dur for s in queue)
    exec_busy = _union_len([(s.t0, s.t1) for s in execs])
    busy_or_waiting = _union_len(
        [(s.t0, s.t1) for s in execs] + [(s.t0, s.t1) for s in waits]
    )
    recovery_wait = busy_or_waiting - exec_busy
    stall = e2e - queue_wait - busy_or_waiting
    if stall == stall:                       # leave NaN (open trace) alone
        stall = max(stall, 0.0)

    stages: Dict[int, Dict[str, Any]] = {}
    for s in execs:
        stages.setdefault(int(s.attrs.get("stage", -1)), []).append(s)  # type: ignore[arg-type]
    stage_rows: Dict[int, Dict[str, Any]] = {}
    for idx in sorted(stages):
        group: List[Span] = stages[idx]      # type: ignore[assignment]
        crit = max(group, key=lambda s: s.t1)
        up = min(float(crit.attrs.get("pred_upload", 0.0)), crit.dur)
        tr = min(float(crit.attrs.get("pred_transfer", 0.0)), crit.dur - up)
        stage_rows[idx] = {
            "wall": max(s.t1 for s in group) - min(s.t0 for s in group),
            "n_replicas": len(group),
            "critical_task": crit.name,
            "critical_device": crit.attrs.get("device"),
            "critical": {"upload": up, "transfer": tr,
                         "exec": max(crit.dur - up - tr, 0.0)},
        }

    actions = {k: sum(1 for s in spans if s.kind == k)
               for k in ("failover", "replan", "salvage", "shed")}
    return {
        "tid": tid,
        "name": inst.name,
        "outcome": inst.attrs.get("outcome", "open"),
        "arrival": arrival,
        "e2e": e2e,
        "queue_wait": queue_wait,
        "exec_busy": exec_busy,
        "upload_total": sum(s.dur for s in spans if s.kind == "model_upload"),
        "transfer_total": sum(
            s.dur for s in spans if s.kind == "parent_transfer"
        ),
        "recovery_wait": recovery_wait,
        "stall": stall,
        "stages": stage_rows,
        "actions": {k: v for k, v in actions.items() if v},
    }


def _err_row(pred: List[float], real: List[float]) -> Dict[str, float]:
    p, r = np.asarray(pred, dtype=float), np.asarray(real, dtype=float)
    return {
        "n": int(p.size),
        "pred_mean": float(p.mean()) if p.size else float("nan"),
        "real_mean": float(r.mean()) if r.size else float("nan"),
        "bias": float((r - p).mean()) if p.size else float("nan"),
        "mae": float(np.abs(r - p).mean()) if p.size else float("nan"),
    }


def calibration(tracer: Tracer) -> Dict[str, Dict[str, Dict[str, Any]]]:
    """Calibration error of the planner's Eq. (2) estimates.

    ``policy`` rows compare the plan-time instance-level prediction
    (``pred_latency`` / ``pred_fail`` of the chosen placement) against the
    realized engine-side service time and the empirical loss rate.
    ``device`` / ``tier`` rows compare per-replica predicted duration
    (exec + upload + transfer) against the realized occupancy window of
    replicas that ran to their scheduled end, and the per-replica
    predicted failure probability against the observed death rate (dead
    at scheduled end, or killed mid-flight by churn).
    """
    by_policy: Dict[str, Dict[str, List[float]]] = {}
    for s in tracer.by_kind("plan"):
        inst = tracer.instance(s.tid)
        if not inst.closed:
            continue
        row = by_policy.setdefault(
            str(s.attrs.get("policy", "?")),
            {"pl": [], "rl": [], "pf": [], "lost": []},
        )
        outcome = inst.attrs.get("outcome")
        row["pf"].append(float(s.attrs.get("pred_fail", float("nan"))))
        row["lost"].append(1.0 if outcome == "lost" else 0.0)
        if outcome == "completed":
            row["pl"].append(float(s.attrs.get("pred_latency", float("nan"))))
            row["rl"].append(inst.t1 - inst.t0)

    policy_rows: Dict[str, Dict[str, Any]] = {}
    for name, row in sorted(by_policy.items()):
        out = {"latency": _err_row(row["pl"], row["rl"])}
        pf = np.asarray(row["pf"], dtype=float)
        out["p_fail"] = {
            "n": int(pf.size),
            "pred_mean": float(pf.mean()) if pf.size else float("nan"),
            "empirical": float(np.mean(row["lost"])) if row["lost"]
                         else float("nan"),
        }
        policy_rows[name] = out

    def group_execs(key: str) -> Dict[str, Dict[str, Any]]:
        groups: Dict[Any, Dict[str, List[float]]] = {}
        for s in tracer.by_kind("exec"):
            if not s.closed:
                continue
            g = groups.setdefault(
                s.attrs.get(key, "?"),
                {"pred": [], "real": [], "pf": [], "dead": []},
            )
            g["pf"].append(float(s.attrs.get("pred_fail", float("nan"))))
            g["dead"].append(
                1.0 if s.attrs.get("outcome") in ("dead", "killed") else 0.0
            )
            if s.attrs.get("outcome") in ("ok", "dead"):
                # ran to its scheduled end: the realized window is the
                # honest counterpart of the predicted Eq. (2) duration
                g["pred"].append(
                    float(s.attrs.get("pred_exec", 0.0))
                    + float(s.attrs.get("pred_upload", 0.0))
                    + float(s.attrs.get("pred_transfer", 0.0))
                )
                g["real"].append(s.dur)
        rows: Dict[str, Dict[str, Any]] = {}
        for gkey in sorted(groups, key=str):
            g = groups[gkey]
            row = {"duration": _err_row(g["pred"], g["real"])}
            pf = np.asarray(g["pf"], dtype=float)
            row["p_fail"] = {
                "n": int(pf.size),
                "pred_mean": float(pf.mean()) if pf.size else float("nan"),
                "empirical": float(np.mean(g["dead"])) if g["dead"]
                             else float("nan"),
            }
            rows[str(gkey)] = row
        return rows

    return {
        "policy": policy_rows,
        "device": group_execs("device"),
        "tier": group_execs("tier"),
    }


def slow_instances(tracer: Tracer, k: int = 5) -> List[Dict[str, Any]]:
    """The k slowest COMPLETED instances, each with its breakdown — the
    'why was this instance slow' report."""
    done = [
        s for s in tracer.instances()
        if s.closed and s.attrs.get("outcome") == "completed"
    ]
    done.sort(key=lambda s: s.t1 - s.t0, reverse=True)
    return [instance_breakdown(tracer, s.tid) for s in done[:k]]


def lost_instances(tracer: Tracer, k: Optional[int] = None
                   ) -> List[Dict[str, Any]]:
    """Every lost instance (latest first, optionally capped at ``k``) with
    its breakdown and failure context — the 'why was this instance lost'
    report.  Shed instances are excluded: they never ran."""
    lost = [
        s for s in tracer.instances()
        if s.closed and s.attrs.get("outcome") == "lost"
    ]
    lost.sort(key=lambda s: s.t1, reverse=True)
    out = []
    for s in lost[:k]:
        row = instance_breakdown(tracer, s.tid)
        row["reason"] = s.attrs.get("reason", "task_dead")
        deaths = [
            x for x in tracer.spans_of(s.tid)
            if x.kind == "exec" and x.closed
            and x.attrs.get("outcome") in ("dead", "killed")
        ]
        row["replica_deaths"] = len(deaths)
        row["death_devices"] = sorted(
            {int(x.attrs.get("device", -1)) for x in deaths}
        )
        out.append(row)
    return out


def attribution_report(tracer: Tracer, top_k: int = 5) -> Dict[str, Any]:
    """The full report: trace-side ledger, aggregate critical-path
    breakdown over completed instances, planner calibration, and the
    slow/lost offender lists."""
    completed = [
        instance_breakdown(tracer, s.tid)
        for s in tracer.instances()
        if s.closed and s.attrs.get("outcome") == "completed"
    ]
    fields = ("e2e", "queue_wait", "exec_busy", "upload_total",
              "transfer_total", "recovery_wait", "stall")
    agg = {"n": len(completed)}
    for f in fields:
        vals = np.asarray([b[f] for b in completed], dtype=float)
        agg[f"{f}_mean"] = float(vals.mean()) if vals.size else float("nan")
        agg[f"{f}_p99"] = (
            float(np.quantile(vals, 0.99)) if vals.size else float("nan")
        )
    return {
        "ledger": tracer.outcome_counts(),
        "critical_path": agg,
        "calibration": calibration(tracer),
        "slow": slow_instances(tracer, top_k),
        "lost": lost_instances(tracer, top_k),
    }


def format_report(report: Dict[str, Any]) -> str:
    """Human-readable rendering of :func:`attribution_report`."""
    lines: List[str] = []
    led = report["ledger"]
    lines.append("== instance ledger (from spans alone) ==")
    lines.append("  " + "  ".join(f"{k}={v}" for k, v in sorted(led.items())))
    cp = report["critical_path"]
    lines.append(f"== critical path (mean over {cp['n']} completed) ==")
    for f in ("e2e", "queue_wait", "exec_busy", "upload_total",
              "transfer_total", "recovery_wait", "stall"):
        lines.append(
            f"  {f:<15} mean {cp[f + '_mean']:8.3f}s"
            f"   p99 {cp[f + '_p99']:8.3f}s"
        )
    lines.append("== calibration: policy ==")
    for name, row in report["calibration"]["policy"].items():
        lat, pf = row["latency"], row["p_fail"]
        lines.append(
            f"  {name:<16} latency pred {lat['pred_mean']:.3f}s"
            f" real {lat['real_mean']:.3f}s bias {lat['bias']:+.3f}s"
            f" (n={lat['n']})  P_f pred {pf['pred_mean']:.3f}"
            f" emp {pf['empirical']:.3f}"
        )
    lines.append("== calibration: tier ==")
    for name, row in report["calibration"]["tier"].items():
        d, pf = row["duration"], row["p_fail"]
        lines.append(
            f"  tier {name:<4} dur pred {d['pred_mean']:.3f}s"
            f" real {d['real_mean']:.3f}s bias {d['bias']:+.3f}s"
            f" (n={d['n']})  P_f pred {pf['pred_mean']:.3f}"
            f" death-rate {pf['empirical']:.3f}"
        )
    lines.append(f"== slowest completed ({len(report['slow'])}) ==")
    for b in report["slow"]:
        lines.append(
            f"  [{b['tid']}] {b['name']:<14} e2e {b['e2e']:7.3f}s = "
            f"queue {b['queue_wait']:.3f} + exec {b['exec_busy']:.3f} + "
            f"recovery {b['recovery_wait']:.3f} + stall {b['stall']:.3f}"
            + (f"  actions {b['actions']}" if b["actions"] else "")
        )
    lines.append(f"== lost ({len(report['lost'])} shown) ==")
    for b in report["lost"]:
        lines.append(
            f"  [{b['tid']}] {b['name']:<14} reason {b['reason']}"
            f" after {b['e2e']:.3f}s, {b['replica_deaths']} replica deaths"
            f" on devices {b['death_devices']}"
            + (f"  actions {b['actions']}" if b["actions"] else "")
        )
    return "\n".join(lines)
