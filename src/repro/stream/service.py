"""The always-on orchestration service loop.

:class:`StreamingOrchestrator` turns the closed-loop
:class:`repro.api.Orchestrator` into an open-loop service: arrivals from
:mod:`repro.stream.arrivals` flow through the bounded
:class:`~repro.stream.admission.AdmissionController`, admitted waves are
planned through the existing fused ``orchestrate_batch`` path (one batched
``decide_batch`` kernel call per wave-stage), and execution — churn,
recovery, salvage included — runs on the unchanged discrete-event engine.

The loop advances in fixed ``tick`` steps:

  1. step the engine to the tick boundary (task completions, churn events);
  2. offer every arrival with ``t <= now`` to the admission controller
     (deadline shedding, SLO-class backpressure);
  3. pop the next dispatch wave (criticals first, EDF) and plan it fused at
     ``now`` — under queue pressure ``best_effort`` instances go through
     the degraded policy (replication off) to protect critical p99;
  4. sample the metrics registry on its interval.

Admission decisions therefore happen at tick granularity: an arrival waits
at most one tick before its first shed/dispatch decision.

Accounting: shed instances are charged to the engine's conservation ledger
(``admitted == completed + lost + shed``, asserted by ``Engine.drain``),
and the admission queue's own ledger must net to zero after the run — the
T_alloc-style invariant for the queue.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, replace as _dc_replace
from typing import List, Optional, Sequence, Tuple, Union

from ..core.orchestrator import orchestrate_batch
from ..core.policy import IBDASHPolicy, Policy
from ..sim.engine import SimResult
from .admission import (
    AdmissionConfig,
    AdmissionController,
    PlacementLatencyEstimator,
    ShedRecord,
)
from .arrivals import Arrival
from .metrics import MetricsRegistry

__all__ = ["StreamingOrchestrator", "StreamResult"]


@dataclass
class StreamResult:
    """Outcome of one open-loop service run."""

    result: SimResult               # the paper-shaped per-instance records
    metrics: dict                   # MetricsRegistry.snapshot() export
    stats: dict                     # engine counters (admitted/shed/lost/...)
    n_arrivals: int
    shed_log: List[ShedRecord]

    @property
    def shed_rate(self) -> float:
        return self.stats["shed"] / self.n_arrivals if self.n_arrivals else 0.0

    def p(self, q: str = "p99", slo: str = "latency_critical") -> float:
        """E2E latency quantile for one SLO class (q in p50/p99/p999)."""
        h = self.metrics["histograms"].get(f"e2e_{slo}", {})
        return float(h.get(q, float("nan")))


def _auto_degrade(policy: Policy) -> Optional[Policy]:
    """Default degraded policy: the same IBDASH scoring with replication
    off (gamma=0) — best_effort work keeps its latency-optimal primary but
    stops consuming backup capacity.  Non-IBDASH-family policies have no
    replication to shed, so there is nothing to degrade."""
    if isinstance(policy, IBDASHPolicy) and policy.cfg.gamma > 0:
        return IBDASHPolicy(_dc_replace(policy.cfg, gamma=0))
    return None


class StreamingOrchestrator:
    """Open-loop service loop over one :class:`repro.api.Orchestrator`.

    ``admission=None`` runs the no-admission baseline: an unbounded FIFO
    with shedding disabled — every offered instance eventually executes,
    however late.  ``degrade_policy`` may be a Policy, ``"auto"`` (IBDASH
    with gamma=0 when the main policy is IBDASH-family), or None (off).
    """

    def __init__(
        self,
        orchestrator,
        *,
        admission: Optional[AdmissionConfig] = AdmissionConfig(),
        tick: float = 0.25,
        wave_cap: Optional[int] = None,
        metrics_interval: float = 1.0,
        degrade_policy: Union[Policy, str, None] = "auto",
    ):
        self.orch = orchestrator
        self.cfg = admission if admission is not None else AdmissionConfig(
            queue_cap=None, shed=False, degrade_threshold=float("inf")
        )
        self.tick = float(tick)
        self.wave_cap = wave_cap
        self.metrics_interval = float(metrics_interval)
        self.estimator = PlacementLatencyEstimator(
            orchestrator.cluster, orchestrator.policy
        )
        self.controller = AdmissionController(self.cfg, self.estimator)
        self.metrics = MetricsRegistry()
        if degrade_policy == "auto":
            degrade_policy = _auto_degrade(orchestrator.policy)
        self.degrade_policy = degrade_policy
        # (arrival, dispatch_t, degraded) per dispatched instance, aligned
        # with engine.records order (app names are NOT instance-unique, so
        # stream metadata travels by submission order, never by name)
        self._meta: List[Tuple[Arrival, float, bool]] = []
        self._shed_synced = 0
        self._shed_logged = 0
        self._plan_time = 0.0
        self._planned = 0

    # -- internals --------------------------------------------------------------
    def _sync_shed(self) -> None:
        """Mirror controller sheds into the engine ledger + metrics (a shed
        instance counts as admitted-and-shed so the engine's conservation
        identity covers the whole service)."""
        eng = self.orch.engine
        new = self.controller.shed - self._shed_synced
        if new:
            eng.stats.admitted += new
            eng.stats.shed += new
            self._shed_synced = self.controller.shed
        log = self.controller.shed_log
        m, tr = self.metrics, eng.trace
        for rec in log[self._shed_logged:]:
            m.counter("shed").inc()
            m.counter(f"shed_{rec.slo}").inc()
            m.counter(f"shed_reason_{rec.reason}").inc()
            if tr is not None:
                # a shed instance never reaches the engine: its whole
                # trace is one zero-length envelope with the drop instant,
                # so the ledger still round-trips from spans alone
                tid = tr.begin_instance(
                    rec.kind, rec.t, uid=rec.uid, slo=rec.slo
                )
                tr.event(tid, "shed", rec.t, reason=rec.reason)
                tr.end_instance(tid, rec.t, outcome="shed")
        self._shed_logged = len(log)

    def _dispatch(self, wave: List[Arrival], now: float) -> None:
        degrade = (
            self.degrade_policy is not None
            and self.controller.fill >= self.cfg.degrade_threshold
        )
        if degrade:
            groups = [
                (self.orch.policy, [a for a in wave if a.slo.critical]),
                (self.degrade_policy, [a for a in wave if not a.slo.critical]),
            ]
        else:
            groups = [(self.orch.policy, wave)]
        eng, cluster = self.orch.engine, self.orch.cluster
        for pol, arrivals in groups:
            if not arrivals:
                continue
            degraded = pol is not self.orch.policy
            apps = [a.instantiate() for a in arrivals]
            times = [now] * len(apps)
            t0 = time.perf_counter()
            plans = orchestrate_batch(apps, cluster, pol, times=times)
            dt = time.perf_counter() - t0
            self._plan_time += dt
            self._planned += len(apps)
            self.metrics.histogram("wave_plan_s").observe(dt)
            eng.add_arrivals(apps, times, plans=plans)
            self._meta.extend((a, now, degraded) for a in arrivals)
            if degraded:
                self.metrics.counter("degraded").inc(len(arrivals))

    def _finalize(self, rec0: int) -> None:
        """Join the engine's outcome records back to their arrivals (by
        submission order) and fill the E2E histograms."""
        records = self.orch.engine.records[rec0:]
        if len(records) != len(self._meta):
            raise RuntimeError(
                f"record/metadata drift: {len(records)} records vs "
                f"{len(self._meta)} dispatched arrivals"
            )
        m = self.metrics
        tr = self.orch.engine.trace
        for rec, (arrival, disp_t, degraded) in zip(records, self._meta):
            if tr is not None and rec.tid >= 0:
                # the queue wait the engine never saw: true arrival ->
                # dispatch wave (the instance envelope starts at dispatch)
                tr.add_span(
                    rec.tid, "admission_queue", arrival.t, disp_t,
                    slo=arrival.slo.name, degraded=degraded,
                    deadline=arrival.deadline,
                )
            if rec.failed:
                m.counter("failed").inc()
                m.counter(f"failed_{arrival.slo.name}").inc()
                continue
            m.counter("completed").inc()
            e2e = rec.finished - arrival.t
            m.histogram("e2e").observe(e2e)
            m.histogram(f"e2e_{arrival.slo.name}").observe(e2e)
            if rec.finished > arrival.deadline + 1e-9:
                m.counter("deadline_missed").inc()
                m.counter(f"deadline_missed_{arrival.slo.name}").inc()
        if self._plan_time > 0:
            m.gauge("placements_per_sec").set(self._planned / self._plan_time)

    # -- the service loop -------------------------------------------------------
    def run(self, arrivals: Sequence[Arrival]) -> StreamResult:
        """Drive the whole stream to quiescence and export the metrics."""
        arrivals = sorted(arrivals, key=lambda a: a.t)
        orch, m = self.orch, self.metrics
        rec0 = len(orch.engine.records)
        n = len(arrivals)
        idx = 0
        now = orch.now
        next_sample = now
        while True:
            orch.step(until=now)
            while idx < n and arrivals[idx].t <= now:
                a = arrivals[idx]
                idx += 1
                if self.controller.offer(a, now):
                    m.counter("admitted").inc()
                    m.counter(f"admitted_{a.slo.name}").inc()
            wave = self.controller.pop_wave(now, self.wave_cap)
            if wave:
                self._dispatch(wave, now)
            self._sync_shed()
            if now >= next_sample:
                m.gauge("queue_depth").set(len(self.controller))
                m.gauge("queue_fill").set(self.controller.fill)
                m.histogram("queue_depth_samples").observe(
                    len(self.controller)
                )
                m.sample(now)
                next_sample = now + self.metrics_interval
            if idx >= n and not len(self.controller) \
                    and orch.pending_events == 0:
                break
            now += self.tick
        orch.drain()                    # asserts the conservation identity
        self.controller.assert_drained()
        self._finalize(rec0)
        m.gauge("queue_depth").set(0.0)
        # one export surface: the engine's typed ledger is published into
        # the same registry the service metrics live in
        orch.engine.stats.to_registry(m)
        m.sample(orch.now)
        return StreamResult(
            result=orch.result(scenario="stream", horizon=orch.now),
            metrics=m.snapshot(),
            stats=dict(orch.stats),
            n_arrivals=n,
            shed_log=list(self.controller.shed_log),
        )
