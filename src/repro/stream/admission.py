"""Bounded admission with deadline-aware shedding and SLO-class backpressure.

The controller sits between the open-loop arrival stream and the fused
``orchestrate_batch`` dispatch path.  Three mechanisms:

  * **Deadline-aware shedding** — an arrival (or a queued entry at dequeue
    time) is dropped when it *provably* cannot meet its deadline under the
    controller's own latency model: the idle-fleet placement-latency
    estimate from the batched scorers (:class:`PlacementLatencyEstimator`)
    plus, for ``best_effort`` work, a queue-delay estimate from the entries
    ahead of it.  ``latency_critical`` entries are dequeued first, so their
    shed test uses the idle estimate alone — a critical instance is never
    deadline-shed while it could still finish on an idle fleet.
  * **Backpressure** — the queue is bounded (``queue_cap``).  A
    ``latency_critical`` arrival hitting a full queue evicts the
    ``best_effort`` entry with the *latest* deadline; a ``best_effort``
    arrival hitting a full queue is shed outright.  Criticals are only
    capacity-shed once no best-effort entry remains to evict.
  * **Degradation signal** — above ``degrade_threshold`` queue fill the
    service dispatches ``best_effort`` waves through a degraded policy
    (replication off) to protect the p99 of critical traffic; the
    controller just exposes the fill fraction.

Every shed is logged (:class:`ShedRecord`) with the exact predicate inputs
so the property tests can re-verify each decision against an independent
idle-fleet replan.  The controller keeps a conservation ledger —
``offered == dispatched + shed + len(queue)`` — asserted by
:meth:`AdmissionController.assert_drained` (the admission-queue analogue of
the engine's T_alloc netting).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from ..core.cluster import ClusterState, Device
from ..core.orchestrator import orchestrate
from ..core.policy import Policy
from .arrivals import Arrival

__all__ = [
    "AdmissionConfig",
    "AdmissionController",
    "PlacementLatencyEstimator",
    "ShedRecord",
]


@dataclass(frozen=True)
class AdmissionConfig:
    """Knobs of the bounded admission queue.

    ``queue_cap=None`` gives an unbounded queue; ``shed=False`` disables
    deadline shedding too — together they are the no-admission baseline
    (the open-loop run every over-offered instance still executes)."""

    queue_cap: Optional[int] = 512
    # multiplier on the idle-fleet estimate inside the shed predicate
    # (>1 sheds earlier / more conservatively; 1.0 = exactly "provably
    # cannot meet the deadline under the estimator")
    safety: float = 1.0
    # queue fill fraction beyond which best_effort dispatch degrades
    # (replication off); >= 1.0 disables degradation
    degrade_threshold: float = 0.75
    shed: bool = True


@dataclass(frozen=True)
class ShedRecord:
    """One shed decision with the predicate inputs that justified it."""

    uid: int
    kind: str                 # workload key (stream name)
    slo: str                  # SLO-class name
    reason: str               # "deadline" | "stale" | "capacity" | "evicted"
    t: float                  # decision time
    deadline: float
    est: float                # idle-fleet placement-latency estimate
    wait_est: float           # queue-delay estimate used (0 for criticals)
    best_depth: int           # best_effort entries queued at decision time


def _idle_clone(cluster: ClusterState) -> ClusterState:
    """A pristine copy of the fleet's static side: same devices (classes,
    memory, link rates, tiers, failure rates), empty T_alloc, cold caches,
    everything alive — the reference fleet the shed predicate is defined
    against."""
    devices = [
        Device(
            did=d.did, cls=d.cls, mem_total=d.mem_total, lam=d.lam,
            tier=d.tier, up_bw=d.up_bw, down_bw=d.down_bw,
        )
        for d in cluster.devices
    ]
    return ClusterState(
        devices=devices, model=cluster.model, horizon=60.0, dt=cluster.dt,
        backhaul=cluster.backhaul, model_source=cluster.model_source,
    )


class PlacementLatencyEstimator:
    """Idle-fleet Eq. (3) latency per workload kind, from the same batched
    scorer path the dispatcher uses (``orchestrate`` over an idle clone of
    the fleet).  Estimates are cached per stream kind — the stream service
    plans thousands of instances of a handful of app types."""

    def __init__(self, cluster: ClusterState, policy: Policy):
        self.cluster = cluster
        self.policy = policy
        self._idle = _idle_clone(cluster)
        self._cache: Dict[str, float] = {}

    def estimate(self, arrival: Arrival) -> float:
        """Idle-fleet end-to-end latency estimate for this arrival's kind
        (``inf`` when the app is infeasible even on the idle fleet)."""
        key = arrival.kind
        est = self._cache.get(key)
        if est is None:
            plan = orchestrate(
                arrival.instantiate(), self._idle, 0.0, self.policy
            )
            est = float(plan.est_latency) if plan.feasible else float("inf")
            self._cache[key] = est
        return est

    def n_alive(self, t: float) -> int:
        return max(1, int(self.cluster.alive_mask(t).sum()))


# queue entries: (deadline, tiebreak, Arrival, est)
_Entry = Tuple[float, int, Arrival, float]


class AdmissionController:
    """Bounded, SLO-class-aware admission queue (EDF within each class)."""

    def __init__(
        self,
        cfg: AdmissionConfig,
        estimator: PlacementLatencyEstimator,
    ):
        self.cfg = cfg
        self.estimator = estimator
        self._critical: List[_Entry] = []
        self._best: List[_Entry] = []
        self._best_est_sum = 0.0        # running sum of queued best ests
        self._crit_est_sum = 0.0
        self._seq = itertools.count()
        # conservation ledger: offered == dispatched + shed + len(self)
        self.offered = 0
        self.dispatched = 0
        self.shed = 0
        self.shed_log: List[ShedRecord] = []

    def __len__(self) -> int:
        return len(self._critical) + len(self._best)

    @property
    def best_depth(self) -> int:
        return len(self._best)

    @property
    def fill(self) -> float:
        """Queue fill fraction (0 when unbounded)."""
        if self.cfg.queue_cap is None or self.cfg.queue_cap <= 0:
            return 0.0
        return len(self) / self.cfg.queue_cap

    # -- shed predicate ---------------------------------------------------------
    def _wait_estimate(self, critical: bool, now: float) -> float:
        """Expected queue delay from the entries dequeued ahead: their mean
        idle-fleet latency, divided by the live device count (waves run
        concurrently across the fleet).  Criticals are dequeued first and
        their shed test deliberately uses NO wait term — see module doc."""
        if critical:
            return 0.0
        ahead = len(self._critical) + len(self._best)
        if ahead == 0:
            return 0.0
        est_sum = self._crit_est_sum + self._best_est_sum
        return est_sum / self.estimator.n_alive(now)

    def _shed(
        self, arrival: Arrival, now: float, est: float, wait: float,
        reason: str,
    ) -> None:
        self.shed += 1
        self.shed_log.append(ShedRecord(
            uid=arrival.uid, kind=arrival.kind, slo=arrival.slo.name,
            reason=reason, t=now, deadline=arrival.deadline, est=est,
            wait_est=wait, best_depth=len(self._best),
        ))

    # -- offer / dispatch -------------------------------------------------------
    def offer(self, arrival: Arrival, now: float) -> bool:
        """Admit (True) or shed (False) one arrival at time ``now``."""
        self.offered += 1
        cfg = self.cfg
        est = self.estimator.estimate(arrival)
        if cfg.shed:
            wait = self._wait_estimate(arrival.slo.critical, now)
            if now + wait + cfg.safety * est > arrival.deadline:
                self._shed(arrival, now, est, wait, "deadline")
                return False
        if cfg.queue_cap is not None and len(self) >= cfg.queue_cap:
            if arrival.slo.critical and self._best:
                # evict the best_effort entry with the LATEST deadline
                worst = max(range(len(self._best)),
                            key=lambda i: self._best[i][0])
                _, _, victim, vest = self._best.pop(worst)
                heapq.heapify(self._best)
                self._best_est_sum -= vest
                self._shed(victim, now, vest, 0.0, "evicted")
            else:
                self._shed(arrival, now, est, 0.0, "capacity")
                return False
        entry = (arrival.deadline, next(self._seq), arrival, est)
        if arrival.slo.critical:
            heapq.heappush(self._critical, entry)
            self._crit_est_sum += est
        else:
            heapq.heappush(self._best, entry)
            self._best_est_sum += est
        return True

    def pop_wave(
        self, now: float, max_n: Optional[int] = None
    ) -> List[Arrival]:
        """Dequeue the next dispatch wave: criticals first (EDF), then
        best_effort (EDF).  Entries that went stale while queued — ``now``
        plus the idle estimate already exceeds their deadline — are shed
        here instead of wasting fleet capacity."""
        cfg = self.cfg
        wave: List[Arrival] = []
        budget = len(self) if max_n is None else max_n
        for heap, critical in ((self._critical, True), (self._best, False)):
            while heap and len(wave) < budget:
                _, _, arrival, est = heapq.heappop(heap)
                if critical:
                    self._crit_est_sum -= est
                else:
                    self._best_est_sum -= est
                if cfg.shed and now + cfg.safety * est > arrival.deadline:
                    self._shed(arrival, now, est, 0.0, "stale")
                    continue
                wave.append(arrival)
        self.dispatched += len(wave)
        return wave

    # -- conservation -----------------------------------------------------------
    def assert_drained(self) -> None:
        """Post-drain occupancy nets to zero: the queue is empty and the
        ledger balances (every offered instance was dispatched or shed)."""
        if len(self):
            raise RuntimeError(
                f"admission queue not drained: {len(self)} entries remain"
            )
        if self.offered != self.dispatched + self.shed:
            raise RuntimeError(
                "admission ledger drift: offered "
                f"{self.offered} != dispatched {self.dispatched} + shed "
                f"{self.shed}"
            )
        if abs(self._crit_est_sum) > 1e-6 or abs(self._best_est_sum) > 1e-6:
            raise RuntimeError(
                "admission queue-delay accumulators did not net to zero: "
                f"critical {self._crit_est_sum!r}, best {self._best_est_sum!r}"
            )
