"""Always-on orchestration service: open-loop arrivals, bounded admission
with deadline-aware shedding, SLO classes, and a metrics export surface.

  * :mod:`repro.stream.arrivals` — Poisson / diurnal / trace-replay arrival
    processes, seeded per-stream like :mod:`repro.sim.churn`;
  * :mod:`repro.stream.admission` — the bounded queue: backpressure,
    deadline-aware shedding from idle-fleet scorer estimates, and
    ``latency_critical`` / ``best_effort`` SLO-class trade-offs;
  * :mod:`repro.stream.service` — :class:`StreamingOrchestrator`, the
    service loop draining admitted waves through fused
    ``orchestrate_batch`` under churn + recovery + salvage;
  * :mod:`repro.stream.metrics` — counters / histograms / interval samples,
    exportable as JSON.
"""
from .arrivals import (
    BEST_EFFORT,
    LATENCY_CRITICAL,
    AppStream,
    Arrival,
    SLOClass,
    default_streams,
    diurnal_arrivals,
    poisson_arrivals,
    trace_replay,
)
from .admission import (
    AdmissionConfig,
    AdmissionController,
    PlacementLatencyEstimator,
    ShedRecord,
)
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .service import StreamingOrchestrator, StreamResult

__all__ = [
    "SLOClass",
    "LATENCY_CRITICAL",
    "BEST_EFFORT",
    "AppStream",
    "Arrival",
    "default_streams",
    "poisson_arrivals",
    "diurnal_arrivals",
    "trace_replay",
    "AdmissionConfig",
    "AdmissionController",
    "PlacementLatencyEstimator",
    "ShedRecord",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "StreamingOrchestrator",
    "StreamResult",
]
