"""Open-loop arrival processes for the always-on orchestration service.

The paper's evaluation replays a fixed closed-loop burst (~1000 instances
inside each cycle's first 1.5 s); real AR/video-analytics traffic is an
open-loop *stream* whose rate the fleet cannot always absorb.  This module
generates that stream:

  * :func:`poisson_arrivals` — homogeneous Poisson traffic per app stream;
  * :func:`diurnal_arrivals` — a time-varying (sinusoidal day-shape) rate,
    sampled by thinning a homogeneous process at the peak rate;
  * :func:`trace_replay` — replay of recorded ``(t, stream[, deadline])``
    rows, so a production trace can drive the simulator directly.

Determinism contract (same as :mod:`repro.sim.churn`): every stream draws
from ONE rng keyed by ``(seed, stream index)``, so adding or removing a
stream never reshuffles any other stream's arrival times — workload mixes
are extensible under common random numbers.

Arrivals are deliberately *lazy* about DAG construction: an
:class:`Arrival` carries its :class:`AppStream` (builder + SLO class) and
only instantiates the relabelled :class:`~repro.core.dag.AppDAG` when the
admission controller actually dispatches it.  Shed work therefore costs a
few hundred nanoseconds, and generation sustains well over 10k
instances/sec (gated in ``benchmarks/bench_stream.py``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.dag import AppDAG

__all__ = [
    "SLOClass",
    "LATENCY_CRITICAL",
    "BEST_EFFORT",
    "AppStream",
    "Arrival",
    "default_streams",
    "poisson_arrivals",
    "diurnal_arrivals",
    "trace_replay",
]


@dataclass(frozen=True)
class SLOClass:
    """A service-level objective class the admission controller trades off.

    ``deadline`` is the end-to-end budget in seconds from *arrival* (not
    dispatch).  ``critical`` classes are dequeued first, are only ever
    deadline-shed when they provably cannot finish even on an idle fleet,
    and under queue pressure evict ``best_effort`` entries instead of being
    dropped themselves."""

    name: str
    deadline: float
    critical: bool = False


LATENCY_CRITICAL = SLOClass("latency_critical", deadline=6.0, critical=True)
BEST_EFFORT = SLOClass("best_effort", deadline=30.0, critical=False)


@dataclass(frozen=True)
class AppStream:
    """One application traffic stream: a DAG builder plus its SLO class.

    ``weight`` is the stream's share of the total offered rate."""

    name: str
    builder: Callable[[], AppDAG]
    slo: SLOClass = BEST_EFFORT
    weight: float = 1.0


@dataclass
class Arrival:
    """One ``(app, class, deadline, t)`` event of the open-loop stream.

    Either ``stream`` (lazy: the DAG is built at dispatch) or ``app`` (an
    already-concrete instance, e.g. trace replay of recorded DAGs) is set.
    ``deadline`` is absolute simulation time."""

    t: float
    slo: SLOClass
    deadline: float
    stream: Optional[AppStream] = None
    app: Optional[AppDAG] = None
    uid: int = -1

    @property
    def kind(self) -> str:
        """Stable workload key (estimator cache key; NOT instance-unique)."""
        return self.stream.name if self.stream is not None else self.app.name

    def instantiate(self) -> AppDAG:
        """The concrete DAG instance, with instance-unique task names."""
        if self.app is not None:
            return self.app
        return self.stream.builder().relabel(f"#{self.uid}")


def default_streams(
    critical: Sequence[str] = ("video", "matrix"),
    *,
    slo_critical: float = LATENCY_CRITICAL.deadline,
    slo_best_effort: float = BEST_EFFORT.deadline,
) -> Tuple[AppStream, ...]:
    """The paper's four applications as streams: ``critical`` names get the
    ``latency_critical`` class (AR-style traffic), the rest ``best_effort``."""
    from ..sim.apps import APP_BUILDERS

    crit = SLOClass("latency_critical", deadline=slo_critical, critical=True)
    best = SLOClass("best_effort", deadline=slo_best_effort, critical=False)
    return tuple(
        AppStream(name, builder, slo=crit if name in critical else best)
        for name, builder in APP_BUILDERS.items()
    )


def _stream_rng(seed: int, idx: int) -> np.random.Generator:
    """The keyed-stream contract: one rng per (seed, stream index)."""
    return np.random.default_rng((int(seed), int(idx)))


def _poisson_times(
    rng: np.random.Generator, rate: float, horizon: float, t0: float
) -> np.ndarray:
    """Vectorised homogeneous Poisson event times on [t0, t0 + horizon)."""
    if rate <= 0.0 or horizon <= 0.0:
        return np.empty(0)
    n_guess = int(rate * horizon + 6 * np.sqrt(rate * horizon) + 16)
    gaps = rng.exponential(1.0 / rate, size=n_guess)
    times = np.cumsum(gaps)
    while times.size and times[-1] < horizon:       # rare under-draw
        extra = rng.exponential(1.0 / rate, size=max(16, n_guess // 4))
        times = np.concatenate([times, times[-1] + np.cumsum(extra)])
    return t0 + times[times < horizon]


def _merge(
    streams: Sequence[AppStream], per_stream: List[np.ndarray]
) -> List[Arrival]:
    """Time-sort the per-stream event times into one Arrival list with
    deterministic uids (ties broken by stream index)."""
    ts = np.concatenate(per_stream) if per_stream else np.empty(0)
    sidx = np.concatenate(
        [np.full(t.size, i, dtype=np.int64) for i, t in enumerate(per_stream)]
    ) if per_stream else np.empty(0, dtype=np.int64)
    order = np.lexsort((sidx, ts))
    out: List[Arrival] = []
    for uid, j in enumerate(order.tolist()):
        s = streams[sidx[j]]
        t = float(ts[j])
        out.append(Arrival(
            t=t, slo=s.slo, deadline=t + s.slo.deadline, stream=s, uid=uid,
        ))
    return out


def poisson_arrivals(
    streams: Sequence[AppStream],
    rate: float,
    horizon: float,
    *,
    seed: int = 0,
    t0: float = 0.0,
) -> List[Arrival]:
    """Homogeneous Poisson traffic at ``rate`` total instances/sec, split
    across ``streams`` by weight, on ``[t0, t0 + horizon)``."""
    wsum = sum(s.weight for s in streams)
    per = [
        _poisson_times(_stream_rng(seed, i), rate * s.weight / wsum, horizon, t0)
        for i, s in enumerate(streams)
    ]
    return _merge(list(streams), per)


def diurnal_arrivals(
    streams: Sequence[AppStream],
    base_rate: float,
    peak_rate: float,
    horizon: float,
    *,
    period: float = 60.0,
    phase: float = 0.0,
    seed: int = 0,
    t0: float = 0.0,
) -> List[Arrival]:
    """Time-varying (diurnal) traffic: the instantaneous rate follows

        lam(t) = base + (peak - base) * (1 - cos(2 pi (t - phase) / period)) / 2

    (troughs at ``phase`` modulo ``period``), sampled by thinning a
    homogeneous process at ``peak_rate`` — the standard exact method for
    inhomogeneous Poisson streams."""
    if peak_rate < base_rate:
        raise ValueError("peak_rate must be >= base_rate")
    wsum = sum(s.weight for s in streams)
    per: List[np.ndarray] = []
    for i, s in enumerate(streams):
        rng = _stream_rng(seed, i)
        peak_i = peak_rate * s.weight / wsum
        cand = _poisson_times(rng, peak_i, horizon, 0.0)
        lam = base_rate + (peak_rate - base_rate) * 0.5 * (
            1.0 - np.cos(2.0 * np.pi * (cand - phase) / period)
        )
        keep = rng.random(cand.size) < lam / peak_rate
        per.append(t0 + cand[keep])
    return _merge(list(streams), per)


def trace_replay(
    rows: Iterable[tuple],
    streams: Sequence[AppStream],
) -> List[Arrival]:
    """Replay recorded traffic: rows of ``(t, stream_name)`` or
    ``(t, stream_name, deadline)`` (absolute deadline overriding the
    stream's SLO default).  Rows are sorted by time; uids follow that
    order, so a replay is bit-deterministic."""
    by_name = {s.name: s for s in streams}
    parsed = []
    for row in rows:
        t, name = float(row[0]), row[1]
        s = by_name[name]
        deadline = float(row[2]) if len(row) > 2 else t + s.slo.deadline
        parsed.append((t, s, deadline))
    parsed.sort(key=lambda r: r[0])
    return [
        Arrival(t=t, slo=s.slo, deadline=deadline, stream=s, uid=uid)
        for uid, (t, s, deadline) in enumerate(parsed)
    ]
