"""Metrics primitives for the streaming service — re-export shim.

The counters / gauges / exact-quantile histograms and the get-or-create
registry moved to :mod:`repro.obs.metrics`, the unified metrics layer
shared by the stream service and the engine's typed counter ledger
(:class:`~repro.obs.metrics.EngineStats`).  This module keeps the
original import path working; new code should import from ``repro.obs``.
"""
from __future__ import annotations

from ..obs.metrics import Counter, Gauge, Histogram, MetricsRegistry

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]
