"""Metrics export surface for the streaming service.

A tiny dependency-free registry of counters / gauges / histograms, sampled
on a configurable interval and exportable as JSON — the structured health
surface an external controller would scrape.  The service records:

  counters    admitted, shed (by reason), deadline_missed, failed,
              completed, degraded — total and per SLO class
  gauges      queue_depth, queue_fill, placements_per_sec
  histograms  e2e latency per class (p50/p99/p999), queue depth samples,
              per-wave planning wall time

Histograms store raw observations (the service sees at most a few hundred
thousand instances per run) so quantiles are exact rather than
sketch-approximate; ``summary()`` reduces them to the export shape.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


class Counter:
    """Monotone event count."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Exact-quantile histogram over raw observations."""

    __slots__ = ("name", "values")

    def __init__(self, name: str):
        self.name = name
        self.values: List[float] = []

    def observe(self, v: float) -> None:
        self.values.append(float(v))

    @property
    def count(self) -> int:
        return len(self.values)

    def quantile(self, q: float) -> float:
        if not self.values:
            return float("nan")
        return float(np.quantile(np.asarray(self.values), q))

    def summary(self) -> Dict[str, float]:
        if not self.values:
            return {"count": 0}
        arr = np.asarray(self.values)
        return {
            "count": int(arr.size),
            "mean": float(arr.mean()),
            "p50": float(np.quantile(arr, 0.50)),
            "p99": float(np.quantile(arr, 0.99)),
            "p999": float(np.quantile(arr, 0.999)),
            "max": float(arr.max()),
        }


class MetricsRegistry:
    """Get-or-create registry + interval sampler.

    ``sample(t)`` appends one row — every counter and gauge value at
    instant ``t`` — to :attr:`samples`; the service calls it on its
    configured interval so the export carries the time series, not just
    the final totals."""

    def __init__(self):
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}
        self.samples: List[Dict[str, float]] = []

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(name)
        return h

    def sample(self, t: float) -> Dict[str, float]:
        row: Dict[str, float] = {"t": float(t)}
        for name, c in self.counters.items():
            row[name] = c.value
        for name, g in self.gauges.items():
            row[name] = g.value
        self.samples.append(row)
        return row

    def snapshot(self) -> dict:
        """The full export shape (JSON-serialisable)."""
        return {
            "counters": {k: c.value for k, c in sorted(self.counters.items())},
            "gauges": {k: g.value for k, g in sorted(self.gauges.items())},
            "histograms": {
                k: h.summary() for k, h in sorted(self.histograms.items())
            },
            "samples": self.samples,
        }

    def to_json(self, path: Optional[str] = None, indent: int = 2) -> str:
        text = json.dumps(self.snapshot(), indent=indent)
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text
