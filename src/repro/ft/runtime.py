"""Fleet monitoring and elastic re-meshing.

At 1000+ nodes, failures are the steady state, not the exception (the
paper's central claim, transplanted from PEDs to preemptible pods).  This
module provides:

  * ``FleetMonitor`` — heartbeat bookkeeping with a phi-style timeout
    detector and ONLINE estimation of each pod class's failure rate
    ``lambda`` (the paper's Table-IV fit, running live instead of offline);
  * ``plan_remesh`` — given the surviving pods, choose the largest
    supported (data, model) mesh that fits, assign pods to mesh coordinates
    deterministically, and report which batch shards must be re-assigned —
    the elastic-scaling path after a failure (restore comes from the
    replicated checkpoints of :mod:`repro.ckpt`).

Failure semantics follow the paper: pods depart silently; detection is by
missed heartbeats only.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.availability import (
    SurvivalForecast,
    fit_failure_rate,
    prob_fail_during,
)

__all__ = ["PodState", "FleetMonitor", "ElasticPlan", "plan_remesh"]


@dataclass
class PodState:
    pod_id: str
    cls: str = "default"            # capacity class (e.g. "reserved"/"preemptible")
    joined: float = 0.0
    last_heartbeat: float = 0.0
    alive: bool = True
    death_time: Optional[float] = None


@dataclass
class FleetMonitor:
    """Heartbeat-based failure detector + online lambda estimation."""

    timeout: float = 30.0           # seconds without heartbeat -> dead
    pods: Dict[str, PodState] = field(default_factory=dict)
    # per-class exposure bookkeeping for the lambda MLE
    _exposure: Dict[str, float] = field(default_factory=dict)
    _deaths: Dict[str, int] = field(default_factory=dict)

    def join(self, pod_id: str, cls: str = "default",
             now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        self.pods[pod_id] = PodState(pod_id, cls, joined=now, last_heartbeat=now)

    def heartbeat(self, pod_id: str, now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        p = self.pods[pod_id]
        if p.alive:
            self._exposure[p.cls] = self._exposure.get(p.cls, 0.0) + (
                now - p.last_heartbeat
            )
            p.last_heartbeat = now

    def sweep(self, now: Optional[float] = None) -> List[str]:
        """Mark pods dead on heartbeat timeout; returns newly-dead pod ids."""
        now = time.monotonic() if now is None else now
        dead = []
        for p in self.pods.values():
            if p.alive and (now - p.last_heartbeat) > self.timeout:
                p.alive = False
                p.death_time = now
                self._deaths[p.cls] = self._deaths.get(p.cls, 0) + 1
                dead.append(p.pod_id)
        return dead

    def alive_pods(self) -> List[str]:
        return [p.pod_id for p in self.pods.values() if p.alive]

    # -- availability model (paper Fig. 7 / Table IV, estimated online) -----
    def lam(self, cls: str = "default") -> float:
        """MLE failure rate of one pod class, via the SAME
        :func:`~repro.core.availability.fit_failure_rate` estimator the
        paper fits offline on the CrowdBind trace (deaths / alive-exposure,
        right-censored exponential): the class's accumulated heartbeat
        exposure is one censored observation plus one death record per
        timeout.  These live estimates feed straight back into the churn
        generator (:func:`repro.sim.churn.churn_from_monitor`), so the
        monitoring runtime and the simulator share one availability model."""
        exposure = self._exposure.get(cls, 0.0)
        if exposure <= 0:
            return 1e-6
        deaths = self._deaths.get(cls, 0)
        return fit_failure_rate(
            [exposure] + [0.0] * deaths, [True] + [False] * deaths
        ) or 1e-9

    def fleet_lams(self) -> List[float]:
        return [self.lam(p.cls) for p in self.pods.values() if p.alive]

    def forecast(
        self,
        classes: Sequence[str],
        horizon: float = 30.0,
        n_points: int = 16,
    ) -> np.ndarray:
        """(D, K) survival-probability tensor extrapolated from the online
        lambda MLE: entry ``[d, k]`` is P(a class-``classes[d]`` pod stays
        up through the next ``k/(K-1) * horizon`` seconds).  The same shape
        :class:`~repro.sim.churn.ChurnSchedule.forecast` exports, so the
        monitor can stand in as the availability forecast for live fleets."""
        return self.forecaster(classes, horizon=horizon,
                               n_points=n_points).sample(0.0)

    def forecaster(
        self,
        classes: Sequence[str],
        *,
        horizon: float = 30.0,
        n_points: int = 16,
    ) -> SurvivalForecast:
        """A :class:`SurvivalForecast` over the MLE rates of ``classes``
        (one entry per device), installable on a ``ClusterState`` so the
        ``churn_aware`` policy plans against the monitor's live estimates."""
        return SurvivalForecast.from_rates(
            [self.lam(c) for c in classes],
            horizon=horizon, n_points=n_points,
        )

    def prob_job_interrupted(self, horizon: float) -> float:
        """P(any member pod dies within ``horizon`` s) under independence."""
        total = sum(self.fleet_lams())
        return prob_fail_during(total, horizon)


@dataclass(frozen=True)
class ElasticPlan:
    """Output of :func:`plan_remesh`."""

    mesh_shape: Tuple[int, ...]          # (data, model) [pods folded into data]
    axis_names: Tuple[str, ...]
    assignment: Tuple[Tuple[str, Tuple[int, ...]], ...]  # pod -> mesh coords
    dropped_pods: Tuple[str, ...]
    batch_reshard: bool                  # global batch must be re-split
    restore_step: Optional[int] = None


def plan_remesh(
    alive: Sequence[str],
    *,
    model_parallel: int,
    prev_data_parallel: Optional[int] = None,
    restore_step: Optional[int] = None,
) -> ElasticPlan:
    """Choose the largest (data, model) mesh supported by the survivors.

    The model axis is load-bearing (sharded parameters) and cannot shrink
    without resharding checkpoints, so it is held fixed; the data axis
    absorbs the loss — classic elastic data parallelism.  Surviving pods
    are assigned to mesh coordinates in sorted order (deterministic across
    all participants, no coordinator needed)."""
    alive = sorted(alive)
    n = len(alive)
    if n < model_parallel:
        raise ValueError(
            f"only {n} pods alive; cannot sustain model_parallel={model_parallel}"
        )
    data = n // model_parallel
    used = data * model_parallel
    assignment = tuple(
        (alive[i], (i // model_parallel, i % model_parallel))
        for i in range(used)
    )
    return ElasticPlan(
        mesh_shape=(data, model_parallel),
        axis_names=("data", "model"),
        assignment=assignment,
        dropped_pods=tuple(alive[used:]),
        batch_reshard=(prev_data_parallel is not None and data != prev_data_parallel),
        restore_step=restore_step,
    )
