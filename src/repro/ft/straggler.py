"""Straggler mitigation = the paper's replication loop, applied to gang jobs.

A straggling or about-to-be-preempted pod delays the whole synchronous
step.  IBDASH's insight: when the predicted failure probability of a
placement exceeds beta, replicate onto the next-best resource as long as
the weighted score alpha*L + (1-alpha)*F keeps improving (Algorithm 1,
lines 30-41).  Here the "task" is a shard of work (e.g. a data-shard's
gradient computation or an eval/ckpt job) and the "devices" are pods whose
failure rates come from the online FleetMonitor fit.

``StragglerMitigator.decide`` is pure (testable): given per-pod expected
completion times and failure rates it returns which backup pods to launch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.availability import prob_fail_during

__all__ = ["BackupDecision", "StragglerMitigator"]


@dataclass(frozen=True)
class BackupDecision:
    primary: int                     # index of the chosen pod
    backups: Tuple[int, ...]         # replica pods, best-first
    pred_fail: float                 # combined P(all replicas fail)
    est_latency: float               # primary's expected completion


@dataclass
class StragglerMitigator:
    alpha: float = 0.5               # joint weight (paper Eq. 5)
    beta: float = 0.05               # failure-probability threshold
    gamma: int = 2                   # max backups per task

    def decide(
        self,
        est_latency: Sequence[float],     # per-pod expected completion (s)
        lams: Sequence[float],            # per-pod failure rates
        eligible: Optional[Sequence[bool]] = None,
    ) -> BackupDecision:
        lat = np.asarray(est_latency, dtype=np.float64)
        lam = np.asarray(lams, dtype=np.float64)
        ok = np.ones(len(lat), dtype=bool) if eligible is None else np.asarray(eligible)
        cand = np.flatnonzero(ok)
        if cand.size == 0:
            raise ValueError("no eligible pods")
        order = cand[np.argsort(lat[cand], kind="stable")]

        pf = np.array([prob_fail_during(lam[i], lat[i]) for i in range(len(lat))])
        primary = int(order[0])
        l_ref = max(lat[primary], 1e-9)
        comb = pf[primary]
        score = self.alpha * (lat[primary] / l_ref) + (1 - self.alpha) * comb
        backups: List[int] = []
        qi = 1
        while comb >= self.beta and len(backups) < self.gamma and qi < order.size:
            i = int(order[qi]); qi += 1
            new_comb = comb * pf[i]
            new_score = self.alpha * (lat[i] / l_ref) + (1 - self.alpha) * new_comb
            if new_score <= score:
                backups.append(i)
                comb, score = new_comb, new_score
            else:
                break
        return BackupDecision(
            primary=primary, backups=tuple(backups),
            pred_fail=float(comb), est_latency=float(lat[primary]),
        )

    def expected_step_speedup(
        self, lat: Sequence[float], lams: Sequence[float], horizon: float
    ) -> float:
        """Expected saving from backups on one synchronous step: without a
        backup a failure costs a full restore ``horizon``; with backups the
        step completes unless all replicas fail."""
        d = self.decide(lat, lams)
        pf_primary = prob_fail_during(lams[d.primary], lat[d.primary])
        return (pf_primary - d.pred_fail) * horizon
