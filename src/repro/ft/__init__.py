"""Fault-tolerance runtime: failure detection, elastic re-meshing, straggler
mitigation via IBDASH-style replication, and availability-driven policies.
"""
from .runtime import FleetMonitor, ElasticPlan, plan_remesh, PodState
from .straggler import StragglerMitigator, BackupDecision

__all__ = [
    "FleetMonitor",
    "PodState",
    "ElasticPlan",
    "plan_remesh",
    "StragglerMitigator",
    "BackupDecision",
]
