"""Pure-jnp oracles for every Pallas kernel.

Each function is the mathematical ground truth the kernels are validated
against (tests sweep shapes/dtypes and assert_allclose).  They are also the
portable fallback implementation the model uses on non-TPU backends.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["attention_ref", "decode_attention_ref", "rwkv6_ref"]


def attention_ref(
    q: jnp.ndarray,            # (B, S, Hq, D)
    k: jnp.ndarray,            # (B, S, Hk, D)
    v: jnp.ndarray,            # (B, S, Hk, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Naive GQA attention (full S x S score materialisation)."""
    B, S, Hq, D = q.shape
    Hk = k.shape[2]
    g = Hq // Hk
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    qg = q.reshape(B, S, Hk, g, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), dtype=bool)
    if causal:
        mask &= pos[None, :] <= pos[:, None]
    if window is not None:
        mask &= pos[None, :] > pos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v)
    return out.reshape(B, S, Hq, D)


def decode_attention_ref(
    q: jnp.ndarray,            # (B, Hq, D)       single query token
    k: jnp.ndarray,            # (B, C, Hk, D)    cache
    v: jnp.ndarray,            # (B, C, Hk, D)
    lengths: jnp.ndarray,      # (B,) valid cache lengths
    *,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Naive single-token GQA decode over a (possibly padded) KV cache."""
    B, Hq, D = q.shape
    C, Hk = k.shape[1], k.shape[2]
    g = Hq // Hk
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    qg = q.reshape(B, Hk, g, D)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(C)[None, :] < lengths[:, None]          # (B, C)
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgk,bkhd->bhgd", w, v)
    return out.reshape(B, Hq, D)


def rwkv6_ref(
    r: jnp.ndarray,            # (B, T, H, N)
    k: jnp.ndarray,            # (B, T, H, N)
    v: jnp.ndarray,            # (B, T, H, N)
    w: jnp.ndarray,            # (B, T, H, N) per-channel decay in (0, 1)
    u: jnp.ndarray,            # (H, N) bonus
    S0: jnp.ndarray,           # (B, H, N, N) initial state [k-dim, v-dim]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact sequential RWKV6 WKV recurrence:

        y_t = r_t . (S_{t-1} + u * k_t (x) v_t)
        S_t = diag(w_t) S_{t-1} + k_t (x) v_t
    """

    def step(S, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhi,bhij->bhj", rt, S + u[..., :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, y

    f32 = jnp.float32
    rs = jnp.moveaxis(r, 1, 0).astype(f32)
    ks = jnp.moveaxis(k, 1, 0).astype(f32)
    vs = jnp.moveaxis(v, 1, 0).astype(f32)
    ws = jnp.moveaxis(w, 1, 0).astype(f32)
    S_T, ys = jax.lax.scan(step, S0.astype(f32), (rs, ks, vs, ws))
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), S_T
