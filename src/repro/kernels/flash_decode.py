"""Single-token GQA decode attention as a Pallas TPU kernel.

Decode attention is memory-bound: one query token streams the whole KV
cache through VMEM once.  TPU-native design:
  * grid (batch, kv_head, kv_blocks), kv_blocks sequential ("arbitrary") so
    the online-softmax state rides in VMEM scratch — the classic GPU
    "split-K + second-pass reduce" becomes a sequential VMEM accumulation
    (no inter-core reduction needed on TPU; splitting across cores is the
    mesh's job via sequence-sharded caches, see launch/sharding.py);
  * all g = Hq/Hk grouped query heads share each streamed K/V tile — the
    GQA bandwidth saving is the whole point of the layout;
  * variable cache fill is handled by a per-batch ``lengths`` mask.

Validated in interpret mode against
:func:`repro.kernels.ref.decode_attention_ref`.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_decode"]

NEG_INF = -1e30

_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, block_k: int, kv_blocks: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]                          # (g, D)
    k = k_ref[0, :, 0, :]                    # (block_k, D)
    v = v_ref[0, :, 0, :]
    length = len_ref[0]

    s = jax.lax.dot_general(
        q.astype(jnp.float32), k.astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    ) * scale                                 # (g, block_k)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    s = jnp.where(k_pos < length, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_prev * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ki == kv_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_decode(
    q: jnp.ndarray,                # (B, Hq, D) single query token
    k: jnp.ndarray,                # (B, C, Hk, D) cache
    v: jnp.ndarray,                # (B, C, Hk, D)
    lengths: jnp.ndarray,          # (B,) int32 valid lengths
    *,
    scale: Optional[float] = None,
    block_k: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, D = q.shape
    C, Hk = k.shape[1], k.shape[2]
    g = Hq // Hk
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    block_k = min(block_k, C)
    if C % block_k:
        raise ValueError(f"cache size {C} not divisible by block_k={block_k}")
    kv_blocks = C // block_k

    qg = q.reshape(B, Hk, g, D)
    kernel = functools.partial(_kernel, scale=scale, block_k=block_k,
                               kv_blocks=kv_blocks)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hk, kv_blocks),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ki: (b,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, D), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, ki: (b, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, D), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hk, g, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, D), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lengths, qg, k, v)
    return out.reshape(B, Hq, D)
