"""Chunked RWKV6 WKV scan as a Pallas TPU kernel.

The RWKV6 recurrence

    y_t = r_t . (S_{t-1} + u * k_t (x) v_t)
    S_t = diag(w_t) S_{t-1} + k_t (x) v_t

is sequential per token on GPU (CUDA kernels walk t one by one).  The
TPU-native adaptation processes the sequence in CHUNKS of c tokens:

  inter-chunk   y_state = (r * Wexc) @ S_in                (MXU, c x N @ N x N)
  intra-chunk   A[t,i]  = sum_n r[t,n] k[i,n] e^{cum[t-1,n]-cum[i,n]}  (i<t)
                A[t,t]  = sum_n r[t,n] u[n] k[t,n]
                y_intra = A @ v                             (MXU, c x c @ c x N)
  state update  S_out   = diag(Wall) S_in + (k * Wrem)^T @ v

where cum is the cumulative log-decay inside the chunk.  All decay ratios
are of the form exp(negative), so the computation is numerically stable
without the secondary chunking CUDA implementations need for their
division-based formulation.  The A tensor is built via an explicit
(c, c, N) broadcast — VPU work bounded by c * c * N * 4 bytes of VMEM
(1 MiB at c=64, N=64).

Grid: (B, H, T/c) with the chunk dimension sequential; S rides in VMEM
scratch between chunks.  Validated in interpret mode against
:func:`repro.kernels.ref.rwkv6_ref`.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rwkv6_scan"]

_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref, s_ref, *,
            chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0]

    r = r_ref[0, :, 0, :].astype(jnp.float32)     # (c, N)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    w = w_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)              # (N,)
    S = s_ref[...]                                # (N, N) [k-dim, v-dim]
    c, N = r.shape

    logw = jnp.log(jnp.maximum(w, 1e-30))         # (c, N) negative
    cum = jnp.cumsum(logw, axis=0)                # inclusive cumulative decay
    cum_exc = cum - logw                          # exclusive (prod_{j<t})

    # inter-chunk: queries see the carried state decayed by cum_exc
    r_dec = r * jnp.exp(cum_exc)                  # (c, N)
    y = jax.lax.dot_general(r_dec, S, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (c, N)

    # intra-chunk pairwise decay: exp(cum_exc[t] - cum[i]) for i < t (<= 1)
    # built as an explicit (c, c, N) tensor — stable, VPU-bound.
    ratio = jnp.exp(
        jnp.clip(cum_exc[:, None, :] - cum[None, :, :], max=0.0)
    )                                             # (c, c, N)
    pair = (r[:, None, :] * k[None, :, :] * ratio).sum(-1)       # (c, c)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    A = jnp.where(i_idx < t_idx, pair, 0.0)
    diag = (r * u[None, :] * k).sum(-1)           # (c,)
    A = A + jnp.where(i_idx == t_idx, diag[:, None], 0.0)
    y = y + jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # state update: S' = diag(prod w) S + (k * remaining-decay)^T @ v
    total = cum[-1]                               # (N,)
    k_rem = k * jnp.exp(total[None, :] - cum)     # (c, N), factors <= 1
    S_new = jnp.exp(total)[:, None] * S + jax.lax.dot_general(
        k_rem, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )
    s_ref[...] = S_new
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == chunks - 1)
    def _finish():
        sT_ref[0, 0] = S_new


def rwkv6_scan(
    r: jnp.ndarray,                # (B, T, H, N)
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,                # per-channel decay in (0, 1)
    u: jnp.ndarray,                # (H, N)
    S0: jnp.ndarray,               # (B, H, N, N)
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,T,H,N), S_T (B,H,N,N) float32)."""
    B, T, H, N = r.shape
    chunk = min(chunk, T)
    if T % chunk:
        raise ValueError(f"T={T} must be divisible by chunk={chunk}")
    chunks = T // chunk

    kernel = functools.partial(_kernel, chunks=chunks)
    y, sT = pl.pallas_call(
        kernel,
        grid=(B, H, chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, N), lambda b, h, ci: (h, 0)),
            pl.BlockSpec((1, 1, N, N), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, 1, N, N), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, N), r.dtype),
            jax.ShapeDtypeStruct((B, H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, w, u, S0)
    return y, sT


def rwkv6_scan_trainable(r, k, v, w, u, S0, *, chunk: int = 64,
                         interpret: bool = False):
    """Chunked Pallas forward with an oracle (sequential-scan) backward —
    trainable today; a chunked backward kernel is the production follow-up."""
    from .ref import rwkv6_ref

    @jax.custom_vjp
    def mix(r, k, v, w, u, S0):
        return rwkv6_scan(r, k, v, w, u, S0, chunk=chunk, interpret=interpret)

    def fwd(r, k, v, w, u, S0):
        return mix(r, k, v, w, u, S0), (r, k, v, w, u, S0)

    def bwd(res, g):
        _, vjp = jax.vjp(lambda *a: rwkv6_ref(*a), *res)
        return vjp(g)

    mix.defvjp(fwd, bwd)
    return mix(r, k, v, w, u, S0)
