"""Flash attention (prefill/train forward) as a Pallas TPU kernel.

TPU-native design (vs. the CUDA flash-attention formulation):
  * the grid is (batch, kv_head, q_blocks, kv_blocks) with the kv_blocks
    dimension marked "arbitrary" (sequential) so the online-softmax state
    (m, l, acc) lives in VMEM scratch across kv steps — no atomics, no
    shared-memory tiling; the MXU sees (block_q x D) @ (D x block_k) tiles;
  * block sizes default to 128 — the MXU systolic dimension — and the
    grouped (GQA) q heads for one kv head ride in the same block so K/V
    tiles are loaded once per q block, not once per q head;
  * masking (causal and/or local window) is computed from block-relative
    iotas; fully-masked tiles short-circuit via jnp.where (a production
    kernel would prune them from the grid — block-sparse grids are an
    orthogonal optimisation).

Validated in interpret mode against :func:`repro.kernels.ref.attention_ref`
over shape/dtype sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

# jax version compat: CompilerParams was TPUCompilerParams before 0.7
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: Optional[int],
            block_q: int, block_k: int, kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0]               # (block_q, g, D)
    bq, g, D = q.shape
    k = k_ref[0, :, 0, :]            # (block_k, D)
    v = v_ref[0, :, 0, :]            # (block_k, D)

    qf = q.reshape(bq * g, D)
    s = jax.lax.dot_general(
        qf.astype(jnp.float32), k.astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                         # (bq*g, block_k)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (bq, g), 0)
    q_pos = q_pos.reshape(bq * g, 1)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]               # (bq*g,)
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + p.sum(axis=1)

    acc = acc_ref[...] * alpha[:, None]
    acc += jax.lax.dot_general(
        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] = acc
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == kv_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0] = (acc_ref[...] / denom).reshape(bq, g, D).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,                   # (B, S, Hq, D)
    k: jnp.ndarray,                   # (B, S, Hk, D)
    v: jnp.ndarray,                   # (B, S, Hk, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Blocked online-softmax attention. Returns (B, S, Hq, D)."""
    B, S, Hq, D = q.shape
    Hk = k.shape[2]
    g = Hq // Hk
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    if S % block_q or S % block_k:
        raise ValueError(f"S={S} must be divisible by block sizes")
    q_blocks = S // block_q
    kv_blocks = S // block_k

    # (B, S, Hq, D) -> blocks of (1, block_q, g, D) per kv head
    qg = q.reshape(B, S, Hk, g, D)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, kv_blocks=kv_blocks,
    )
    grid = (B, Hk, q_blocks, kv_blocks)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, g, D), lambda b, h, qi, ki: (b, qi, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, qi, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, qi, ki: (b, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, g, D), lambda b, h, qi, ki: (b, qi, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, Hk, g, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q * g, D), jnp.float32),
            pltpu.VMEM((block_q * g,), jnp.float32),
            pltpu.VMEM((block_q * g,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qg, k, v)
    return out.reshape(B, S, Hq, D)


def flash_attention_trainable(
    q, k, v, *, causal: bool = True, window: Optional[int] = None,
    block_q: int = 128, block_k: int = 128, interpret: bool = False,
):
    """Flash-attention forward (Pallas) with an oracle backward.

    The backward pass recomputes attention via the pure-jnp reference and
    differentiates it — numerically identical to the kernel's math.  A
    dedicated backward Pallas kernel (dq/dk/dv tiles with the saved
    logsumexp) is the production follow-up; this wrapper keeps the fused
    forward while remaining fully trainable."""
    from .ref import attention_ref

    @jax.custom_vjp
    def attn(q, k, v):
        return flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)

    def fwd(q, k, v):
        return attn(q, k, v), (q, k, v)

    def bwd(res, g):
        q, k, v = res
        _, vjp = jax.vjp(
            lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal, window=window),
            q, k, v,
        )
        return vjp(g)

    attn.defvjp(fwd, bwd)
    return attn(q, k, v)
