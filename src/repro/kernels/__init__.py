"""Pallas TPU kernels for the compute hot-spots (pl.pallas_call + explicit
BlockSpec VMEM tiling), each with a jit'd wrapper (ops.py) and a pure-jnp
oracle (ref.py):

  flash_attention  blocked online-softmax GQA attention (train/prefill)
  flash_decode     single-token cache-streaming GQA attention (serve)
  rwkv6_scan       chunked RWKV6 WKV recurrence (SSM train/prefill)
"""
from .ops import attention, decode_attention, default_impl, rwkv6

__all__ = ["attention", "decode_attention", "rwkv6", "default_impl"]
