"""Jit'd public wrappers around the Pallas kernels.

``impl`` selection:
  "auto"              pallas on TPU, reference elsewhere (this CPU container)
  "pallas"            compiled Pallas kernel (TPU)
  "pallas_interpret"  Pallas kernel body executed in Python (correctness on CPU)
  "ref"               pure-jnp oracle

Model code calls these wrappers; the dry-run lowers the ref path (identical
math, XLA-countable FLOPs) while TPU deployments flip ``impl='pallas'``.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref as _ref
from .flash_attention import flash_attention as _flash_attention
from .flash_decode import flash_decode as _flash_decode
from .rwkv6_scan import rwkv6_scan as _rwkv6_scan

__all__ = ["attention", "decode_attention", "rwkv6", "default_impl"]


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@partial(jax.jit, static_argnames=("causal", "window", "impl", "block_q", "block_k"))
def attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
    causal: bool = True, window: Optional[int] = None,
    impl: str = "auto", block_q: int = 128, block_k: int = 128,
) -> jnp.ndarray:
    impl = default_impl() if impl == "auto" else impl
    if impl == "ref":
        return _ref.attention_ref(q, k, v, causal=causal, window=window)
    return _flash_attention(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k,
        interpret=(impl == "pallas_interpret"),
    )


@partial(jax.jit, static_argnames=("impl", "block_k"))
def decode_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, lengths: jnp.ndarray, *,
    impl: str = "auto", block_k: int = 256,
) -> jnp.ndarray:
    impl = default_impl() if impl == "auto" else impl
    if impl == "ref":
        return _ref.decode_attention_ref(q, k, v, lengths)
    return _flash_decode(
        q, k, v, lengths, block_k=block_k,
        interpret=(impl == "pallas_interpret"),
    )


@partial(jax.jit, static_argnames=("impl", "chunk"))
def rwkv6(
    r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
    u: jnp.ndarray, S0: jnp.ndarray, *,
    impl: str = "auto", chunk: int = 64,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    impl = default_impl() if impl == "auto" else impl
    if impl == "ref":
        return _ref.rwkv6_ref(r, k, v, w, u, S0)
    return _rwkv6_scan(
        r, k, v, w, u, S0, chunk=chunk,
        interpret=(impl == "pallas_interpret"),
    )
